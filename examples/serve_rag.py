"""End-to-end serving driver: batched requests through one LookaheadEngine
whose trie stays warm across requests (the Alipay deployment pattern —
paper §5.3).  RAG-profile synthetic traffic; per-request lossless check.

    PYTHONPATH=src python examples/serve_rag.py [--requests 12] [--batch 2]
"""
import argparse
import time

import jax

from repro.core import LookaheadConfig, LookaheadEngine, reference_decode
from repro.models.transformer import TransformerConfig, init_params
from repro.serving.session import make_session_fns
from repro.training.data import PROFILES, SyntheticCorpus


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=48)
    args = ap.parse_args()

    cfg = TransformerConfig(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
                            d_ff=256, vocab_size=512, max_seq_len=768)
    params = init_params(cfg, jax.random.key(0))
    la = LookaheadConfig(decoding_length=32, branch_length=12,
                         strategy="hierarchical")
    fns = make_session_fns(cfg, params, slots=la.slots)
    engine = LookaheadEngine(fns, la)

    corpus = SyntheticCorpus(PROFILES["antrag"], 512, seed=7)
    requests = [corpus.sample()[0][:96] for _ in range(args.requests)]

    # dev-set warmup (paper Appendix D): preload responses
    engine.warmup([reference_decode(fns, p, args.max_new)
                   for p in requests[:2]])

    served = 0
    t0 = time.time()
    for i in range(0, len(requests), args.batch):
        chunk = requests[i:i + args.batch]
        outs = engine.generate_batch(chunk, args.max_new)
        for p, o in zip(chunk, outs):
            ref = reference_decode(fns, p, args.max_new)
            status = "LOSSLESS✓" if o.tokens == ref else "MISMATCH✗"
            print(f"req{served:03d}: {len(o.tokens)} tokens in "
                  f"{o.stats.steps} steps (EDL {o.stats.edl:.2f}) {status}")
            served += 1
    dt = time.time() - t0
    print(f"\nserved {served} requests in {dt:.1f}s; trie holds "
          f"{len(engine.trie)} nodes (~{engine.trie.memory_bytes()//1024} KiB)")


if __name__ == "__main__":
    main()
