"""End-to-end serving driver: a stream of requests through one ServingEngine
whose trie stays warm across requests (the Alipay deployment pattern —
paper §5.3).  RAG-profile synthetic traffic with mixed per-request sampling;
per-request lossless check under each request's own params.

RAG responses quote the reference documents already sitting in the prompt,
so speculation here drafts through ``PromptCopySource`` (LLMA-style
longest-suffix copy from the request's own prompt/context — per-request,
nothing pollutes the shared trie) with the trie as secondary source under a
small quota (DESIGN.md §Draft sources).

    PYTHONPATH=src python examples/serve_rag.py [--requests 12] [--lanes 2]
"""
import argparse
import time

import jax

from repro.core import DraftPolicy, Request, SamplingParams, reference_decode
from repro.models.transformer import TransformerConfig, init_params
from repro.serving.api import EngineConfig, build_engine
from repro.training.data import PROFILES, SyntheticCorpus


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--lanes", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=48)
    args = ap.parse_args()

    cfg = TransformerConfig(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
                            d_ff=256, vocab_size=512, max_seq_len=768)
    params = init_params(cfg, jax.random.key(0))
    ecfg = EngineConfig(lanes=args.lanes, prefill_len=128,
                        decoding_length=32, branch_length=12,
                        draft_policy=DraftPolicy(
                            sources=("prompt_copy", "trie"),
                            quotas=(24, 8)))
    engine = build_engine(ecfg, cfg, params)

    corpus = SyntheticCorpus(PROFILES["antrag"], 512, seed=7)
    requests = [
        Request(prompt=corpus.sample()[0][:96],
                params=SamplingParams(max_new_tokens=args.max_new)
                if i % 3 else
                SamplingParams(max_new_tokens=args.max_new, sample=True,
                               temperature=0.8, seed=7 * i + 1),
                metadata={"i": i})
        for i in range(args.requests)]

    # dev-set warmup (paper Appendix D): preload responses
    engine.warmup([reference_decode(engine.fns, r.prompt, params=r.params)
                   for r in requests[:2]])

    t0 = time.time()
    handles = [engine.submit(r) for r in requests]
    engine.run()                       # continuous batching drains the pool
    dt = time.time() - t0

    drafted, accepted = {}, {}
    for r, h in zip(requests, handles):
        o = h.result()
        ref = reference_decode(engine.fns, r.prompt, params=r.params)
        mode = (f"sampled τ={r.params.temperature}" if r.params.sample
                else "greedy")
        status = "LOSSLESS✓" if o.tokens == ref else "MISMATCH✗"
        print(f"req{r.metadata['i']:03d} [{mode:>12s}]: {len(o.tokens)} "
              f"tokens in {o.stats.steps} steps (EDL {o.stats.edl:.2f}) "
              f"{status}")
        for k, v in o.stats.source_drafted.items():
            drafted[k] = drafted.get(k, 0) + v
        for k, v in o.stats.source_accepted.items():
            accepted[k] = accepted.get(k, 0) + v
    st = engine.stats
    print(f"\nserved {st.finished} requests in {dt:.1f}s "
          f"(occupancy {st.occupancy:.2f}); trie holds "
          f"{len(engine.trie)} nodes "
          f"(~{engine.trie.memory_bytes()//1024} KiB)")
    if drafted:
        print("draft sources (accepted/drafted): " + "   ".join(
            f"{n} {accepted.get(n, 0)}/{d}" for n, d in sorted(
                drafted.items())))


if __name__ == "__main__":
    main()
