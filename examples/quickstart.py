"""Quickstart: lossless Lookahead decoding behind the request-centric API.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import DraftPolicy, Request, SamplingParams, reference_decode
from repro.models.transformer import TransformerConfig, init_params
from repro.serving.api import EngineConfig, build_engine


def main() -> None:
    cfg = TransformerConfig(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
                            d_ff=256, vocab_size=512, max_seq_len=512)
    params = init_params(cfg, jax.random.key(0))

    # one validated spec; build_engine compiles the session and wires the
    # continuous-batching scheduler behind a Request/handle surface
    ecfg = EngineConfig(lanes=2, prefill_len=64, decoding_length=32,
                        branch_length=8)
    engine = build_engine(ecfg, cfg, params)

    prompt = list(np.random.RandomState(0).randint(2, 512, size=48))

    # ground truth: plain step-by-step greedy decoding
    ref = reference_decode(engine.fns, prompt, max_new_tokens=64)

    # lookahead: same model functions, trie-driven multi-branch drafts.
    # submit() returns a streaming handle; result() drives to completion.
    engine.warmup([ref])             # e.g. a previous response for this topic
    out = engine.submit(prompt, max_new_tokens=64).result()

    assert out.tokens == ref, "lossless property violated!"
    print(f"output ({len(out.tokens)} tokens): {out.tokens[:16]} ...")
    print(f"steps: {out.stats.steps}  (baseline would take {len(ref)})")
    print(f"EDL (tokens/step): {out.stats.edl:.2f}")
    print(f"steps-compression: {len(ref) / out.stats.steps:.2f}x "
          f"(= speedup in the IO-bound decode regime)")
    print("LOSSLESS ✓ — identical to step-by-step greedy decoding")

    # mixed per-request sampling in ONE lane pool: a greedy and a sampled
    # request co-batched; each is bit-identical to step-by-step decoding
    # under its own params (the per-lane param vectors are traced inputs)
    sampled = SamplingParams(max_new_tokens=64, sample=True,
                             temperature=0.7, seed=42)
    h_greedy = engine.submit(prompt, max_new_tokens=64)
    h_sampled = engine.submit(Request(prompt=prompt, params=sampled))
    deltas = []
    h_sampled.on_token(deltas.extend)        # incremental stream
    r_greedy, r_sampled = h_greedy.result(), h_sampled.result()
    assert r_greedy.tokens == ref
    assert r_sampled.tokens == reference_decode(engine.fns, prompt,
                                                params=sampled)
    assert deltas == r_sampled.tokens        # stream == final result
    print("mixed params ✓ — greedy + sampled co-batched, both lossless; "
          f"sampled stream arrived in {r_sampled.stats.steps} deltas")

    # mixed-source speculation: one request drafting from the trie, its own
    # prompt (LLMA-style copy) AND an adaptive n-gram model, merged into one
    # tree under per-source quotas with an adaptive per-lane budget.  Drafts
    # are host-side and verified on device, so ANY policy stays lossless —
    # per-source acceptance shows which generator earned its slots.
    mixed_draft = DraftPolicy(sources=("trie", "prompt_copy", "ngram"),
                              quotas=(16, 8, 8), adaptive=True, min_budget=4)
    h = engine.submit(Request(prompt=prompt, params=SamplingParams(
        max_new_tokens=64, draft=mixed_draft)))
    out_mixed = h.result()
    assert out_mixed.tokens == ref, "draft policy changed an output!"
    acc = out_mixed.stats.source_acceptance()
    print("mixed draft sources ✓ — trie+prompt_copy+ngram merged, adaptive "
          "budget, still lossless; acceptance: "
          + (", ".join(f"{k} {v:.0%}" for k, v in sorted(acc.items()))
             or "no drafts placed"))

    # attention-backend selection: the same engine spec under the Pallas
    # tree-attention / flash-prefill kernels (compiled on TPU, interpret
    # mode elsewhere) — outputs stay bit-identical per backend (I1)
    import dataclasses
    engine_pallas = build_engine(dataclasses.replace(ecfg, backend="pallas"),
                                 cfg, params)
    engine_pallas.warmup([ref])
    out_pallas = engine_pallas.submit(prompt, max_new_tokens=64).result()
    assert out_pallas.tokens == out.tokens, "backend changed an output!"
    print("pallas backend ✓ — same tokens through the blocked kernels")

    # paged KV cache: a block pool sized to the actual footprint
    # (prompt + budget + tree width) instead of max_seq_len per lane —
    # outputs stay bit-identical (DESIGN.md §Paged KV cache)
    from repro.serving.block_allocator import worst_case_pool_blocks
    blocks = worst_case_pool_blocks(2, 64, 64, ecfg.slots, cfg.max_seq_len,
                                    64)
    engine_paged = build_engine(
        dataclasses.replace(ecfg, kv_layout="paged", block_size=64,
                            n_blocks=blocks), cfg, params)
    engine_paged.warmup([ref])
    out_paged = engine_paged.submit(prompt, max_new_tokens=64).result()
    assert out_paged.tokens == out.tokens, "kv layout changed an output!"
    dense_rows, paged_rows = cfg.max_seq_len, (blocks - 1) * 64
    print(f"paged kv cache ✓ — same tokens from {paged_rows} pooled cache "
          f"rows instead of {dense_rows} per lane")

    # radix prefix cache: requests sharing a system prompt re-use its KV
    # blocks (refcounted, copy-on-write at the boundary) and prefill only
    # their own tail — bit-identical outputs, most prefill skipped
    # (DESIGN.md §Prefix cache)
    engine_pfx = build_engine(
        dataclasses.replace(ecfg, kv_layout="paged", block_size=16,
                            prefix_cache=True), cfg, params)
    system_prompt = prompt[:40]      # the shared conversation header
    rng = np.random.RandomState(7)
    questions = [system_prompt + list(rng.randint(2, 512, size=12))
                 for _ in range(6)]
    handles = [engine_pfx.submit(q, max_new_tokens=32) for q in questions]
    outs = [h.result() for h in handles]
    for q, o in zip(questions, outs):
        assert o.tokens == reference_decode(engine_pfx.fns, q,
                                            max_new_tokens=32), \
            "prefix cache changed an output!"
    st = engine_pfx.stats
    print(f"prefix cache ✓ — {st.prefix_hits}/{st.prefix_lookups} admissions "
          f"hit the shared system prompt, "
          f"{st.prefill_tokens_saved:.0%} of prefill tokens skipped, "
          "all outputs still bit-identical")

    # runtime sanitizer: EngineConfig(sanitize=True) (or --sanitize on the
    # serve driver) arms a shadow block ledger, a per-request lifecycle
    # state machine and a retrace monitor; any double free, use-after-free,
    # leaked block or unexpected recompile raises at the faulting call.
    # Default-off costs nothing; on, outputs are still bit-identical
    # (DESIGN.md §Invariants & analysis).
    engine_san = build_engine(
        dataclasses.replace(ecfg, kv_layout="paged", block_size=16,
                            prefix_cache=True, sanitize=True), cfg, params)
    out_san = engine_san.submit(prompt, max_new_tokens=64).result()
    assert out_san.tokens == ref, "sanitizer changed an output!"
    print("sanitizer ✓ — ledger/lifecycle/retrace audits clean, "
          "outputs unchanged")


if __name__ == "__main__":
    main()
