"""Quickstart: lossless Lookahead decoding on a small LM.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import (LookaheadConfig, LookaheadEngine, baseline_config,
                        reference_decode)
from repro.models.transformer import TransformerConfig, init_params
from repro.serving.session import make_session_fns


def main() -> None:
    cfg = TransformerConfig(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
                            d_ff=256, vocab_size=512, max_seq_len=512)
    params = init_params(cfg, jax.random.key(0))
    la = LookaheadConfig(decoding_length=32, branch_length=8,
                         strategy="hierarchical")
    fns = make_session_fns(cfg, params, slots=la.slots)

    prompt = list(np.random.RandomState(0).randint(2, 512, size=48))

    # ground truth: plain step-by-step greedy decoding
    ref = reference_decode(fns, prompt, max_new_tokens=64)

    # lookahead: same model functions, trie-driven multi-branch drafts
    engine = LookaheadEngine(fns, la)
    engine.warmup([ref])             # e.g. a previous response for this topic
    out = engine.generate(prompt, max_new_tokens=64)

    assert out.tokens == ref, "lossless property violated!"
    print(f"output ({len(out.tokens)} tokens): {out.tokens[:16]} ...")
    print(f"steps: {out.stats.steps}  (baseline would take {len(ref)})")
    print(f"EDL (tokens/step): {out.stats.edl:.2f}")
    print(f"steps-compression: {len(ref) / out.stats.steps:.2f}x "
          f"(= speedup in the IO-bound decode regime)")
    print("LOSSLESS ✓ — identical to step-by-step greedy decoding")

    # attention-backend selection: the same session under the Pallas
    # tree-attention / flash-prefill kernels (compiled on TPU, interpret
    # mode elsewhere) — outputs stay bit-identical per backend (I1)
    fns_pallas = make_session_fns(cfg, params, slots=la.slots,
                                  backend="pallas")
    engine_pallas = LookaheadEngine(fns_pallas, la)
    engine_pallas.warmup([ref])
    out_pallas = engine_pallas.generate(prompt, max_new_tokens=64)
    assert out_pallas.tokens == out.tokens, "backend changed an output!"
    print("pallas backend ✓ — same tokens through the blocked kernels")

    # paged KV cache: a block pool sized to the actual footprint
    # (prompt + budget + tree width) instead of max_seq_len per lane —
    # outputs stay bit-identical (DESIGN.md §Paged KV cache)
    from repro.serving.block_allocator import demand_blocks
    blocks = demand_blocks(len(prompt), 64, la.slots, cfg.max_seq_len, 64)
    fns_paged = make_session_fns(cfg, params, slots=la.slots,
                                 kv_layout="paged", block_size=64,
                                 n_blocks=1 + blocks)
    engine_paged = LookaheadEngine(fns_paged, la)
    engine_paged.warmup([ref])
    out_paged = engine_paged.generate(prompt, max_new_tokens=64)
    assert out_paged.tokens == out.tokens, "kv layout changed an output!"
    dense_rows, paged_rows = cfg.max_seq_len, blocks * 64
    print(f"paged kv cache ✓ — same tokens from {paged_rows} cache rows "
          f"instead of {dense_rows}")


if __name__ == "__main__":
    main()
