"""Training driver: LM pre-training with checkpoint/restart, preemption
handling and straggler timeouts.  Default config is CPU-sized; pass
--preset 100m on real hardware for the ~100M-parameter run.

    PYTHONPATH=src python examples/train_lm.py --steps 60
"""
import argparse
import os

import jax
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig, init_params, lm_loss
from repro.training.checkpoint import CheckpointManager
from repro.training.data import PROFILES, SyntheticCorpus, lm_train_batches
from repro.training.fault_tolerance import PreemptionHandler, run_with_timeout
from repro.training.optimizer import adamw_init
from repro.training.train_step import make_train_step

PRESETS = {
    "tiny": TransformerConfig(n_layers=4, d_model=128, n_heads=4,
                              n_kv_heads=2, d_ff=512, vocab_size=2048),
    # ~100M params (deliverable-scale; hours on CPU, minutes on a v5e slice)
    "100m": TransformerConfig(n_layers=12, d_model=768, n_heads=12,
                              n_kv_heads=12, d_ff=2048, vocab_size=32768,
                              remat=True),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--step-timeout", type=float, default=300.0)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    print(f"model: {cfg.n_params()/1e6:.1f}M params")
    loss_fn = lambda p, b: lm_loss(cfg, p, b["tokens"], b["labels"])
    step = jax.jit(make_train_step(loss_fn, lr=3e-4, weight_decay=0.01))

    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    params = init_params(cfg, jax.random.key(0))
    opt = adamw_init(params)
    start = 0
    if mgr.latest_step() is not None:       # resume-from-latest
        state, start = mgr.restore({"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        print(f"resumed from step {start}")

    corpus = SyntheticCorpus(PROFILES["gsm8k"], cfg.vocab_size, seed=0)
    batches = lm_train_batches(cfg.vocab_size, args.batch, args.seq,
                               seed=start, corpus=corpus)
    handler = PreemptionHandler().install()
    for i in range(start, args.steps):
        b = {k: jnp.asarray(v) for k, v in next(batches).items()}
        # straggler mitigation: a wedged step is abandoned + retried once
        params, opt, m = run_with_timeout(step, args.step_timeout, params,
                                          opt, b, retries=1)
        if (i + 1) % 10 == 0 or i == start:
            print(f"step {i+1}: loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f}")
        if (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, {"params": params, "opt": opt}, blocking=False)
        if handler.preempted:
            print("preemption signal — checkpointing and exiting")
            mgr.save(i + 1, {"params": params, "opt": opt})
            break
    mgr.wait()
    mgr.save(args.steps, {"params": params, "opt": opt})
    print(f"done; checkpoints at {args.ckpt_dir}: {mgr.all_steps()}")
    handler.uninstall()


if __name__ == "__main__":
    main()
