"""Lossless lookahead under SAMPLING (paper: 'supports the greedy search and
sample generation strategy').  Position-keyed Gumbel sampling makes the
sampled stream deterministic given (key, position) — so drafts verify
against it exactly and the accelerated stream is bit-identical.

    PYTHONPATH=src python examples/sample_decoding.py
"""
import jax
import numpy as np

from repro.core import LookaheadConfig, LookaheadEngine, reference_decode
from repro.models.transformer import TransformerConfig, init_params
from repro.serving.session import make_session_fns


def main() -> None:
    cfg = TransformerConfig(n_layers=3, d_model=96, n_heads=4, n_kv_heads=2,
                            d_ff=192, vocab_size=256, max_seq_len=512)
    params = init_params(cfg, jax.random.key(1))
    for temp in (0.7, 1.0):
        fns = make_session_fns(cfg, params, sample=True, temperature=temp,
                               base_key=jax.random.key(123), slots=25)
        prompt = list(np.random.RandomState(1).randint(2, 256, size=32))
        ref = reference_decode(fns, prompt, 48)
        eng = LookaheadEngine(fns, LookaheadConfig(decoding_length=24,
                                                   branch_length=8))
        eng.warmup([ref])
        out = eng.generate(prompt, 48)
        assert out.tokens == ref
        print(f"temperature={temp}: {out.stats.steps} steps for "
              f"{len(out.tokens)} tokens (EDL {out.stats.edl:.2f}) — "
              "bit-identical to step-by-step sampling ✓")


if __name__ == "__main__":
    main()
