"""Property-based losslessness fuzz (ISSUE 3 satellite).

Hypothesis (or the conftest shim on bare environments) drives random
workloads — prompts, arrival orders, per-request ``max_new_tokens``, KV
block sizes — through the continuous-batching scheduler and asserts every
request's output is bit-identical to single-request greedy decode through
the same session, for the full (kv layout x attention backend) matrix:

    dense/dense   dense/pallas   paged/dense   paged/pallas

and additionally that all four matrix cells agree with each other (the
registry + paged I1 contract).

Sessions compile once per matrix cell and are reused across examples
(fixed shapes, I2); reference decodes are memoized per (cell, prompt,
budget).  Examples are generated from a drawn integer seed so the same
code path works with real hypothesis and with the shim's reduced strategy
surface.

Every scheduler here runs with ``sanitize=True`` (ISSUE 9): the runtime
sanitizer's lifecycle machine, shadow block ledger and retrace monitor
audit each run and raise on any violation — so this suite doubles as the
allocator/lifecycle fuzz for the analysis layer, at zero extra cost.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import DraftPolicy, LookaheadConfig, reference_decode
from repro.models.transformer import TransformerConfig, init_params
from repro.serving.scheduler import ContinuousScheduler
from repro.serving.session import make_session_fns

pytestmark = pytest.mark.paged

PREFILL = 32
SLOTS = 9
VOCAB = 53
BLOCK_SIZES = (8, 16)          # drawn per example for the paged cells

_CFG = TransformerConfig(n_layers=1, d_model=32, n_heads=4, n_kv_heads=2,
                         d_ff=64, vocab_size=VOCAB, max_seq_len=160)
_PARAMS = init_params(_CFG, jax.random.key(11))
_SESSIONS = {}
_REFS = {}


def _cells(block_size):
    return (("dense", "dense", 0), ("dense", "pallas", 0),
            ("paged", "dense", block_size), ("paged", "pallas", block_size))


def _get_fns(layout, backend, block_size):
    key = (layout, backend, block_size)
    if key not in _SESSIONS:
        _SESSIONS[key] = make_session_fns(
            _CFG, _PARAMS, slots=SLOTS, prefill_len=PREFILL, backend=backend,
            kv_layout=layout,
            block_size=block_size if layout == "paged" else None)
    return _SESSIONS[key]


def _ref(cell_key, prompt, max_new):
    key = (cell_key, tuple(prompt), max_new)
    if key not in _REFS:
        _REFS[key] = reference_decode(_get_fns(*cell_key), prompt, max_new)
    return _REFS[key]


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 4), st.integers(0, 1))
def test_fuzz_scheduler_matches_reference_decode(seed, n_req, bs_idx):
    rng = np.random.RandomState(seed % 2**31)
    block_size = BLOCK_SIZES[bs_idx]
    prompts = [rng.randint(1, VOCAB - 1,
                           size=rng.randint(1, PREFILL - 4)).tolist()
               for _ in range(n_req)]
    budgets = [int(rng.randint(1, 18)) for _ in range(n_req)]
    order = rng.permutation(n_req)
    lanes = int(rng.randint(1, 3))
    la = LookaheadConfig(decoding_length=SLOTS - 1, branch_length=4)

    outputs = {}
    for cell in _cells(block_size):
        fns = _get_fns(*cell)
        sched = ContinuousScheduler(fns, la, lanes=lanes,
                                    prefill_len=PREFILL, sanitize=True)
        rid_to_idx = {}
        for i in order:
            rid_to_idx[sched.submit(prompts[i], budgets[i])] = int(i)
        res = sched.run()
        assert len(res) == n_req
        got = [None] * n_req
        for r in res:
            i = rid_to_idx[r.rid]
            got[i] = r.tokens
            assert r.tokens == _ref(cell, prompts[i], budgets[i]), \
                (cell, seed, i)
        outputs[cell] = got

    # every matrix cell agrees bit-for-bit with every other
    baseline = outputs[("dense", "dense", 0)]
    for cell, got in outputs.items():
        assert got == baseline, (cell, seed)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_fuzz_paged_backpressure_lossless(seed):
    """Same property under a deliberately tiny block pool: admissions
    serialize behind block backpressure, outputs stay bit-identical."""
    rng = np.random.RandomState(seed % 2**31)
    n_req = int(rng.randint(2, 6))
    prompts = [rng.randint(1, VOCAB - 1,
                           size=rng.randint(1, 20)).tolist()
               for _ in range(n_req)]
    budgets = [int(rng.randint(1, 12)) for _ in range(n_req)]
    cell = ("paged", "dense", 8)
    # capacity: exactly one worst-case request at a time
    # (demand <= ceil((20 + 12 + 9)/8) = 6 blocks)
    fns = _SESSIONS.get("small")
    if fns is None:
        fns = _SESSIONS["small"] = make_session_fns(
            _CFG, _PARAMS, slots=SLOTS, prefill_len=PREFILL,
            kv_layout="paged", block_size=8, n_blocks=7)
    la = LookaheadConfig(decoding_length=SLOTS - 1, branch_length=4)
    sched = ContinuousScheduler(fns, la, lanes=2, prefill_len=PREFILL,
                                sanitize=True)
    rid_to_idx = {sched.submit(p, m): i
                  for i, (p, m) in enumerate(zip(prompts, budgets))}
    res = sched.run()
    assert len(res) == n_req
    for r in res:
        i = rid_to_idx[r.rid]
        assert r.tokens == _ref(cell, prompts[i], budgets[i]), (seed, i)


# ------------------------------------------- fused step + overlap fuzz (I1)
@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 5), st.integers(0, 1))
def test_fuzz_overlap_mode_lossless(seed, n_req, bs_idx):
    """The fused single-sync decode step with ``overlap_drafts`` on and off:
    random workloads through every matrix cell must stay bit-identical to
    reference_decode AND to each other (overlap defers bookkeeping into the
    device flight window but may never change tokens), while the decode hot
    path makes exactly one host sync per step."""
    rng = np.random.RandomState(seed % 2**31)
    block_size = BLOCK_SIZES[bs_idx]
    prompts = [rng.randint(1, VOCAB - 1,
                           size=rng.randint(1, PREFILL - 4)).tolist()
               for _ in range(n_req)]
    budgets = [int(rng.randint(1, 18)) for _ in range(n_req)]
    lanes = int(rng.randint(1, 3))
    la = LookaheadConfig(decoding_length=SLOTS - 1, branch_length=4)
    for cell in _cells(block_size):
        fns = _get_fns(*cell)
        outs = {}
        for overlap in (False, True):
            sched = ContinuousScheduler(fns, la, lanes=lanes,
                                        prefill_len=PREFILL,
                                        overlap_drafts=overlap,
                                        sanitize=True)
            rid_to_idx = {sched.submit(p, m): i
                          for i, (p, m) in enumerate(zip(prompts, budgets))}
            res = sched.run()
            assert len(res) == n_req
            got = [None] * n_req
            for r in res:
                i = rid_to_idx[r.rid]
                got[i] = r.tokens
                assert r.tokens == _ref(cell, prompts[i], budgets[i]), \
                    (cell, seed, overlap, i)
            st_ = sched.stats
            assert st_.decode_syncs == st_.decode_steps, (cell, overlap)
            assert not sched._retired and not sched._pending
            outs[overlap] = got
        assert outs[True] == outs[False], (cell, seed)


# --------------------------------------------------- draft-source fuzz (I5)
_SOURCE_COMBOS = (("trie",), ("prompt_copy",), ("ngram",),
                  ("trie", "ngram"), ("trie", "prompt_copy", "ngram"))


@pytest.mark.draft
@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, len(_SOURCE_COMBOS) - 1),
       st.integers(0, 1))
def test_fuzz_draft_sources_lossless(seed, combo_idx, adaptive):
    """Random workloads under random draft-source combinations, quotas,
    namespaces and adaptive budgets: draft content is host-side only, so
    every request must stay bit-identical to single-request greedy decode —
    on a dense AND a paged/pallas matrix cell."""
    rng = np.random.RandomState(seed % 2**31)
    sources = _SOURCE_COMBOS[combo_idx]
    quotas = ()
    if len(sources) > 1 and rng.rand() < 0.5:
        quotas = tuple(int(rng.randint(1, SLOTS)) for _ in sources)
    policy = DraftPolicy(
        sources=sources, quotas=quotas,
        namespace="" if rng.rand() < 0.5 else f"ns{rng.randint(2)}",
        adaptive=bool(adaptive), min_budget=int(rng.randint(1, SLOTS)))
    n_req = int(rng.randint(1, 5))
    prompts = [rng.randint(1, VOCAB - 1,
                           size=rng.randint(1, PREFILL - 4)).tolist()
               for _ in range(n_req)]
    budgets = [int(rng.randint(1, 16)) for _ in range(n_req)]
    lanes = int(rng.randint(1, 3))
    la = LookaheadConfig(decoding_length=SLOTS - 1, branch_length=4)
    for cell in (("dense", "dense", 0), ("paged", "pallas", 8)):
        fns = _get_fns(*cell)
        sched = ContinuousScheduler(fns, la, lanes=lanes,
                                    prefill_len=PREFILL,
                                    draft_policy=policy, sanitize=True)
        rid_to_idx = {sched.submit(p, m): i
                      for i, (p, m) in enumerate(zip(prompts, budgets))}
        res = sched.run()
        assert len(res) == n_req
        for r in res:
            i = rid_to_idx[r.rid]
            assert r.tokens == _ref(cell, prompts[i], budgets[i]), \
                (cell, seed, sources, i)


# ----------------------------------------------- prefix-cache fuzz (ISSUE 7)
@pytest.mark.prefix
@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 1), st.integers(0, 1))
def test_fuzz_prefix_cache_lossless(seed, bs_idx, overlap):
    """Random shared-prefix prompt sets (a common head + random tails, plus
    divergent miss traffic) through the paged cells with the radix prefix
    cache on and off: block sharing, COW boundary forks and suffix prefill
    may never change a single token — both modes must equal reference_decode
    and each other."""
    rng = np.random.RandomState(seed % 2**31)
    block_size = BLOCK_SIZES[bs_idx]
    shared = rng.randint(1, VOCAB - 1,
                         size=int(rng.randint(4, PREFILL - 10))).tolist()
    n_req = int(rng.randint(2, 6))
    prompts = [shared + rng.randint(
        1, VOCAB - 1, size=rng.randint(1, PREFILL - len(shared))).tolist()
        for _ in range(n_req)]
    prompts.append(rng.randint(1, VOCAB - 1, size=8).tolist())  # miss traffic
    budgets = [int(rng.randint(1, 14)) for _ in prompts]
    lanes = int(rng.randint(1, 3))
    la = LookaheadConfig(decoding_length=SLOTS - 1, branch_length=4)
    for backend in ("dense", "pallas"):
        cell = ("paged", backend, block_size)
        fns = _get_fns(*cell)
        outs = {}
        for cached in (False, True):
            sched = ContinuousScheduler(fns, la, lanes=lanes,
                                        prefill_len=PREFILL,
                                        overlap_drafts=bool(overlap),
                                        prefix_cache=cached, sanitize=True)
            rid_to_idx = {sched.submit(p, m): i
                          for i, (p, m) in enumerate(zip(prompts, budgets))}
            res = sched.run()
            assert len(res) == len(prompts)
            got = [None] * len(prompts)
            for r in res:
                i = rid_to_idx[r.rid]
                got[i] = r.tokens
                assert r.tokens == _ref(cell, prompts[i], budgets[i]), \
                    (cell, seed, cached, i)
            outs[cached] = got
        assert outs[True] == outs[False], (cell, seed)


# ------------------------------------------- cancel-under-overlap (ISSUE 8)
@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 6), st.integers(0, 1))
def test_fuzz_cancel_under_overlap_lossless(seed, n_req, bs_idx):
    """Random cancellation traffic against overlap-mode schedulers with
    scrub_freed on (any teardown mistake destroys live KV): random victims
    cancelled at random step counts; every SURVIVOR must stay bit-identical
    to reference_decode on both layouts, every victim must come back
    flagged, and no deferred state may leak past idle."""
    rng = np.random.RandomState(seed % 2**31)
    block_size = BLOCK_SIZES[bs_idx]
    prompts = [rng.randint(1, VOCAB - 1,
                           size=rng.randint(1, PREFILL - 4)).tolist()
               for _ in range(n_req)]
    budgets = [int(rng.randint(2, 18)) for _ in range(n_req)]
    lanes = int(rng.randint(1, 3))
    victims = {int(i): int(rng.randint(0, 6))       # rid -> cancel at step
               for i in rng.choice(n_req, size=max(1, n_req // 2),
                                   replace=False)}
    la = LookaheadConfig(decoding_length=SLOTS - 1, branch_length=4)
    for cell in (("dense", "dense", 0), ("paged", "pallas", block_size)):
        fns = _get_fns(*cell)
        sched = ContinuousScheduler(fns, la, lanes=lanes,
                                    prefill_len=PREFILL,
                                    overlap_drafts=True, scrub_freed=True,
                                    sanitize=True)
        rid_to_idx = {sched.submit(p, m): i
                      for i, (p, m) in enumerate(zip(prompts, budgets))}
        step = 0
        while not sched.idle:
            for rid, at in victims.items():
                if step == at and rid not in sched.results:
                    sched.cancel(rid)
            sched.step()
            step += 1
        assert not sched._retired and not sched._pending
        if sched.allocator is not None:
            assert not sched.allocator._tables
        assert len(sched.results) == n_req
        for rid, res in sched.results.items():
            i = rid_to_idx[rid]
            if res.cancelled:
                assert rid in victims and res.finish_reason == "cancelled"
                # a cancelled stream is a clean PREFIX of the reference
                ref = _ref(cell, prompts[i], budgets[i])
                assert res.tokens == ref[:len(res.tokens)], (cell, seed, i)
            else:
                assert res.tokens == _ref(cell, prompts[i], budgets[i]), \
                    (cell, seed, i)


# --------------------------------------- multi-tenant autotune (ISSUE 8)
@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 1), st.integers(0, 1))
def test_fuzz_mixed_namespace_autotune_lossless(seed, shares_on, bs_idx):
    """Mixed-namespace arrival streams with the per-namespace draft
    controller on vs off (and optionally weighted-fair lane shares): the
    controller only gates which draft tokens get BUILT, so every request
    must be bit-identical across both runs and to reference_decode."""
    from repro.core.autotune import AutoTuneConfig, AutoTuner
    from repro.core.request import Request, SamplingParams

    rng = np.random.RandomState(seed % 2**31)
    block_size = BLOCK_SIZES[bs_idx]
    n_req = int(rng.randint(2, 7))
    prompts = [rng.randint(1, VOCAB - 1,
                           size=rng.randint(1, PREFILL - 4)).tolist()
               for _ in range(n_req)]
    budgets = [int(rng.randint(1, 16)) for _ in range(n_req)]
    combos = (("trie",), ("trie", "ngram"), ("trie", "prompt_copy", "ngram"))
    policies = [DraftPolicy(sources=combos[rng.randint(len(combos))],
                            namespace=f"ns{rng.randint(2)}")
                for _ in range(n_req)]
    lanes = int(rng.randint(1, 3))
    shares = ({"ns0": 0.5, "ns1": 0.5} if shares_on else None)
    la = LookaheadConfig(decoding_length=SLOTS - 1, branch_length=4)
    for cell in (("dense", "dense", 0), ("paged", "dense", block_size)):
        fns = _get_fns(*cell)
        outs = {}
        for tune in (False, True):
            autotune = (AutoTuner(AutoTuneConfig(min_trials=2, drop_rate=0.3,
                                                 probe_period=2))
                        if tune else False)
            sched = ContinuousScheduler(fns, la, lanes=lanes,
                                        prefill_len=PREFILL,
                                        lane_shares=shares,
                                        autotune=autotune, sanitize=True)
            handles = [sched.submit_request(Request(
                prompt=list(p),
                params=SamplingParams(max_new_tokens=m, draft=pol)))
                for p, m, pol in zip(prompts, budgets, policies)]
            sched.run()
            got = [h.result().tokens for h in handles]
            for i, t in enumerate(got):
                assert t == _ref(cell, prompts[i], budgets[i]), \
                    (cell, seed, tune, i)
            outs[tune] = got
        assert outs[True] == outs[False], (cell, seed)
