"""Radix prefix cache: tree insert/lookup/upgrade, COW boundary forks, LRU
eviction under pool pressure, allocator refcount invariants, namespace
isolation — and scheduler integration (bit-identical serving with the cache
on vs off vs reference_decode, refcount-aware scrub on finish→admit
interleave, compile-once suffix buckets)."""
import jax
import numpy as np
import pytest

from repro.core import reference_decode
from repro.core.draft_sources import DraftPolicy
from repro.core.request import Request, SamplingParams
from repro.models.transformer import TransformerConfig, init_params
from repro.serving.api import EngineConfig, build_engine
from repro.serving.block_allocator import BlockAllocator
from repro.serving.prefix_cache import PrefixCache

pytestmark = pytest.mark.prefix

BS = 4


def toks(*vals):
    return list(vals)


def _alloc_with(a, rid, tokens, reserve=None):
    """Allocate enough blocks for ``tokens`` under ``rid``."""
    n = -(-len(tokens) // a.block_size)
    return a.alloc(rid, n, reserve=reserve)


# --------------------------------------------------------------- allocator refs
def test_share_and_refcounted_free():
    a = BlockAllocator(n_blocks=10, block_size=BS)
    ids = a.alloc(1, 3)
    a.alloc(2, 0, reserve=4)
    a.share(2, ids[:2])
    assert a.refcount(ids[0]) == 2 and a.refcount(ids[2]) == 1
    # freeing the first owner releases only the unshared block
    assert a.free(1) == [ids[2]]
    assert a.refcount(ids[0]) == 1
    # second owner's free releases the rest
    assert sorted(a.free(2)) == sorted(ids[:2])
    assert a.n_free == a.capacity


def test_share_rejects_free_blocks_and_overreservation():
    a = BlockAllocator(n_blocks=10, block_size=BS)
    ids = a.alloc(1, 2)
    a.free(1)
    a.alloc(2, 0, reserve=1)
    with pytest.raises(ValueError):
        a.share(2, [ids[0]])        # not live anymore
    live = a.alloc(3, 1)
    with pytest.raises(RuntimeError):
        a.share(2, [live[0], live[0]])   # exceeds rid 2's reservation


def test_cache_ref_pins_blocks_out_of_free_list():
    a = BlockAllocator(n_blocks=10, block_size=BS)
    ids = a.alloc(1, 3)
    a.cache_ref(ids[:2])
    assert a.free(1) == [ids[2]]           # cache-held ids stay live
    assert a.n_cache_only == 2
    assert a.available == a.capacity - 2   # cache residency is not reservable
    freed = a.cache_unref(ids[:2])
    assert sorted(freed) == sorted(ids[:2])
    assert a.n_cache_only == 0 and a.n_free == a.capacity
    with pytest.raises(ValueError):
        a.cache_unref([ids[0]])            # double unref


def test_cache_ref_is_single_ownership():
    a = BlockAllocator(n_blocks=10, block_size=BS)
    ids = a.alloc(1, 1)
    a.cache_ref(ids)
    with pytest.raises(ValueError):
        a.cache_ref(ids)                   # at most one cache reference


def test_fork_cow_allocates_from_own_reservation():
    a = BlockAllocator(n_blocks=10, block_size=BS)
    src = a.alloc(1, 1)[0]
    a.alloc(2, 0, reserve=2)
    dst = a.fork_cow(2, src)
    assert dst != src and a.table(2) == [dst]
    assert a.refcount(src) == 1            # fork does NOT share the source
    with pytest.raises(ValueError):
        a.fork_cow(2, 9)                   # free block: nothing to fork


def test_shared_blocks_not_double_freed():
    a = BlockAllocator(n_blocks=10, block_size=BS)
    ids = a.alloc(1, 2)
    a.cache_ref(ids)
    for rid in (2, 3):
        a.alloc(rid, 0, reserve=3)
        a.share(rid, ids)
    assert a.refcount(ids[0]) == 4     # rid 1 + cache + rid 2 + rid 3
    assert a.free(2) == [] and a.free(3) == [] and a.free(1) == []
    freed = a.cache_unref(ids)
    assert sorted(freed) == sorted(ids)
    assert a.n_free == a.capacity          # every block back exactly once


# ------------------------------------------------------------------- radix tree
def _tree(n_blocks=32):
    a = BlockAllocator(n_blocks=n_blocks, block_size=BS)
    return PrefixCache(a), a


def test_insert_then_lookup_full_blocks():
    pc, a = _tree()
    prompt = list(range(10, 19))                 # 9 tokens: 2 full + 1 part
    blocks = _alloc_with(a, 1, prompt)
    pc.insert(prompt, blocks)
    assert pc.n_blocks == 3
    # same prompt again: full blocks shared, boundary block COW-forked,
    # capped one short of the full prompt
    m = pc.lookup(prompt)
    assert m.blocks == blocks[:2]
    # boundary leaf holds 1 token; the cap (len-1 == 8) forbids using it
    assert m.cow_block is None and m.cow_tokens == 0
    assert m.n_tokens == len(prompt) - 1
    pc.unpin(m)


def test_lookup_misses_on_cold_tree_and_divergence():
    pc, a = _tree()
    prompt = list(range(20, 32))
    blocks = _alloc_with(a, 1, prompt)
    pc.insert(prompt, blocks)
    assert pc.lookup(list(range(50, 60))).n_tokens == 0
    # divergence inside the second block: only the first block shared, the
    # second becomes a COW fork up to the divergence point
    other = prompt[:6] + [99] * 6
    m = pc.lookup(other)
    assert m.blocks == blocks[:1]
    assert m.cow_block == blocks[1] and m.cow_tokens == 2
    assert m.n_tokens == 6
    pc.unpin(m)


def test_insert_dedup_keeps_tree_blocks():
    pc, a = _tree()
    prompt = list(range(8))
    b1 = _alloc_with(a, 1, prompt)
    pc.insert(prompt, b1)
    b2 = _alloc_with(a, 2, prompt)
    pc.insert(prompt, b2)                        # same path: no new adoption
    assert pc.n_blocks == 2
    m = pc.lookup(prompt + [7])
    assert m.blocks == b1                        # the ORIGINAL blocks
    pc.unpin(m)
    assert a.refcount(b2[0]) == 1                # rid 2 still sole owner


def test_insert_upgrades_partial_leaf():
    pc, a = _tree()
    short = list(range(6))                       # 1 full + 2-token partial
    b1 = _alloc_with(a, 1, short)
    pc.insert(short, b1)
    longer = list(range(8)) + [70, 71]           # extends through that block
    b2 = _alloc_with(a, 2, longer)
    pc.insert(longer, b2)
    a.free(1)
    # the partial leaf was upgraded to rid 2's fuller block and gained a child
    m = pc.lookup(longer + [9])
    assert m.blocks == [b1[0], b2[1]] and m.cow_block == b2[2]
    pc.unpin(m)
    assert a.refcount(b1[1]) == 0                # old partial: released


def test_namespace_isolation():
    pc, a = _tree()
    prompt = list(range(12))
    b1 = _alloc_with(a, 1, prompt)
    pc.insert(prompt, b1, namespace="tenant-a")
    assert pc.lookup(prompt, namespace="tenant-b").n_tokens == 0
    assert pc.lookup(prompt, namespace="").n_tokens == 0
    m = pc.lookup(prompt, namespace="tenant-a")
    assert m.n_tokens == len(prompt) - 1
    pc.unpin(m)


def test_lru_eviction_under_pool_pressure_spares_pinned():
    a = BlockAllocator(n_blocks=5, block_size=BS)    # capacity 4 (NULL excl.)
    pc = PrefixCache(a)
    old = list(range(100, 108))
    new = list(range(200, 208))
    pc.insert(old, _alloc_with(a, 1, old))
    pc.insert(new, _alloc_with(a, 2, new))
    a.free(1), a.free(2)
    assert a.available == 0 and pc.n_blocks == 4
    m = pc.lookup(new)                   # pins the 'new' path
    freed = pc.evict(2)                  # must take the LRU ('old') leaves
    assert len(freed) == 2 and a.available == 2
    assert pc.lookup(old).n_tokens == 0  # 'old' gone ...
    assert m.blocks and all(a.refcount(b) > 0 for b in m.blocks)  # 'new' not
    pc.unpin(m)


def test_max_blocks_cap_trims_lru():
    a = BlockAllocator(n_blocks=32, block_size=BS)
    pc = PrefixCache(a, max_blocks=3)
    p1, p2 = list(range(8)), list(range(50, 58))
    pc.insert(p1, _alloc_with(a, 1, p1))
    pc.insert(p2, _alloc_with(a, 2, p2))
    assert pc.n_blocks <= 3
    assert pc.lookup(p2).n_tokens > 0    # the most recent insert survived


# ------------------------------------------------------ serving integration
@pytest.fixture(scope="module")
def small_model():
    cfg = TransformerConfig(vocab_size=128, d_model=64, n_layers=2,
                            n_heads=4, n_kv_heads=2, d_ff=128,
                            max_seq_len=256, kv_layout="paged",
                            kv_block_size=16)
    return cfg, init_params(cfg, jax.random.key(0))


def _serve(cfg, params, prompts, *, prefix_cache, overlap=False,
           n_blocks=None, scrub=True, decode_backend=None, namespaces=None,
           max_new=10):
    ecfg = EngineConfig(lanes=2, prefill_len=64, decoding_length=4,
                        branch_length=4, kv_layout="paged", block_size=16,
                        scrub_freed=scrub, prefix_cache=prefix_cache,
                        overlap_drafts=overlap, n_blocks=n_blocks,
                        decode_backend=decode_backend,
                        default_params=SamplingParams(max_new_tokens=max_new))
    eng = build_engine(ecfg, cfg, params)
    handles = []
    for i, p in enumerate(prompts):
        draft = (DraftPolicy(namespace=namespaces[i]) if namespaces
                 else None)
        sp = SamplingParams(max_new_tokens=max_new, draft=draft)
        handles.append(eng.submit(Request(prompt=p, params=sp)))
    eng.run()
    return [h.result().tokens for h in handles], eng


def _shared_prompts(n, seed=0, shared_len=40, tail=12):
    rng = np.random.RandomState(seed)
    shared = rng.randint(1, 128, size=shared_len).tolist()
    return [shared + rng.randint(1, 128, size=tail).tolist()
            for _ in range(n)]


def test_serving_bit_identical_and_saves_prefill(small_model):
    cfg, params = small_model
    prompts = _shared_prompts(6) + [list(range(1, 31))]   # hits + one miss
    off, _ = _serve(cfg, params, prompts, prefix_cache=False)
    on, eng = _serve(cfg, params, prompts, prefix_cache=True)
    assert on == off
    st = eng.stats
    assert st.prefix_hits >= 3 and st.prefix_cow_forks >= 1
    assert st.prefill_tokens_saved >= 0.30
    assert reference_decode(eng.fns, prompts[0], 10) == on[0]
    assert reference_decode(eng.fns, prompts[-1], 10) == on[-1]


def test_serving_overlap_mode_identical(small_model):
    cfg, params = small_model
    prompts = _shared_prompts(8, seed=3)
    off, _ = _serve(cfg, params, prompts, prefix_cache=False)
    on, eng = _serve(cfg, params, prompts, prefix_cache=True, overlap=True)
    assert on == off and eng.stats.prefix_hits > 0


def test_serving_pallas_decode_identical(small_model):
    cfg, params = small_model
    prompts = _shared_prompts(5, seed=4)
    off, _ = _serve(cfg, params, prompts, prefix_cache=False,
                    decode_backend="pallas")
    on, eng = _serve(cfg, params, prompts, prefix_cache=True,
                     decode_backend="pallas")
    assert on == off and eng.stats.prefix_hits > 0


def test_compile_once_suffix_buckets(small_model):
    cfg, params = small_model
    prompts = _shared_prompts(10, seed=5)
    _, eng = _serve(cfg, params, prompts, prefix_cache=True)
    fns = eng.fns
    assert fns.prefill_suffix._cache_size() <= len(fns.suffix_buckets)
    assert fns.prefill_suffix._cache_size() >= 1
    assert fns.copy_block._cache_size() == 1
    # cold admissions ride the batched prefill / prefill_into_slot paths;
    # neither retraces (compile-once invariant I2)
    assert fns.prefill._cache_size() <= 1
    assert fns.prefill_into_slot._cache_size() <= 1


def test_finish_admit_interleave_shared_prefix_scrub(small_model):
    """Satellite regression: request B shares A's promoted prefix blocks;
    C finishes and is scrubbed while B still decodes; B's own retire must
    not scrub the cache-held blocks.  scrub_freed=True makes any violation
    destroy resident KV and break token equality."""
    cfg, params = small_model
    rng = np.random.RandomState(6)
    shared = rng.randint(1, 128, size=40).tolist()
    prompts = ([shared + rng.randint(1, 128, size=12).tolist()
                for _ in range(5)]
               + [rng.randint(1, 128, size=20).tolist()]   # unrelated C
               + [shared + rng.randint(1, 128, size=12).tolist()
                  for _ in range(3)])
    off, _ = _serve(cfg, params, prompts, prefix_cache=False, scrub=True)
    for overlap in (False, True):
        on, eng = _serve(cfg, params, prompts, prefix_cache=True,
                         scrub=True, overlap=overlap)
        assert on == off, f"overlap={overlap}"
        a = eng.scheduler.allocator
        assert not a._tables                       # all requests retired
        assert all(a.refcount(b) == 1 for b in a._cache_held)
        assert a.n_cache_only == eng.scheduler.prefix.n_blocks


def test_serving_namespace_isolation(small_model):
    """Same prompt under two namespaces must not share KV (no cross-tenant
    hits), yet outputs stay identical to the uncached path."""
    cfg, params = small_model
    prompts = _shared_prompts(6, seed=7)
    ns = ["a" if i % 2 == 0 else "b" for i in range(len(prompts))]
    off, _ = _serve(cfg, params, prompts, prefix_cache=False, namespaces=ns)
    on, eng = _serve(cfg, params, prompts, prefix_cache=True, namespaces=ns)
    assert on == off
    # per-namespace trees: both namespaces hold their own copy
    roots = eng.scheduler.prefix._roots
    assert set(roots) >= {"a", "b"}


def test_backpressure_eviction_drains_queue(small_model):
    """Pool sized so admissions must evict cached blocks: the queue still
    drains (no deadlock) and outputs stay identical."""
    cfg, params = small_model
    prompts = _shared_prompts(8, seed=8)
    # worst-case demand per request: ceil((52 + 10 + 5) / 16) = 5 blocks;
    # 2 lanes -> 10 + NULL. One spare block for the cache to fight over.
    off, _ = _serve(cfg, params, prompts, prefix_cache=False, n_blocks=11)
    on, eng = _serve(cfg, params, prompts, prefix_cache=True, n_blocks=11)
    assert on == off
    assert eng.stats.prefix_evicted_blocks > 0


def test_precohort_eviction_scrub_is_queued_and_flushed():
    """Satellite regression (silent scrub skip): prefix-cache evictions made
    while claiming the INITIAL cohort happen before any prefill, so no
    device cache exists to scrub against — the old code dropped them
    silently under ``scrub_freed=True``.  They must be queued and flushed
    right after the cohort prefill creates the cache, skipping ids the
    cohort itself re-allocated (their rows hold live KV)."""
    import dataclasses

    from repro.core import LookaheadConfig
    from repro.serving.scheduler import ContinuousScheduler
    from repro.serving.session import make_session_fns

    cfg = TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                            d_ff=64, vocab_size=128, max_seq_len=160)
    params = init_params(cfg, jax.random.key(12))
    fns = make_session_fns(cfg, params, slots=9, prefill_len=32,
                           kv_layout="paged", block_size=16, n_blocks=12)
    calls = []
    orig = fns.reset_blocks

    def counting_reset(cache, ids):
        calls.append(np.asarray(ids).copy())
        return orig(cache, ids)

    fns = dataclasses.replace(fns, reset_blocks=counting_reset)
    la = LookaheadConfig(decoding_length=8, branch_length=4)
    sched = ContinuousScheduler(fns, la, lanes=2, prefill_len=32,
                                scrub_freed=True, prefix_cache=True)

    # pre-warm: a 6-block cached prefix held ONLY by the cache (the original
    # owner freed it), disjoint from the upcoming prompts so nothing hits
    warm_tokens = [60 + (i % 60) for i in range(6 * 16)]
    warm_ids = sched.allocator.alloc(999, 6, reserve=6)
    sched.prefix.insert(warm_tokens, warm_ids)
    assert sched.allocator.free(999) == []          # cache-held: stays live
    assert sched.allocator.available == 5

    # two admissions: 7-token prompts (1 initial block) with a 4-block
    # worst-case reservation each — the second claim must LRU-evict cached
    # blocks before any cache exists
    rng = np.random.RandomState(13)
    prompts = [rng.randint(1, 50, size=7).tolist() for _ in range(2)]
    for p in prompts:
        sched.submit(p, 40)
    sched._admit()
    assert sched.stats.prefix_evicted_blocks >= 1
    # the flush ran: backlog empty, and the reset covered evicted ids that
    # stayed free (at least one; cohort re-allocation may take the rest)
    assert sched._scrub_backlog == []
    scrubbed = {int(b) for arr in calls for b in arr if b != 0}
    assert scrubbed and scrubbed <= set(warm_ids)
    for b in scrubbed:
        assert sched.allocator.refcount(b) == 0

    # and the workload still completes losslessly
    res = {r.rid: r.tokens for r in sched.run()}
    for rid, p in enumerate(prompts):
        assert res[rid] == reference_decode(fns, p, 40)
