"""Tiny-model engine builder for fleet tests.

Lives outside test_fleet.py so a spawned subprocess replica can import
the builder without dragging in the test module (whose hypothesis import
is satisfied by a conftest shim that only exists in the pytest parent).
"""
import jax

from repro.models.transformer import TransformerConfig, init_params
from repro.serving.api import EngineConfig, ServingEngine, build_session_fns

TINY_CFG = TransformerConfig(n_layers=1, d_model=32, n_heads=4, n_kv_heads=2,
                             d_ff=64, vocab_size=53, max_seq_len=160)
TINY_ECFG = EngineConfig(lanes=2, prefill_len=32, decoding_length=8,
                         branch_length=4)


def build_tiny() -> ServingEngine:
    params = init_params(TINY_CFG, jax.random.key(11))
    return ServingEngine(build_session_fns(TINY_ECFG, TINY_CFG, params),
                         TINY_ECFG)
