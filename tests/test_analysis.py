"""The analysis layer itself (ISSUE 9): linter rules R1-R5 against
known-bad and known-good fixture snippets, sanitizer units (double free,
leak-at-idle, out-of-order lifecycle, poison probes, retrace manifest),
and the serving-level integration — a sanitized scheduler run stays
bit-identical, and the lifecycle machine pins PR 8's cancel-of-pending
ordering (blocks held until the deferred drain).
"""
import subprocess
import sys
import textwrap
import types
from pathlib import Path

import pytest

from repro.analysis.lint import lint_file, lint_source, main as lint_main
from repro.analysis.sanitizer import (ADMITTED, DRAINED, InvariantViolation,
                                      LifecycleMonitor, RetraceMonitor,
                                      ShadowLedger)
from repro.serving.block_allocator import BlockAllocator

pytestmark = pytest.mark.analysis

REPO = Path(__file__).resolve().parents[1]


def _rules(source, rule_id):
    """Finding rule-ids of one snippet, filtered to one rule."""
    return [f for f in lint_source(textwrap.dedent(source))
            if f.rule == rule_id]


# ------------------------------------------------------------------- R1
R1_BAD = """
    import numpy as np

    class Sched:
        def _pull(self, x):
            return np.asarray(x)

        def step(self):
            cache, chosen = self.fns.fused_step(self.cache, self.lens)
            n = int(chosen[0])
            arr = np.asarray(chosen)
            chosen.block_until_ready()
            return n, arr, chosen.item()
"""

R1_GOOD = """
    import numpy as np

    class Sched:
        def _pull(self, x):
            return np.asarray(x)

        def step(self):
            cache, chosen = self.fns.fused_step(self.cache, self.lens)
            chosen = self._pull(chosen)
            toks = np.asarray(self.prompt, dtype=np.int32)
            return int(chosen[0]), toks
"""


def test_r1_flags_raw_pulls_on_device_values():
    found = _rules(R1_BAD, "R1")
    assert len(found) == 4          # int(), np.asarray(), buR(), .item()
    assert any("block_until_ready" in f.message for f in found)


def test_r1_accepts_pull_choke_point_and_host_values():
    # laundering through _pull() makes the name host data again, and
    # np.asarray on plain host values (the prompt list) is fine
    assert _rules(R1_GOOD, "R1") == []


def test_r1_ignores_classes_without_pull_contract():
    src = """
        import numpy as np

        class NotAScheduler:
            def step(self):
                out = self.fns.fused_step(self.cache)
                return int(out[0])
    """
    assert _rules(src, "R1") == []


def test_r1_suppression_comment():
    src = """
        import numpy as np

        class Sched:
            def _pull(self, x):
                return np.asarray(x)

            def warmup(self):
                c, chosen = self.fns.prefill(self.toks, self.lens)
                return int(chosen[0])  # repro-lint: disable=R1
    """
    assert _rules(src, "R1") == []


# ------------------------------------------------------------------- R2
def test_r2_flags_bare_jit_and_missing_argnums():
    src = """
        import jax

        @jax.jit
        def f(x):
            return x

        g = jax.jit(lambda x: x)
    """
    assert len(_rules(src, "R2")) == 2


def test_r2_flags_self_closure():
    src = """
        import functools, jax

        class Sched:
            def make(self):
                @functools.partial(jax.jit, donate_argnums=())
                def f(x):
                    return x + self.offset
                return f
    """
    found = _rules(src, "R2")
    assert len(found) == 1 and "closes over" in found[0].message


def test_r2_accepts_explicit_argnums():
    src = """
        import functools, jax

        @functools.partial(jax.jit, donate_argnums=(0,), static_argnums=(2,))
        def f(cache, x, n):
            return cache, x

        g = jax.jit(lambda x: x, donate_argnums=())
    """
    assert _rules(src, "R2") == []


# ------------------------------------------------------------------- R3
def test_r3_flags_pr8_cancel_shape_dropped_free_result():
    # the PR 8 use-after-free reconstruction: cancel-of-pending frees the
    # request's blocks mid-dispatch and throws away the refcount-zero ids
    src = """
        class Sched:
            def cancel_pending(self, rid, lane):
                del self._pending[lane]
                self.alloc.free(rid)
    """
    found = _rules(src, "R3")
    assert len(found) == 1 and "dropped on the floor" in found[0].message


def test_r3_flags_unpaired_acquire():
    src = """
        class PrefixAdopter:
            def adopt(self, rid, blocks):
                self.alloc.share(rid, blocks)
    """
    found = _rules(src, "R3")
    assert len(found) == 1 and "share" in found[0].message


def test_r3_accepts_paired_and_consumed():
    src = """
        class Sched:
            def admit(self, rid, blocks):
                self.alloc.share(rid, blocks)

            def retire(self, rid):
                freed = self.alloc.free(rid)
                self.scrub(freed)

        class BlockAllocator:
            def free(self, rid):
                return []

            def share(self, rid, blocks):
                self.noop(blocks)
    """
    # the scheduler pairs + consumes; the allocator DEFINES the API and
    # is skipped entirely
    assert _rules(src, "R3") == []


# ------------------------------------------------------------------- R4
def test_r4_flags_value_dependent_shapes_into_jitted_fns():
    src = """
        import jax
        import numpy as np

        step = jax.jit(lambda t: t, donate_argnums=())

        def go(toks, n):
            a = step(np.asarray(toks[:n]))
            b = step(np.zeros((len(toks),)))
            return a, b
    """
    assert len(_rules(src, "R4")) == 2


def test_r4_accepts_fixed_buckets():
    src = """
        import jax
        import numpy as np

        step = jax.jit(lambda t: t, donate_argnums=())

        def go(toks, n, buf):
            buf[0, :n] = np.asarray(toks[:n])   # host staging: fine
            return step(buf)
    """
    assert _rules(src, "R4") == []


# ------------------------------------------------------------------- R5
def test_r5_flags_donation_mask_mutations():
    src = """
        import numpy as np

        def sync(cache, tables):
            cache["block_tables"] = np.asarray(tables)
            del cache["k"]
            cache.pop("v")
    """
    assert len(_rules(src, "R5")) == 3


def test_r5_accepts_device_leaves():
    src = """
        import jax.numpy as jnp

        def sync(cache, tables):
            cache["block_tables"] = jnp.asarray(tables)
            other = {}
            other["x"] = np.asarray([1])
    """
    assert _rules(src, "R5") == []


# ------------------------------------------------------------------- R6
def test_r6_flags_unpaired_state_dict():
    src = """
        class SaveOnly:
            def state_dict(self):
                return {}

        class LoadOnly:
            def load_state_dict(self, state):
                pass
    """
    found = _rules(src, "R6")
    assert len(found) == 2
    assert "never be restored" in found[0].message
    assert "never donates" in found[1].message


def test_r6_accepts_paired_and_suppressed():
    src = """
        class Paired:
            def state_dict(self):
                return {}

            def load_state_dict(self, state):
                pass

        class Justified:
            def load_state_dict(self, state):  # repro-lint: disable=R6
                pass
    """
    assert _rules(src, "R6") == []


def test_r6_inherited_half_does_not_pair():
    # Inheriting one half does not satisfy the pairing: the serialized
    # shape is the defining class's business, so a subclass overriding
    # only load_state_dict is flagged.
    src = """
        class Base:
            def state_dict(self):
                return {}

            def load_state_dict(self, state):
                pass

        class Child(Base):
            def load_state_dict(self, state):
                pass
    """
    found = _rules(src, "R6")
    assert len(found) == 1
    assert "Child" in found[0].message


# ------------------------------------------------------- driver / repo gate
def test_repo_lints_clean():
    """The merge gate: `python -m repro.analysis.lint src/` exits 0."""
    assert lint_main([str(REPO / "src")]) == 0


def test_lint_cli_exit_codes(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("import jax\n\n@jax.jit\ndef f(x):\n    return x\n")
    env_cmd = [sys.executable, "-m", "repro.analysis.lint", str(bad)]
    proc = subprocess.run(env_cmd, capture_output=True, text=True,
                          cwd=str(REPO))
    assert proc.returncode == 1
    assert "R2" in proc.stdout
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", "--list-rules"],
        capture_output=True, text=True, cwd=str(REPO))
    assert proc.returncode == 0
    assert all(r in proc.stdout
               for r in ("R1", "R2", "R3", "R4", "R5", "R6"))


def test_lint_file_select(tmp_path):
    from repro.analysis.rules import all_rules
    bad = tmp_path / "bad.py"
    bad.write_text("import jax\ng = jax.jit(lambda x: x)\n")
    only_r1 = [r for r in all_rules() if r.rule_id == "R1"]
    assert lint_file(bad, only_r1) == []
    assert len(lint_file(bad)) == 1


# ----------------------------------------------------------- lifecycle unit
def test_lifecycle_legal_path():
    mon = LifecycleMonitor()
    for state in ("queued", "admitted", "active", "retiring", "drained"):
        mon.transition(7, state)
    assert mon.state(7) == DRAINED
    mon.assert_all_drained()


def test_lifecycle_out_of_order_raises_with_history():
    mon = LifecycleMonitor()
    mon.transition(3, "queued")
    mon.transition(3, "admitted")
    with pytest.raises(InvariantViolation) as exc:
        mon.transition(3, "drained")    # skipped retiring
    assert "queued -> admitted" in str(exc.value)
    assert mon.state(3) == ADMITTED     # rejected transition did not apply


def test_lifecycle_stuck_request_fails_idle_audit():
    mon = LifecycleMonitor()
    mon.transition(1, "queued")
    mon.transition(1, "admitted")
    with pytest.raises(InvariantViolation, match="not drained"):
        mon.assert_all_drained()


# -------------------------------------------------------------- ledger unit
def _allocated_pair():
    alloc = BlockAllocator(8, 4)
    ledger = ShadowLedger()
    alloc.observer = ledger
    return alloc, ledger


def test_ledger_mirrors_clean_lifecycle():
    alloc, ledger = _allocated_pair()
    alloc.alloc(0, 2, reserve=3)
    alloc.extend(0, 1)
    alloc.free(0)
    ledger.assert_idle(alloc)


def test_ledger_double_free():
    alloc, ledger = _allocated_pair()
    blocks = alloc.alloc(0, 2)
    alloc.free(0)
    with pytest.raises(InvariantViolation, match="double free"):
        ledger.on_event("free_enter", rid=0, table=blocks)


def test_ledger_leak_at_idle():
    alloc, ledger = _allocated_pair()
    alloc.alloc(0, 2)
    with pytest.raises(InvariantViolation, match="leak|allocations"):
        ledger.assert_idle(alloc)


def test_ledger_free_while_request_active():
    # PR 8's use-after-free window: blocks freed while the request's
    # dispatch may still be writing into them (lifecycle not retiring)
    lifecycle = LifecycleMonitor()
    alloc = BlockAllocator(8, 4)
    ledger = ShadowLedger(lifecycle)
    alloc.observer = ledger
    lifecycle.transition(5, "queued")
    alloc.alloc(5, 2)
    lifecycle.transition(5, "admitted")
    with pytest.raises(InvariantViolation, match="use-after-free"):
        alloc.free(5)       # never transitioned to retiring


def test_ledger_cache_ref_pairing():
    alloc, ledger = _allocated_pair()
    blocks = alloc.alloc(0, 2)
    alloc.cache_ref(blocks)
    assert alloc.free(0) == []          # cache still holds both
    assert sorted(alloc.cache_unref(blocks)) == sorted(blocks)
    ledger.assert_idle(alloc)


def test_ledger_poison_probe():
    import numpy as np
    ledger = ShadowLedger()
    cache = {"k": np.zeros((2, 8, 4, 2, 4)), "v": np.zeros((2, 8, 4, 2, 4))}
    ledger.on_scrubbed([3])
    ledger.check_poison(cache)          # all-zero: clean
    cache["k"][0, 3, 1] = 1.0           # stray write into freed block
    with pytest.raises(InvariantViolation, match="use-after-free write"):
        ledger.check_poison(cache)


# ------------------------------------------------------------- retrace unit
def _fake_fns(counts):
    def member(name):
        fn = lambda *a, **k: None                      # noqa: E731
        fn._cache_size = lambda: counts[name]
        return fn
    return types.SimpleNamespace(
        prefill=member("prefill"), fused_step=member("fused_step"),
        suffix_buckets=())


def test_retrace_monitor_deltas():
    counts = {"prefill": 1, "fused_step": 1}
    mon = RetraceMonitor(_fake_fns(counts))
    mon.check()                         # no compiles since attach
    counts["fused_step"] += 1           # one compile: within manifest
    mon.check()
    counts["fused_step"] += 1           # second compile: retrace
    with pytest.raises(InvariantViolation, match="retrace"):
        mon.check()


def test_retrace_manifest_override():
    counts = {"prefill": 0, "fused_step": 0}
    mon = RetraceMonitor(_fake_fns(counts), manifest={"prefill": 3})
    counts["prefill"] = 3
    mon.check()
    counts["prefill"] = 4
    with pytest.raises(InvariantViolation):
        mon.check()


# ------------------------------------------------- serving-level integration
@pytest.fixture(scope="module")
def paged_fns():
    import jax
    from repro.models.transformer import TransformerConfig, init_params
    from repro.serving.session import make_session_fns
    cfg = TransformerConfig(n_layers=1, d_model=32, n_heads=4, n_kv_heads=2,
                            d_ff=64, vocab_size=53, max_seq_len=160)
    params = init_params(cfg, jax.random.key(11))
    return make_session_fns(cfg, params, slots=9, prefill_len=32,
                            kv_layout="paged", block_size=8)


def _mk_sched(fns, **kw):
    from repro.core import LookaheadConfig
    from repro.serving.scheduler import ContinuousScheduler
    la = LookaheadConfig(decoding_length=8, branch_length=4)
    return ContinuousScheduler(fns, la, lanes=2, prefill_len=32, **kw)


def _prompts(n, seed):
    import numpy as np
    rng = np.random.RandomState(seed)
    return [rng.randint(1, 52, size=rng.randint(4, 26)).tolist()
            for _ in range(n)]


def test_sanitized_run_bit_identical_and_audited(paged_fns):
    """sanitize=True changes nothing about outputs, and a full run ends
    with the idle audit (lifecycles drained, ledger matched, retrace
    manifest honored) having passed."""
    prompts = _prompts(5, seed=21)
    outs = {}
    for sanitize in (False, True):
        sched = _mk_sched(paged_fns, sanitize=sanitize, scrub_freed=True,
                          overlap_drafts=True, prefix_cache=True)
        rids = [sched.submit(p, 12) for p in prompts]
        sched.run()
        outs[sanitize] = [sched.results[r].tokens for r in rids]
    assert outs[True] == outs[False]


def test_sanitizer_default_off_not_even_imported(paged_fns):
    sched = _mk_sched(paged_fns)
    assert sched.sanitizer is None
    assert sched.allocator.observer is None


def test_cancel_of_pending_holds_blocks_until_deferred_drain(paged_fns):
    """Regression pin for PR 8's cancel use-after-free fix, via the
    lifecycle machine: cancelling an overlap admission whose prefill is
    still in flight must leave the request in `retiring` WITH its blocks
    still owned (nothing may re-allocate them under the in-flight
    dispatch); the deferred drain then frees the blocks and moves it to
    `drained`."""
    prompts = _prompts(3, seed=22)
    sched = _mk_sched(paged_fns, sanitize=True, scrub_freed=True,
                      overlap_drafts=True)
    r0 = sched.submit(prompts[0], 12)
    sched.step()                         # initial cohort: r0 active
    r1 = sched.submit(prompts[1], 12)
    sched._admit()                       # overlap: r1's prefill in flight
    assert 1 in sched._pending and sched._pending[1].rid == r1
    assert sched.cancel(r1)
    san = sched.sanitizer
    # the fix under test: retiring (blocks HELD), not drained (blocks freed)
    assert san.lifecycle.state(r1) == "retiring"
    assert sched.allocator.owns(r1)
    assert r1 in sched.results and sched.results[r1].cancelled
    sched.run()                          # deferred drain runs + idle audit
    assert san.lifecycle.history(r1) == ["queued", "admitted", "retiring",
                                         "drained"]
    assert not sched.allocator.owns(r1)
    assert sched.results[r0].tokens      # survivor unharmed


def test_premature_free_of_pending_raises(paged_fns):
    """The sanitizer actually catches the PR 8 bug shape: freeing a
    pending admission's blocks at cancel time (instead of deferring to
    the drain) trips the ledger's use-after-free gate."""
    prompts = _prompts(2, seed=23)
    sched = _mk_sched(paged_fns, sanitize=True, scrub_freed=True,
                      overlap_drafts=True)
    sched.submit(prompts[0], 12)
    sched.step()
    r1 = sched.submit(prompts[1], 12)
    sched._admit()
    assert sched._pending[1].rid == r1   # prefill in flight
    with pytest.raises(InvariantViolation, match="use-after-free"):
        sched.allocator.free(r1)         # the buggy pre-PR-8 teardown
