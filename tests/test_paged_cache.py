"""Paged KV-cache tests: layout/shape contracts, block-table attention
parity (gather path vs the Pallas streaming kernel), scheduler losslessness
vs the dense layout, reset-slot hygiene under block reuse, and compile-once
shapes (I2) for the paged step functions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LookaheadConfig, reference_decode
from repro.models import transformer as tx
from repro.models.attention import build_full_tree_mask
from repro.serving.scheduler import ContinuousScheduler
from repro.serving.session import make_session_fns

pytestmark = pytest.mark.paged

PREFILL = 32


def _model(seed=0, max_seq_len=160):
    cfg = tx.TransformerConfig(n_layers=2, d_model=32, n_heads=4,
                               n_kv_heads=2, d_ff=64, vocab_size=53,
                               max_seq_len=max_seq_len)
    return cfg, tx.init_params(cfg, jax.random.key(seed))


def _prompts(n, lo=4, hi=24, vocab=52, seed=0):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(1, vocab, size=rng.randint(lo, hi)))
            for _ in range(n)]


# ------------------------------------------------------------------- layout
def test_init_paged_cache_shapes_and_axes():
    cfg, _ = _model()
    cfg = tx.TransformerConfig(**{**cfg.__dict__, "kv_layout": "paged",
                                  "kv_block_size": 16})
    assert tx.blocks_per_lane(cfg) == 10          # ceil(160 / 16)
    cache = tx.init_paged_cache(cfg, lanes=3, n_blocks=7)
    assert cache["k"].shape == (2, 7, 16, 2, 8)
    assert cache["v"].shape == (2, 7, 16, 2, 8)
    assert cache["block_tables"].shape == (3, 10)
    assert cache["block_tables"].dtype == jnp.int32
    axes = tx.cache_logical_axes(cfg)
    assert set(axes) == {"k", "v", "block_tables"}
    # default pool = dense-equivalent worst case + NULL block
    assert tx.init_paged_cache(cfg, lanes=2)["k"].shape[1] == 1 + 2 * 10


def test_paged_row_index_maps_through_tables():
    bt = jnp.asarray([[3, 1, 0], [2, 0, 0]], jnp.int32)
    pos = jnp.asarray([[0, 5, 16, 21], [1, 15, 16, 40]], jnp.int32)
    rows = tx.paged_row_index(bt, pos, 16)
    # lane 0: block 3 rows 0,5; block 1 rows 0,5
    np.testing.assert_array_equal(np.asarray(rows[0]), [48, 53, 16, 21])
    # lane 1: block 2 rows 1,15; block 0 (NULL) row 0; past-coverage
    # positions clip to the last table entry (NULL) -> garbage rows
    np.testing.assert_array_equal(np.asarray(rows[1]), [33, 47, 0, 8])


# ------------------------------------------------------------ kernel parity
@pytest.mark.kernels
@pytest.mark.parametrize("dh,bs", [(8, 16), (16, 8), (8, 32)])
def test_paged_kernel_matches_gather_reference(dh, bs):
    """paged_tree_attention == dense attention over the gathered cache."""
    from repro.kernels.tree_attention.paged import paged_tree_attention
    from repro.models.layers import gqa_attention

    rng = np.random.RandomState(0)
    B, T, H, K, nb, bpl = 3, 5, 4, 2, 9, 4
    S_virtual = bpl * bs
    q = jnp.asarray(rng.randn(B, T, H, dh), jnp.float32)
    k_cache = jnp.asarray(rng.randn(nb, bs, K, dh), jnp.float32)
    v_cache = jnp.asarray(rng.randn(nb, bs, K, dh), jnp.float32)
    # distinct physical blocks per lane; lane 2 mostly NULL
    bt = jnp.asarray([[1, 2, 3, 0], [4, 5, 6, 7], [8, 0, 0, 0]], jnp.int32)
    lens = jnp.asarray([bs + 3, 2 * bs + 1, 4], jnp.int32)
    tree = np.zeros((B, T, T), dtype=bool)
    for b in range(B):
        tree[b] = np.tril(rng.rand(T, T) < 0.7) | np.eye(T, dtype=bool)
    mask = build_full_tree_mask(lens, jnp.asarray(tree), S_virtual)

    out = paged_tree_attention(q, k_cache, v_cache, bt, mask)

    flat = k_cache.reshape(nb * bs, K, dh)
    flatv = v_cache.reshape(nb * bs, K, dh)
    pos = jnp.broadcast_to(jnp.arange(S_virtual)[None], (B, S_virtual))
    rows = tx.paged_row_index(bt, pos, bs)
    kg = jnp.take(flat, rows.reshape(-1), axis=0).reshape(B, S_virtual, K, dh)
    vg = jnp.take(flatv, rows.reshape(-1), axis=0).reshape(B, S_virtual, K,
                                                           dh)
    ref = gqa_attention(q, kg, vg, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------- prefill/commit I3
def test_paged_prefill_matches_dense_rows():
    """Admitting through block tables writes the same KV a dense prefill
    would, modulo the block permutation."""
    cfg, params = _model()
    pcfg = tx.TransformerConfig(**{**cfg.__dict__, "kv_layout": "paged",
                                   "kv_block_size": 16})
    prompts = _prompts(2, lo=10, hi=30, seed=5)
    toks = np.zeros((2, PREFILL), dtype=np.int32)
    lens = np.zeros((2,), dtype=np.int32)
    for b, p in enumerate(prompts):
        toks[b, :len(p)] = p
        lens[b] = len(p)
    dense_cache, dense_last = tx.prefill(cfg, params, jnp.asarray(toks),
                                         jnp.asarray(lens),
                                         tx.init_cache(cfg, 2))
    cache = tx.init_paged_cache(pcfg, lanes=2, n_blocks=9)
    bt = np.zeros((2, tx.blocks_per_lane(pcfg)), np.int32)
    bt[0, :3] = [2, 7, 1]
    bt[1, :3] = [5, 3, 8]
    cache["block_tables"] = jnp.asarray(bt)
    cache, last = tx.prefill_paged(pcfg, params, jnp.asarray(toks),
                                   jnp.asarray(lens), cache)
    np.testing.assert_allclose(np.asarray(last), np.asarray(dense_last),
                               rtol=1e-5, atol=1e-5)
    kf = np.asarray(cache["k"]).reshape(2, 9 * 16, 2, 8)
    rows = np.asarray(tx.paged_row_index(
        jnp.asarray(bt), jnp.arange(PREFILL)[None].repeat(2, 0), 16))
    for b in range(2):
        n = int(lens[b])
        np.testing.assert_allclose(kf[:, rows[b, :n]].transpose(0, 1, 2, 3),
                                   np.asarray(dense_cache["k"])[:, b, :n],
                                   rtol=1e-5, atol=1e-5)


# ------------------------------------------------- scheduler losslessness
@pytest.mark.parametrize("backend", ["dense", "pallas", "flash_decode"])
def test_paged_scheduler_lossless_per_backend(backend):
    """Paged serving equals reference decode through the same backend AND
    equals the dense layout bit-for-bit (the tentpole's I1 contract)."""
    cfg, params = _model(seed=3)
    prompts = _prompts(4, seed=21)
    la = LookaheadConfig(decoding_length=8, branch_length=4)
    outs = {}
    for layout in ("dense", "paged"):
        fns = make_session_fns(cfg, params, slots=9, prefill_len=PREFILL,
                               backend=backend, kv_layout=layout,
                               block_size=16)
        refs = [reference_decode(fns, p, 12) for p in prompts]
        sched = ContinuousScheduler(fns, la, lanes=2, prefill_len=PREFILL)
        for p in prompts:
            sched.submit(p, 12)
        res = sched.run()
        for r, ref in zip(res, refs):
            assert r.tokens == ref, (layout, backend)
        outs[layout] = [r.tokens for r in res]
    assert outs["paged"] == outs["dense"]


def test_paged_sampling_lossless():
    """Position-keyed sampling is layout-independent too."""
    cfg, params = _model(seed=2)
    prompts = _prompts(4, seed=13)
    fns = make_session_fns(cfg, params, sample=True, temperature=0.8,
                           base_key=jax.random.key(7), slots=9,
                           prefill_len=PREFILL, kv_layout="paged",
                           block_size=8)
    refs = [reference_decode(fns, p, 14) for p in prompts]
    sched = ContinuousScheduler(fns, LookaheadConfig(decoding_length=8,
                                                     branch_length=4),
                                lanes=2, prefill_len=PREFILL)
    for p in prompts:
        sched.submit(p, 14)
    for r, ref in zip(sched.run(), refs):
        assert r.tokens == ref


# ----------------------------------------------------- reset-slot hygiene
def test_paged_reset_scrubs_freed_blocks_only():
    """reset_blocks zeroes exactly the named physical blocks (NULL-padded
    ids are harmless); other requests' blocks are untouched."""
    cfg, params = _model()
    pcfg = tx.TransformerConfig(**{**cfg.__dict__, "kv_layout": "paged",
                                   "kv_block_size": 16})
    cache = tx.init_paged_cache(pcfg, lanes=2, n_blocks=6)
    filled = {k: (jnp.ones_like(v) if k != "block_tables" else v)
              for k, v in cache.items()}
    out = tx.reset_blocks(filled, np.asarray([2, 4, 0, 0], np.int32))
    k = np.asarray(out["k"])
    assert not k[:, 2].any() and not k[:, 4].any()
    for blk in (1, 3, 5):
        assert k[:, blk].all()


def test_paged_finish_admit_interleave_with_scrub():
    """Regression for reset hygiene: with scrub-on-free enabled, a pool so
    small that a finishing request's blocks are immediately re-allocated to
    the next admission must not scrub the new request's KV.  (A lane/table-
    keyed scrub after re-allocation would; the scheduler scrubs by physical
    id at free time instead.)"""
    cfg, params = _model(seed=4)
    prompts = _prompts(6, lo=4, hi=20, seed=33)
    budgets = [2, 10, 1, 8, 3, 6]      # instant finishes interleave admits
    la = LookaheadConfig(decoding_length=8, branch_length=4)
    fns = make_session_fns(cfg, params, slots=9, prefill_len=PREFILL,
                           kv_layout="paged", block_size=16, n_blocks=7)
    refs = [reference_decode(fns, p, m) for p, m in zip(prompts, budgets)]
    sched = ContinuousScheduler(fns, la, lanes=2, prefill_len=PREFILL,
                                scrub_freed=True)
    for p, m in zip(prompts, budgets):
        sched.submit(p, m)
    res = sched.run()
    assert len(res) == len(prompts)
    for r, ref in zip(res, refs):
        assert r.tokens == ref
    # blocks really were recycled across requests (the hazard was live)
    assert sched.stats.admitted == len(prompts)
    assert sched.stats.peak_blocks <= 6
    # paged sessions must not expose the lane-keyed scrub at all
    assert fns.reset_slot is None and fns.reset_blocks is not None


def test_paged_near_max_prompt_raises_clearly():
    """Near-max-length prompts have no room for a tree step; dense degrades
    through the lock-step loop, paged (which has no lock-step fallback)
    must refuse with an actionable error instead of crashing incidentally."""
    from repro.core import LookaheadEngine
    cfg, params = _model(max_seq_len=64)
    la = LookaheadConfig(decoding_length=14, branch_length=4)
    prompt = list(range(1, 51))
    fns_d = make_session_fns(cfg, params, slots=la.slots)
    assert len(LookaheadEngine(fns_d, la).generate(prompt, 8).tokens) == 1
    fns_p = make_session_fns(cfg, params, slots=la.slots, kv_layout="paged",
                             block_size=16)
    with pytest.raises(ValueError, match="paged layout has no lock-step"):
        LookaheadEngine(fns_p, la).generate(prompt, 8)


# ------------------------------------------------------------ compile-once
def test_paged_step_fns_compile_once():
    """I2 for the paged layout: block-table edits change values, never
    shapes — one executable per step fn across varied workloads."""
    cfg, params = _model(seed=5)
    fns = make_session_fns(cfg, params, slots=9, prefill_len=PREFILL,
                           kv_layout="paged", block_size=16)
    la = LookaheadConfig(decoding_length=8, branch_length=4)
    for seed, n, budget in [(40, 5, 12), (41, 3, 7), (42, 4, 20)]:
        sched = ContinuousScheduler(fns, la, lanes=2, prefill_len=PREFILL)
        for p in _prompts(n, lo=4, hi=30, seed=seed):
            sched.submit(p, budget)
        sched.run()
    assert fns.prefill._cache_size() == 1
    assert fns.prefill_into_slot._cache_size() == 1
    assert fns.fused_step._cache_size() == 1
    assert fns.tree_step._cache_size() == 0   # unfused parity oracle only
    assert fns.commit._cache_size() == 0
