"""Distribution correctness on 8 virtual devices (subprocess so the main
test session keeps 1 device): flash-decode == dense, MoE EP == ref,
elastic checkpoint resharding, and the logical-axis rule translation."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.distributed.sharding import DEFAULT_RULES, logical_spec

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_logical_spec_divisibility_fallback():
    import jax
    # no mesh active → constrain is a no-op, spec helper still pure
    spec = logical_spec(("batch", None), shape=(7, 3), mesh=None,
                        rules=DEFAULT_RULES)
    assert tuple(spec) == (None, None)


@pytest.mark.slow
def test_flash_decode_equals_dense_8dev():
    _run_subprocess("""
        import jax, numpy as np, dataclasses
        import jax.numpy as jnp
        from repro.models import transformer as tx
        from repro.distributed.sharding import sharding_ctx
        cfg = tx.TransformerConfig(n_layers=2, d_model=64, n_heads=8,
                                   n_kv_heads=4, d_ff=128, vocab_size=97,
                                   max_seq_len=64)
        params = tx.init_params(cfg, jax.random.key(0))
        B, T = 2, 5
        rng = np.random.RandomState(0)
        lens = jnp.array([10, 7], dtype=jnp.int32)
        kf = rng.randn(2, B, 64, 4, 8).astype(np.float32) * 0.1
        cache = {"k": jnp.asarray(kf), "v": jnp.asarray(kf) * 0.5}
        toks = jnp.asarray(rng.randint(1, 97, (B, T)), jnp.int32)
        depth = jnp.asarray([[0, 1, 1, 2, 2]] * B, jnp.int32)
        pos = lens[:, None] + depth
        parent = [-1, 0, 0, 1, 2]
        m = np.zeros((T, T), bool)
        for i in range(T):
            j = i
            while j >= 0:
                m[i, j] = True; j = parent[j]
        mask = jnp.asarray(np.stack([m] * B))
        c1, l1 = tx.tree_step(cfg, params, dict(cache), lens, toks, pos, mask)
        cfg2 = dataclasses.replace(cfg, decode_backend="flash_decode")
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with sharding_ctx(mesh):
            fn = jax.jit(lambda c, le, t, p, mm:
                         tx.tree_step(cfg2, params, c, le, t, p, mm))
            c2, l2 = fn(dict(cache), lens, toks, pos, mask)
        assert np.allclose(np.asarray(l1), np.asarray(l2), atol=3e-5)
        assert np.allclose(np.asarray(c1["k"]), np.asarray(c2["k"]), atol=3e-5)
        assert np.allclose(np.asarray(c1["v"]), np.asarray(c2["v"]), atol=3e-5)
        print("OK")
    """)


@pytest.mark.slow
def test_moe_ep_equals_ref_8dev():
    _run_subprocess("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.models import moe as M
        rng = np.random.RandomState(0)
        N, D, E, F, k = 96, 16, 8, 24, 2
        x = jnp.asarray(rng.randn(N, D).astype(np.float32))
        wr = jnp.asarray(rng.randn(D, E).astype(np.float32) * 0.3)
        wg = jnp.asarray(rng.randn(E, D, F).astype(np.float32) * 0.2)
        wu = jnp.asarray(rng.randn(E, D, F).astype(np.float32) * 0.2)
        wd = jnp.asarray(rng.randn(E, F, D).astype(np.float32) * 0.2)
        ref = M.moe_ref(x, wr, wg, wu, wd, k)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        ep = M.moe_ep(x, wr, wg, wu, wd, k, capacity_factor=8.0, mesh=mesh)
        assert np.allclose(np.asarray(ref), np.asarray(ep), atol=1e-4)
        # gradients flow through the EP path (all_to_all transposes)
        g = jax.grad(lambda w: M.moe_ep(x, wr, w, wu, wd, k, 8.0,
                                        mesh).sum())(wg)
        assert np.isfinite(np.asarray(g)).all()
        print("OK")
    """)


@pytest.mark.slow
def test_elastic_checkpoint_reshard_8dev():
    _run_subprocess("""
        import jax, numpy as np, tempfile
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.training.checkpoint import CheckpointManager
        mesh8 = jax.make_mesh((4, 2), ("data", "model"))
        x = jnp.arange(64.0).reshape(8, 8)
        xs = jax.device_put(x, NamedSharding(mesh8, P("data", "model")))
        with tempfile.TemporaryDirectory() as d:
            m = CheckpointManager(d)
            m.save(1, {"w": xs}, logical_axes={"w": ("batch", "tensor")})
            # restore onto a DIFFERENT mesh shape (elastic: lost 4 devices)
            mesh4 = jax.make_mesh((2, 2), ("data", "model"),
                                  devices=jax.devices()[:4])
            out, step = m.restore({"w": x}, mesh=mesh4)
            assert step == 1
            np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(x))
            shard_shape = out["w"].sharding.shard_shape(out["w"].shape)
            assert shard_shape == (4, 4), shard_shape
        print("OK")
    """)


@pytest.mark.slow
def test_mesh_factory_shapes():
    _run_subprocess("""
        import jax
        from repro.launch.mesh import make_host_mesh
        m = make_host_mesh(data=4, model=2)
        assert dict(m.shape) == {"data": 4, "model": 2}
        print("OK")
    """)
