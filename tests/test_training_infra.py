import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import TransformerConfig, init_params, lm_loss
from repro.training import checkpoint as C
from repro.training import fault_tolerance as F
from repro.training.data import PROFILES, SyntheticCorpus, lm_train_batches
from repro.training.optimizer import adamw_init, clip_by_global_norm
from repro.training.train_step import make_train_step


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = TransformerConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                            d_ff=64, vocab_size=64)
    params = init_params(cfg, jax.random.key(0))
    loss = lambda p, b: lm_loss(cfg, p, b["tokens"], b["labels"])
    return cfg, params, loss


def test_overfit_single_batch(tiny_lm):
    cfg, params, loss = tiny_lm
    step = jax.jit(make_train_step(loss, lr=3e-3))
    opt = adamw_init(params)
    b = {k: jnp.asarray(v) for k, v in
         next(lm_train_batches(64, 8, 16, seed=0)).items()}
    first = None
    for i in range(25):
        params, opt, m = step(params, opt, b)
        first = first or float(m["loss"])
    assert float(m["loss"]) < first - 0.5


def test_grad_accum_equivalence(tiny_lm):
    cfg, params, loss = tiny_lm
    b = {k: jnp.asarray(v) for k, v in
         next(lm_train_batches(64, 8, 16, seed=1)).items()}
    s1 = jax.jit(make_train_step(loss, lr=1e-3, accum_steps=1))
    s2 = jax.jit(make_train_step(loss, lr=1e-3, accum_steps=4))
    p1, o1, m1 = s1(params, adamw_init(params), b)
    p2, o2, m2 = s2(params, adamw_init(params), b)
    # same data => same mean loss & near-identical update
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    d = max(float(jnp.abs(a - c).max()) for a, c in
            zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 1e-4, d


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-4)


def test_checkpoint_roundtrip_retention_integrity(tiny_lm):
    cfg, params, _ = tiny_lm
    with tempfile.TemporaryDirectory() as d:
        mgr = C.CheckpointManager(d, keep=2)
        opt = adamw_init(params)
        mgr.save(1, {"p": params, "o": opt})
        mgr.save(5, {"p": params, "o": opt}, blocking=False)
        mgr.wait()
        mgr.save(9, {"p": params, "o": opt})
        assert mgr.all_steps() == [5, 9]
        restored, step = mgr.restore({"p": params, "o": opt})
        assert step == 9
        for a, b in zip(jax.tree.leaves(restored["p"]),
                        jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # corrupt → integrity check raises
        path = os.path.join(d, "step_0000000009", "arrays.npz")
        raw = bytearray(open(path, "rb").read())
        raw[len(raw) // 2] ^= 0xFF
        open(path, "wb").write(bytes(raw))
        with pytest.raises(Exception):
            mgr.restore({"p": params, "o": opt}, step=9)


def test_resume_from_latest_continues_training(tiny_lm):
    cfg, params, loss = tiny_lm
    step = jax.jit(make_train_step(loss, lr=1e-3))
    b = {k: jnp.asarray(v) for k, v in
         next(lm_train_batches(64, 4, 16, seed=2)).items()}
    with tempfile.TemporaryDirectory() as d:
        mgr = C.CheckpointManager(d)
        opt = adamw_init(params)
        for i in range(3):
            params, opt, _ = step(params, opt, b)
        mgr.save(3, {"p": params, "o": opt})
        # simulate crash + restart
        restored, st = mgr.restore({"p": params, "o": opt})
        assert st == 3
        p2, o2, m = step(restored["p"], restored["o"], b)
        assert np.isfinite(float(m["loss"]))
        assert int(o2.step) == 4


def test_preemption_checkpoint_flow(tiny_lm):
    cfg, params, loss = tiny_lm
    h = F.PreemptionHandler().install()
    step = jax.jit(make_train_step(loss, lr=1e-3))
    opt = adamw_init(params)
    b = {k: jnp.asarray(v) for k, v in
         next(lm_train_batches(64, 4, 16, seed=3)).items()}
    with tempfile.TemporaryDirectory() as d:
        mgr = C.CheckpointManager(d)
        stopped_at = None
        for i in range(10):
            params, opt, _ = step(params, opt, b)
            if i == 4:
                h.trigger()          # deliver "SIGTERM"
            if h.preempted:
                mgr.save(i + 1, {"p": params})
                stopped_at = i + 1
                break
        assert stopped_at == 5
        assert mgr.latest_step() == 5
    h.uninstall()


def test_straggler_and_retry():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient collective failure")
        return 42

    assert F.retry(flaky, attempts=4, base_delay=0.001) == 42
    with pytest.raises(F.StragglerTimeout):
        F.run_with_timeout(lambda: time.sleep(1.0), 0.05, retries=1)
    assert F.run_with_timeout(lambda: 7, 1.0) == 7


def test_elastic_world_shapes():
    assert F.elastic_world(512, 16, prefer_pods=2) == (2, 16, 16)
    assert F.elastic_world(384, 16, prefer_pods=2) == (2, 8, 16)   # lost chips
    assert F.elastic_world(16, 16) == (1, 1, 16)
    with pytest.raises(ValueError):
        F.elastic_world(8, 16)


def test_corpus_profiles_stats():
    for name, prof in PROFILES.items():
        c = SyntheticCorpus(prof, 512, seed=1)
        pr, ans = c.sample()
        assert len(pr) == prof.prompt_len
        assert len(ans) == prof.answer_len
    # antrag must have much higher prompt-copy rate than dolly
    assert PROFILES["antrag"].copy_from_prompt > \
        PROFILES["dolly"].copy_from_prompt
