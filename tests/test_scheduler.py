"""Continuous-batching scheduler invariants (DESIGN.md §Scheduler).

I1: per-request losslessness — a request's tokens equal reference_decode
    output regardless of arrival order, slot assignment or co-batched
    requests (greedy AND position-keyed sample mode).
I2: fixed shapes — every StepFns member compiles exactly once per engine.
I3: the committed cache prefix of a lane equals the stepwise cache.
"""
import itertools

import jax
import numpy as np
import pytest

from repro.core import LookaheadConfig, LookaheadEngine, reference_decode
from repro.models.transformer import TransformerConfig, init_params
from repro.serving.scheduler import ContinuousScheduler
from repro.serving.session import make_session_fns

PREFILL = 48


@pytest.fixture(scope="module")
def fns():
    cfg = TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                            d_ff=128, vocab_size=101, max_seq_len=320)
    params = init_params(cfg, jax.random.key(0))
    return make_session_fns(cfg, params, slots=17, prefill_len=PREFILL)


@pytest.fixture(scope="module")
def sample_fns():
    cfg = TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                            d_ff=128, vocab_size=101, max_seq_len=320)
    params = init_params(cfg, jax.random.key(2))
    return make_session_fns(cfg, params, sample=True, temperature=0.8,
                            base_key=jax.random.key(7), slots=17,
                            prefill_len=PREFILL)


def _prompts(n, lo=8, hi=40, vocab=100, seed=0):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(1, vocab, size=rng.randint(lo, hi)))
            for _ in range(n)]


def _la(**kw):
    base = dict(decoding_length=16, branch_length=6)
    base.update(kw)
    return LookaheadConfig(**base)


def test_scheduler_lossless_any_arrival_order(fns):
    """I1: same outputs for every submission order of the same request set."""
    prompts = _prompts(4, seed=11)
    refs = [reference_decode(fns, p, 24) for p in prompts]
    for order in itertools.permutations(range(4)):
        sched = ContinuousScheduler(fns, _la(), lanes=2, prefill_len=PREFILL)
        rids = {}
        for i in order:
            rids[sched.submit(prompts[i], 24)] = i
        res = sched.run()
        for r in res:
            assert r.tokens == refs[rids[r.rid]], order


def test_scheduler_lossless_mixed_budgets(fns):
    """Short requests leave mid-flight; late requests join freed slots; every
    output still equals the (budget-truncated) reference."""
    prompts = _prompts(7, seed=12)
    budgets = [3, 28, 1, 9, 28, 2, 14]
    refs = [reference_decode(fns, p, m) for p, m in zip(prompts, budgets)]
    sched = ContinuousScheduler(fns, _la(), lanes=2, prefill_len=PREFILL)
    for p, m in zip(prompts, budgets):
        sched.submit(p, m)
    res = sched.run()
    assert len(res) == len(prompts)
    for r, ref in zip(res, refs):
        assert r.tokens == ref
    # the pool really was reused: more requests than lanes were admitted
    assert sched.stats.admitted == len(prompts)
    assert sched.stats.finished == len(prompts)
    assert sched.stats.occupancy > 0.5


def test_scheduler_lossless_sampling(sample_fns):
    """I1 in sample mode: the position-keyed RNG makes sampling a pure
    function of (key, absolute position, logits) — batch composition and
    slot assignment must not leak into the stream."""
    prompts = _prompts(5, seed=13)
    refs = [reference_decode(sample_fns, p, 20) for p in prompts]
    sched = ContinuousScheduler(sample_fns, _la(decoding_length=12),
                                lanes=2, prefill_len=PREFILL)
    for p in prompts:
        sched.submit(p, 20)
    for r, ref in zip(sched.run(), refs):
        assert r.tokens == ref


@pytest.mark.parametrize("backend", ["dense", "pallas", "flash_decode"])
def test_scheduler_lossless_per_backend(backend):
    """I1 holds under every attention backend: scheduler outputs equal
    reference_decode run through the SAME backend, and equal the dense
    outputs bit-for-bit (registry contract, DESIGN.md §Attention
    backends)."""
    cfg = TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                            d_ff=64, vocab_size=53, max_seq_len=160)
    params = init_params(cfg, jax.random.key(3))
    prompts = _prompts(4, lo=4, hi=24, vocab=52, seed=21)
    outs = {}
    for name in ("dense", backend):
        fns_b = make_session_fns(cfg, params, slots=9, prefill_len=32,
                                 backend=name)
        refs = [reference_decode(fns_b, p, 12) for p in prompts]
        sched = ContinuousScheduler(fns_b, _la(decoding_length=8,
                                               branch_length=4),
                                    lanes=2, prefill_len=32)
        for p in prompts:
            sched.submit(p, 12)
        res = sched.run()
        for r, ref in zip(res, refs):
            assert r.tokens == ref, name
        outs[name] = [r.tokens for r in res]
    assert outs[backend] == outs["dense"]


def test_engine_wrapper_routes_through_scheduler(fns):
    """generate/generate_batch keep their contract on the scheduler path and
    agree with the legacy lock-step loop."""
    prompts = _prompts(3, seed=14)
    eng = LookaheadEngine(fns, _la())
    outs = eng.generate_batch(prompts, 24)
    eng2 = LookaheadEngine(fns, _la())
    locks = eng2.generate_batch_lockstep(prompts, 24)
    for a, b in zip(outs, locks):
        assert a.tokens == b.tokens
    one = LookaheadEngine(fns, _la()).generate(prompts[0], 24)
    assert one.tokens == outs[0].tokens


def test_step_fns_compile_once():
    """I2: varying prompt lengths, budgets and request counts never retrace
    the jitted step functions — one executable per (lanes, T) /
    (lanes, prefill_len) / (1, prefill_len) shape.  The decode hot path is
    the single-dispatch ``fused_step``; ``tree_step``/``commit`` stay cold
    (they are the unfused parity oracle and the lock-step loop's surface)."""
    cfg = TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                            d_ff=64, vocab_size=53, max_seq_len=160)
    params = init_params(cfg, jax.random.key(5))
    fresh = make_session_fns(cfg, params, slots=9, prefill_len=PREFILL)
    la = _la(decoding_length=8, branch_length=4)
    # several scheduler generations with different workloads, same lanes
    for seed, n, budget in [(40, 5, 12), (41, 3, 7), (42, 4, 20)]:
        sched = ContinuousScheduler(fresh, la, lanes=2, prefill_len=PREFILL)
        for p in _prompts(n, lo=4, hi=40, vocab=52, seed=seed):
            sched.submit(p, budget)
        sched.run()
    assert fresh.prefill._cache_size() == 1           # (lanes, prefill_len)
    assert fresh.prefill_into_slot._cache_size() == 1  # (1, prefill_len)
    assert fresh.fused_step._cache_size() == 1         # (lanes, T)
    assert fresh.tree_step._cache_size() == 0          # parity oracle only
    assert fresh.commit._cache_size() == 0


@pytest.mark.parametrize("overlap", [False, True], ids=["serial", "overlap"])
def test_decode_hot_path_one_sync_per_step(fns, overlap):
    """The fused decode step makes exactly ONE device->host pull (the
    packed accept array) per decode step, serial and overlap mode alike;
    admission pulls stay off the decode counter."""
    prompts = _prompts(6, seed=31)
    sched = ContinuousScheduler(fns, _la(), lanes=2, prefill_len=PREFILL,
                                overlap_drafts=overlap)
    for p in prompts:
        sched.submit(p, 16)
    res = sched.run()
    assert len(res) == len(prompts)
    st = sched.stats
    assert st.decode_steps > 0
    assert st.decode_syncs == st.decode_steps
    assert st.syncs_per_decode_step == 1.0
    # total pulls = decode steps + one first-token pull per admission batch
    # (the initial cohort is one batched pull; mid-flight admissions pull
    # once each) — strictly fewer than 2 per decode step overall
    assert st.host_syncs <= st.decode_steps + st.admitted
    # breakdown accrues on every decode step
    br = st.breakdown()
    assert br["device_step_ms"] > 0.0
    assert br["syncs_per_step"] == 1.0


def test_overlap_mode_bit_identical_to_serial(fns):
    """overlap_drafts defers bookkeeping but never changes tokens: same
    request set through serial and overlap schedulers, same outputs, and
    both equal reference_decode (I1)."""
    prompts = _prompts(6, seed=33)
    budgets = [3, 24, 1, 15, 24, 8]
    refs = [reference_decode(fns, p, m) for p, m in zip(prompts, budgets)]
    outs = {}
    for overlap in (False, True):
        sched = ContinuousScheduler(fns, _la(), lanes=2, prefill_len=PREFILL,
                                    overlap_drafts=overlap)
        for p, m in zip(prompts, budgets):
            sched.submit(p, m)
        res = sched.run()
        assert len(res) == len(prompts)
        outs[overlap] = [r.tokens for r in res]
        for r, ref in zip(res, refs):
            assert r.tokens == ref, overlap
        assert sched.stats.finished == len(prompts)
        assert not sched._retired and not sched._pending
    assert outs[True] == outs[False]


def test_reset_slot_scrubs_one_lane_only():
    """reset_slot zeroes exactly the freed lane's KV rows (debug scrub; I3
    means correctness never depends on it)."""
    cfg = TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                            d_ff=64, vocab_size=53, max_seq_len=160)
    params = init_params(cfg, jax.random.key(6))
    fns = make_session_fns(cfg, params, slots=9, prefill_len=16)
    toks = np.asarray(_prompts(2, lo=10, hi=11, vocab=52, seed=50),
                      dtype=np.int32)
    toks = np.pad(toks, ((0, 0), (0, 16 - toks.shape[1])))
    lens = np.asarray([10, 10], dtype=np.int32)
    cache, _ = fns.prefill(toks, lens)
    before = {k: np.asarray(v).copy() for k, v in cache.items()}
    cache = fns.reset_slot(cache, 1)
    after = {k: np.asarray(v) for k, v in cache.items()}
    for k in ("k", "v"):
        np.testing.assert_array_equal(after[k][:, 0], before[k][:, 0])
        assert not after[k][:, 1].any()


def test_prefill_into_slot_matches_batched_prefill(fns):
    """I3 at admission: admitting request r into lane l writes the same KV
    rows a batched prefill would have put there."""
    prompts = _prompts(3, lo=6, hi=20, seed=15)
    toks = np.zeros((3, PREFILL), dtype=np.int32)
    lens = np.zeros((3,), dtype=np.int32)
    for b, p in enumerate(prompts):
        toks[b, :len(p)] = p
        lens[b] = len(p)
    cache_ref, roots_ref = fns.prefill(toks, lens)
    cache_ref = {k: np.asarray(v) for k, v in cache_ref.items()}
    roots_ref = np.asarray(roots_ref)

    cache = fns.init_cache(3)
    roots = []
    for lane in (2, 0, 1):   # deliberately out of order
        cache, r = fns.prefill_into_slot(
            cache, lane, toks[lane][None], lens[lane][None])
        roots.append((lane, int(np.asarray(r)[0])))
    for lane, root in roots:
        assert root == int(roots_ref[lane])
        n = int(lens[lane])
        np.testing.assert_allclose(
            np.asarray(cache["k"])[:, lane, :n],
            cache_ref["k"][:, lane, :n], rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(cache["v"])[:, lane, :n],
            cache_ref["v"][:, lane, :n], rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_cancel_of_pending_overlap_admission_defers_block_free(layout):
    """Satellite regression (use-after-free): cancelling a request whose
    overlap-mode admission prefill is still IN FLIGHT must finalize the
    host-visible side immediately but route the KV block free through the
    deferred-retirement queue — freeing at cancel time would let a
    same-iteration admission be handed block ids the in-flight prefill is
    still writing into.  Driven the only way it can happen in production:
    a co-resident request's first-token stream callback cancels a pending
    neighbor mid-settle."""
    cfg = TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                            d_ff=64, vocab_size=53, max_seq_len=160)
    params = init_params(cfg, jax.random.key(9))
    kw = (dict(kv_layout="paged", block_size=16, n_blocks=24)
          if layout == "paged" else {})
    fns = make_session_fns(cfg, params, slots=9, prefill_len=32, **kw)
    la = _la(decoding_length=8, branch_length=4)
    prompts = _prompts(4, lo=6, hi=20, vocab=52, seed=61)
    budgets = [30, 8, 8, 10]
    refs = [reference_decode(fns, p, m) for p, m in zip(prompts, budgets)]

    from repro.core.request import Request, SamplingParams
    sched = ContinuousScheduler(fns, la, lanes=3, prefill_len=32,
                                overlap_drafts=True, scrub_freed=True)

    def _submit(i):
        return sched.submit_request(Request(
            prompt=list(prompts[i]),
            params=SamplingParams(max_new_tokens=budgets[i])))

    ha = _submit(0)
    sched.step()                 # initial cohort: A active on lane 0
    assert sched.n_active == 1

    hb, hc = _submit(1), _submit(2)
    seen = {}

    def on_b_token(delta):
        if seen:
            return
        # fires inside _decode's pending-settle loop: C is still a pending
        # admission whose prefill dispatch is in flight on device
        assert any(rs.rid == hc.rid for rs in sched._pending.values())
        res = hc.cancel()
        seen["result"] = res
        seen["retired"] = any(rs.rid == hc.rid for rs in sched._retired)
        if sched.allocator is not None:
            # the bug under test: blocks must STILL be owned here — the
            # deferred free runs behind the next device dispatch
            seen["owned_at_cancel"] = sched.allocator.owns(hc.rid)

    hb.on_token(on_b_token)
    sched.step()                 # B+C go pending; B's settle cancels C
    assert seen, "B's first-token callback never fired"
    assert seen["result"].cancelled
    assert seen["result"].finish_reason == "cancelled"
    assert seen["retired"]
    if layout == "paged":
        assert seen["owned_at_cancel"]
        # the deferred free drained inside that same step's flight window
        assert not sched.allocator.owns(hc.rid)
    assert hc.done and hc.cancel() is seen["result"]   # idempotent
    assert not any(rs.rid == hc.rid for rs in sched._pending.values())

    # the freed lane is reusable: D admits into it and stays lossless
    hd = _submit(3)
    sched.run()
    assert ha.result().tokens == refs[0]
    assert hb.result().tokens == refs[1]
    assert hd.result().tokens == refs[3]
    assert not sched._retired and not sched._pending
    if layout == "paged":
        assert not sched.allocator._tables      # every rid fully released
    ns = sched.stats.ns("")
    assert ns.cancelled == 1 and ns.finished == 4


def test_breakdown_accrues_per_rider_steps(fns):
    """Satellite regression (telemetry skew): a request's per-phase ms are
    the SUM of the measured splits of exactly the decode steps it rode —
    not a whole-run mean apportioned to everyone.  A short request
    co-resident with a long one must report only its own steps' time, and
    the overlap mode's hidden host ms must show up per request too."""
    from repro.core.request import Request, SamplingParams
    from repro.core.draft_sources import DraftPolicy
    prompts = _prompts(2, seed=62)
    sched = ContinuousScheduler(fns, _la(), lanes=2, prefill_len=PREFILL,
                                record_breakdown=True)
    hs = sched.submit_request(Request(prompt=prompts[0], params=SamplingParams(
        max_new_tokens=4, draft=DraftPolicy(namespace="a"))))
    hl = sched.submit_request(Request(prompt=prompts[1], params=SamplingParams(
        max_new_tokens=28, draft=DraftPolicy(namespace="b"))))
    sched.run()
    short, long_ = hs.result(), hl.result()
    k = short.stats.steps - 1          # decode steps (start() counts one)
    n = long_.stats.steps - 1
    assert 0 < k < n == len(sched.step_breakdown)
    for field in ("host_draft_ms", "device_step_ms", "accept_commit_ms"):
        assert getattr(short.stats, field) == pytest.approx(
            sum(e[field] for e in sched.step_breakdown[:k]), rel=1e-9), field
        assert getattr(long_.stats, field) == pytest.approx(
            sum(e[field] for e in sched.step_breakdown), rel=1e-9), field
    # the long request rode more wall time than the short one
    assert long_.stats.device_step_ms > short.stats.device_step_ms
    # per-namespace lane-step accounting matches the ride counts
    assert sched.stats.ns("a").lane_steps == k
    assert sched.stats.ns("b").lane_steps == n

    # overlap mode: hidden host ms (bookkeeping drained inside the flight
    # window) accrues on the riders of the draining steps — it was dropped
    # entirely by the old global-mean stamping
    sched2 = ContinuousScheduler(fns, _la(), lanes=2, prefill_len=PREFILL,
                                 overlap_drafts=True, record_breakdown=True)
    h2s = sched2.submit_request(Request(
        prompt=prompts[0], params=SamplingParams(max_new_tokens=4)))
    h2l = sched2.submit_request(Request(
        prompt=prompts[1], params=SamplingParams(max_new_tokens=28)))
    sched2.run()
    assert h2l.result().stats.hidden_host_ms > 0.0
    assert h2l.result().stats.hidden_host_ms == pytest.approx(
        sum(e["hidden_host_ms"] for e in sched2.step_breakdown), rel=1e-9)
