"""Per-assigned-architecture smoke tests: REDUCED same-family config, one
forward / train step on CPU, asserting shapes + finiteness.  The FULL configs
are exercised via the dry-run only (ShapeDtypeStructs, no allocation)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfgreg
from repro.models import transformer as tx
from repro.training.data import (batched_molecules, random_geometric_graph,
                                 seq_rec_batch, two_tower_batch,
                                 wide_deep_batch)

RNG = np.random.RandomState(0)
LM_ARCHS = ["phi3_mini_3_8b", "qwen2_1_5b", "phi3_medium_14b",
            "qwen3_moe_30b_a3b", "moonshot_v1_16b_a3b", "antglm_10b"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_and_serve(arch):
    mod = cfgreg.get_arch(arch)
    cfg = mod.smoke_config()
    full = mod.full_config()
    # smoke keeps family traits
    assert cfg.moe == full.moe and cfg.qkv_bias == full.qkv_bias
    params = tx.init_params(cfg, jax.random.key(0))
    B, S = 2, 16
    toks = jnp.asarray(RNG.randint(1, cfg.vocab_size, (B, S)), jnp.int32)
    logits = tx.train_logits(cfg, params, toks)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    loss = tx.lm_loss(cfg, params, toks, toks)
    assert np.isfinite(float(loss))
    # serve one tree step
    cache = tx.init_cache(cfg, B)
    cache, last = tx.prefill(cfg, params, toks, jnp.full((B,), S, jnp.int32),
                             cache)
    T = 5
    tree_toks = jnp.asarray(RNG.randint(1, cfg.vocab_size, (B, T)), jnp.int32)
    pos = jnp.full((B,), S, jnp.int32)[:, None] + jnp.arange(T)[None, :]
    mask = jnp.asarray(np.tril(np.ones((T, T), bool))[None].repeat(B, 0))
    cache, lg = tx.tree_step(cfg, params, cache, jnp.full((B,), S, jnp.int32),
                             tree_toks, pos, mask)
    assert lg.shape == (B, T, cfg.vocab_size)
    assert bool(jnp.isfinite(lg).all())


def test_equiformer_smoke():
    mod = cfgreg.get_arch("equiformer_v2")
    cfg = mod.smoke_config()
    from repro.models.gnn import equiformer as eq
    params = eq.init_params(cfg, jax.random.key(0))
    g = random_geometric_graph(RNG, 24, cfg.d_feat_in, max_edges=96)
    out = eq.forward(cfg, params, jnp.asarray(g["node_feat"]),
                     jnp.asarray(g["positions"]), jnp.asarray(g["edges"]),
                     jnp.asarray(g["edge_mask"]))
    assert out["node_out"].shape == (24, cfg.n_out)
    assert bool(jnp.isfinite(out["node_out"]).all())
    loss = eq.node_class_loss(cfg, params, {
        **{k: jnp.asarray(v) for k, v in g.items()},
        "labels": jnp.asarray(RNG.randint(0, cfg.n_out, (24,)), jnp.int32)})
    assert np.isfinite(float(loss))


def test_wide_deep_smoke():
    mod = cfgreg.get_arch("wide_deep")
    cfg = mod.smoke_config()
    from repro.models.recsys import wide_deep as wd
    params = wd.init_params(cfg, jax.random.key(0))
    b = wide_deep_batch(RNG, 8, cfg.n_sparse, cfg.rows_per_table,
                        cfg.multi_hot, cfg.n_dense)
    loss = wd.loss(cfg, params, {k: jnp.asarray(v) for k, v in b.items()})
    assert np.isfinite(float(loss))
    logits = wd.forward(cfg, params, jnp.asarray(b["sparse_ids"]),
                        jnp.asarray(b["sparse_mask"]),
                        jnp.asarray(b["dense"]))
    assert logits.shape == (8,) and bool(jnp.isfinite(logits).all())


def test_two_tower_smoke():
    mod = cfgreg.get_arch("two_tower_retrieval")
    cfg = mod.smoke_config()
    from repro.models.recsys import two_tower as tt
    params = tt.init_params(cfg, jax.random.key(0))
    b = two_tower_batch(RNG, 16, cfg.n_user_fields, cfg.n_item_fields,
                        cfg.rows_per_table)
    loss = tt.loss(cfg, params, {k: jnp.asarray(v) for k, v in b.items()})
    assert np.isfinite(float(loss))
    cand = jnp.asarray(RNG.randn(4096, cfg.tower_dims[-1]).astype(np.float32))
    scores, idx = tt.score_candidates(cfg, params,
                                      jnp.asarray(b["user_ids"][:1]), cand,
                                      k=16)
    assert scores.shape == (16,) and idx.shape == (16,)


@pytest.mark.parametrize("arch,causal", [("bert4rec", False),
                                         ("sasrec", True)])
def test_seq_rec_smoke(arch, causal):
    mod = cfgreg.get_arch(arch)
    cfg = mod.smoke_config()
    import importlib
    m = importlib.import_module(f"repro.models.recsys.{arch}")
    params = m.init_params(cfg, jax.random.key(0))
    b = seq_rec_batch(RNG, 4, cfg.seq_len, cfg.n_items, causal=causal)
    loss = m.loss(cfg, params, {k: jnp.asarray(v) for k, v in b.items()})
    assert np.isfinite(float(loss))
    scores = m.serve(cfg, params, jnp.asarray(b["ids"]),
                     jnp.asarray(b["pad_mask"]))
    assert scores.shape == (4, cfg.n_items)
    cand = jnp.asarray(RNG.randint(2, cfg.n_items, (4, 32)), jnp.int32)
    rank = m.serve(cfg, params, jnp.asarray(b["ids"]),
                   jnp.asarray(b["pad_mask"]), cand)
    assert rank.shape == (4, 32)


def test_molecule_batched_smoke():
    mod = cfgreg.get_arch("equiformer_v2")
    cfg = dataclasses.replace(mod.smoke_config(), n_out=1, node_level=False)
    from repro.models.gnn import equiformer as eq
    params = eq.init_params(cfg, jax.random.key(0))
    b = batched_molecules(RNG, 4, 10, cfg.d_feat_in, 24)
    loss = eq.energy_loss(cfg, params,
                          {k: jnp.asarray(v) for k, v in b.items()})
    assert np.isfinite(float(loss))


def test_all_assigned_cells_enumerated():
    cells = cfgreg.assigned_cells()
    assert len(cells) == 40
    archs = {a for a, _ in cells}
    assert len(archs) == 10
