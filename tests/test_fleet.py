"""repro.fleet (ISSUE 10): warm draft-state persistence, the
namespace-affinity router, gossip merge, and fleet bit-identity.

Property tests cover every state_dict/load_state_dict pair (round-trips
must be bit-identical down to retrieval order), the file format's
corruption/version rejects, and the gossip-merge CRDT-join laws (merged
frequency = element-wise max; shared capacity never exceeded).  The
end-to-end tests drive a real 2-replica in-process fleet on a tiny model
and assert every output token matches a single-replica reference (I1:
routing, gossip and warm state are pure performance policies).
"""
import json
import types

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import DraftPolicy, Request, SamplingParams
from repro.core.draft_sources import (AdaptiveBudget, NgramSource,
                                      PromptCopySource, TrieSource)
from repro.core.strategies import LookaheadConfig
from repro.core.trie import TrieForest, TrieTree
from repro.fleet import (DraftStateError, EngineReplica, FleetRouter,
                         GossipCoordinator)
from repro.fleet.persist import (collect_draft_state, install_draft_state,
                                 load_draft_state, save_draft_state)
from repro.models.transformer import init_params
from repro.serving.api import EngineConfig, ServingEngine, build_session_fns

from fleet_tiny import TINY_CFG as _CFG, TINY_ECFG as _ECFG, build_tiny

pytestmark = pytest.mark.fleet

_CHAIN = st.lists(st.integers(1, 30), min_size=1, max_size=6)
_CHAINS = st.lists(_CHAIN, min_size=1, max_size=12)


def _cfg() -> LookaheadConfig:
    return LookaheadConfig(decoding_length=8, branch_length=4)


def _walk(tree: TrieTree):
    """{root-path: freq} snapshot of a trie."""
    out = {}
    stack = [((), tree.root)]
    while stack:
        path, node = stack.pop()
        for tok, child in node.children.items():
            p = path + (tok,)
            out[p] = child.freq
            stack.append((p, child))
    return out


# ------------------------------------------------------ state round-trips
@settings(max_examples=25)
@given(_CHAINS)
def test_trie_state_roundtrip_bit_identical(chains):
    t = TrieTree(capacity=10_000)
    for c in chains:
        t.insert(c)
    sd = t.state_dict()
    t2 = TrieTree(capacity=10_000)
    t2.load_state_dict(sd)
    assert t2.state_dict() == sd          # serialization is a fixed point
    for ctx in chains + [[1], [2, 3], [30]]:
        assert t.retrieve(ctx, decoding_length=8) == \
            t2.retrieve(ctx, decoding_length=8)


@settings(max_examples=15)
@given(_CHAINS, _CHAINS)
def test_forest_state_roundtrip(chains_a, chains_b):
    f = TrieForest(capacity=10_000)
    for c in chains_a:
        f.tree("a").insert(c)
    for c in chains_b:
        f.tree("b").insert(c)
    sd = f.state_dict()
    f2 = TrieForest(capacity=10_000)
    f2.load_state_dict(sd)
    assert f2.state_dict() == sd
    assert len(f2) == len(f)
    for ctx in chains_a[:3]:
        assert f.tree("a").retrieve(ctx, decoding_length=8) == \
            f2.tree("a").retrieve(ctx, decoding_length=8)


def test_trie_source_roundtrip():
    src = TrieSource(_cfg())
    src.observe_prompt(1, [5, 6, 7, 8], namespace="docs")
    src.observe_output(1, [9, 10, 11], namespace="docs")
    src.end_request(1) if hasattr(src, "end_request") else None
    sd = src.state_dict()
    s2 = TrieSource(_cfg())
    s2.load_state_dict(sd)
    assert s2.state_dict() == sd
    assert s2.retrieve(2, [9, 10], budget=8, namespace="docs") == \
        src.retrieve(2, [9, 10], budget=8, namespace="docs")


def test_ngram_source_roundtrip():
    src = NgramSource(_cfg())
    rng = np.random.RandomState(3)
    for rid in range(4):
        toks = rng.randint(1, 20, size=24).tolist()
        src.observe_prompt(rid, toks)
        src.observe_output(rid, toks[::-1])
    sd = src.state_dict()
    s2 = NgramSource(_cfg())
    s2.load_state_dict(sd)
    assert s2.state_dict() == sd
    for ctx in ([1, 2, 3], [5, 6], [19]):
        assert s2.retrieve(9, ctx, budget=6) == src.retrieve(9, ctx, budget=6)


def test_stateless_source_rejects_foreign_state():
    src = PromptCopySource(_cfg())
    assert src.state_dict() == {}
    src.load_state_dict({})                      # empty is fine
    with pytest.raises(ValueError):
        src.load_state_dict({"kind": "trie", "forest": {}})


def test_trie_load_rejects_malformed():
    t = TrieTree()
    with pytest.raises(ValueError):
        t.load_state_dict({"tokens": [1], "parents": [], "freqs": [1.0]})
    with pytest.raises(ValueError):
        # parent pointing forward breaks the preorder contract
        t.load_state_dict({"tokens": [1, 2], "parents": [1, -1],
                           "freqs": [1.0, 1.0]})


# ----------------------------------------------------------- file format
def _payload():
    src = TrieSource(_cfg())
    src.observe_output(1, [3, 4, 5], namespace="docs")
    return {"sources": {"trie": src.state_dict()}}


def test_save_load_file_roundtrip(tmp_path):
    path = str(tmp_path / "state.json")
    save_draft_state(path, _payload())
    assert load_draft_state(path) == _payload()


def test_load_rejects_corruption(tmp_path):
    path = str(tmp_path / "state.json")
    save_draft_state(path, _payload())
    doc = json.loads(open(path).read())
    doc["payload"]["sources"]["trie"]["forest"]["namespaces"] = {}
    open(path, "w").write(json.dumps(doc))       # checksum now stale
    with pytest.raises(DraftStateError):
        load_draft_state(path)


def test_load_rejects_truncation_and_version(tmp_path):
    path = str(tmp_path / "state.json")
    save_draft_state(path, _payload())
    text = open(path).read()
    open(path, "w").write(text[:len(text) // 2])   # torn file
    with pytest.raises(DraftStateError):
        load_draft_state(path)
    doc = json.loads(text)
    doc["version"] = 2
    open(path, "w").write(json.dumps(doc))
    with pytest.raises(DraftStateError):
        load_draft_state(path)
    open(path, "w").write(json.dumps({"format": "other", "version": 1}))
    with pytest.raises(DraftStateError):
        load_draft_state(path)
    with pytest.raises(DraftStateError):
        load_draft_state(str(tmp_path / "absent.json"))


def test_install_rejects_unknown_source():
    sch = types.SimpleNamespace(sources={}, config=_cfg(), prefix=None)
    with pytest.raises(DraftStateError):
        install_draft_state(sch, {"sources": {"no-such-source": {"x": 1}}})


def test_collect_skips_stateless_and_installs_unseen():
    cfg = _cfg()
    trie = TrieSource(cfg)
    trie.observe_output(1, [3, 4, 5])
    sch = types.SimpleNamespace(
        sources={"trie": trie, "prompt_copy": PromptCopySource(cfg)},
        config=cfg, prefix=None)
    payload = collect_draft_state(sch)
    assert set(payload["sources"]) == {"trie"}    # stateless one skipped
    sch2 = types.SimpleNamespace(sources={}, config=cfg, prefix=None)
    install_draft_state(sch2, payload)            # creates via registry
    assert sch2.sources["trie"].retrieve(2, [3, 4], budget=8) == \
        trie.retrieve(2, [3, 4], budget=8)


# ----------------------------------------------------------- gossip merge
@settings(max_examples=15)
@given(_CHAINS, _CHAINS)
def test_merge_is_crdt_join(chains_a, chains_b):
    """merge(A, B): frequency = element-wise max over the union of
    branches (idempotent — a re-echoed snapshot never inflates), so
    repeated all-to-all gossip converges."""
    ta, tb = TrieTree(capacity=10_000), TrieTree(capacity=10_000)
    for c in chains_a:
        ta.insert(c)
    for c in chains_b:
        tb.insert(c)
    merged = TrieTree(capacity=10_000)
    merged.load_state_dict(ta.state_dict())
    merged.merge_state(tb.state_dict())
    wa, wb, wm = _walk(ta), _walk(tb), _walk(merged)
    assert set(wm) == set(wa) | set(wb)
    for path, freq in wm.items():
        assert freq == max(wa.get(path, 0.0), wb.get(path, 0.0))
    # idempotence: merging the same donor again changes nothing
    merged.merge_state(tb.state_dict())
    assert _walk(merged) == wm


@settings(max_examples=10)
@given(_CHAINS, _CHAINS)
def test_forest_merge_respects_capacity(chains_a, chains_b):
    f = TrieForest(capacity=24)
    for c in chains_a:
        f.tree("a").insert(c)
    donor = TrieForest(capacity=10_000)
    for c in chains_b:
        donor.tree("a").insert(c)
        donor.tree("b").insert(c)
    f.merge_state(donor.state_dict())
    assert len(f) <= f.capacity


def test_ngram_merge_is_max():
    a, b = NgramSource(_cfg()), NgramSource(_cfg())
    a.observe_output(1, [1, 2, 3, 1, 2, 3])      # high counts in a
    b.observe_output(2, [1, 2, 4])
    before = json.dumps(a.state_dict(), sort_keys=True)
    a.merge_state(a.state_dict())                # self-merge: no-op
    assert json.dumps(a.state_dict(), sort_keys=True) == before
    a.merge_state(b.state_dict())
    s = a.state_dict()
    a.merge_state(b.state_dict())                # idempotent
    assert a.state_dict() == s


def test_adaptive_budget_quota_cap():
    b = AdaptiveBudget(16, min_budget=4)
    for _ in range(8):
        b.update(16)                             # hot lane, wide budget
    assert b.value == 16
    assert b.cap(6) == 6                         # bandit gated the lane
    assert b.update(16) == 6                     # cap overrides the EMA
    assert b.cap(2) == 2                         # cap overrides min_budget
    b.quota_cap = None                           # sources recovered
    assert b.update(16) == 16


# ---------------------------------------------------------------- router
class _FakeRep:
    def __init__(self, i, depth=0):
        self.replica_id = f"r{i}"
        self.queue_depth = depth


def test_home_replica_deterministic_and_stable():
    r1 = FleetRouter([_FakeRep(i) for i in range(3)])
    r2 = FleetRouter([_FakeRep(i) for i in range(3)])
    for ns in ("docs", "code", "chat", "", "tenant-42"):
        assert r1.home_replica(ns) == r2.home_replica(ns)
    # adding a replica must not remap every namespace (consistent hashing)
    r4 = FleetRouter([_FakeRep(i) for i in range(4)])
    names = [f"ns{i}" for i in range(64)]
    moved = sum(r1.home_replica(n) != r4.home_replica(n) for n in names)
    assert moved < len(names)


def test_affinity_spills_at_queue_depth():
    reps = [_FakeRep(0), _FakeRep(1)]
    router = FleetRouter(reps, policy="affinity", max_queue_depth=2)
    ns = "docs"
    home = router.home_replica(ns)
    assert router.route(ns).replica == home
    reps[home].queue_depth = 2                   # home replica saturated
    p = router.route(ns)
    assert p.spilled and p.replica != home
    fs_spills = router._spills
    assert fs_spills == 1 and router._affinity_hits == 1
    # both saturated: still admits (backpressure shifts load, never rejects)
    reps[1 - home].queue_depth = 2
    assert router.route(ns).replica in (0, 1)


def test_round_robin_rotation():
    router = FleetRouter([_FakeRep(i) for i in range(3)],
                         policy="round_robin")
    assert [router.route("x").replica for _ in range(6)] == [0, 1, 2] * 2


def test_gossip_cadence():
    calls = []

    class _Rep(_FakeRep):
        def draft_state(self, *, max_prefix_keys=64):
            calls.append(("snap", self.replica_id))
            return {"sources": {}}

        def merge_draft_state(self, payload):
            calls.append(("merge", self.replica_id))

    g = GossipCoordinator([_Rep(0), _Rep(1)], every=3)
    fired = [g.tick() for _ in range(6)]
    assert fired == [False, False, True, False, False, True]
    assert g.exchanges == 2
    assert GossipCoordinator([_Rep(0), _Rep(1)], every=0).tick() is False
    with pytest.raises(ValueError):
        GossipCoordinator([], every=-1)


# ------------------------------------------------------------- end to end
@pytest.fixture(scope="module")
def tiny_fns():
    params = init_params(_CFG, jax.random.key(11))
    return build_session_fns(_ECFG, _CFG, params)


def _reqs(n, max_new=8, seed=0):
    rng = np.random.RandomState(seed)
    out = []
    for i in range(n):
        ns = ("docs", "code", "chat")[i % 3]
        policy = DraftPolicy(sources=("trie",), namespace=ns).validate()
        prompt = rng.randint(1, _CFG.vocab_size, size=12).tolist()
        out.append(Request(prompt=prompt, params=SamplingParams(
            max_new_tokens=max_new, draft=policy)))
    return out


def test_fleet_bit_identical_to_single(tiny_fns):
    reqs = _reqs(9)
    single = ServingEngine(tiny_fns, _ECFG)
    handles = [single.submit(Request(prompt=list(r.prompt),
                                     params=r.params)) for r in reqs]
    single.run()
    ref = [h.result().tokens for h in handles]

    for policy in ("affinity", "round_robin"):
        router = FleetRouter(
            [EngineReplica(lambda: ServingEngine(tiny_fns, _ECFG),
                           replica_id=f"r{i}") for i in range(2)],
            policy=policy)
        for r in reqs:
            router.submit(r.prompt, r.params)
        router.drain()
        assert [res["tokens"] for res in router.results()] == ref
        fs = router.fleet_stats()
        assert fs.routed == len(reqs)
        ns_sum = fs.namespace_summary()
        assert sum(row["finished"] for row in ns_sum.values()) == len(reqs)
        router.close()


def test_gossip_fleet_bit_identical(tiny_fns):
    reqs = _reqs(8)
    single = ServingEngine(tiny_fns, _ECFG)
    handles = [single.submit(Request(prompt=list(r.prompt),
                                     params=r.params)) for r in reqs]
    single.run()
    ref = [h.result().tokens for h in handles]

    replicas = [EngineReplica(lambda: ServingEngine(tiny_fns, _ECFG),
                              replica_id=f"r{i}") for i in range(2)]
    router = FleetRouter(replicas, policy="affinity")
    gossip = GossipCoordinator(replicas, every=2)
    for r in reqs:
        router.submit(r.prompt, r.params)
        router.step_all()
        gossip.tick()
    while not router.idle:
        router.step_all()
        gossip.tick()
    assert gossip.exchanges >= 1
    assert [res["tokens"] for res in router.results()] == ref
    router.close()


def test_warm_state_round_trip_through_engine(tiny_fns, tmp_path):
    path = str(tmp_path / "warm.json")
    reqs = _reqs(6)
    donor = ServingEngine(tiny_fns, _ECFG)
    handles = [donor.submit(Request(prompt=list(r.prompt),
                                    params=r.params)) for r in reqs]
    donor.run()
    ref = [h.result().tokens for h in handles]
    donor.save_draft_state(path)
    nodes = len(donor.scheduler.sources["trie"].forest)
    assert nodes > 0

    warm = ServingEngine(tiny_fns, _ECFG)
    warm.load_draft_state(path)
    assert len(warm.scheduler.sources["trie"].forest) == nodes
    handles = [warm.submit(Request(prompt=list(r.prompt),
                                   params=r.params)) for r in reqs]
    warm.run()
    assert [h.result().tokens for h in handles] == ref   # I1


def test_load_draft_state_requires_idle(tiny_fns, tmp_path):
    path = str(tmp_path / "warm.json")
    donor = ServingEngine(tiny_fns, _ECFG)
    donor.submit(_reqs(1)[0])
    donor.run()
    donor.save_draft_state(path)
    busy = ServingEngine(tiny_fns, _ECFG)
    busy.submit(_reqs(1)[0])
    with pytest.raises(RuntimeError):
        busy.load_draft_state(path)


def test_warm_prefix_priming_restores_hits(tmp_path):
    """Persisted prefix keys are re-prefilled on load, so the restarted
    engine's first requests hit the radix cache instead of re-prefilling
    the shared head from scratch."""
    params = init_params(_CFG, jax.random.key(11))
    ecfg = EngineConfig(lanes=2, prefill_len=32, decoding_length=8,
                        branch_length=4, kv_layout="paged", block_size=8,
                        n_blocks=64, prefix_cache=True)
    fns = build_session_fns(ecfg, _CFG, params)
    rng = np.random.RandomState(7)
    policy = DraftPolicy(sources=("trie",), namespace="docs").validate()
    prompts = [rng.randint(1, _CFG.vocab_size, size=24).tolist()
               for _ in range(2)]
    reqs = [Request(prompt=list(p), params=SamplingParams(
        max_new_tokens=6, draft=policy)) for p in prompts for _ in range(2)]

    donor = ServingEngine(fns, ecfg)
    handles = [donor.submit(Request(prompt=list(r.prompt),
                                    params=r.params)) for r in reqs]
    donor.run()
    ref = [h.result().tokens for h in handles]
    path = str(tmp_path / "warm.json")
    donor.save_draft_state(path)
    assert "prefix" in load_draft_state(path)

    warm = ServingEngine(fns, ecfg)
    warm.load_draft_state(path)
    base_hits = warm.scheduler.stats.prefix_hits
    handles = [warm.submit(Request(prompt=list(r.prompt),
                                   params=r.params)) for r in reqs]
    warm.run()
    assert [h.result().tokens for h in handles] == ref   # I1
    assert warm.scheduler.stats.prefix_hits > base_hits, \
        "primed prefix keys never produced a cache hit"


def test_subprocess_replica_matches_inproc():
    """One spawned-worker replica produces the same tokens as an
    in-process one (slow: spawns an interpreter; the builder compiles the
    tiny model inside the child)."""
    reqs = _reqs(2, max_new=4)
    inproc = EngineReplica(build_tiny, replica_id="a", mode="inproc")
    rids = [inproc.submit(r.prompt, r.params) for r in reqs]
    inproc.drain()
    ref = [inproc.result(rid)["tokens"] for rid in rids]
    sub = EngineReplica(build_tiny, replica_id="b", mode="subprocess")
    try:
        rids = [sub.submit(r.prompt, r.params) for r in reqs]
        sub.drain()
        assert [sub.result(rid)["tokens"] for rid in rids] == ref
        assert sub.stats_snapshot()["finished"] == len(reqs)
    finally:
        sub.close()


test_subprocess_replica_matches_inproc = pytest.mark.slow(
    test_subprocess_replica_matches_inproc)
