"""Request-centric serving API (DESIGN.md §Serving API).

Covers the ISSUE-4 acceptance surface:

  * per-request ``SamplingParams`` honored inside ONE co-batched scheduler
    run (mixed greedy + distinct temperatures/seeds + stop conditions),
    every request bit-identical to ``reference_decode`` under its own
    params, across the dense/paged × dense/pallas matrix;
  * streaming: concatenated handle deltas == ``result().tokens`` (iterator
    and callback styles);
  * ``cancel()`` mid-flight: lane + KV blocks released (allocator returns
    to empty), co-resident requests unperturbed;
  * compile-once (I2): per-lane params are traced inputs — mixed params
    never retrace;
  * lockstep-vs-continuous retirement alignment in the cache-overflow
    regime (the PR-3 known divergence, now pinned at the boundary);
  * user-input validation raises ValueError (not bare asserts).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (LookaheadConfig, LookaheadEngine, Request,
                        SamplingParams, reference_decode)
from repro.models.transformer import TransformerConfig, init_params
from repro.serving.api import EngineConfig, ServingEngine, build_engine
from repro.serving.scheduler import ContinuousScheduler

PREFILL = 32
VOCAB = 53
_CFG = TransformerConfig(n_layers=1, d_model=32, n_heads=4, n_kv_heads=2,
                         d_ff=64, vocab_size=VOCAB, max_seq_len=160)
_PARAMS = init_params(_CFG, jax.random.key(11))
_ECFG = EngineConfig(lanes=2, prefill_len=PREFILL, decoding_length=8,
                     branch_length=4)

CELLS = [("dense", "dense"), ("dense", "pallas"),
         ("paged", "dense"), ("paged", "pallas")]
_ENGINES = {}


def _engine(layout, backend) -> ServingEngine:
    key = (layout, backend)
    if key not in _ENGINES:
        _ENGINES[key] = build_engine(
            dataclasses.replace(_ECFG, kv_layout=layout, backend=backend,
                                block_size=8 if layout == "paged" else 64),
            _CFG, _PARAMS)
    return _ENGINES[key]


def _prompts(n, lo=4, hi=24, seed=0):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(1, VOCAB - 1, size=rng.randint(lo, hi)))
            for _ in range(n)]


def _mix(n, seed=0, max_new=16, stop_sequences=()):
    """Greedy + sampled params at distinct temperatures/seeds."""
    rng = np.random.RandomState(seed)
    out = []
    for i in range(n):
        if i % 2:
            out.append(SamplingParams(
                max_new_tokens=max_new, sample=True,
                temperature=float(rng.choice([0.3, 0.7, 1.1])),
                seed=int(rng.randint(0, 10_000)),
                stop_sequences=stop_sequences))
        else:
            out.append(SamplingParams(max_new_tokens=max_new,
                                      stop_sequences=stop_sequences))
    return out


# ---------------------------------------------------------------- mixed params
@pytest.mark.parametrize("layout,backend", CELLS)
def test_mixed_params_lossless_per_request(layout, backend):
    """Acceptance: mixed greedy + distinct temperatures co-batched in one
    lane pool, each request bit-identical to reference_decode under its own
    params, on every (kv layout, attention backend) cell."""
    eng = _engine(layout, backend)
    prompts = _prompts(5, seed=3)
    plist = _mix(5, seed=4)
    handles = [eng.submit(Request(prompt=p, params=q))
               for p, q in zip(prompts, plist)]
    eng.run()
    for h, p, q in zip(handles, prompts, plist):
        assert h.result().tokens == reference_decode(eng.fns, p, params=q), \
            (layout, backend, q)


def test_seed_controls_sampled_stream():
    """Distinct seeds give distinct streams; equal seeds equal streams
    (sampling is a pure function of (seed, position, logits))."""
    eng = _engine("dense", "dense")
    prompt = _prompts(1, lo=10, hi=11, seed=8)[0]
    outs = {}
    for seed in (1, 2):
        q = SamplingParams(max_new_tokens=16, sample=True, temperature=0.9,
                           seed=seed)
        outs[seed] = eng.submit(prompt, params=q).result().tokens
        assert outs[seed] == reference_decode(eng.fns, prompt, params=q)
    q1 = SamplingParams(max_new_tokens=16, sample=True, temperature=0.9,
                        seed=1)
    assert eng.submit(prompt, params=q1).result().tokens == outs[1]
    assert outs[1] != outs[2]   # astronomically unlikely to collide


# ------------------------------------------------------------------- streaming
def test_stream_deltas_concatenate_to_result():
    """(a) iterator and callback streams both reproduce result().tokens."""
    eng = _engine("dense", "dense")
    prompts = _prompts(4, seed=5)
    plist = _mix(4, seed=6)
    handles = [eng.submit(Request(prompt=p, params=q))
               for p, q in zip(prompts, plist)]
    cb_tokens = {h.rid: [] for h in handles}
    for h in handles:
        h.on_token(lambda d, r=h.rid: cb_tokens[r].extend(d))
    # iterate the FIRST handle (pumps the whole pool), then drain the rest
    it_tokens = list(handles[0])
    eng.run()
    assert it_tokens == handles[0].result().tokens
    for h in handles:
        assert cb_tokens[h.rid] == h.result().tokens
        assert h.tokens == h.result().tokens
        assert h.done


def test_on_token_replays_backlog():
    eng = _engine("dense", "dense")
    h = eng.submit(_prompts(1, seed=9)[0], max_new_tokens=8)
    res = h.result()
    late = []
    h.on_token(late.extend)     # registered after completion: full replay
    assert late == res.tokens


# ---------------------------------------------------------------------- cancel
def test_cancel_mid_flight_releases_blocks_and_lanes():
    """(c) a cancelled request frees lane + KV blocks (allocator returns to
    empty) and never perturbs co-resident outputs."""
    eng = build_engine(
        dataclasses.replace(_ECFG, kv_layout="paged", block_size=8,
                            scrub_freed=True),
        _CFG, _PARAMS)
    prompts = _prompts(4, seed=13)
    plist = _mix(4, seed=14, max_new=24)
    refs = [reference_decode(eng.fns, p, params=q)
            for p, q in zip(prompts, plist)]
    handles = [eng.submit(Request(prompt=p, params=q))
               for p, q in zip(prompts, plist)]
    for _ in range(3):          # let the victim make some progress
        eng.step()
    victim = handles[1]
    assert not victim.done
    res = victim.cancel()
    assert res.cancelled and res.finish_reason == "cancelled"
    assert res.tokens == refs[1][:len(res.tokens)]   # prefix of its stream
    eng.run()
    for i, h in enumerate(handles):
        if h is victim:
            continue
        assert h.result().tokens == refs[i], "cancel perturbed a neighbor"
    alloc = eng.scheduler.allocator
    assert alloc.n_allocated == 0 and alloc.n_reserved == 0
    assert eng.scheduler.n_active == 0
    assert victim.cancel() is res     # idempotent after completion


def test_cancel_queued_request_never_admits():
    eng = _engine("dense", "dense")
    prompts = _prompts(3, seed=15)
    hs = [eng.submit(p, max_new_tokens=12) for p in prompts]
    # lanes=2: the third request is queued; cancel it before any step
    res = hs[2].cancel()
    assert res.cancelled and res.tokens == []
    eng.run()
    for h, p in zip(hs[:2], prompts[:2]):
        assert h.result().tokens == reference_decode(eng.fns, p,
                                                     max_new_tokens=12)


# ----------------------------------------------------------------------- stops
def test_stop_sequence_truncation_matches_stepwise():
    """A tree step may accept past the stop match; host-side truncation must
    reproduce exactly what step-by-step decoding emits (I1)."""
    eng = _engine("dense", "dense")
    prompts = _prompts(4, seed=21)
    # derive stop strings that WILL fire: slices of the unconstrained output
    bare = [reference_decode(eng.fns, p, max_new_tokens=24) for p in prompts]
    plist = []
    for i, b in enumerate(bare):
        stops = ((tuple(b[5:7]),) if i % 2 else
                 (tuple(b[3:6]), (VOCAB + 7,)))   # 2nd never fires
        base = _mix(4, seed=22, max_new=24)[i]
        plist.append(dataclasses.replace(base, stop_sequences=stops))
    handles = [eng.submit(Request(prompt=p, params=q))
               for p, q in zip(prompts, plist)]
    eng.run()
    for h, p, q in zip(handles, prompts, plist):
        res = h.result()
        assert res.tokens == reference_decode(eng.fns, p, params=q), q
        if res.finish_reason == "stop":
            assert any(res.tokens[-len(s):] == list(s)
                       for s in q.stop_sequences if len(s) <= len(res.tokens))


def test_stop_token_ids_act_like_eos():
    eng = _engine("dense", "dense")
    prompt = _prompts(1, seed=23)[0]
    bare = reference_decode(eng.fns, prompt, max_new_tokens=20)
    stop_tok = bare[6]
    q = SamplingParams(max_new_tokens=20, stop_token_ids=(stop_tok,))
    res = eng.submit(prompt, params=q).result()
    assert res.tokens == reference_decode(eng.fns, prompt, params=q)
    assert res.tokens[-1] == stop_tok and res.finish_reason == "stop"
    assert len(res.tokens) <= len(bare)


# ------------------------------------------------------------------- I2 traces
def test_mixed_params_never_retrace():
    """(d) per-lane param vectors are traced inputs: serving mixed greedy /
    sampled / stop-constrained traffic compiles each member exactly once."""
    fresh = build_engine(_ECFG, _CFG, _PARAMS)
    for seed in (31, 32):
        prompts = _prompts(4, seed=seed)
        for p, q in zip(prompts, _mix(4, seed=seed, max_new=10,
                                      stop_sequences=((VOCAB + 5,),))):
            fresh.submit(Request(prompt=p, params=q))
        fresh.run()
    assert fresh.fns.prefill._cache_size() == 1
    assert fresh.fns.prefill_into_slot._cache_size() == 1
    assert fresh.fns.fused_step._cache_size() == 1
    assert fresh.fns.tree_step._cache_size() == 0  # unfused parity oracle only
    assert fresh.fns.commit._cache_size() == 0


# ------------------------------------------- overflow retirement (PR-3 fix)
def test_lockstep_and_continuous_agree_in_overflow_regime():
    """Regression (ISSUE 4 satellite): both serving loops retire at the SAME
    token when generation hits the KV-cache cap — truncation is
    token-granular (cache_token_limit), not step-granular."""
    cfg = TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                            d_ff=64, vocab_size=VOCAB, max_seq_len=96)
    params = init_params(cfg, jax.random.key(3))
    from repro.serving.session import make_session_fns
    fns = make_session_fns(cfg, params, slots=9, prefill_len=32)
    la = LookaheadConfig(decoding_length=8, branch_length=4)
    rng = np.random.RandomState(7)
    prompts = [list(rng.randint(1, VOCAB - 1, size=n))
               for n in (20, 31, 5, 28)]
    budgets = [200] * 4                      # all must hit the cache cap
    cont = LookaheadEngine(fns, la).generate_batch(prompts, budgets)
    lock = LookaheadEngine(fns, la).generate_batch_lockstep(prompts, budgets)
    for a, b in zip(cont, lock):
        assert a.tokens == b.tokens
        assert a.finish_reason == b.finish_reason == "cache"
    # pinned boundary: truncation lands exactly at the shared token cap
    for r, p in zip(cont, prompts):
        assert len(r.tokens) == 96 - 9 - len(p) + 1   # == cache_token_limit


def test_overflow_boundary_budget_pinned():
    """At budget == cache_token_limit the request finishes by 'length'; one
    more token flips it to 'cache' with the SAME output."""
    cfg = TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                            d_ff=64, vocab_size=VOCAB, max_seq_len=96)
    params = init_params(cfg, jax.random.key(3))
    from repro.serving.session import make_session_fns
    fns = make_session_fns(cfg, params, slots=9, prefill_len=32)
    la = LookaheadConfig(decoding_length=8, branch_length=4)
    prompt = list(np.random.RandomState(9).randint(1, VOCAB - 1, size=16))
    limit = 96 - 9 - 16 + 1
    at = LookaheadEngine(fns, la).generate(prompt, limit)
    over = LookaheadEngine(fns, la).generate(prompt, limit + 1)
    assert at.tokens == over.tokens
    assert at.finish_reason == "length"
    assert over.finish_reason == "cache"


# ------------------------------------------------------------------ validation
def test_budget_list_mismatch_raises_value_error():
    eng = _engine("dense", "dense")
    lae = LookaheadEngine(eng.fns, LookaheadConfig(decoding_length=8,
                                                   branch_length=4))
    with pytest.raises(ValueError, match="budget"):
        lae.generate_batch(_prompts(3, seed=41), [4, 5])


def test_long_prompt_raises_value_error_in_lockstep():
    cfg = TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                            d_ff=64, vocab_size=VOCAB, max_seq_len=160)
    params = init_params(cfg, jax.random.key(5))
    from repro.serving.session import make_session_fns
    fns = make_session_fns(cfg, params, slots=9, prefill_len=8)
    lae = LookaheadEngine(fns, LookaheadConfig(decoding_length=8,
                                               branch_length=4))
    with pytest.raises(ValueError, match="prefill_len"):
        lae.generate_batch_lockstep([_prompts(1, lo=12, hi=13, seed=42)[0]],
                                    4)


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="max_new_tokens"):
        SamplingParams(max_new_tokens=0).validate()
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(sample=True, temperature=0.0).validate()
    with pytest.raises(ValueError, match="stop sequence"):
        SamplingParams(stop_sequences=((),)).validate()
    # list inputs normalize to hashable tuples
    q = SamplingParams(stop_token_ids=[1, 2], stop_sequences=[[3, 4]])
    assert q.stop_token_ids == (1, 2) and q.stop_sequences == ((3, 4),)


def test_engine_config_validation():
    with pytest.raises(ValueError, match="lanes"):
        EngineConfig(lanes=0).validate()
    with pytest.raises(ValueError, match="kv_layout"):
        EngineConfig(kv_layout="sparse").validate()
    with pytest.raises(ValueError, match="backend"):
        EngineConfig(backend="cuda").validate()
    with pytest.raises(ValueError, match="sampling"):
        EngineConfig(sampling="nucleus").validate()
    with pytest.raises(ValueError, match="max_seq_len"):
        build_engine(EngineConfig(prefill_len=1024), _CFG, _PARAMS)


def test_greedy_only_session_rejects_sampled_requests():
    eng = build_engine(dataclasses.replace(_ECFG, sampling="greedy"),
                       _CFG, _PARAMS)
    with pytest.raises(ValueError, match="greedy"):
        eng.submit(_prompts(1, seed=44)[0],
                   params=SamplingParams(max_new_tokens=4, sample=True))
    # and the argmax-only path still serves greedy traffic losslessly
    p = _prompts(1, seed=45)[0]
    assert eng.submit(p, max_new_tokens=8).result().tokens == \
        reference_decode(eng.fns, p, max_new_tokens=8)


def test_bare_request_inherits_session_defaults():
    """Request(params=None) resolves to the engine's default_params at
    submit — including the sampled mode, not the library defaults."""
    eng = build_engine(
        dataclasses.replace(_ECFG, default_params=SamplingParams(
            max_new_tokens=9, sample=True, temperature=0.6, seed=17)),
        _CFG, _PARAMS)
    prompt = _prompts(1, seed=51)[0]
    res = eng.submit(Request(prompt=prompt)).result()
    assert res.tokens == reference_decode(
        eng.fns, prompt, params=SamplingParams(max_new_tokens=9, sample=True,
                                               temperature=0.6, seed=17))
    assert len(res.tokens) <= 9


def test_scheduler_drops_handles_at_retire():
    """Finished requests leave no handle entry behind (long-running server
    loops must not accrete per-request state)."""
    eng = _engine("dense", "dense")
    hs = [eng.submit(p, max_new_tokens=6) for p in _prompts(3, seed=52)]
    hs[2].cancel()                       # queued-cancel path too
    eng.run()
    assert eng.scheduler.handles == {}
    assert all(h.done for h in hs)       # callers still hold their results


def test_legacy_surfaces_keep_working():
    """Acceptance: old generate/generate_batch/submit call sites run
    unchanged through the compat wrappers."""
    eng = _engine("dense", "dense")
    lae = LookaheadEngine(eng.fns, LookaheadConfig(decoding_length=8,
                                                   branch_length=4))
    prompts = _prompts(3, seed=46)
    outs = lae.generate_batch(prompts, 10)
    assert [o.tokens for o in outs] == \
        [reference_decode(eng.fns, p, 10) for p in prompts]
    one = lae.generate(prompts[0], 10)
    assert one.tokens == outs[0].tokens
    sched = ContinuousScheduler(eng.fns,
                                LookaheadConfig(decoding_length=8,
                                                branch_length=4),
                                lanes=2, prefill_len=PREFILL)
    rid = sched.submit(prompts[0], 10)       # positional legacy submit
    assert isinstance(rid, int)
    res = sched.run()
    assert res[0].tokens == outs[0].tokens
