"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.embedding_bag.ops import (embedding_bag_fused,
                                             embedding_bag_reference)
from repro.kernels.flash_prefill.ops import (flash_prefill,
                                             flash_prefill_reference)
from repro.kernels.tree_attention.ops import (tree_attention,
                                              tree_attention_reference)

pytestmark = pytest.mark.kernels

RNG = np.random.RandomState(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("B,T,H,K,dh,S", [
    (1, 1, 4, 4, 64, 128),       # plain 1-token decode (no draft)
    (2, 5, 8, 4, 64, 256),
    (1, 9, 4, 1, 96, 512),       # MQA, non-128 dh (padded inside)
    (2, 65, 12, 2, 128, 1024),   # lookahead slots, qwen2-like GQA
    (1, 33, 16, 16, 128, 384),   # MHA, uneven S vs block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tree_attention_sweep(B, T, H, K, dh, S, dtype):
    q = jnp.asarray(RNG.randn(B, T, H, dh), dtype) * 0.3
    k = jnp.asarray(RNG.randn(B, S, K, dh), dtype) * 0.3
    v = jnp.asarray(RNG.randn(B, S, K, dh), dtype) * 0.3
    lens = RNG.randint(S // 4, S // 2, size=(B,))
    mask = np.zeros((B, T, S), bool)
    for b in range(B):
        mask[b, :, :lens[b]] = True
        mask[b, :, lens[b]:lens[b] + T] = np.tril(np.ones((T, T), bool))
    mask = jnp.asarray(mask)
    out = tree_attention(q, k, v, mask, block_s=128, interpret=True)
    ref = tree_attention_reference(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,S,H,K,dh,bq,bk", [
    (2, 256, 4, 2, 64, 64, 128),
    (1, 512, 8, 8, 96, 128, 128),
    (2, 256, 6, 2, 128, 128, 64),
    (1, 128, 2, 1, 80, 64, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_sweep(B, S, H, K, dh, bq, bk, dtype):
    q = jnp.asarray(RNG.randn(B, S, H, dh), dtype) * 0.3
    k = jnp.asarray(RNG.randn(B, S, K, dh), dtype) * 0.3
    v = jnp.asarray(RNG.randn(B, S, K, dh), dtype) * 0.3
    out = flash_prefill(q, k, v, block_q=bq, block_k=bk, interpret=True)
    ref = flash_prefill_reference(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("V,D,N,L", [
    (100, 128, 16, 4), (500, 256, 8, 7), (64, 128, 32, 3), (1000, 128, 4, 1),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_sweep(V, D, N, L, dtype):
    t = jnp.asarray(RNG.randn(V, D), dtype)
    ids = jnp.asarray(RNG.randint(0, V, (N, L)), jnp.int32)
    m = jnp.asarray(RNG.rand(N, L) > 0.3)
    w = jnp.asarray(RNG.rand(N, L).astype(np.float32))
    out = embedding_bag_fused(t, ids, m, w, interpret=True)
    ref = embedding_bag_reference(t, ids,
                                  w * m.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,T,H,K,dh,S,bs", [
    (2, 5, 4, 2, 64, 320, 128),   # S % block_s != 0: padded to 384, 3 blocks
    (1, 9, 4, 4, 96, 200, 128),   # ragged S AND padded dh
    (2, 7, 8, 2, 64, 640, 512),   # the old collapse case: now 2x512 blocks
])
def test_tree_attention_ragged_s_keeps_blocking(B, T, H, K, dh, S, bs):
    """S not divisible by block_s pads up to the block multiple (masked
    rows) instead of silently collapsing to one full-S block; interpret
    mode is auto-detected from the platform (no explicit flag)."""
    q = jnp.asarray(RNG.randn(B, T, H, dh), jnp.float32) * 0.3
    k = jnp.asarray(RNG.randn(B, S, K, dh), jnp.float32) * 0.3
    v = jnp.asarray(RNG.randn(B, S, K, dh), jnp.float32) * 0.3
    mask = jnp.asarray(RNG.rand(B, T, S) > 0.4).at[:, :, 0].set(True)
    out = tree_attention(q, k, v, mask, block_s=bs)
    ref = tree_attention_reference(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("B,S,H,K,dh", [
    (2, 320, 4, 2, 64),           # S % 256 != 0 → shared-block padding path
    (1, 300, 6, 3, 80),           # ragged S AND padded dh
])
def test_flash_prefill_ragged_s(B, S, H, K, dh):
    """Ragged prefill lengths pad S to a block multiple; causality keeps the
    pad keys invisible to real queries."""
    q = jnp.asarray(RNG.randn(B, S, H, dh), jnp.float32) * 0.3
    k = jnp.asarray(RNG.randn(B, S, K, dh), jnp.float32) * 0.3
    v = jnp.asarray(RNG.randn(B, S, K, dh), jnp.float32) * 0.3
    out = flash_prefill(q, k, v, block_q=256, block_k=512)
    ref = flash_prefill_reference(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=1e-4)


def test_tree_attention_matches_model_semantics():
    """Kernel mask semantics == transformer dense tree-step semantics."""
    from repro.models.layers import gqa_attention
    B, T, H, K, dh, S = 2, 7, 4, 2, 64, 256
    q = jnp.asarray(RNG.randn(B, T, H, dh), jnp.float32) * 0.4
    k = jnp.asarray(RNG.randn(B, S, K, dh), jnp.float32) * 0.4
    v = jnp.asarray(RNG.randn(B, S, K, dh), jnp.float32) * 0.4
    mask = jnp.asarray(RNG.rand(B, T, S) > 0.4)
    mask = mask.at[:, :, 0].set(True)      # no all-masked rows
    dense = gqa_attention(q, k, v, mask)
    kern = tree_attention(q, k, v, mask, block_s=128, interpret=True)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(dense),
                               atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("B,S,H,K,dh,blk", [
    (1, 256, 4, 2, 64, 64), (2, 512, 4, 4, 128, 128), (1, 384, 6, 2, 96, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_triangular_grid(B, S, H, K, dh, blk, dtype):
    """Beyond-paper kernel: triangular (qi >= kj) grid — upper blocks never
    scheduled — must match the rectangular kernel and the oracle."""
    q = jnp.asarray(RNG.randn(B, S, H, dh), dtype) * 0.3
    k = jnp.asarray(RNG.randn(B, S, K, dh), dtype) * 0.3
    v = jnp.asarray(RNG.randn(B, S, K, dh), dtype) * 0.3
    out = flash_prefill(q, k, v, block_q=blk, block_k=blk, interpret=True,
                        triangular=True)
    ref = flash_prefill_reference(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))
