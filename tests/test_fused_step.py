"""Fused-step parity: the single-dispatch device step (tree forward +
token choice + device accept walk + commit, one packed array out) must be
bit-identical to the unfused logits path it replaces (ISSUE 6).

Three levels:

  * op-level — ``verify_accept_device`` replicates the host
    ``verify_accept`` walk exactly on real DraftTrees (ragged n_slots,
    first-child tie-breaking, idle placeholder lanes via n_live == 0);
  * step-level — one ``fused_step`` call returns the same packed
    (n_acc, acc_tokens, kv_slots) the host walk derives from the unfused
    ``tree_step`` logits, and commits the same KV rows, across GQA shapes
    and mixed greedy/sampled per-lane params;
  * serving-level — a scheduler driven by ``fused_step`` produces
    bit-identical outputs to one forced onto the legacy
    tree_step/verify/commit path, for dense/paged x dense/pallas (the
    pallas cells run the interpret-mode kernels on CPU), with exactly one
    decode-hot-path sync per step (vs two on the legacy path).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import LookaheadConfig, reference_decode
from repro.core.request import (Request, SamplingParams, build_draft_tree,
                                idle_tree)
from repro.core.trie import TrieTree
from repro.core.verify import verify_accept_batch
from repro.models.transformer import (TransformerConfig, init_params,
                                      verify_accept_device)
from repro.serving.scheduler import ContinuousScheduler
from repro.serving.session import make_session_fns

PREFILL = 32
SLOTS = 9
VOCAB = 61

_CFG = TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab_size=VOCAB, max_seq_len=192)
_PARAMS = init_params(_CFG, jax.random.key(21))

CELLS = (("dense", "dense", 0), ("dense", "pallas", 0),
         ("paged", "dense", 8), ("paged", "pallas", 8))


def _prompts(rng, n, lo=4, hi=24):
    return [list(rng.randint(1, VOCAB - 1, size=rng.randint(lo, hi)))
            for _ in range(n)]


# ------------------------------------------------------------------ op level
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_device_walk_matches_host_verify(seed):
    """verify_accept_device == verify_accept on genuine trie-built trees
    with chosen vectors crafted to follow real acceptance chains."""
    rng = np.random.RandomState(seed)
    la = LookaheadConfig(decoding_length=SLOTS - 1, branch_length=5)
    trie = TrieTree(capacity=4096)
    for _ in range(20):
        trie.insert_ngrams(rng.randint(1, VOCAB, size=30).tolist(),
                           la.branch_length)
    W = SLOTS
    trees = []
    for _ in range(5):
        ctx = rng.randint(1, VOCAB, size=rng.randint(6, 30)).tolist()
        trees.append(build_draft_tree(trie, la, ctx, 0, W))
    trees.append(idle_tree(W, 0))                  # idle placeholder lane
    B = len(trees)
    chosen = rng.randint(1, VOCAB, size=(B, W)).astype(np.int32)
    # follow the tree: make the model "predict" real children often enough
    # that walks go deep (later children overwrite earlier on a shared
    # parent — the first-child tie-break is exactly what is under test)
    for b, t in enumerate(trees):
        for c in range(1, t.n_slots):
            if rng.rand() < 0.6:
                chosen[b, t.parent[c]] = t.tokens[c]

    accepted, kv_slots = verify_accept_batch(trees, chosen)
    tok = np.stack([t.tokens for t in trees]).astype(np.int32)
    parent = np.stack([t.parent for t in trees]).astype(np.int32)
    n_live = np.asarray([t.n_slots for t in trees[:-1]] + [0], np.int32)
    n_acc, acc_tok, kvs = jax.jit(verify_accept_device)(tok, parent, n_live,
                                                        chosen)
    n_acc, acc_tok, kvs = (np.asarray(n_acc), np.asarray(acc_tok),
                           np.asarray(kvs))
    for b in range(B - 1):
        n = int(n_acc[b])
        assert n == len(accepted[b]), (seed, b)
        assert acc_tok[b, :n].tolist() == [int(x) for x in accepted[b]]
        assert kvs[b, :n].tolist() == [int(x) for x in kv_slots[b]]
        assert not acc_tok[b, n:].any() and not kvs[b, n:].any()
    assert int(n_acc[B - 1]) == 0                  # idle lane accepts nothing


# ---------------------------------------------------------------- step level
@pytest.mark.kernels
@pytest.mark.parametrize("layout,backend,bs", CELLS,
                         ids=[f"{l}-{b}" for l, b, _ in CELLS])
def test_fused_step_matches_unfused_step(layout, backend, bs):
    """One fused_step vs tree_step + host verify + commit on identical
    caches: same packed results, same committed KV rows — with a ragged-T
    draft mix (full tree / shallow tree / idle lane) and mixed
    greedy/sampled lane params."""
    fns = make_session_fns(_CFG, _PARAMS, slots=SLOTS, prefill_len=PREFILL,
                           backend=backend, kv_layout=layout,
                           block_size=bs or None)
    rng = np.random.RandomState(7)
    lanes = 3
    toks = np.full((lanes, PREFILL), 0, dtype=np.int32)
    lens = np.zeros((lanes,), dtype=np.int32)
    for b, p in enumerate(_prompts(rng, lanes, lo=8, hi=PREFILL)):
        toks[b, :len(p)] = p
        lens[b] = len(p)
    lane_params = {"greedy": np.asarray([True, False, True]),
                   "temp": np.asarray([1.0, 0.8, 1.0], np.float32),
                   "seed": np.asarray([0, 77, 0], np.uint32)}
    if layout == "paged":
        bpl = fns.blocks_per_lane
        tables = np.arange(1, 1 + lanes * bpl,
                           dtype=np.int32).reshape(lanes, bpl)
        cache, _ = fns.prefill(toks, lens, tables, lane_params=lane_params)
    else:
        cache, _ = fns.prefill(toks, lens, lane_params=lane_params)
    cache = {k: np.asarray(v) for k, v in cache.items()}

    la = LookaheadConfig(decoding_length=SLOTS - 1, branch_length=4)
    trie = TrieTree(capacity=4096)
    for _ in range(12):
        trie.insert_ngrams(rng.randint(1, VOCAB, size=24).tolist(), 4)
    trees = [build_draft_tree(trie, la,
                              toks[0, :lens[0]].tolist(), 0, SLOTS),
             build_draft_tree(trie, LookaheadConfig(decoding_length=2,
                                                    branch_length=2),
                              toks[1, :lens[1]].tolist(), 0, SLOTS),
             idle_tree(SLOTS, 0)]                  # ragged T + idle lane
    tok = np.stack([t.tokens for t in trees])
    pos = (lens[:, None] + np.stack([t.depth for t in trees])).astype(
        np.int32)
    mask = np.stack([t.tree_mask for t in trees])
    parent = np.stack([t.parent for t in trees]).astype(np.int32)
    n_live = np.asarray([trees[0].n_slots, trees[1].n_slots, 0], np.int32)

    # ---- unfused reference: tree_step -> host walk -> commit
    c1 = {k: v.copy() for k, v in cache.items()}
    c1, chosen = fns.tree_step(c1, lens, tok, pos, mask,
                               lane_params=lane_params)
    chosen = np.asarray(chosen)
    accepted, kv_slots = verify_accept_batch(trees, chosen)
    gather = np.zeros((lanes, SLOTS), dtype=np.int32)
    n_acc = np.zeros((lanes,), dtype=np.int32)
    for b in range(2):                             # idle lane commits 0
        gather[b, :len(kv_slots[b])] = kv_slots[b]
        n_acc[b] = len(kv_slots[b])
    c1, new_lens = fns.commit(c1, lens, gather, n_acc)

    # ---- fused: one dispatch, one packed array
    c2 = {k: v.copy() for k, v in cache.items()}
    c2, packed = fns.fused_step(c2, lens, tok, pos, mask, parent, n_live,
                                lane_params=lane_params)
    packed = np.asarray(packed)
    assert packed.shape == (lanes, 1 + 2 * SLOTS)
    for b in range(2):
        n = int(packed[b, 0])
        assert n == len(accepted[b]), (layout, backend, b)
        assert packed[b, 1:1 + n].tolist() == \
            [int(x) for x in accepted[b]]
        assert packed[b, 1 + SLOTS:1 + SLOTS + n].tolist() == \
            [int(x) for x in kv_slots[b]]
    assert int(packed[2, 0]) == 0
    for name in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(c2[name]),
                                      np.asarray(c1[name]),
                                      err_msg=f"{layout}/{backend}/{name}")


# ------------------------------------------------------------- serving level
@pytest.mark.kernels
@pytest.mark.parametrize("layout,backend,bs", CELLS,
                         ids=[f"{l}-{b}" for l, b, _ in CELLS])
def test_fused_scheduler_matches_legacy_path(layout, backend, bs):
    """Scheduler on fused_step vs the same StepFns with fused_step stripped
    (legacy two-dispatch decode): bit-identical outputs, both equal
    reference_decode, and the sync counters show 1 vs 2 pulls per step."""
    fns = make_session_fns(_CFG, _PARAMS, slots=SLOTS, prefill_len=PREFILL,
                           backend=backend, kv_layout=layout,
                           block_size=bs or None)
    legacy = dataclasses.replace(fns, fused_step=None)
    rng = np.random.RandomState(13)
    prompts = _prompts(rng, 5)
    specs = [SamplingParams(max_new_tokens=int(rng.randint(1, 16)),
                            sample=bool(i % 2),
                            temperature=(0.6, 0.9)[i % 2], seed=100 + i)
             for i, _ in enumerate(prompts)]
    refs = [reference_decode(fns, p, params=s)
            for p, s in zip(prompts, specs)]
    la = LookaheadConfig(decoding_length=SLOTS - 1, branch_length=4)
    outs = {}
    for name, f in (("fused", fns), ("legacy", legacy)):
        sched = ContinuousScheduler(f, la, lanes=2, prefill_len=PREFILL)
        handles = [sched.submit_request(Request(prompt=p, params=s))
                   for p, s in zip(prompts, specs)]
        sched.run()
        outs[name] = [h.result().tokens for h in handles]
        st = sched.stats
        per_step = 1 if name == "fused" else 2
        assert st.decode_syncs == per_step * st.decode_steps, name
    assert outs["fused"] == outs["legacy"]
    for got, ref in zip(outs["fused"], refs):
        assert got == ref
