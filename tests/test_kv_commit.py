"""Cache-state invariant: after lookahead accepts k tokens, the KV cache
prefix equals what step-by-step decoding would have produced."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LookaheadConfig, LookaheadEngine
from repro.models.transformer import (TransformerConfig, commit_cache,
                                      init_cache, init_params, prefill,
                                      tree_step)


def _run_collect_cache(fns_cfg, params, prompt, n_new, la_cfg):
    """Generate and return (tokens, final cache ndarray, final len)."""
    from repro.serving.session import make_session_fns
    fns = make_session_fns(fns_cfg, params, slots=la_cfg.slots)
    eng = LookaheadEngine(fns, la_cfg)
    # intercept: engine doesn't expose cache; re-run manually instead
    return eng.generate(prompt, n_new).tokens


def test_cache_prefix_matches_stepwise():
    cfg = TransformerConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                            d_ff=64, vocab_size=29, max_seq_len=128)
    params = init_params(cfg, jax.random.key(0))
    prompt = [3, 7, 11, 2, 9]
    B, L = 1, len(prompt)

    # --- step-by-step ground-truth cache
    cache = init_cache(cfg, B)
    toks = jnp.asarray([prompt], jnp.int32)
    cache, logits = prefill(cfg, params, toks, jnp.asarray([L]), cache)
    lens = jnp.asarray([L], jnp.int32)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(6):
        t = jnp.asarray([[out[-1]]], jnp.int32)
        pos = lens[:, None]
        mask = jnp.ones((B, 1, 1), bool)
        cache, lg = tree_step(cfg, params, cache, lens, t, pos, mask)
        gather = jnp.zeros((B, 1), jnp.int32)
        cache, lens = commit_cache(cache, lens, gather, jnp.asarray([1]))
        out.append(int(jnp.argmax(lg[0, 0])))
    ref_cache, ref_lens, ref_out = cache, lens, out

    # --- lookahead with a warm trie (drafts accepted >1 at a time)
    cache = init_cache(cfg, B)
    cache, logits = prefill(cfg, params, toks, jnp.asarray([L]), cache)
    lens = jnp.asarray([L], jnp.int32)
    from repro.core.trie import TrieTree
    from repro.core.draft import build_hierarchical
    from repro.core.verify import verify_accept
    trie = TrieTree(capacity=4096)
    trie.insert_ngrams(ref_out, 6)
    out = [int(jnp.argmax(logits[0]))]
    while len(out) < 7:
        branches, scores = trie.retrieve(prompt + out, decoding_length=8)
        tree = build_hierarchical(out[-1], branches, scores, 8)
        t = jnp.asarray(tree.tokens[None], jnp.int32)
        pos = lens[:, None] + jnp.asarray(tree.depth[None], jnp.int32)
        mask = jnp.asarray(tree.tree_mask[None])
        cache, lg = tree_step(cfg, params, cache, lens, t, pos, mask)
        chosen = np.asarray(jnp.argmax(lg, -1))[0]
        acc, slots = verify_accept(tree, chosen)
        acc = acc[:7 - len(out)]
        slots = slots[:len(acc)]
        g = np.zeros((B, tree.size), np.int32)
        g[0, :len(slots)] = slots
        cache, lens = commit_cache(cache, lens, jnp.asarray(g),
                                   jnp.asarray([len(slots)]))
        out.extend(acc)
    assert out == ref_out
    assert int(lens[0]) == int(ref_lens[0])
    n = int(lens[0])
    np.testing.assert_allclose(
        np.asarray(ref_cache["k"])[:, :, :n],
        np.asarray(cache["k"])[:, :, :n], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(ref_cache["v"])[:, :, :n],
        np.asarray(cache["v"])[:, :, :n], rtol=1e-5, atol=1e-5)
