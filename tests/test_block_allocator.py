"""BlockAllocator unit tests: alloc/extend/free, free-list reuse,
reservation accounting, fragmentation, and scheduler admission backpressure
when blocks are exhausted (the queue must drain without deadlock)."""
import jax
import numpy as np
import pytest

from repro.core import LookaheadConfig, reference_decode
from repro.models.transformer import TransformerConfig, init_params
from repro.serving.block_allocator import NULL_BLOCK, BlockAllocator
from repro.serving.scheduler import ContinuousScheduler
from repro.serving.session import make_session_fns

pytestmark = pytest.mark.paged


# ------------------------------------------------------------------ alloc/free
def test_alloc_hands_out_distinct_nonnull_ids():
    a = BlockAllocator(n_blocks=8, block_size=16)
    ids = a.alloc(1, 4)
    assert len(ids) == len(set(ids)) == 4
    assert NULL_BLOCK not in ids
    assert all(1 <= b < 8 for b in ids)
    assert a.table(1) == ids
    assert a.n_free == 3 and a.n_allocated == 4


def test_extend_appends_and_respects_reservation():
    a = BlockAllocator(n_blocks=10, block_size=16)
    first = a.alloc(7, 2, reserve=5)
    more = a.extend(7, 2)
    assert a.table(7) == first + more
    assert a.n_blocks_of(7) == 4 and a.reserved_of(7) == 5
    a.extend(7, 1)
    with pytest.raises(RuntimeError):
        a.extend(7, 1)           # beyond the reservation


def test_free_returns_blocks_and_reuses_them():
    a = BlockAllocator(n_blocks=6, block_size=16)
    ids = a.alloc(1, 5)
    freed = a.free(1)
    assert sorted(freed) == sorted(ids)
    assert a.n_free == 5 and a.n_reserved == 0
    # the free list really is reused, not regrown
    again = a.alloc(2, 5)
    assert sorted(again) == sorted(ids)
    with pytest.raises(KeyError):
        a.free(1)


def test_reservation_backpressure_accounting():
    a = BlockAllocator(n_blocks=9, block_size=16)     # capacity 8
    a.alloc(1, 1, reserve=5)
    # only 1 block physically taken, but 5 promised: available is 3
    assert a.n_allocated == 1 and a.available == 3
    assert a.can_admit(3) and not a.can_admit(4)
    with pytest.raises(RuntimeError):
        a.alloc(2, 1, reserve=4)
    a.free(1)
    assert a.can_admit(8)
    with pytest.raises(ValueError):
        a.alloc(3, 1, reserve=9)  # can never fit -> error, not backpressure


def test_alloc_errors():
    a = BlockAllocator(n_blocks=4, block_size=8)
    a.alloc(1, 1)
    with pytest.raises(ValueError):
        a.alloc(1, 1)            # duplicate rid
    with pytest.raises(ValueError):
        a.alloc(2, 3, reserve=2)  # reserve < initial
    with pytest.raises(KeyError):
        a.extend(99, 1)
    with pytest.raises(ValueError):
        BlockAllocator(n_blocks=1, block_size=8)   # no room beside NULL


def test_fragmentation_accounting():
    a = BlockAllocator(n_blocks=16, block_size=16)
    a.alloc(1, 3)                 # 48 rows allocated
    a.alloc(2, 1)                 # 16 rows allocated
    assert a.blocks_for_tokens(33) == 3
    assert a.frag_rows(1, 33) == 48 - 33
    assert a.frag_rows(2, 16) == 0
    assert a.frag_rows_total({1: 33, 2: 16}) == 15
    # unknown usage counts the whole allocation as waste
    assert a.frag_rows_total({1: 33}) == 15 + 16


# --------------------------------------------------- scheduler backpressure
def test_scheduler_block_backpressure_drains_without_deadlock():
    """A pool too small for concurrent requests serializes admissions
    (block_waits > 0) yet every request completes losslessly."""
    cfg = TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                            d_ff=64, vocab_size=53, max_seq_len=160)
    params = init_params(cfg, jax.random.key(3))
    bs = 16
    rng = np.random.RandomState(31)
    prompts = [list(rng.randint(1, 52, size=rng.randint(4, 24)))
               for _ in range(5)]
    budgets = [12, 5, 12, 3, 9]
    la = LookaheadConfig(decoding_length=8, branch_length=4)
    # demand per request: ceil((plen + max_new + 9)/16) <= 3 blocks; a pool
    # of 4 usable blocks can hold at most one long request at a time
    fns = make_session_fns(cfg, params, slots=9, prefill_len=32,
                           kv_layout="paged", block_size=bs, n_blocks=5)
    refs = [reference_decode(fns, p, m) for p, m in zip(prompts, budgets)]
    sched = ContinuousScheduler(fns, la, lanes=2, prefill_len=32)
    for p, m in zip(prompts, budgets):
        sched.submit(p, m)
    res = sched.run()
    assert len(res) == len(prompts)
    for r, ref in zip(res, refs):
        assert r.tokens == ref
    assert sched.stats.block_waits > 0          # backpressure actually hit
    assert sched.stats.peak_blocks <= sched.allocator.capacity
    assert sched.allocator.n_free == sched.allocator.capacity  # all returned
    assert sched.allocator.n_reserved == 0


def test_scheduler_rejects_unservable_request():
    """A single request whose worst-case demand exceeds the whole pool is
    refused at submit (it could never be admitted -> deadlock)."""
    cfg = TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                            d_ff=64, vocab_size=53, max_seq_len=160)
    params = init_params(cfg, jax.random.key(3))
    fns = make_session_fns(cfg, params, slots=9, prefill_len=32,
                           kv_layout="paged", block_size=16, n_blocks=3)
    la = LookaheadConfig(decoding_length=8, branch_length=4)
    sched = ContinuousScheduler(fns, la, lanes=2, prefill_len=32)
    with pytest.raises(ValueError):
        sched.submit(list(range(1, 30)), 100)
