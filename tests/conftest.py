import inspect
import os
import random
import sys
import types

# Tests run on ONE device (the dry-run sets its own 512-device env in a
# subprocess / separate invocation — never globally).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


# --------------------------------------------------------------------------
# Optional-hypothesis shim: on a bare environment the property tests still
# collect and run against pseudo-random examples drawn from a tiny stand-in
# implementing exactly the strategy surface this suite uses
# (st.integers, st.lists, @given, @settings).  With the real hypothesis
# installed the shim is inert.
# --------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    _DEFAULT_EXAMPLES = 25

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _lists(elements, min_size=0, max_size=8):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements._draw(rng) for _ in range(n)]
        return _Strategy(draw)

    def _given(*strategies):
        def deco(fn):
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            # hypothesis fills the RIGHTMOST positional params from the
            # strategies, in order; anything left of them stays a fixture
            n = len(strategies)
            drawn_names = [p.name for p in params[len(params) - n:]]

            def wrapper(*args, **kwargs):
                n_ex = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                rng = random.Random(0xC0FFEE)
                for _ in range(n_ex):
                    drawn = {name: s._draw(rng)
                             for name, s in zip(drawn_names, strategies)}
                    fn(*args, **kwargs, **drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__dict__.update(fn.__dict__)
            # hide the drawn params from pytest's fixture resolution
            wrapper.__signature__ = sig.replace(
                parameters=params[:len(params) - n])
            return wrapper
        return deco

    def _settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.lists = _lists
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
