import os
import sys

# Tests run on ONE device (the dry-run sets its own 512-device env in a
# subprocess / separate invocation — never globally).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
