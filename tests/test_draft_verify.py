import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.draft import (BUILDERS, build_hierarchical, build_parallel,
                              build_single)
from repro.core.verify import verify_accept

branches_strategy = st.lists(
    st.lists(st.integers(1, 9), min_size=1, max_size=5),
    min_size=0, max_size=12)


def _check_legal(tree):
    """Invariants: depth = parent depth + 1, mask = ancestor closure."""
    n = tree.size
    assert tree.parent[0] == -1 and tree.depth[0] == 0
    for i in range(1, tree.n_slots):
        p = tree.parent[i]
        assert 0 <= p < i
        assert tree.depth[i] == tree.depth[p] + 1
    for i in range(n):
        anc = {i}
        j = i if i < tree.n_slots else 0
        while j >= 0:
            anc.add(j)
            j = tree.parent[j] if j > 0 else -1
        anc.add(0)
        got = set(np.nonzero(tree.tree_mask[i])[0].tolist())
        assert got == {a for a in anc if a < n}, (i, got, anc)


@settings(max_examples=60, deadline=None)
@given(branches_strategy, st.integers(1, 16))
def test_property_tree_legality(branches, L):
    for name, builder in BUILDERS.items():
        tree = builder(42, branches, None, L)
        assert tree.size == 1 + L
        assert 1 <= tree.n_slots <= 1 + L
        _check_legal(tree)


def test_hierarchical_merges_prefixes():
    tree = build_hierarchical(7, [[1], [1, 2], [1, 3]], None, 8)
    # slots: root, 1, 2, 3  (prefix [1] stored once)
    assert tree.n_slots == 4
    par = build_parallel(7, [[1], [1, 2], [1, 3]], None, 8)
    # maximal paths [1,2],[1,3] stored independently: 1+2+2
    assert par.n_slots == 5


def test_single_is_chain():
    tree = build_single(7, [[1, 2, 3], [4]], None, 8)
    assert tree.n_slots == 4
    assert list(tree.parent[1:4]) == [0, 1, 2]


def test_budget_respected():
    tree = build_hierarchical(7, [[i] for i in range(50)], None, 10)
    assert tree.n_slots == 11


def test_verify_worst_case_accepts_one():
    tree = build_hierarchical(7, [[1], [2]], None, 4)
    chosen = np.array([99, 0, 0, 0, 0])   # no draft matches 99
    acc, slots = verify_accept(tree, chosen)
    assert acc == [99] and slots == [0]


def test_verify_walks_longest_path():
    tree = build_hierarchical(7, [[1, 2, 3]], None, 4)
    # chosen[root]=1 matches slot1; chosen[slot1]=2 matches slot2; ...
    chosen = np.array([1, 2, 3, 4, 0])
    acc, slots = verify_accept(tree, chosen)
    assert acc == [1, 2, 3, 4]
    assert slots == [0, 1, 2, 3]


def test_verify_branches_choose_matching_child():
    tree = build_hierarchical(7, [[1, 5], [2, 6]], None, 6)
    # root chooses 2 → the [2, 6] branch; chosen at that slot = 6 → accept
    c = np.zeros(tree.size, dtype=np.int64)
    c[0] = 2
    slot2 = [i for i in range(tree.n_slots) if tree.tokens[i] == 2][0]
    c[slot2] = 6
    slot6 = [i for i in range(tree.n_slots) if tree.tokens[i] == 6][0]
    c[slot6] = 11
    acc, slots = verify_accept(tree, c)
    assert acc == [2, 6, 11]
    assert slots == [0, slot2, slot6]


@settings(max_examples=60, deadline=None)
@given(branches_strategy, st.integers(1, 12),
       st.lists(st.integers(0, 9), min_size=13, max_size=13))
def test_property_verify_sound(branches, L, chosen):
    tree = build_hierarchical(3, branches, None, L)
    chosen = np.array(chosen[:tree.size] + [0] * max(0, tree.size - len(chosen)))
    acc, slots = verify_accept(tree, chosen)
    assert len(acc) >= 1 and len(acc) == len(slots)
    assert acc[0] == chosen[0] and slots[0] == 0
    # each committed slot's token equals its parent's chosen id
    for j in range(1, len(slots)):
        s = slots[j]
        assert tree.tokens[s] == chosen[slots[j - 1]]
        assert tree.parent[s] == slots[j - 1]
