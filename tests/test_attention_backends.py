"""Attention-backend registry: parity + losslessness contracts.

The registry (repro.models.attention) must make backends interchangeable:

  * op-level — each backend's prefill / tree-attend closures match the
    dense reference within float tolerance across GQA shapes, including a
    head_dim that is not a multiple of 128 and cache lengths ragged
    against the kernel block size;
  * model-level — ``tree_step``/``prefill`` logits agree across backends
    and greedy token choice is bit-identical;
  * serving-level — ``generate``/``generate_batch`` outputs are
    bit-identical under every backend (greedy AND position-keyed sampling),
    which is invariant I1 extended over the registry.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LookaheadConfig, LookaheadEngine, reference_decode
from repro.models import attention
from repro.models.transformer import (TransformerConfig, init_cache,
                                      init_params, prefill, tree_step)
from repro.serving.session import make_session_fns

RNG = np.random.RandomState(0)
BACKENDS = ("dense", "pallas", "flash_decode")


def _cfg(**kw):
    base = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                vocab_size=101, max_seq_len=256)
    base.update(kw)
    return TransformerConfig(**base)


# ------------------------------------------------------------------ registry
def test_registry_contents_and_errors():
    names = attention.available_backends()
    for expected in BACKENDS:
        assert expected in names
    with pytest.raises(KeyError, match="unknown attention backend"):
        attention.get_backend("nope")
    with pytest.raises(KeyError, match="nope"):
        make_session_fns(_cfg(), init_params(_cfg(), jax.random.key(0)),
                         decode_backend="nope")


# ------------------------------------------------------------- op-level parity
@pytest.mark.kernels
@pytest.mark.parametrize("B,T,H,K,dh,S", [
    (2, 5, 8, 4, 64, 256),        # even shapes
    (1, 9, 4, 1, 96, 320),        # MQA, dh not a multiple of 128, ragged S
    (2, 7, 6, 2, 80, 200),        # GQA=3, ragged S vs every block size
    (1, 1, 4, 4, 128, 384),       # plain 1-token decode (no draft)
])
@pytest.mark.parametrize("backend", ["pallas", "flash_decode"])
def test_tree_attend_matches_dense(B, T, H, K, dh, S, backend):
    cfg = _cfg(n_heads=H, n_kv_heads=K, head_dim=dh, d_model=H * dh,
               max_seq_len=S)
    q = jnp.asarray(RNG.randn(B, T, H, dh), jnp.float32) * 0.3
    k = jnp.asarray(RNG.randn(B, T, K, dh), jnp.float32) * 0.3
    v = jnp.asarray(RNG.randn(B, T, K, dh), jnp.float32) * 0.3
    kc = jnp.asarray(RNG.randn(B, S, K, dh), jnp.float32) * 0.3
    vc = jnp.asarray(RNG.randn(B, S, K, dh), jnp.float32) * 0.3
    lens = jnp.asarray(RNG.randint(S // 4, S - T, size=(B,)), jnp.int32)
    tree = np.tril(np.ones((T, T), bool))
    mask = jnp.asarray(np.stack([tree] * B))

    ref_at = attention.get_backend("dense").make_tree_attend(
        cfg, lens, mask, S)
    ref, rk, rv = ref_at(q, k, v, kc, vc)
    if backend == "flash_decode":
        # a 1-device mesh drives the real shard_map/_local_attend math
        # (without one the backend degrades to the dense closure)
        from repro.distributed.sharding import sharding_ctx
        mesh = jax.make_mesh((1,), ("data",))
        with sharding_ctx(mesh):
            got_at = attention.get_backend(backend).make_tree_attend(
                cfg, lens, mask, S)
            got, gk, gv = got_at(q, k, v, kc, vc)
    else:
        got_at = attention.get_backend(backend).make_tree_attend(
            cfg, lens, mask, S)
        got, gk, gv = got_at(q, k, v, kc, vc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=3e-5, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(gk), np.asarray(rk))
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(rv))


@pytest.mark.kernels
@pytest.mark.parametrize("B,S,H,K,dh", [
    (2, 48, 4, 2, 64),            # short ragged prompt pad length
    (1, 300, 6, 3, 80),           # ragged S, dh not a multiple of 128
])
@pytest.mark.parametrize("backend", ["pallas", "flash_decode"])
def test_prefill_attention_matches_dense(B, S, H, K, dh, backend):
    cfg = _cfg(n_heads=H, n_kv_heads=K, head_dim=dh, d_model=H * dh,
               max_seq_len=max(512, S))
    q = jnp.asarray(RNG.randn(B, S, H, dh), jnp.float32) * 0.3
    k = jnp.asarray(RNG.randn(B, S, K, dh), jnp.float32) * 0.3
    v = jnp.asarray(RNG.randn(B, S, K, dh), jnp.float32) * 0.3
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    lens = jnp.asarray(RNG.randint(S // 2, S + 1, size=(B,)), jnp.int32)
    len_mask = positions < lens[:, None]
    ref = attention.get_backend("dense").prefill_attention(
        cfg, q, k, v, positions, len_mask)
    got = attention.get_backend(backend).prefill_attention(
        cfg, q, k, v, positions, len_mask)
    # pad rows (>= lens) intentionally differ (causal-only kernel); real
    # rows must match the dense mask semantics
    for b in range(B):
        n = int(lens[b])
        np.testing.assert_allclose(np.asarray(got)[b, :n],
                                   np.asarray(ref)[b, :n],
                                   atol=3e-5, rtol=1e-4)


# ---------------------------------------------------------- model-level parity
@pytest.mark.kernels
def test_tree_step_and_prefill_logits_across_backends():
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    B, T, P = 2, 5, 48
    toks = jnp.asarray(RNG.randint(1, 101, (B, P)), jnp.int32)
    lens = jnp.asarray([37, 22], jnp.int32)
    cache, ref_lg = prefill(cfg, params, toks, lens, init_cache(cfg, B))
    tt = jnp.asarray(RNG.randint(1, 101, (B, T)), jnp.int32)
    depth = jnp.asarray([[0, 1, 1, 2, 2]] * B, jnp.int32)
    parent = [-1, 0, 0, 1, 2]
    m = np.zeros((T, T), bool)
    for i in range(T):
        j = i
        while j >= 0:
            m[i, j] = True
            j = parent[j]
    mask = jnp.asarray(np.stack([m] * B))
    _, ref_tl = tree_step(cfg, params,
                          {k: v.copy() for k, v in cache.items()},
                          lens, tt, lens[:, None] + depth, mask)
    for backend in ("pallas", "flash_decode"):
        cfg_b = dataclasses.replace(cfg, prefill_backend=backend,
                                    decode_backend=backend)
        cache_b, lg = prefill(cfg_b, params, toks, lens, init_cache(cfg_b, B))
        np.testing.assert_allclose(np.asarray(lg), np.asarray(ref_lg),
                                   atol=1e-4, rtol=1e-4)
        _, tl = tree_step(cfg_b, params,
                          {k: v.copy() for k, v in cache.items()},
                          lens, tt, lens[:, None] + depth, mask)
        np.testing.assert_allclose(np.asarray(tl), np.asarray(ref_tl),
                                   atol=1e-4, rtol=1e-4)
        assert bool(jnp.all(jnp.argmax(tl, -1) == jnp.argmax(ref_tl, -1)))


# -------------------------------------------------------- serving-level parity
def _prompts(n, lo=6, hi=30, vocab=100, seed=3):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(1, vocab, size=rng.randint(lo, hi)))
            for _ in range(n)]


@pytest.mark.kernels
@pytest.mark.parametrize("sample", [False, True],
                         ids=["greedy", "sampled"])
def test_generate_bit_identical_across_backends(sample):
    cfg = _cfg(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
               vocab_size=53, max_seq_len=160)
    params = init_params(cfg, jax.random.key(1))
    la = LookaheadConfig(decoding_length=8, branch_length=4)
    prompts = _prompts(3, vocab=52)
    outs = {}
    for backend in BACKENDS:
        fns = make_session_fns(cfg, params, slots=la.slots, prefill_len=32,
                               sample=sample, temperature=0.8,
                               base_key=jax.random.key(7), backend=backend)
        eng = LookaheadEngine(fns, la)
        eng.warmup([p[::-1] for p in prompts])       # shared trie content
        outs[backend] = [r.tokens for r in eng.generate_batch(prompts, 14)]
    for backend in BACKENDS[1:]:
        assert outs[backend] == outs["dense"], backend
