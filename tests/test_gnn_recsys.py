import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.gnn import equiformer as eq
from repro.models.gnn import sampler as smp
from repro.models.gnn import so3
from repro.models.recsys import embedding as E

RNG = np.random.RandomState(0)


# ------------------------------------------------------------------ SO(3)
def _rand_rot(n, rng):
    A = rng.randn(n, 3, 3)
    Q = np.linalg.qr(A)[0]
    Q[:, :, 0] *= np.sign(np.linalg.det(Q))[:, None]
    return Q


def test_wigner_orthogonal_and_composes():
    R1 = jnp.asarray(_rand_rot(4, RNG))
    R2 = jnp.asarray(_rand_rot(4, RNG))
    b1 = so3.wigner_blocks(R1, 6)
    b2 = so3.wigner_blocks(R2, 6)
    b12 = so3.wigner_blocks(jnp.einsum("eij,ejk->eik", R1, R2), 6)
    for l in range(7):
        eye = np.eye(2 * l + 1)
        np.testing.assert_allclose(
            np.asarray(jnp.einsum("eij,ekj->eik", b1[l], b1[l])),
            np.broadcast_to(eye, (4,) + eye.shape), atol=2e-5)
        np.testing.assert_allclose(np.asarray(b1[l] @ b2[l]),
                                   np.asarray(b12[l]), atol=2e-5)


def test_wigner_action_on_sph_harm():
    R = jnp.asarray(_rand_rot(3, RNG))
    r = RNG.randn(5, 3)
    r /= np.linalg.norm(r, axis=-1, keepdims=True)
    r = jnp.asarray(r)
    blocks = so3.wigner_blocks(R, 4)
    Y = so3.real_sph_harm(r, 4)
    YR = so3.real_sph_harm(jnp.einsum("eij,nj->eni", R, r), 4)
    off = 0
    for l in range(5):
        n = 2 * l + 1
        np.testing.assert_allclose(
            np.asarray(YR[..., off:off + n]),
            np.asarray(jnp.einsum("eij,nj->eni", blocks[l],
                                  Y[:, off:off + n])), atol=3e-5)
        off += n


def test_rotation_to_z_including_poles():
    v = RNG.randn(10, 3)
    v[0] = [0, 0, 1]
    v[1] = [0, 0, -1]
    v /= np.linalg.norm(v, axis=-1, keepdims=True)
    R = so3.rotation_to_z(jnp.asarray(v))
    out = np.asarray(jnp.einsum("eij,ej->ei", R, jnp.asarray(v)))
    np.testing.assert_allclose(out, np.broadcast_to([0, 0, 1.0], out.shape),
                               atol=1e-5)
    np.testing.assert_allclose(np.linalg.det(np.asarray(R)), 1.0, atol=1e-5)


# ------------------------------------------------------------- equiformer
@pytest.fixture(scope="module")
def eq_setup():
    cfg = eq.EquiformerConfig(n_layers=2, channels=16, l_max=2, m_max=1,
                              n_heads=4, d_feat_in=8, n_rbf=8, n_out=3)
    params = eq.init_params(cfg, jax.random.key(0))
    N, Ed = 20, 60
    g = {"node_feat": jnp.asarray(RNG.randn(N, 8).astype(np.float32)),
         "positions": jnp.asarray(RNG.randn(N, 3).astype(np.float32)),
         "edges": jnp.asarray(RNG.randint(0, N, (Ed, 2)), jnp.int32),
         "edge_mask": jnp.ones((Ed,), bool)}
    return cfg, params, g


def test_rotation_invariance(eq_setup):
    cfg, params, g = eq_setup
    out = eq.forward(cfg, params, g["node_feat"], g["positions"],
                     g["edges"], g["edge_mask"])
    Q = _rand_rot(1, RNG)[0].astype(np.float32)
    out_r = eq.forward(cfg, params, g["node_feat"],
                       g["positions"] @ jnp.asarray(Q.T), g["edges"],
                       g["edge_mask"])
    np.testing.assert_allclose(np.asarray(out["node_out"]),
                               np.asarray(out_r["node_out"]), atol=5e-4)


def test_translation_invariance(eq_setup):
    cfg, params, g = eq_setup
    out = eq.forward(cfg, params, g["node_feat"], g["positions"],
                     g["edges"], g["edge_mask"])
    out_t = eq.forward(cfg, params, g["node_feat"],
                       g["positions"] + jnp.asarray([3.0, -1.0, 2.0]),
                       g["edges"], g["edge_mask"])
    np.testing.assert_allclose(np.asarray(out["node_out"]),
                               np.asarray(out_t["node_out"]), atol=5e-4)


def test_chunked_equals_dense(eq_setup):
    cfg, params, g = eq_setup
    out = eq.forward(cfg, params, g["node_feat"], g["positions"],
                     g["edges"], g["edge_mask"])
    cfg_c = dataclasses.replace(cfg, edge_chunk=20)
    out_c = eq.forward(cfg_c, params, g["node_feat"], g["positions"],
                       g["edges"], g["edge_mask"])
    np.testing.assert_allclose(np.asarray(out["node_out"]),
                               np.asarray(out_c["node_out"]), atol=1e-4)


def test_edge_mask_drops_padding(eq_setup):
    cfg, params, g = eq_setup
    # adding masked-out junk edges must not change anything
    junk = jnp.asarray(RNG.randint(0, 20, (16, 2)), jnp.int32)
    edges2 = jnp.concatenate([g["edges"], junk])
    mask2 = jnp.concatenate([g["edge_mask"], jnp.zeros((16,), bool)])
    o1 = eq.forward(cfg, params, g["node_feat"], g["positions"], g["edges"],
                    g["edge_mask"])
    o2 = eq.forward(cfg, params, g["node_feat"], g["positions"], edges2,
                    mask2)
    np.testing.assert_allclose(np.asarray(o1["node_out"]),
                               np.asarray(o2["node_out"]), atol=1e-4)


# ---------------------------------------------------------------- sampler
def test_neighbor_sampler_validity():
    rng = np.random.RandomState(1)
    full = rng.randint(0, 300, (4000, 2))
    g = smp.CSRGraph.from_edges(full, 300)
    nodes, e, m, slots = smp.sample_subgraph(g, np.arange(16), [5, 3], rng)
    ne = int(m.sum())
    assert ne > 0
    edge_set = {(int(a), int(b)) for a, b in full}
    for i in range(ne):
        s, d = int(e[i, 0]), int(e[i, 1])
        assert (int(nodes[s]), int(nodes[d])) in edge_set
    # seeds are at their reported slots
    for seed, slot in zip(range(16), slots):
        assert int(nodes[slot]) == seed


# ------------------------------------------------------------- embeddings
def test_embedding_bag_combiners():
    t = jnp.asarray(RNG.randn(50, 8).astype(np.float32))
    ids = jnp.asarray(RNG.randint(0, 50, (6, 4)), jnp.int32)
    m = jnp.asarray(RNG.rand(6, 4) > 0.3)
    s = E.embedding_bag(t, ids, mask=m, combiner="sum")
    mean = E.embedding_bag(t, ids, mask=m, combiner="mean")
    mx = E.embedding_bag(t, ids, mask=m, combiner="max")
    emb = np.asarray(jnp.take(t, ids, axis=0))
    mm = np.asarray(m)[..., None]
    np.testing.assert_allclose(np.asarray(s), (emb * mm).sum(1), atol=1e-5)
    denom = np.maximum(mm.sum(1), 1.0)
    np.testing.assert_allclose(np.asarray(mean), (emb * mm).sum(1) / denom,
                               atol=1e-5)
    ref_max = np.where(mm > 0, emb, -np.inf).max(1)
    ref_max = np.where(np.isfinite(ref_max), ref_max, 0.0)
    np.testing.assert_allclose(np.asarray(mx), ref_max, atol=1e-5)


def test_embedding_bag_ragged_matches_fixed():
    t = jnp.asarray(RNG.randn(30, 4).astype(np.float32))
    flat = jnp.asarray([1, 2, 3, 4, 5], jnp.int32)
    seg = jnp.asarray([0, 0, 1, 1, 1], jnp.int32)
    out = E.embedding_bag_ragged(t, flat, seg, 3)
    tn = np.asarray(t)
    np.testing.assert_allclose(np.asarray(out)[0], tn[1] + tn[2], atol=1e-6)
    np.testing.assert_allclose(np.asarray(out)[1], tn[3] + tn[4] + tn[5],
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(out)[2], 0.0, atol=1e-6)


def test_hashed_lookup_shapes_and_determinism():
    q = jnp.asarray(RNG.randn(16, 8).astype(np.float32))
    r = jnp.asarray(RNG.randn(10, 8).astype(np.float32))
    ids = jnp.asarray([0, 9, 17, 159], jnp.int32)
    out = E.hashed_lookup(q, r, ids)
    assert out.shape == (4, 8)
    # same id → same embedding; distinct ids under 160 → (quotient, rem) pairs
    out2 = E.hashed_lookup(q, r, ids)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
