"""THE paper claim: lookahead generation is bit-identical to step-by-step
decoding (greedy and fixed-key sampling), while taking fewer steps."""
import dataclasses

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (LookaheadConfig, LookaheadEngine, baseline_config,
                        llma_config, reference_decode)
from repro.models.transformer import TransformerConfig, init_params
from repro.serving.session import make_session_fns
from repro.training.data import PROFILES, SyntheticCorpus


@pytest.fixture(scope="module")
def dense_fns():
    cfg = TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                            d_ff=128, vocab_size=101, max_seq_len=320)
    params = init_params(cfg, jax.random.key(0))
    return make_session_fns(cfg, params, slots=17)


@pytest.fixture(scope="module")
def moe_fns():
    cfg = TransformerConfig(n_layers=2, d_model=48, n_heads=4, n_kv_heads=4,
                            vocab_size=67, max_seq_len=320, moe=True,
                            n_experts=4, top_k=2, moe_d_ff=32,
                            n_shared_experts=1, moe_impl="ref")
    params = init_params(cfg, jax.random.key(1))
    return make_session_fns(cfg, params, slots=17)


@pytest.fixture(scope="module")
def sample_fns():
    cfg = TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                            d_ff=128, vocab_size=101, max_seq_len=320)
    params = init_params(cfg, jax.random.key(2))
    return make_session_fns(cfg, params, sample=True, temperature=0.8,
                            base_key=jax.random.key(7), slots=17)


def _prompts(n, lo=8, hi=40, vocab=100, seed=0):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(1, vocab, size=rng.randint(lo, hi)))
            for _ in range(n)]


@pytest.mark.parametrize("strategy", ["hierarchical", "parallel", "single"])
def test_lossless_greedy_all_strategies(dense_fns, strategy):
    for i, prompt in enumerate(_prompts(3, seed=3)):
        ref = reference_decode(dense_fns, prompt, 40)
        eng = LookaheadEngine(dense_fns, LookaheadConfig(
            decoding_length=16, branch_length=6, strategy=strategy))
        eng.warmup([ref])
        out = eng.generate(prompt, 40)
        assert out.tokens == ref, (strategy, i)
        assert out.stats.steps <= len(ref)        # never MORE steps


def test_lossless_moe(moe_fns):
    for prompt in _prompts(3, vocab=66, seed=4):
        ref = reference_decode(moe_fns, prompt, 32)
        eng = LookaheadEngine(moe_fns, LookaheadConfig(decoding_length=12,
                                                       branch_length=5))
        eng.warmup([ref])
        out = eng.generate(prompt, 32)
        assert out.tokens == ref


def test_lossless_sampling(sample_fns):
    for prompt in _prompts(3, seed=5):
        ref = reference_decode(sample_fns, prompt, 32)
        eng = LookaheadEngine(sample_fns, LookaheadConfig(decoding_length=12,
                                                          branch_length=5))
        eng.warmup([ref])
        out = eng.generate(prompt, 32)
        assert out.tokens == ref


def test_lossless_batched(dense_fns):
    prompts = _prompts(4, seed=6)
    refs = [reference_decode(dense_fns, p, 30) for p in prompts]
    eng = LookaheadEngine(dense_fns, LookaheadConfig(decoding_length=16,
                                                     branch_length=6))
    eng.warmup(refs)
    outs = eng.generate_batch(prompts, 30)
    for o, r in zip(outs, refs):
        assert o.tokens == r


def test_trie_state_never_corrupts_output(dense_fns):
    """Serving many different requests through ONE engine (shared trie) must
    stay lossless for every request — the deployment invariant."""
    eng = LookaheadEngine(dense_fns, LookaheadConfig(decoding_length=16,
                                                     branch_length=6))
    for prompt in _prompts(6, seed=7):
        ref = reference_decode(dense_fns, prompt, 24)
        out = eng.generate(prompt, 24)
        assert out.tokens == ref


def test_eos_stops_generation():
    cfg = TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                            d_ff=64, vocab_size=13, max_seq_len=320)
    params = init_params(cfg, jax.random.key(3))
    fns = make_session_fns(cfg, params, slots=9)
    prompt = [1, 2, 3]
    ref = reference_decode(fns, prompt, 50, eos_id=5)
    eng = LookaheadEngine(fns, LookaheadConfig(decoding_length=8,
                                               branch_length=4), eos_id=5)
    out = eng.generate(prompt, 50)
    assert out.tokens == ref
    if 5 in ref:
        assert ref.index(5) == len(ref) - 1


def test_speedup_on_templated_corpus(dense_fns):
    """On a corpus with n-gram reuse the steps-compression must beat 1.3x
    once the trie is warm (paper Fig. 6)."""
    corpus = SyntheticCorpus(PROFILES["antrag"], 100, seed=9)
    eng = LookaheadEngine(dense_fns, LookaheadConfig(decoding_length=24,
                                                     branch_length=8))
    # warm with model outputs for corpus prompts
    prompts = [corpus.sample()[0][:48] for _ in range(4)]
    for p in prompts:
        eng.generate(p, 40)
    out = eng.generate(prompts[0], 40)      # repeat seen prompt
    assert out.stats.edl > 1.3, out.stats
