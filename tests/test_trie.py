import random

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.trie import TrieTree


def _walk(trie):
    """Snapshot the trie as {root-path: (freq, frozenset(prompt rids))}."""
    out = {}
    stack = [((), trie.root)]
    while stack:
        path, node = stack.pop()
        for tok, child in node.children.items():
            p = path + (tok,)
            out[p] = (child.freq, frozenset(child.prompt_freq))
            stack.append((p, child))
    return out


def test_insert_retrieve_roundtrip():
    t = TrieTree(capacity=1000)
    t.insert([1, 2, 3])
    t.insert([1, 2, 4])
    t.insert([9, 9])
    branches, scores = t.retrieve([5, 1], decoding_length=8)
    paths = {tuple(b) for b in branches}
    assert (2,) in paths and (2, 3) in paths and (2, 4) in paths
    assert len(scores) == len(branches)


def test_multi_stage_backoff():
    t = TrieTree(capacity=1000)
    t.insert([7, 8, 9])
    # context suffix [3, 7] fails at len 2, backs off to [7]
    branches, _ = t.retrieve([3, 7], decoding_length=8)
    assert (8,) in {tuple(b) for b in branches}


def test_frequency_ranking_and_budget():
    t = TrieTree(capacity=1000)
    for _ in range(5):
        t.insert([1, 2])
    t.insert([1, 3])
    branches, scores = t.retrieve([1], decoding_length=1)
    assert branches[0] == [2]          # highest frequency wins the budget
    assert len(branches) == 1


def test_prompt_boost():
    t = TrieTree(capacity=1000, prompt_boost=100.0)
    for _ in range(5):
        t.insert([1, 2])               # output branch, freq 5
    t.insert([1, 3], request_id=42)    # prompt branch, freq 1 but boosted
    branches, _ = t.retrieve([1], decoding_length=1)
    assert branches[0] == [3]


def test_eliminate_removes_prompt_branches():
    t = TrieTree(capacity=1000)
    t.insert([1, 2, 3], request_id=7)
    assert len(t) == 3
    t.eliminate(7)
    assert len(t) == 0
    # persistent branches survive other requests' elimination
    t.insert([4, 5])
    t.eliminate(7)
    assert len(t) == 2


def test_prune_decay():
    t = TrieTree(capacity=8, decay=0.5)
    for i in range(20):
        t.insert([i, i + 100, i + 200])   # push over capacity repeatedly
    assert len(t) <= 8 * 3  # prune keeps it bounded (runs during insert)


def test_ngram_insert_window():
    t = TrieTree(capacity=10_000)
    t.insert_ngrams([1, 2, 3, 4, 5], branch_length=3)
    assert t.match([3, 4, 5]) is not None
    assert t.match([1, 2, 3]) is not None
    assert t.match([1, 3]) is None


@settings(max_examples=50, deadline=None)
@given(st.lists(st.lists(st.integers(0, 20), min_size=1, max_size=6),
                min_size=1, max_size=30))
def test_property_retrieved_paths_exist(branch_sets):
    t = TrieTree(capacity=100_000)
    for b in branch_sets:
        t.insert(b)
    for ctx in ([branch_sets[0][0]], [0], [20]):
        branches, _ = t.retrieve(ctx, decoding_length=16)
        for br in branches:
            assert t.match(list(ctx[-1:]) + br) is not None or \
                t.match(br) is not None


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 6), st.lists(st.integers(0, 50), min_size=2,
                                   max_size=40))
def test_property_capacity_bound(cap_factor, tokens):
    cap = cap_factor * 8
    t = TrieTree(capacity=cap, decay=0.0)
    t.insert_ngrams(tokens, branch_length=4)
    # decay=0 prune removes every prunable node when tripped
    assert len(t) <= max(cap, 4)


# --------------------------------------------------------------------------
# Random-operation invariants (ISSUE 3 satellite): after ANY interleaving of
# insert / eliminate / decay-prune, the trie's bookkeeping stays consistent,
# retrieval only ever returns real root-paths, and eliminating one request
# never perturbs persistent (output-branch) frequencies.
# --------------------------------------------------------------------------
BRANCH_LEN = 4


def _random_ops(rng, t, n_ops, vocab=12):
    """Apply a random op sequence; returns the set of live prompt rids."""
    live = set()
    for _ in range(n_ops):
        op = rng.randrange(4)
        if op == 0:                                     # output branch
            toks = [rng.randrange(vocab)
                    for _ in range(rng.randint(1, BRANCH_LEN))]
            t.insert(toks)
        elif op == 1:                                   # prompt branch
            rid = rng.randrange(6)
            toks = [rng.randrange(vocab)
                    for _ in range(rng.randint(2, 2 * BRANCH_LEN))]
            t.insert_ngrams(toks, BRANCH_LEN, request_id=rid)
            live.add(rid)
        elif op == 2 and live:                          # branch eliminating
            rid = rng.choice(sorted(live))
            t.eliminate(rid)
            live.discard(rid)
        else:                                           # decay-prune
            t.prune()
    return live


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_property_node_count_consistent_and_bounded(seed):
    rng = random.Random(seed)
    cap = rng.choice([8, 16, 32])
    t = TrieTree(capacity=cap, decay=0.0)
    live = _random_ops(rng, t, rng.randint(5, 40))
    snap = _walk(t)
    # len(t) is exactly the number of live nodes (no leaked bookkeeping)
    assert len(t) == len(snap)
    # with decay=0 every prune removes all unprotected nodes, so the trie
    # can only exceed capacity by live prompt paths plus the overshoot of
    # the single insert that tripped the prune
    protected = sum(1 for _, (f, rids) in snap.items() if rids)
    assert len(t) <= max(cap, protected) + 2 * BRANCH_LEN
    # eliminating every live request with decay=0 prunes to (almost) empty
    for rid in sorted(live):
        t.eliminate(rid)
    t.prune()
    assert len(t) <= 2 * BRANCH_LEN


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_property_retrieved_branches_are_root_paths(seed):
    rng = random.Random(seed)
    t = TrieTree(capacity=64, decay=0.5)
    _random_ops(rng, t, rng.randint(5, 40))
    for _ in range(5):
        ctx = [rng.randrange(12) for _ in range(rng.randint(1, 8))]
        branches, scores = t.retrieve(ctx, decoding_length=16)
        assert len(branches) == len(scores)
        for br in branches:
            # the branch must extend some suffix of the context through
            # real trie nodes (retrieve matched exactly such a suffix)
            assert any(
                t.match(ctx[-plen:] + br) is not None
                for plen in range(1, min(8, len(ctx)) + 1)), (ctx, br)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_property_eliminate_preserves_persistent_freqs(seed):
    rng = random.Random(seed)
    t = TrieTree(capacity=10_000)    # no pruning interference
    live = _random_ops(rng, t, rng.randint(5, 30))
    before = _walk(t)
    victim = rng.choice(sorted(live)) if live else 99
    t.eliminate(victim)
    after = _walk(t)
    for path, (freq, rids) in before.items():
        if freq > 0.0:
            # persistent frequency survives any other request's elimination
            assert path in after, (path, victim)
            assert after[path][0] == freq, path
        if path in after:
            assert after[path][1] == rids - {victim}, path
    # no path appears from nowhere
    assert set(after) <= set(before)

