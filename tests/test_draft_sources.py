"""Draft-source registry, merger, namespaces, adaptive budget (ISSUE 5).

Host-side units: DraftPolicy validation, the multi-source merger's
quota/dedup/budget accounting, PromptCopySource / NgramSource retrieval,
TrieSource namespace isolation under shared capacity accounting, and the
bit-identity of the default policy's draft trees with the legacy hardwired
path.

End-to-end parity: every shipped source alone AND merged combinations
(adaptive on and off) through the continuous scheduler equal
``reference_decode`` bit-for-bit on both KV layouts × dense/pallas
backends — the DraftSource layer is host-only, so I1 must be untouched by
ANY policy.  Plus: per-source telemetry invariants and compile-once (I2)
under mixed per-request policies.
"""
import jax
import numpy as np
import pytest

from repro.core import (AdaptiveBudget, DraftPolicy, LookaheadConfig,
                        NgramSource, PromptCopySource, TrieSource, TrieTree,
                        available_sources, build_draft_from_policy,
                        build_draft_tree, merge_branches, reference_decode)
from repro.core.request import Request, SamplingParams
from repro.models.transformer import TransformerConfig, init_params
from repro.serving.scheduler import ContinuousScheduler
from repro.serving.session import make_session_fns

pytestmark = pytest.mark.draft

PREFILL = 32
SLOTS = 9
VOCAB = 53

_CFG = TransformerConfig(n_layers=1, d_model=32, n_heads=4, n_kv_heads=2,
                         d_ff=64, vocab_size=VOCAB, max_seq_len=160)
_PARAMS = init_params(_CFG, jax.random.key(11))
_SESSIONS = {}
_REFS = {}

CELLS = (("dense", "dense"), ("dense", "pallas"),
         ("paged", "dense"), ("paged", "pallas"))


def _get_fns(layout, backend):
    key = (layout, backend)
    if key not in _SESSIONS:
        _SESSIONS[key] = make_session_fns(
            _CFG, _PARAMS, slots=SLOTS, prefill_len=PREFILL, backend=backend,
            kv_layout=layout, block_size=16 if layout == "paged" else None)
    return _SESSIONS[key]


def _ref(cell, prompt, max_new):
    key = (cell, tuple(prompt), max_new)
    if key not in _REFS:
        _REFS[key] = reference_decode(_get_fns(*cell), prompt, max_new)
    return _REFS[key]


def _la(**kw):
    base = dict(decoding_length=SLOTS - 1, branch_length=4)
    base.update(kw)
    return LookaheadConfig(**base)


def _prompts(n, seed, lo=2, hi=24):
    rng = np.random.RandomState(seed)
    return [rng.randint(1, VOCAB - 1, size=rng.randint(lo, hi)).tolist()
            for _ in range(n)]


# ------------------------------------------------------------------- registry
def test_registry_ships_three_sources():
    names = available_sources()
    for required in ("trie", "prompt_copy", "ngram"):
        assert required in names


def test_policy_validation():
    DraftPolicy().validate()
    DraftPolicy(sources=("trie", "ngram"), quotas=(6, 2)).validate()
    with pytest.raises(ValueError, match="empty"):
        DraftPolicy(sources=()).validate()
    with pytest.raises(ValueError, match="unknown draft source"):
        DraftPolicy(sources=("nope",)).validate()
    with pytest.raises(ValueError, match="duplicate"):
        DraftPolicy(sources=("trie", "trie")).validate()
    with pytest.raises(ValueError, match="one cap per source"):
        DraftPolicy(sources=("trie", "ngram"), quotas=(4,)).validate()
    with pytest.raises(ValueError, match="quota"):
        DraftPolicy(sources=("trie",), quotas=(0,)).validate()
    with pytest.raises(ValueError, match="min_budget"):
        DraftPolicy(min_budget=0).validate()
    with pytest.raises(ValueError, match="ema_alpha"):
        DraftPolicy(ema_alpha=0.0).validate()


def test_unknown_source_rejected_at_submit():
    fns = _get_fns("dense", "dense")
    sched = ContinuousScheduler(fns, _la(), lanes=1, prefill_len=PREFILL)
    with pytest.raises(ValueError, match="unknown draft source"):
        sched.submit_request(Request(
            prompt=[1, 2, 3],
            params=SamplingParams(max_new_tokens=4,
                                  draft=DraftPolicy(sources=("bogus",)))))


# ----------------------------------------------------- default bit-identity
def test_default_policy_trees_bit_identical_to_legacy():
    """The single-trie default MUST build slot-for-slot identical trees to
    the pre-registry ``build_draft_tree`` for any trie state."""
    rng = np.random.RandomState(3)
    cfg = _la(decoding_length=16, branch_length=6)
    for _ in range(50):
        trie = TrieTree(capacity=4096)
        src = TrieSource(cfg, trie=trie)
        for _ in range(rng.randint(1, 25)):
            seq = rng.randint(1, 40, size=rng.randint(2, 12)).tolist()
            trie.insert_ngrams(
                seq, cfg.branch_length,
                request_id=int(rng.randint(3)) if rng.rand() < .5 else None)
        ctx = rng.randint(1, 40, size=rng.randint(1, 20)).tolist()
        W = 1 + cfg.decoding_length
        a = build_draft_tree(trie, cfg, ctx, 0, W)
        b = build_draft_from_policy([src], DraftPolicy(), cfg, 0, ctx, 0, W)
        np.testing.assert_array_equal(a.tokens, b.tokens)
        np.testing.assert_array_equal(a.parent, b.parent)
        np.testing.assert_array_equal(a.tree_mask, b.tree_mask)
        assert a.n_slots == b.n_slots


# ------------------------------------------------------------------- sources
def test_prompt_copy_retrieves_continuation_of_suffix_match():
    cfg = _la(branch_length=6)
    src = PromptCopySource(cfg)
    # suffix [1,2,3] occurred earlier; its continuation is [4,5,6,...]
    ctx = [9, 1, 2, 3, 4, 5, 6, 7, 8, 1, 2, 3]
    branches, scores = src.retrieve(0, ctx, budget=8)
    assert branches and branches[0][:4] == [4, 5, 6, 7]
    # no earlier occurrence of any suffix -> no branches
    assert src.retrieve(0, [1, 2, 3, 4, 5], budget=8) == ([], [])
    # budget bounds the copied chain
    short, _ = src.retrieve(0, ctx, budget=2)
    assert all(len(b) <= 2 for b in short)


def test_prompt_copy_is_per_request_state_free():
    """Nothing a request does leaks into another request's retrievals."""
    cfg = _la()
    src = PromptCopySource(cfg)
    src.observe_prompt(1, [5, 6, 7, 5, 6, 7])
    src.observe_output(1, [5, 6, 7, 5, 6])
    # request 2's context has no repeats -> empty regardless of request 1
    assert src.retrieve(2, [10, 11, 12, 13], budget=8) == ([], [])
    src.retire(1)


def test_single_source_quota_caps_tree():
    """A quota on a one-source policy bounds the tree like it would on the
    merge path (regression: it used to be silently ignored)."""
    cfg = _la(decoding_length=8, branch_length=8)
    src = NgramSource(cfg)
    src.observe_prompt(0, [1, 2, 3] * 8)
    pol = DraftPolicy(sources=("ngram",), quotas=(2,))
    tree = build_draft_from_policy([src], pol, cfg, 0, [1, 2], 0,
                                   width=1 + cfg.decoding_length)
    assert 1 < tree.n_slots <= 3          # root + at most the 2-slot quota
    uncapped = build_draft_from_policy([src], DraftPolicy(sources=("ngram",)),
                                       cfg, 0, [1, 2], 0,
                                       width=1 + cfg.decoding_length)
    assert uncapped.n_slots > tree.n_slots


def test_ngram_incremental_observe_counts_once():
    """Streaming observe_output must produce the same count table as one
    bulk absorb of the final output (regression: the overlap window used to
    double-count n-grams near each high-water mark)."""
    cfg = _la(branch_length=5)
    out = [1, 2, 3, 1, 2, 3, 1, 2, 3, 4]
    inc = NgramSource(cfg)
    for cut in (2, 3, 5, 6, 9, len(out)):
        inc.observe_output(7, out[:cut])
    bulk = NgramSource(cfg)
    bulk._absorb(out)
    assert inc._counts == bulk._counts


def test_ngram_source_learns_and_continues():
    cfg = _la(branch_length=5)
    src = NgramSource(cfg)
    src.observe_prompt(0, [1, 2, 3, 1, 2, 3, 1, 2, 3])
    branches, _ = src.retrieve(0, [7, 1, 2], budget=8)
    assert branches and branches[0][0] == 3
    # the model adapts across requests (shared, like the trie)
    branches2, _ = src.retrieve(99, [2, 3, 1], budget=8)
    assert branches2 and branches2[0][0] == 2
    # cold model -> nothing
    assert NgramSource(cfg).retrieve(0, [1, 2, 3], budget=8) == ([], [])


# -------------------------------------------------------------------- merger
def test_merger_respects_quotas_budget_and_dedup():
    per = [
        ("a", [[1], [1, 2], [1, 2, 3], [7], [7, 8]],
         [5.0, 4.0, 3.0, 2.0, 1.0]),
        ("b", [[1, 2, 3, 4, 5], [9, 9, 9]], [9.0, 8.0]),
    ]
    branches, scores, tags = merge_branches(per, budget=6, quotas=[3, 3])
    # total NEW tokens across merged branches == budget
    seen = set()
    total = 0
    per_src = {"a": 0, "b": 0}
    for b, t in zip(branches, tags):
        path = tuple(b)
        known = len(path)
        while known > 0 and path[:known] not in seen:
            known -= 1
        new = len(path) - known
        for d in range(known + 1, len(path) + 1):
            seen.add(path[:d])
        total += new
        per_src[t] += new
    assert total <= 6
    assert per_src["a"] <= 3 and per_src["b"] <= 3
    # b's [1,2,3,4,5] overlaps a's [1,2,3]: only its NEW tail is charged
    assert ("b" in tags)
    # a fully-covered branch is skipped outright
    per2 = [("a", [[1, 2]], [1.0]), ("b", [[1, 2]], [1.0])]
    b2, _, t2 = merge_branches(per2, budget=8, quotas=[8, 8])
    assert t2 == ["a"]          # b's identical branch added nothing
    # quota exhaustion stops a source but not the others
    per3 = [("a", [[1, 2, 3, 4, 5, 6]], [1.0]), ("b", [[8, 9]], [1.0])]
    b3, _, t3 = merge_branches(per3, budget=8, quotas=[2, 8])
    a_new = sum(len(b) for b, t in zip(b3, t3) if t == "a")
    assert a_new == 2 and "b" in t3


def test_merger_interleaves_sources_round_robin():
    per = [("a", [[1], [2], [3]], [1.0, 1.0, 1.0]),
           ("b", [[4], [5], [6]], [1.0, 1.0, 1.0])]
    _, _, tags = merge_branches(per, budget=4, quotas=[4, 4])
    assert tags == ["a", "b", "a", "b"]


# ---------------------------------------------------------- adaptive budget
def test_adaptive_budget_warmup_growth_and_decay():
    ctl = AdaptiveBudget(32, min_budget=4, alpha=0.5, headroom=2.0)
    assert ctl.value == 4                     # warmup: start at the floor
    for _ in range(10):
        ctl.update(20)
    assert ctl.value == 32                    # sustained acceptance -> cap
    for _ in range(20):
        ctl.update(1)
    assert ctl.value == 4                     # dry steps -> back to floor
    # clamping: floor above cap collapses to cap
    assert AdaptiveBudget(2, min_budget=10).value == 2


def test_adaptive_budget_from_policy():
    pol = DraftPolicy(adaptive=True, min_budget=2, ema_alpha=1.0,
                      headroom=1.0)
    ctl = AdaptiveBudget.from_policy(pol, 8)
    ctl.update(5)
    assert ctl.value == 5


# ---------------------------------------------------------------- namespaces
def test_namespace_isolation_retrieval_and_eliminate():
    """Tenant A's inserts/eliminates never perturb tenant B's retrievals."""
    cfg = _la(decoding_length=8, branch_length=5)
    src = TrieSource(cfg)
    src.observe_prompt(1, [1, 2, 3, 4, 5, 6], namespace="a")
    src.observe_prompt(2, [1, 2, 9, 9, 9, 9], namespace="b")
    before = src.retrieve(2, [1, 2], budget=8, namespace="b")
    # A's branches are invisible to B (and vice versa)
    a_only = src.retrieve(1, [1, 2], budget=8, namespace="a")
    assert a_only[0] and before[0] and a_only[0] != before[0]
    # retiring A (eliminate + capacity check) leaves B untouched
    src.retire(1, namespace="a")
    assert src.retrieve(2, [1, 2], budget=8, namespace="b") == before
    # A's prompt branches are gone from A's own namespace
    assert src.retrieve(1, [1, 2], budget=8, namespace="a") == ([], [])
    # unknown namespace: no state created, nothing retrieved
    assert src.retrieve(3, [1, 2], budget=8, namespace="zz") == ([], [])
    assert "zz" not in src.forest.namespaces()


def test_namespace_shared_capacity_accounting():
    """One node budget across namespaces: exceeding it decay-prunes every
    namespace (persistent low-freq branches fall out of both tenants)."""
    cfg = _la()
    src = TrieSource(cfg)
    src.forest.capacity = 40
    for ns in ("a", "b"):
        for s in range(6):
            seq = [100 * (ns == "b") + 10 * s + d for d in range(6)]
            src.forest.tree(ns).insert(seq)     # persistent, freq 1
    assert len(src.forest) > src.forest.capacity
    src.forest.check_capacity()
    # freq-1 leaf chains decay to 0.5 < 1 and are pruned in BOTH namespaces
    assert len(src.forest) == 0
    assert set(src.forest.namespaces()) == {"", "a", "b"}


def test_namespace_end_to_end_lossless():
    """Per-request namespaces through the scheduler: isolated tries, shared
    capacity, outputs still equal reference decode."""
    fns = _get_fns("dense", "dense")
    prompts = _prompts(4, seed=31)
    sched = ContinuousScheduler(fns, _la(), lanes=2, prefill_len=PREFILL)
    handles = []
    for i, p in enumerate(prompts):
        pol = DraftPolicy(namespace=f"tenant{i % 2}")
        handles.append(sched.submit_request(Request(
            prompt=p, params=SamplingParams(max_new_tokens=12, draft=pol))))
    sched.run()
    for p, h in zip(prompts, handles):
        assert h.result().tokens == _ref(("dense", "dense"), p, 12)
    ns = sched.sources["trie"].forest.namespaces()
    assert "tenant0" in ns and "tenant1" in ns


# ------------------------------------------------------------ parity suite
POLICIES = {
    "trie": DraftPolicy(),
    "prompt_copy": DraftPolicy(sources=("prompt_copy",)),
    "ngram": DraftPolicy(sources=("ngram",)),
    "trie+ngram": DraftPolicy(sources=("trie", "ngram"), quotas=(6, 2)),
    "all+adaptive": DraftPolicy(
        sources=("trie", "prompt_copy", "ngram"), adaptive=True,
        min_budget=2),
}


@pytest.mark.parametrize("policy_name", sorted(POLICIES))
def test_source_parity_vs_reference_all_cells(policy_name):
    """Each source alone and merged (± adaptive budget) is lossless on every
    (kv layout × attention backend) cell — and all cells agree."""
    policy = POLICIES[policy_name]
    prompts = _prompts(3, seed=17)
    budgets = [11, 5, 14]
    outs = {}
    for cell in CELLS:
        fns = _get_fns(*cell)
        sched = ContinuousScheduler(fns, _la(), lanes=2,
                                    prefill_len=PREFILL,
                                    draft_policy=policy)
        rid_to_idx = {}
        for i, (p, m) in enumerate(zip(prompts, budgets)):
            h = sched.submit_request(Request(
                prompt=p, params=SamplingParams(max_new_tokens=m)))
            rid_to_idx[h.rid] = i
        res = sched.run()
        got = [None] * len(prompts)
        for r in res:
            i = rid_to_idx[r.rid]
            got[i] = r.tokens
            assert r.tokens == _ref(cell, prompts[i], budgets[i]), \
                (policy_name, cell, i)
        outs[cell] = got
    baseline = outs[("dense", "dense")]
    for cell, got in outs.items():
        assert got == baseline, (policy_name, cell)


def test_mixed_policies_one_pool_lossless():
    """Different requests speculate through different sources inside ONE
    lane pool; each stays lossless (policy is per-request, like params)."""
    fns = _get_fns("dense", "dense")
    prompts = _prompts(5, seed=23)
    pols = [DraftPolicy(), DraftPolicy(sources=("prompt_copy",)),
            DraftPolicy(sources=("ngram",)),
            DraftPolicy(sources=("trie", "ngram")),
            DraftPolicy(adaptive=True, min_budget=1)]
    sched = ContinuousScheduler(fns, _la(), lanes=2, prefill_len=PREFILL)
    handles = [sched.submit_request(Request(
        prompt=p, params=SamplingParams(max_new_tokens=10, draft=pol)))
        for p, pol in zip(prompts, pols)]
    sched.run()
    for p, h in zip(prompts, handles):
        assert h.result().tokens == _ref(("dense", "dense"), p, 10)


# ---------------------------------------------------------------- telemetry
def test_per_source_telemetry_invariants():
    """sum(source_accepted) == tokens - steps (one free root token per
    step), and drafted counts cover every live tree slot."""
    fns = _get_fns("dense", "dense")
    prompts = _prompts(4, seed=41, lo=8, hi=24)
    sched = ContinuousScheduler(
        fns, _la(), lanes=2, prefill_len=PREFILL,
        draft_policy=DraftPolicy(sources=("trie", "ngram")))
    handles = [sched.submit_request(Request(
        prompt=p, params=SamplingParams(max_new_tokens=16)))
        for p in prompts]
    sched.run()
    any_drafted = False
    for h in handles:
        st = h.result().stats
        assert sum(st.source_accepted.values()) == st.tokens - st.steps
        for name, acc in st.source_accepted.items():
            assert acc <= st.source_drafted.get(name, 0)
        assert set(st.source_drafted) <= {"trie", "ngram"}
        any_drafted = any_drafted or bool(st.source_drafted)
        rates = st.source_acceptance()
        assert all(0.0 <= r <= 1.0 for r in rates.values())
    assert any_drafted


# ------------------------------------------------------------- compile-once
def test_compile_once_under_mixed_policies():
    """I2: per-request draft policies (incl. adaptive budgets and merged
    sources) are host-side only — no StepFns member retraces."""
    cfg = TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                            d_ff=64, vocab_size=VOCAB, max_seq_len=160)
    params = init_params(cfg, jax.random.key(5))
    fresh = make_session_fns(cfg, params, slots=SLOTS, prefill_len=PREFILL)
    for pol in POLICIES.values():
        sched = ContinuousScheduler(fresh, _la(), lanes=2,
                                    prefill_len=PREFILL, draft_policy=pol)
        for p in _prompts(3, seed=7):
            sched.submit(p, 8)
        sched.run()
    assert fresh.prefill._cache_size() == 1
    assert fresh.prefill_into_slot._cache_size() == 1
    assert fresh.fused_step._cache_size() == 1
    assert fresh.tree_step._cache_size() == 0   # unfused parity oracle only
    assert fresh.commit._cache_size() == 0
