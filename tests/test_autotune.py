"""Per-namespace draft-source auto-tuning (DESIGN.md §Multi-tenant SLOs).

Unit level: the EMA controller's disable/probe/re-enable state machine and
its gate decisions.  Integration level: a scheduler whose policy includes a
source that never verifies — the controller must zero its quota (and skip
its retrieve cost) on that namespace while outputs stay bit-identical to
an autotune-off run AND reference_decode (I1: gating only shapes which
draft tokens get built; verification is lossless).  Compile level: the
controller's state feeds no traced shape, so it can never retrace (I2).
"""
import jax
import numpy as np
import pytest

from repro.core import LookaheadConfig, reference_decode
from repro.core.autotune import (AutoTuneConfig, AutoTuner,
                                 NamespaceController)
from repro.core.draft_sources import (DraftPolicy, DraftSource,
                                      register_source)
from repro.core.request import Request, SamplingParams
from repro.models.transformer import TransformerConfig, init_params
from repro.serving.scheduler import ContinuousScheduler
from repro.serving.session import make_session_fns

PREFILL = 48


@pytest.fixture(scope="module")
def fns():
    cfg = TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                            d_ff=128, vocab_size=101, max_seq_len=320)
    params = init_params(cfg, jax.random.key(0))
    return make_session_fns(cfg, params, slots=17, prefill_len=PREFILL)


def _prompts(n, lo=8, hi=40, vocab=100, seed=0):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(1, vocab, size=rng.randint(lo, hi)))
            for _ in range(n)]


def _la(**kw):
    base = dict(decoding_length=16, branch_length=6)
    base.update(kw)
    return LookaheadConfig(**base)


class CountingJunk(DraftSource):
    """Drafts a chain of one repeated token; counts retrieve calls so tests
    can prove a disabled source stops paying its host-side cost."""
    name = "junk"

    def __init__(self, config, token=1, chain=4):
        super().__init__(config)
        self.token = token
        self.chain = chain
        self.retrieves = 0

    def retrieve(self, rid, context, *, budget, namespace=""):
        self.retrieves += 1
        k = min(self.chain, budget)
        return ([[self.token] * k], [1.0]) if k >= 1 else ([], [])


# DraftPolicy.validate checks the global registry; the schedulers below get
# their own counting instance through the ``sources`` dict regardless
register_source("junk", CountingJunk)


# ------------------------------------------------------------------ unit
def test_config_validation():
    AutoTuneConfig().validate()
    with pytest.raises(ValueError):
        AutoTuneConfig(min_trials=0).validate()
    with pytest.raises(ValueError):
        AutoTuneConfig(drop_rate=1.0).validate()
    with pytest.raises(ValueError):
        AutoTuneConfig(ema_alpha=0.0).validate()
    with pytest.raises(ValueError):
        AutoTuneConfig(probe_period=0).validate()
    with pytest.raises(ValueError):
        AutoTuneConfig(probe_quota=0).validate()


def test_controller_disables_after_min_trials():
    ctl = NamespaceController(AutoTuneConfig(min_trials=20, drop_rate=0.05))
    # under min_trials: a dead source stays enabled (cold-start protection)
    ctl.observe({"junk": 10}, {"junk": 0})
    assert ctl.stat("junk").enabled
    keep, kq = ctl.gate(["junk", "trie"], [4, 8])
    assert keep == [0, 1] and kq == [4, 8]
    # past min_trials with EMA < drop_rate: disabled, quota zeroed
    ctl.observe({"junk": 15}, {"junk": 0})
    st = ctl.stat("junk")
    assert not st.enabled and st.disables == 1
    keep, kq = ctl.gate(["junk", "trie"], [4, 8])
    assert keep == [1] and kq == [8]


def test_controller_keeps_productive_source():
    ctl = NamespaceController(AutoTuneConfig(min_trials=8, drop_rate=0.05))
    for _ in range(10):
        ctl.observe({"trie": 10}, {"trie": 6})
    st = ctl.stat("trie")
    assert st.enabled and st.ema == pytest.approx(0.6)
    assert st.rate == pytest.approx(0.6)


def test_probe_and_reenable():
    cfg = AutoTuneConfig(min_trials=4, drop_rate=0.05, probe_period=3,
                         probe_quota=2, ema_alpha=1.0)
    ctl = NamespaceController(cfg)
    ctl.observe({"junk": 8}, {"junk": 0})
    assert not ctl.stat("junk").enabled
    # two decisions: skipped; the third grants a probe at probe_quota
    for _ in range(2):
        assert ctl.gate(["junk", "trie"], [6, 6])[0] == [1]
    keep, kq = ctl.gate(["junk", "trie"], [6, 6])
    assert keep == [0, 1] and kq == [2, 6]
    assert ctl.stat("junk").probes == 1
    # the probe pays off (workload drift): re-enabled at full quota
    ctl.observe({"junk": 2}, {"junk": 2})
    assert ctl.stat("junk").enabled
    assert ctl.gate(["junk"], [6]) == ([0], [6])


def test_gate_fallback_never_strips_all_speculation():
    cfg = AutoTuneConfig(min_trials=1, drop_rate=0.05, probe_period=100)
    ctl = NamespaceController(cfg)
    ctl.observe({"a": 4, "b": 4}, {})
    assert ctl.gate(["a", "b"], [3, 5]) == ([0], [3])


def test_autotuner_namespaces_are_isolated():
    tun = AutoTuner(AutoTuneConfig(min_trials=4))
    tun.observe("cold", {"junk": 8}, {"junk": 0})
    tun.observe("warm", {"junk": 8}, {"junk": 8})
    assert not tun.controller("cold").stat("junk").enabled
    assert tun.controller("warm").stat("junk").enabled
    snap = tun.snapshot()
    assert snap["cold"]["junk"]["enabled"] is False
    assert snap["warm"]["junk"]["rate"] == pytest.approx(1.0)


# ----------------------------------------------------------- integration
def _run_workload(fns, prompts, budgets, *, junk=None, autotune=False,
                  namespace="x"):
    policy = DraftPolicy(sources=("trie", "junk"),
                         namespace=namespace).validate()
    la = _la()
    sources = {"junk": junk if junk is not None else CountingJunk(la)}
    sched = ContinuousScheduler(fns, la, lanes=2, prefill_len=PREFILL,
                                sources=sources, autotune=autotune)
    handles = [sched.submit_request(Request(
        prompt=list(p),
        params=SamplingParams(max_new_tokens=m, draft=policy)))
        for p, m in zip(prompts, budgets)]
    sched.run()
    return [h.result().tokens for h in handles], sched


def test_scheduler_zeroes_dead_source_and_stays_lossless(fns):
    """The tentpole end-to-end: a junk source that never verifies is
    disabled on its namespace, its retrieve cost stops accruing, and every
    output is bit-identical with the controller on, off, and to
    reference_decode."""
    prompts = _prompts(6, seed=71)
    budgets = [24, 6, 24, 12, 24, 8]
    refs = [reference_decode(fns, p, m) for p, m in zip(prompts, budgets)]

    off_out, _ = _run_workload(fns, prompts, budgets, autotune=False)
    tuner = AutoTuner(AutoTuneConfig(min_trials=8, drop_rate=0.05,
                                     probe_period=10_000))
    junk = CountingJunk(_la())
    on_out, sched = _run_workload(fns, prompts, budgets, junk=junk,
                                  autotune=tuner)
    assert on_out == off_out == refs        # I1: gating never moves a token

    snap = sched.autotuner.snapshot()["x"]["junk"]
    assert snap["enabled"] is False and snap["disables"] >= 1
    assert snap["ema"] < 0.05

    # disabled means SKIPPED: more traffic on the same scheduler adds no
    # junk retrieve calls (probe_period is out of reach)
    before = junk.retrieves
    more = _prompts(3, seed=72)
    h2 = [sched.submit_request(Request(
        prompt=list(p),
        params=SamplingParams(
            max_new_tokens=10,
            draft=DraftPolicy(sources=("trie", "junk"), namespace="x"))))
        for p in more]
    sched.run()
    assert junk.retrieves == before
    for h, p in zip(h2, more):
        assert h.result().tokens == reference_decode(fns, p, 10)


def test_autotune_is_per_namespace(fns):
    """One scheduler, two tenants sharing the junk source: it is disabled
    only on the namespace where it never verifies — the controller state is
    namespace-scoped, not global."""
    tuner = AutoTuner(AutoTuneConfig(min_trials=8, probe_period=10_000))
    junk = CountingJunk(_la())
    sched = ContinuousScheduler(fns, _la(), lanes=2, prefill_len=PREFILL,
                                sources={"junk": junk}, autotune=tuner)
    prompts = _prompts(6, seed=73)
    handles = []
    for i, p in enumerate(prompts):
        ns = "dead" if i % 2 else "solo"
        srcs = ("trie", "junk") if ns == "dead" else ("trie",)
        handles.append(sched.submit_request(Request(
            prompt=list(p),
            params=SamplingParams(max_new_tokens=16, draft=DraftPolicy(
                sources=srcs, namespace=ns)))))
    sched.run()
    for h, p in zip(handles, prompts):
        assert h.result().tokens == reference_decode(fns, p, 16)
    snap = sched.autotuner.snapshot()
    assert snap["dead"]["junk"]["enabled"] is False
    assert "junk" not in snap.get("solo", {})   # never drafted there


def test_compile_once_with_controller_and_shares():
    """I2: lane shares, budget caps and the autotuner gate live entirely on
    the host — schedulers running with them retrace nothing (one executable
    per step fn, exactly like the plain path)."""
    cfg = TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                            d_ff=64, vocab_size=53, max_seq_len=160)
    params = init_params(cfg, jax.random.key(5))
    fresh = make_session_fns(cfg, params, slots=9, prefill_len=PREFILL)
    la = _la(decoding_length=8, branch_length=4)
    tuner = AutoTuner(AutoTuneConfig(min_trials=4, probe_period=3))
    for seed, n in [(80, 5), (81, 3)]:
        sched = ContinuousScheduler(
            fresh, la, lanes=2, prefill_len=PREFILL,
            sources={"junk": CountingJunk(la)},
            lane_shares={"a": 0.5, "b": 0.5},
            draft_budget_caps={"a": 4},
            autotune=tuner)
        for i, p in enumerate(_prompts(n, lo=4, hi=40, vocab=52, seed=seed)):
            ns = "a" if i % 2 else "b"
            sched.submit_request(Request(prompt=p, params=SamplingParams(
                max_new_tokens=12,
                draft=DraftPolicy(sources=("trie", "junk"), namespace=ns))))
        sched.run()
    assert fresh.prefill._cache_size() == 1
    assert fresh.prefill_into_slot._cache_size() == 1
    assert fresh.fused_step._cache_size() == 1
    assert fresh.tree_step._cache_size() == 0
    assert fresh.commit._cache_size() == 0


def test_lane_shares_cap_tenant_occupancy(fns):
    """Weighted-fair admission: with 50/50 shares on two lanes a flooding
    tenant holds at most ceil(2*0.5)=1 lane, so the other tenant's first
    request is admitted immediately instead of behind the flood (FIFO
    within each tenant is untouched)."""
    prompts = _prompts(8, seed=75)
    sched = ContinuousScheduler(fns, _la(), lanes=2, prefill_len=PREFILL,
                                lane_shares={"hog": 0.5, "svc": 0.5})
    for p in prompts[:6]:
        sched.submit_request(Request(prompt=list(p), params=SamplingParams(
            max_new_tokens=24,
            draft=DraftPolicy(namespace="hog"))))
    for p in prompts[6:]:
        sched.submit_request(Request(prompt=list(p), params=SamplingParams(
            max_new_tokens=4,
            draft=DraftPolicy(namespace="svc"))))
    # the very first admission cohort must already hold one lane per tenant
    sched._admit()
    by_ns = [rs.draft.namespace for rs in sched.states if rs is not None]
    assert sorted(by_ns) == ["hog", "svc"]
    res = sched.run()
    for r in res:     # rids are submit-ordered: rid == prompt index
        assert r.tokens == reference_decode(fns, prompts[r.rid],
                                            24 if r.rid < 6 else 4)
    ns_sum = sched.stats.namespace_summary()
    assert ns_sum["hog"]["finished"] == 6
    assert ns_sum["svc"]["finished"] == 2
    assert ns_sum["hog"]["p99_latency_s"] >= ns_sum["svc"]["p99_latency_s"]
