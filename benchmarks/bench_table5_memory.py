"""Paper Table 5: peak memory vs decoding length.

Measured host trie bytes + analytic v5e device bytes for AntGLM-10B decode
(weights + KV cache + draft-slot activations) — reproducing the paper's
finding that lookahead adds a sub-1% memory overhead."""
from __future__ import annotations

from repro.configs import get_arch
from repro.core.trie import TrieTree
from repro.training.data import PROFILES, SyntheticCorpus

from .common import VOCAB, emit


def run() -> None:
    cfg = get_arch("antglm_10b").full_config()
    n = cfg.n_params()
    base_weights = n * 2                                    # bf16
    seq, batch = 1024, 1
    kv_token = cfg.n_layers * 2 * cfg.n_kv_heads * cfg.dh * 2
    kv = kv_token * seq * batch
    for dl in (1, 2, 4, 8, 16, 32, 64, 128):
        T = 1 + (dl if dl > 1 else 0)
        # extra device bytes vs dl=1: draft-slot activations + logits + masks
        act = cfg.n_layers * T * cfg.d_model * 2 * 4        # hidden per layer
        logits = T * cfg.vocab_size * 4
        mask = T * (seq + T)
        total = base_weights + kv + act + logits + mask
        overhead = (total - (base_weights + kv)) / (base_weights + kv)
        emit(f"table5/dl{dl}", 0.0,
             f"device_GiB={total/2**30:.3f} overhead={overhead*100:.3f}%")
    # host trie memory on an AntRAG-profile corpus (paper: ~260 MiB @ prod
    # scale; proportional here)
    trie = TrieTree(capacity=16 * 64)
    corpus = SyntheticCorpus(PROFILES["antrag"], VOCAB, seed=0)
    for _ in range(200):
        p, a = corpus.sample()
        trie.insert_ngrams(a, 8)
    emit("table5/trie_host", 0.0,
         f"nodes={len(trie)} approx_bytes={trie.memory_bytes()}")


if __name__ == "__main__":
    run()
