"""Paper Table 12 (Appendix E): batched lookahead — batch sizes 1/2/4,
baseline vs LLMA vs lookahead.  First batched implementation of
speculative-style decoding per the paper; heterogenous per-row cache lengths
and per-row draft trees are exercised here."""
from __future__ import annotations

from repro.core import LookaheadConfig

from .common import bench_model, emit, make_dataset, run_serving

METHODS = {
    "baseline": LookaheadConfig(strategy="none", decoding_length=0),
    "llma": LookaheadConfig(strategy="single", decoding_length=16,
                            branch_length=16),
    "la-hier": LookaheadConfig(strategy="hierarchical", decoding_length=32,
                               branch_length=8),
}


def run(n_queries: int = 8, max_new: int = 40) -> None:
    cfg, params = bench_model()
    ds = make_dataset("antrag", n_queries + 4)
    for batch in (1, 2, 4):
        base = None
        for m_name, la in METHODS.items():
            r = run_serving(cfg, params, la, ds[4:], max_new=max_new, phase=2,
                            warm_with_outputs=4, n_queries=n_queries,
                            batch=batch)
            if m_name == "baseline":
                base = r
            emit(f"table12/b{batch}/{m_name}",
                 1e6 * r.wall_s / max(r.total_tokens, 1),
                 f"steps_compression={r.steps_compression:.2f}x "
                 f"edl={r.edl:.2f} "
                 f"rel={r.steps_compression/base.steps_compression:.2f}x")


if __name__ == "__main__":
    run()
