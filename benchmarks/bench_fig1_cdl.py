"""Paper Figure 1: single-forward time vs decode length → the critical
decoding length (CDL).

Two views: (a) measured on this CPU (same flat-then-rising shape, CPU's
FLOPs redundancy), (b) v5e roofline model for AntGLM-10B: t(l) =
max(weight+KV bytes / 819GB/s, 2·N·l / 197T) — the analytic CDL is where
compute time overtakes the weight stream."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16
from repro.models.transformer import tree_step, init_cache

from .common import bench_model


def run() -> None:
    cfg, params = bench_model(max_seq_len=512)
    B, ctx = 1, 256
    rng = np.random.RandomState(0)
    cache = init_cache(cfg, B)
    lens = jnp.asarray([ctx], jnp.int32)
    for dl in (1, 2, 4, 8, 16, 32, 64, 128):
        toks = jnp.asarray(rng.randint(1, 500, (B, dl)), jnp.int32)
        pos = lens[:, None] + jnp.arange(dl)[None, :]
        mask = jnp.asarray(np.tril(np.ones((dl, dl), bool))[None])
        f = jax.jit(lambda c, t, p, m: tree_step(cfg, params, c, lens, t,
                                                 p, m)[1])
        f(cache, toks, pos, mask).block_until_ready()   # compile
        t0 = time.perf_counter()
        for _ in range(10):
            f(cache, toks, pos, mask).block_until_ready()
        cpu_ms = (time.perf_counter() - t0) / 10 * 1e3
        # v5e analytic for AntGLM-10B
        big = get_arch("antglm_10b").full_config()
        n = big.n_params()
        io_t = (n * 2 + big.n_layers * 2 * big.n_kv_heads * big.dh
                * (ctx + dl) * 2) / HBM_BW
        fl_t = 2 * n * dl / PEAK_FLOPS_BF16
        print(f"fig1/dl{dl},{cpu_ms*1e3:.1f},"
              f"cpu_ms={cpu_ms:.2f} v5e_io_ms={io_t*1e3:.3f} "
              f"v5e_compute_ms={fl_t*1e3:.3f} "
              f"bound={'io' if io_t > fl_t else 'compute'}")
    cdl = int(PEAK_FLOPS_BF16 / HBM_BW)   # l where 2Nl/peak == 2N/bw
    print(f"fig1/analytic_cdl,0.0,v5e_CDL~{cdl}_tokens_per_step")


if __name__ == "__main__":
    run()
