"""Continuous batching vs lock-step batching on a mixed-length workload.

The workload alternates short and long ``max_new_tokens`` budgets.  Lock-step
serving chunks requests into fixed batches and every chunk drains at its
slowest member — short requests occupy a device lane doing nothing.  The
slot-based scheduler admits the next queued request into the freed lane
mid-flight, so the same device-step shapes deliver more tokens per wall
second.  Per-request outputs are asserted identical (losslessness is
independent of batch composition).

Output CSV: name,us_per_token,tok/s | steps | occupancy
"""
from __future__ import annotations

import time

from benchmarks.common import (VOCAB, bench_model, emit,
                               make_dataset, make_guided_session_fns)
from repro.core import LookaheadConfig, LookaheadEngine
from repro.serving.scheduler import ContinuousScheduler

PREFILL_LEN = 64
LANES = 4


def run(n_queries: int = 24, max_new: int = 96, lanes: int = LANES) -> None:
    # continuous batching only differs from lock-step when a queue exists
    # behind the lane pool; keep at least a 2x oversubscription
    lanes = max(2, min(lanes, n_queries // 2))
    cfg, params = bench_model()
    la = LookaheadConfig(decoding_length=16, branch_length=8)
    fns = make_guided_session_fns(cfg, params, phase=2, slots=la.slots,
                                  prefill_len=PREFILL_LEN)
    ds = make_dataset("antrag", n_queries, prompt_cap=PREFILL_LEN - 8)
    prompts = [p for p, _ in ds]
    # mixed-length: every other request is short (the continuous-batching case)
    budgets = [max_new if i % 2 else max(max_new // 8, 2)
               for i in range(len(prompts))]

    # --- warmup: compile every device fn for both paths (throwaway tries)
    warm_lock = LookaheadEngine(fns, la)
    warm_lock.generate_batch_lockstep(prompts[:lanes], 4)
    warm_cont = ContinuousScheduler(fns, la, lanes=lanes,
                                    prefill_len=PREFILL_LEN)
    for p in prompts[:lanes]:
        warm_cont.submit(p, 4)
    warm_cont.run()

    # --- lock-step: chunks of `lanes`, each chunk drains at its slowest
    eng = LookaheadEngine(fns, la)
    t0 = time.perf_counter()
    lock_out = []
    lock_steps = 0
    for i in range(0, len(prompts), lanes):
        outs = eng.generate_batch_lockstep(prompts[i:i + lanes],
                                           budgets[i:i + lanes])
        lock_out.extend(outs)
        lock_steps += max(o.stats.steps for o in outs)
    lock_wall = time.perf_counter() - t0
    lock_tok = sum(len(o.tokens) for o in lock_out)

    # --- continuous: same lanes, admission queue keeps them full
    sched = ContinuousScheduler(fns, la, lanes=lanes,
                                prefill_len=PREFILL_LEN)
    t0 = time.perf_counter()
    for p, m in zip(prompts, budgets):
        sched.submit(p, m)
    cont_out = sched.run()
    cont_wall = time.perf_counter() - t0
    cont_tok = sum(len(o.tokens) for o in cont_out)

    # --- losslessness across serving disciplines
    assert len(lock_out) == len(cont_out)
    for a, b in zip(lock_out, cont_out):
        assert a.tokens == b.tokens, "continuous batching changed an output"
    assert cont_tok == lock_tok

    lock_tps = lock_tok / lock_wall
    cont_tps = cont_tok / cont_wall
    emit("batch_lockstep", lock_wall / lock_tok * 1e6,
         f"{lock_tps:.1f} tok/s | {lock_steps} batch-steps")
    emit("batch_continuous", cont_wall / cont_tok * 1e6,
         f"{cont_tps:.1f} tok/s | {sched.stats.decode_steps} steps | "
         f"occupancy {sched.stats.occupancy:.2f}")
    emit("continuous_speedup", 0.0, f"{cont_tps / lock_tps:.2f}x")


if __name__ == "__main__":
    run()
