"""Continuous batching vs lock-step batching on a mixed-length workload,
optionally swept over the attention-backend registry and the KV-cache
layouts (dense vs paged).

The workload alternates short and long ``max_new_tokens`` budgets.  Lock-step
serving chunks requests into fixed batches and every chunk drains at its
slowest member — short requests occupy a device lane doing nothing.  The
slot-based scheduler admits the next queued request into the freed lane
mid-flight, so the same device-step shapes deliver more tokens per wall
second.  Per-request outputs are asserted identical (losslessness is
independent of batch composition) — and, in matrix mode, identical across
every attention backend (dense / pallas / flash_decode) AND every KV layout
(registry I1 contract + DESIGN.md §Paged KV cache).

The paged runs size their block pool to the workload's worst-case footprint
(prompt + budget + tree width) instead of lanes * max_seq_len, so the
benchmark also reports peak KV-cache bytes per layout and asserts the paged
pool is strictly smaller at equal lane count.

    PYTHONPATH=src python -m benchmarks.bench_continuous_batch \
        --backends all --kv-layout dense,paged --queries 8 --max-new 32

Output CSV: name,us_per_token,tok/s | steps | occupancy
"""
from __future__ import annotations

import time
from typing import Sequence, Tuple

from benchmarks.common import (VOCAB, bench_model, emit,
                               make_dataset, make_guided_session_fns)
from repro.core import (DraftPolicy, LookaheadConfig, LookaheadEngine,
                        Request, SamplingParams, reference_decode)
from repro.serving.scheduler import ContinuousScheduler

PREFILL_LEN = 64
LANES = 4
BLOCK_SIZE = 64


def _mixed_params(budgets):
    """Per-request SamplingParams alternating greedy and sampled traffic
    (distinct temperatures/seeds) — seeded sampling is deterministic, so
    outputs must stay bit-identical across layouts/backends/disciplines."""
    return [SamplingParams(max_new_tokens=m) if i % 2 else
            SamplingParams(max_new_tokens=m, sample=True,
                           temperature=(0.4, 0.7, 1.0)[i % 3], seed=100 + i)
            for i, m in enumerate(budgets)]


def _continuous(fns, la, prompts, specs, lanes, draft_policy=None,
                overlap=False, record_breakdown=False, prefix_cache=False
                ) -> Tuple[list, float, object, int]:
    """One scheduler generation; ``specs`` are per-request budgets (ints,
    legacy submit) or SamplingParams (request-centric submit).  Returns the
    scheduler itself so callers can read stats AND per-step breakdowns."""
    sched = ContinuousScheduler(fns, la, lanes=lanes,
                                prefill_len=PREFILL_LEN,
                                draft_policy=draft_policy,
                                overlap_drafts=overlap,
                                record_breakdown=record_breakdown,
                                prefix_cache=prefix_cache)
    t0 = time.perf_counter()
    for p, s in zip(prompts, specs):
        if isinstance(s, SamplingParams):
            sched.submit_request(Request(prompt=list(p), params=s))
        else:
            sched.submit(p, s)
    out = sched.run()
    wall = time.perf_counter() - t0
    cache_bytes = sum(v.nbytes for v in sched.cache.values()) \
        if sched.cache is not None else 0
    return out, wall, sched, cache_bytes


def run(n_queries: int = 24, max_new: int = 96, lanes: int = LANES,
        backends: Sequence[str] = ("dense",),
        kv_layouts: Sequence[str] = ("dense",),
        draft_combos: Sequence[str] = ("trie", "prompt_copy",
                                       "trie+ngram")) -> None:
    # continuous batching only differs from lock-step when a queue exists
    # behind the lane pool; keep at least a 2x oversubscription
    lanes = max(2, min(lanes, n_queries // 2))
    cfg, params = bench_model()
    la = LookaheadConfig(decoding_length=16, branch_length=8)
    ds = make_dataset("antrag", n_queries, prompt_cap=PREFILL_LEN - 8)
    prompts = [p for p, _ in ds]
    # mixed-length: every other request is short (the continuous-batching case)
    budgets = [max_new if i % 2 else max(max_new // 8, 2)
               for i in range(len(prompts))]

    # --- lock-step baseline (dense backend): chunks of `lanes`, each chunk
    # drains at its slowest member
    fns = make_guided_session_fns(cfg, params, phase=2, slots=la.slots,
                                  prefill_len=PREFILL_LEN)
    warm_lock = LookaheadEngine(fns, la)
    warm_lock.generate_batch_lockstep(prompts[:lanes], 4)
    eng = LookaheadEngine(fns, la)
    t0 = time.perf_counter()
    lock_out = []
    lock_steps = 0
    for i in range(0, len(prompts), lanes):
        outs = eng.generate_batch_lockstep(prompts[i:i + lanes],
                                           budgets[i:i + lanes])
        lock_out.extend(outs)
        lock_steps += max(o.stats.steps for o in outs)
    lock_wall = time.perf_counter() - t0
    lock_tok = sum(len(o.tokens) for o in lock_out)
    lock_tps = lock_tok / lock_wall
    emit("batch_lockstep", lock_wall / lock_tok * 1e6,
         f"{lock_tps:.1f} tok/s | {lock_steps} batch-steps")

    # --- continuous: same lanes, admission queue keeps them full; one run
    # per (kv layout, attention backend), outputs asserted identical across
    # all of them.  Paged pools are sized to the workload's worst case, not
    # lanes * max_seq_len.
    from repro.serving.block_allocator import demand_blocks
    dense_eq_blocks = -(-cfg.max_seq_len // BLOCK_SIZE)
    per_lane_blocks = demand_blocks(PREFILL_LEN, max_new, la.slots,
                                    cfg.max_seq_len, BLOCK_SIZE)
    paged_blocks = 1 + lanes * per_lane_blocks
    layout_bytes = {}
    for layout in kv_layouts:
        for backend in backends:
            if layout == "dense" and backend == "dense":
                fns_b = fns
            else:
                fns_b = make_guided_session_fns(
                    cfg, params, phase=2, slots=la.slots,
                    prefill_len=PREFILL_LEN, backend=backend,
                    kv_layout=layout,
                    block_size=BLOCK_SIZE if layout == "paged" else None,
                    n_blocks=paged_blocks if layout == "paged" else None)
            warm, _, _, _ = _continuous(fns_b, la, prompts[:lanes],
                                        [4] * lanes, lanes)  # compile warmup
            cont_out, cont_wall, sched, cache_bytes = _continuous(
                fns_b, la, prompts, budgets, lanes)
            stats = sched.stats
            cont_tok = sum(len(o.tokens) for o in cont_out)
            layout_bytes[layout] = cache_bytes

            # --- losslessness across serving disciplines, backends, layouts
            assert len(lock_out) == len(cont_out)
            for a, b in zip(lock_out, cont_out):
                assert a.tokens == b.tokens, \
                    f"kv_layout {layout!r} / backend {backend!r} changed " \
                    "an output"
            assert cont_tok == lock_tok

            cont_tps = cont_tok / cont_wall
            tag = f"{layout}/{backend}"
            emit(f"batch_continuous[{tag}]", cont_wall / cont_tok * 1e6,
                 f"{cont_tps:.1f} tok/s | {stats.decode_steps} steps | "
                 f"occupancy {stats.occupancy:.2f}")
            emit(f"continuous_speedup[{tag}]", 0.0,
                 f"{cont_tps / lock_tps:.2f}x")
        extra = (f" | peak {stats.peak_blocks} blocks | "
                 f"{stats.block_waits} block-waits"
                 if layout == "paged" else "")
        emit(f"kv_cache_bytes[{layout}]", 0.0,
             f"{layout_bytes[layout] / 2**20:.2f} MiB{extra}")
    if "dense" in layout_bytes and "paged" in layout_bytes:
        # the strict-savings claim only holds when the workload footprint is
        # below max_seq_len; at the cap the paged pool costs one extra NULL
        # block (+ tables) for identical coverage
        if per_lane_blocks < dense_eq_blocks:
            assert layout_bytes["paged"] < layout_bytes["dense"], \
                layout_bytes
        emit("kv_cache_savings[paged/dense]", 0.0,
             f"{layout_bytes['dense'] / layout_bytes['paged']:.2f}x")

    # --- mixed per-request sampling traffic (request-centric API): greedy
    # and sampled requests at distinct temperatures/seeds co-batched in ONE
    # lane pool; seeded position-keyed sampling is deterministic, so every
    # (layout, backend) cell and the lock-step baseline must agree
    # bit-for-bit per request
    plist = _mixed_params(budgets)
    mixed_lock = LookaheadEngine(fns, la).generate_batch_lockstep(
        prompts, params=plist)
    for layout in kv_layouts:
        for backend in backends:
            if layout == "dense" and backend == "dense":
                fns_b = fns
            else:
                fns_b = make_guided_session_fns(
                    cfg, params, phase=2, slots=la.slots,
                    prefill_len=PREFILL_LEN, backend=backend,
                    kv_layout=layout,
                    block_size=BLOCK_SIZE if layout == "paged" else None,
                    n_blocks=paged_blocks if layout == "paged" else None)
            mixed_out, mixed_wall, msched, _ = _continuous(
                fns_b, la, prompts, plist, lanes)
            mstats = msched.stats
            for a, b in zip(mixed_lock, mixed_out):
                assert a.tokens == b.tokens, \
                    f"mixed sampling: kv_layout {layout!r} / backend " \
                    f"{backend!r} changed an output"
            mtok = sum(len(o.tokens) for o in mixed_out)
            emit(f"mixed_sampling[{layout}/{backend}]",
                 mixed_wall / max(mtok, 1) * 1e6,
                 f"{mtok / mixed_wall:.1f} tok/s | "
                 f"{mstats.decode_steps} steps | lossless-per-params ✓")

    # --- draft-source matrix (DESIGN.md §Draft sources): the same workload
    # speculating through trie-only / prompt-copy-only / merged policies —
    # the device step never changes, so every combination must reproduce the
    # lock-step baseline per request AND step-by-step reference decoding
    # (spot-checked on the first queries); only tok/s and acceptance move
    for combo in draft_combos:
        policy = DraftPolicy(sources=tuple(combo.split("+")))
        src_out, src_wall, ssched, _ = _continuous(
            fns, la, prompts, budgets, lanes, draft_policy=policy)
        sstats = ssched.stats
        assert len(src_out) == len(lock_out)
        for a, b in zip(lock_out, src_out):
            assert a.tokens == b.tokens, \
                f"draft sources {combo!r} changed an output"
        for q in range(min(3, len(prompts))):
            ref = reference_decode(fns, prompts[q], budgets[q])
            assert src_out[q].tokens == ref, \
                f"draft sources {combo!r} diverged from reference_decode " \
                f"on query {q}"
        stok = sum(len(o.tokens) for o in src_out)
        drafted: dict = {}
        accepted: dict = {}
        for o in src_out:
            for k, v in o.stats.source_drafted.items():
                drafted[k] = drafted.get(k, 0) + v
            for k, v in o.stats.source_accepted.items():
                accepted[k] = accepted.get(k, 0) + v
        acc = " ".join(f"{n}={accepted.get(n, 0)}/{d}"
                       for n, d in sorted(drafted.items())) or "-"
        emit(f"draft_sources[{combo}]", src_wall / max(stok, 1) * 1e6,
             f"{stok / src_wall:.1f} tok/s | {sstats.decode_steps} steps | "
             f"acc {acc} | lossless ✓")


def run_breakdown(n_queries: int = 16, max_new: int = 48, lanes: int = LANES,
                  json_out: str = None) -> dict:
    """``--breakdown``: per-step latency split for the fused single-sync
    decode step — host draft-building / device step / accept+commit /
    host work hidden inside the device flight window — serial vs
    ``overlap_drafts``, on both KV layouts.

    Asserts (a) outputs bit-identical between the two modes, (b) exactly
    ONE host sync per decode step in both (the packed accept array is the
    only device->host transfer on the hot path).  Emits CSV lines and
    optionally a JSON document (the BENCH trajectory seed).
    """
    import json

    lanes = max(2, min(lanes, n_queries // 2))
    cfg, params = bench_model()
    la = LookaheadConfig(decoding_length=16, branch_length=8)
    ds = make_dataset("antrag", n_queries, prompt_cap=PREFILL_LEN - 8)
    prompts = [p for p, _ in ds]
    budgets = [max_new if i % 2 else max(max_new // 8, 2)
               for i in range(len(prompts))]
    from repro.serving.block_allocator import demand_blocks
    paged_blocks = 1 + lanes * demand_blocks(PREFILL_LEN, max_new, la.slots,
                                             cfg.max_seq_len, BLOCK_SIZE)
    doc = {"bench": "continuous_batch_breakdown", "queries": n_queries,
           "max_new": max_new, "lanes": lanes,
           "slots": la.slots, "cells": {}}
    for layout in ("dense", "paged"):
        fns_b = make_guided_session_fns(
            cfg, params, phase=2, slots=la.slots, prefill_len=PREFILL_LEN,
            kv_layout=layout,
            block_size=BLOCK_SIZE if layout == "paged" else None,
            n_blocks=paged_blocks if layout == "paged" else None)
        outs = {}
        visible = {}
        for mode, overlap in (("serial", False), ("overlap", True)):
            _continuous(fns_b, la, prompts[:lanes], [4] * lanes, lanes,
                        overlap=overlap)                    # compile warmup
            out, wall, sched, _ = _continuous(fns_b, la, prompts, budgets,
                                              lanes, overlap=overlap,
                                              record_breakdown=True)
            st = sched.stats
            assert st.decode_syncs == st.decode_steps, (layout, mode)
            br = st.breakdown()
            outs[mode] = [o.tokens for o in out]
            # host time the device stream actually waits on per step: draft
            # building + accept/commit (overlap additionally reports the
            # bookkeeping it moved INTO the flight window as hidden ms)
            visible[mode] = br["host_draft_ms"] + br["accept_commit_ms"]
            tok = sum(len(t) for t in outs[mode])
            cell = {
                "decode_steps": st.decode_steps,
                "syncs_per_step": br["syncs_per_step"],
                "host_draft_ms": round(br["host_draft_ms"], 4),
                "device_step_ms": round(br["device_step_ms"], 4),
                "accept_commit_ms": round(br["accept_commit_ms"], 4),
                "hidden_host_ms": round(br["hidden_host_ms"], 4),
                "visible_host_ms": round(visible[mode], 4),
                "tokens_per_s": round(tok / wall, 2),
                "steps": sched.step_breakdown[:200],
            }
            doc["cells"][f"{layout}/{mode}"] = cell
            step_ms = (br["host_draft_ms"] + br["device_step_ms"]
                       + br["accept_commit_ms"])
            emit(f"step_breakdown[{layout}/{mode}]", step_ms * 1e3,
                 f"draft {br['host_draft_ms']:.2f} ms | "
                 f"device {br['device_step_ms']:.2f} ms | "
                 f"accept {br['accept_commit_ms']:.2f} ms | "
                 f"hidden {br['hidden_host_ms']:.2f} ms | "
                 f"{br['syncs_per_step']:.1f} sync/step")
        assert outs["serial"] == outs["overlap"], layout   # bit-identical
        emit(f"overlap_host_ms[{layout}]", 0.0,
             f"visible {visible['serial']:.2f} -> {visible['overlap']:.2f} "
             "ms/step | lossless ✓")
    if json_out:
        with open(json_out, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {json_out}")
    return doc


def run_prefix(n_queries: int = 24, max_new: int = 48, lanes: int = LANES,
               shared_len: int = 40, json_out: str = None) -> dict:
    """``--prefix-cache``: radix prefix caching on a prefix-heavy stream.

    Every request opens with the same system prompt (``shared_len`` tokens)
    followed by a per-request tail — the RAG/chat shape the radix cache
    targets — plus a slice of divergent miss traffic.  Runs the paged
    scheduler with the cache off and on, asserts bit-identical outputs (and
    reference_decode on spot-checked queries), and reports hit rate,
    prefill-tokens-saved and tok/s.  A small block size (16) keeps block
    granularity well under the shared head so full-block sharing dominates.
    Emits CSV lines and optionally a JSON document (the BENCH_prefix seed).
    """
    import json

    from repro.serving.block_allocator import demand_blocks

    block_size = 16
    lanes = max(2, min(lanes, n_queries // 2))
    cfg, params = bench_model()
    la = LookaheadConfig(decoding_length=16, branch_length=8)
    shared_len = min(shared_len, PREFILL_LEN - 8)
    tail_cap = max(PREFILL_LEN - shared_len, 4)
    ds = make_dataset("antrag", n_queries + 1, prompt_cap=PREFILL_LEN - 8)
    system_prompt = ds[0][0][:shared_len]
    prompts = [system_prompt + p[:tail_cap - 1] for p, _ in ds[1:]]
    # ~1 in 6 requests is divergent miss traffic (no shared head)
    for i in range(0, len(prompts), 6):
        prompts[i] = ds[1 + i][0]
    budgets = [max_new if i % 2 else max(max_new // 8, 2)
               for i in range(len(prompts))]

    per_lane = demand_blocks(PREFILL_LEN, max_new, la.slots,
                             cfg.max_seq_len, block_size)
    # headroom beyond the lanes' worst case so cached prefixes stay resident
    paged_blocks = 1 + (lanes + 2) * per_lane
    fns = make_guided_session_fns(cfg, params, phase=2, slots=la.slots,
                                  prefill_len=PREFILL_LEN, kv_layout="paged",
                                  block_size=block_size,
                                  n_blocks=paged_blocks)
    doc = {"bench": "continuous_batch_prefix", "queries": len(prompts),
           "max_new": max_new, "lanes": lanes, "shared_len": shared_len,
           "block_size": block_size, "cells": {}}
    outs = {}
    tps = {}
    for mode, cached in (("uncached", False), ("cached", True)):
        _continuous(fns, la, prompts[:lanes * 2], [4] * (lanes * 2), lanes,
                    prefix_cache=cached)                     # compile warmup
        out, wall, sched, _ = _continuous(fns, la, prompts, budgets, lanes,
                                          prefix_cache=cached)
        st = sched.stats
        outs[mode] = [o.tokens for o in out]
        tok = sum(len(t) for t in outs[mode])
        tps[mode] = tok / wall
        cell = {"tokens_per_s": round(tps[mode], 2),
                "decode_steps": st.decode_steps,
                "occupancy": round(st.occupancy, 3)}
        if cached:
            cell.update(
                lookups=st.prefix_lookups, hits=st.prefix_hits,
                hit_rate=round(st.prefix_hit_rate, 4),
                hit_tokens=st.prefix_hit_tokens,
                prompt_tokens=st.prefix_prompt_tokens,
                prefill_tokens_saved=round(st.prefill_tokens_saved, 4),
                cow_forks=st.prefix_cow_forks,
                evicted_blocks=st.prefix_evicted_blocks,
                resident_blocks=sched.prefix.n_blocks)
        doc["cells"][mode] = cell
    # --- losslessness: cache on == cache off == reference, per request
    assert outs["cached"] == outs["uncached"], \
        "prefix cache changed an output"
    for q in range(min(3, len(prompts))):
        ref = reference_decode(fns, prompts[q], budgets[q])
        assert outs["cached"][q] == ref, \
            f"prefix cache diverged from reference_decode on query {q}"
    st = sched.stats
    assert st.prefill_tokens_saved >= 0.30, \
        f"prefix-heavy stream saved only {st.prefill_tokens_saved:.1%} " \
        "of prefill tokens (expected >= 30%)"
    emit("prefix_cache[off]", 0.0, f"{tps['uncached']:.1f} tok/s")
    emit("prefix_cache[on]", 0.0,
         f"{tps['cached']:.1f} tok/s | "
         f"hit {st.prefix_hits}/{st.prefix_lookups} "
         f"({st.prefix_hit_rate:.0%}) | "
         f"saved {st.prefix_hit_tokens}/{st.prefix_prompt_tokens} prefill "
         f"tokens ({st.prefill_tokens_saved:.0%}) | "
         f"{st.prefix_cow_forks} COW forks | "
         f"{st.prefix_evicted_blocks} evicted | lossless ✓")
    emit("prefix_cache_speedup", 0.0,
         f"{tps['cached'] / tps['uncached']:.2f}x")
    doc["prefill_tokens_saved"] = round(st.prefill_tokens_saved, 4)
    doc["speedup"] = round(tps["cached"] / tps["uncached"], 4)
    if json_out:
        with open(json_out, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {json_out}")
    return doc


def run_multi_tenant(n_hot: int = 16, n_svc: int = 8, max_new: int = 48,
                     lanes: int = LANES, json_out: str = None) -> dict:
    """``--multi-tenant``: SLO isolation for a latency-sensitive co-tenant.

    Two namespaces share one lane pool.  Tenant ``hot`` floods the queue at
    t=0 with long-budget requests whose draft policy includes a junk source
    (drafts chains the guided model never emits — pure host overhead, zero
    acceptance).  Tenant ``svc`` trickles short requests in mid-run.  Cell 1
    runs the legacy global-FIFO admission: svc waits behind the entire hot
    backlog.  Cell 2 turns on per-namespace lane shares, a draft-budget cap
    on hot, and the per-namespace autotuner.  Asserts (a) every request's
    output is bit-identical across both cells AND reference_decode on spot
    checks — scheduling policy is pure performance (I1); (b) svc p99 latency
    under shares is <= 0.6x the FIFO cell's; (c) the controller disabled the
    junk source on the hot namespace (quota driven to zero, retrieval cost
    skipped).  Emits CSV lines and optionally the BENCH_slo JSON seed.
    """
    import json

    from repro.core.draft_sources import DraftSource, register_source
    from repro.serving.scheduler import SchedulerStats  # noqa: F401

    class _JunkSource(DraftSource):
        """Drafts chains of token 1.  The guided bench model only ever
        emits tokens >= 2, so nothing this source proposes can verify —
        the autotuner's worst case: real retrieve cost, zero acceptance."""
        name = "junk"

        def retrieve(self, rid, context, *, budget, namespace=""):
            k = min(self.config.branch_length, budget)
            return ([[1] * k], [1.0]) if k >= 1 else ([], [])

    register_source("junk", _JunkSource)

    lanes = max(2, min(lanes, n_hot // 2))
    cfg, params = bench_model()
    la = LookaheadConfig(decoding_length=16, branch_length=8)
    ds = make_dataset("antrag", n_hot + n_svc, prompt_cap=PREFILL_LEN - 8)
    hot_policy = DraftPolicy(sources=("trie", "junk"),
                             namespace="hot").validate()
    svc_policy = DraftPolicy(sources=("trie",), namespace="svc").validate()
    svc_budget = max(max_new // 8, 4)
    hot_reqs = [Request(prompt=list(p), params=SamplingParams(
        max_new_tokens=max_new, draft=hot_policy))
        for p, _ in ds[:n_hot]]
    svc_reqs = [Request(prompt=list(p), params=SamplingParams(
        max_new_tokens=svc_budget, draft=svc_policy))
        for p, _ in ds[n_hot:]]
    svc_gap = 2          # scheduler steps between svc arrivals
    fns = make_guided_session_fns(cfg, params, phase=2, slots=la.slots,
                                  prefill_len=PREFILL_LEN)

    def _drive(shares, caps, autotune):
        """One online run: hot floods at t0, svc arrives every svc_gap
        decode steps.  Returns (rid->tokens, scheduler, wall_s)."""
        sched = ContinuousScheduler(fns, la, lanes=lanes,
                                    prefill_len=PREFILL_LEN,
                                    lane_shares=shares,
                                    draft_budget_caps=caps,
                                    autotune=autotune)
        t0 = time.perf_counter()
        for r in hot_reqs:
            sched.submit_request(Request(prompt=list(r.prompt),
                                         params=r.params))
        step = nxt = 0
        while nxt < len(svc_reqs) or not sched.idle:
            while nxt < len(svc_reqs) and step >= (nxt + 1) * svc_gap:
                sched.submit_request(Request(
                    prompt=list(svc_reqs[nxt].prompt),
                    params=svc_reqs[nxt].params))
                nxt += 1
            if sched.idle:        # hot drained before svc finished arriving
                step = (nxt + 1) * svc_gap
                continue
            sched.step()
            step += 1
        wall = time.perf_counter() - t0
        return ({rid: res.tokens for rid, res in sched.results.items()},
                sched, wall)

    _drive(None, None, False)                              # compile warmup
    doc = {"bench": "continuous_batch_multi_tenant", "hot": n_hot,
           "svc": n_svc, "lanes": lanes, "max_new": max_new,
           "svc_budget": svc_budget, "svc_gap_steps": svc_gap, "cells": {}}
    outs = {}
    p99 = {}
    cells = (("fifo", None, None, False),
             ("slo", {"hot": 0.5, "svc": 0.5}, {"hot": 8}, True))
    for mode, shares, caps, autotune in cells:
        outs[mode], sched, wall = _drive(shares, caps, autotune)
        st = sched.stats
        ns_sum = st.namespace_summary()
        p99[mode] = {ns: row["p99_latency_s"] for ns, row in ns_sum.items()}
        tok = sum(len(t) for t in outs[mode].values())
        cell = {"tokens_per_s": round(tok / wall, 2),
                "decode_steps": st.decode_steps,
                "namespaces": ns_sum}
        if autotune:
            cell["autotune"] = sched.autotuner.snapshot()
        doc["cells"][mode] = cell
        for ns, row in ns_sum.items():
            emit(f"tenant[{mode}/{ns}]", row["p99_latency_s"] * 1e6,
                 f"p50 {row['p50_latency_s'] * 1e3:.1f} ms | "
                 f"p99 {row['p99_latency_s'] * 1e3:.1f} ms | "
                 f"queue-p99 {row['p99_queue_s'] * 1e3:.1f} ms | "
                 f"occ {row['occupancy']:.2f}")

    # --- losslessness: scheduling policy never touches an output token
    assert outs["fifo"].keys() == outs["slo"].keys()
    for rid in outs["fifo"]:
        assert outs["fifo"][rid] == outs["slo"][rid], \
            f"lane shares / autotune changed request {rid}'s output"
    prompts = [list(r.prompt) for r in hot_reqs] + \
        [list(r.prompt) for r in svc_reqs]
    budgets = [max_new] * n_hot + [svc_budget] * n_svc
    for q in (0, n_hot, n_hot + n_svc - 1):
        ref = reference_decode(fns, prompts[q], budgets[q])
        assert outs["slo"][q] == ref, \
            f"multi-tenant cell diverged from reference_decode on rid {q}"

    # --- the controller zeroed the never-accepting source's quota on hot
    snap = sched.autotuner.snapshot()
    junk = snap["hot"]["junk"]
    assert not junk["enabled"] and junk["disables"] >= 1, snap
    assert junk["accepted"] == 0, snap
    assert snap["hot"]["trie"]["enabled"], snap

    # --- the SLO claim: shares cut the co-tenant's tail latency
    ratio = p99["slo"]["svc"] / max(p99["fifo"]["svc"], 1e-9)
    assert ratio <= 0.6, \
        f"svc p99 with shares is {ratio:.2f}x FIFO (expected <= 0.6x)"
    doc["svc_p99_ratio"] = round(ratio, 4)
    emit("svc_p99_ratio[slo/fifo]", 0.0, f"{ratio:.2f}x | junk OFF on hot "
         f"after {junk['drafted']} drafted/0 accepted | lossless ✓")
    if json_out:
        with open(json_out, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {json_out}")
    return doc


if __name__ == "__main__":
    import argparse

    from repro.models.attention import available_backends

    ap = argparse.ArgumentParser()
    ap.add_argument("--backends", default="dense",
                    help="comma-separated backend names, or 'all' for every "
                         f"registered backend ({', '.join(available_backends())})")
    ap.add_argument("--kv-layout", default="dense",
                    help="comma-separated KV layouts (dense, paged) or "
                         "'all' for both")
    ap.add_argument("--queries", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=96)
    ap.add_argument("--lanes", type=int, default=LANES)
    ap.add_argument("--draft-sources", default="trie,prompt_copy,trie+ngram",
                    help="comma-separated draft-source combinations; '+' "
                         "merges sources within one policy")
    ap.add_argument("--breakdown", action="store_true",
                    help="per-step latency breakdown (host draft / device "
                         "step / accept+commit / hidden), serial vs "
                         "--overlap-drafts, instead of the throughput sweep")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix-cache cell: a prefix-heavy stream "
                         "(shared system prompt + per-request tails) with "
                         "the cache off vs on; reports hit rate and "
                         "prefill-tokens-saved, asserts bit-identical")
    ap.add_argument("--shared-prefix", type=int, default=40,
                    help="with --prefix-cache: shared system-prompt length")
    ap.add_argument("--multi-tenant", action="store_true",
                    help="SLO cell: a hot tenant flooding the queue (with a "
                         "junk draft source) vs a latency-sensitive svc "
                         "tenant, global FIFO vs lane shares + draft caps + "
                         "per-namespace autotune; asserts bit-identical "
                         "outputs, svc p99 <= 0.6x and the junk source "
                         "disabled on the hot namespace")
    ap.add_argument("--json-out", default=None,
                    help="with --breakdown / --prefix-cache / "
                         "--multi-tenant: write the records and per-cell "
                         "means to this JSON file")
    args = ap.parse_args()
    if args.breakdown:
        run_breakdown(n_queries=args.queries, max_new=args.max_new,
                      lanes=args.lanes, json_out=args.json_out)
        raise SystemExit(0)
    if args.prefix_cache:
        run_prefix(n_queries=args.queries, max_new=args.max_new,
                   lanes=args.lanes, shared_len=args.shared_prefix,
                   json_out=args.json_out)
        raise SystemExit(0)
    if args.multi_tenant:
        run_multi_tenant(max_new=args.max_new, lanes=args.lanes,
                         json_out=args.json_out)
        raise SystemExit(0)
    names = (available_backends() if args.backends == "all"
             else tuple(args.backends.split(",")))
    layouts = (("dense", "paged") if args.kv_layout == "all"
               else tuple(args.kv_layout.split(",")))
    run(n_queries=args.queries, max_new=args.max_new, lanes=args.lanes,
        backends=names, kv_layouts=layouts,
        draft_combos=tuple(c for c in args.draft_sources.split(",") if c))
