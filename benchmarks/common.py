"""Shared benchmark harness: a small CPU-runnable LM + corpus-driven serving
runs measuring tokens/s, steps-compression and EDL.

Absolute tokens/s on this CPU box is NOT the paper's GPU number; the
hardware-transferable metrics are steps-compression (= speedup in the
IO-bound regime where t(l) is flat, paper §3.4) and EDL.  A v5e-projected
tokens/s is derived from the roofline step-time model.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import LookaheadConfig, LookaheadEngine
from repro.models.transformer import TransformerConfig, init_params
from repro.serving.api import EngineConfig, build_session_fns
from repro.training.data import PROFILES, SyntheticCorpus
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

VOCAB = 512


def bench_model(seed: int = 0, max_seq_len: int = 768) -> Tuple:
    cfg = TransformerConfig(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
                            d_ff=256, vocab_size=VOCAB,
                            max_seq_len=max_seq_len)
    params = init_params(cfg, jax.random.key(seed))
    return cfg, params


# --------------------------------------------------------- guided generation
# A randomly-initialized transformer emits corpus-unrelated tokens, so trie
# drafts never verify and every method degenerates to EDL=1.  Real deployed
# models produce text with heavy cross-query redundancy (that IS the paper's
# premise).  We reproduce that redundancy with a *guided* bench model: the
# full transformer forward runs (realistic step cost), and a deterministic
# continuation bias G[position % P, token] is added to the logits.  The walk
# over the (P × V) state space makes outputs revisit shared chains; P is the
# redundancy knob per dataset profile (small P = high reuse, ≈ AntRAG;
# large P = low reuse, ≈ Dolly).  The bias is a pure function of
# (token, position), so losslessness is untouched.
PROFILE_PHASE = {"antrag": 2, "humaneval": 3, "gsm8k": 5, "dolly": 11}


def make_guided_session_fns(cfg, params, *, phase: int, seed: int = 0,
                            slots: int = 33, pad_id: int = 0,
                            prefill_len: Optional[int] = None,
                            backend: Optional[str] = None,
                            kv_layout: Optional[str] = None,
                            block_size: Optional[int] = None,
                            n_blocks: Optional[int] = None):
    import jax.numpy as jnp

    rng = np.random.RandomState(seed + 1000 * phase)
    # 70% of (phase, token) entries share a phase-independent successor —
    # walks then share chain prefixes and diverge at ~30% of steps, giving
    # the trie the shared-prefix branch structure hierarchical drafts exploit
    base = rng.randint(2, cfg.vocab_size, size=(cfg.vocab_size,))
    spec = rng.randint(2, cfg.vocab_size, size=(phase, cfg.vocab_size))
    shared = rng.rand(phase, cfg.vocab_size) < 0.7
    guide = jnp.asarray(np.where(shared, base[None, :], spec), jnp.int32)

    def bias(logits, tokens, positions):
        nxt = guide[positions % phase, tokens]              # (B, T)
        return logits + 1e4 * jax.nn.one_hot(nxt, cfg.vocab_size,
                                             dtype=logits.dtype)

    ecfg = EngineConfig(prefill_len=prefill_len,
                        decoding_length=slots - 1, pad_id=pad_id,
                        backend=backend,
                        kv_layout=kv_layout or "dense",
                        block_size=block_size or 64, n_blocks=n_blocks)
    return build_session_fns(ecfg, cfg, params, logits_transform=bias)


@dataclass
class RunResult:
    tokens_per_s: float
    steps_compression: float     # steps(baseline) / steps(method)
    edl: float
    total_tokens: int
    wall_s: float
    # per-draft-source speculation telemetry aggregated over the run
    source_drafted: Dict[str, int] = None
    source_accepted: Dict[str, int] = None

    def source_summary(self) -> str:
        if not self.source_drafted:
            return ""
        return " ".join(
            f"{name}={self.source_accepted.get(name, 0)}/{n}"
            for name, n in sorted(self.source_drafted.items()))


_FNS_CACHE: Dict = {}


def run_serving(cfg, params, la_cfg: LookaheadConfig, dataset, *,
                max_new: int = 64, warm: Optional[List[List[int]]] = None,
                n_queries: Optional[int] = None, batch: int = 1,
                phase: Optional[int] = None, warm_with_outputs: int = 0,
                fns=None, draft_policy=None) -> RunResult:
    if fns is None:
        key = (cfg.name, id(params), phase, la_cfg.slots)
        fns = _FNS_CACHE.get(key)
        if fns is None:
            if phase is not None:
                fns = make_guided_session_fns(cfg, params, phase=phase,
                                              slots=la_cfg.slots)
            else:
                fns = make_session_fns(cfg, params, slots=la_cfg.slots)
            _FNS_CACHE[key] = fns
    eng = LookaheadEngine(fns, la_cfg, draft_policy=draft_policy)
    if warm:
        eng.warmup(warm)
    prompts = [p for p, _ in dataset][:n_queries or len(dataset)]
    if warm_with_outputs:
        # paper Appendix D: preload dev-set RESPONSES — i.e. what the model
        # itself answers on dev prompts
        from repro.core import reference_decode
        dev = [p for p, _ in dataset[-warm_with_outputs:]]
        eng.warmup([reference_decode(fns, p, max_new) for p in dev])
    # jit warmup (exclude compile from timing)
    eng.generate_batch(prompts[:batch], 4)
    t0 = time.perf_counter()
    tok = steps = 0
    drafted: Dict[str, int] = {}
    accepted: Dict[str, int] = {}
    for i in range(0, len(prompts), batch):
        chunk = prompts[i:i + batch]
        if len(chunk) < batch:
            break
        outs = eng.generate_batch(chunk, max_new)
        for o in outs:
            tok += len(o.tokens)
            steps += o.stats.steps
            for k, v in o.stats.source_drafted.items():
                drafted[k] = drafted.get(k, 0) + v
            for k, v in o.stats.source_accepted.items():
                accepted[k] = accepted.get(k, 0) + v
    wall = time.perf_counter() - t0
    return RunResult(tokens_per_s=tok / wall,
                     steps_compression=tok / max(steps, 1),
                     edl=tok / max(steps, 1), total_tokens=tok, wall_s=wall,
                     source_drafted=drafted, source_accepted=accepted)


def make_dataset(profile: str, n: int, seed: int = 0,
                 prompt_cap: int = 96) -> List[Tuple[List[int], List[int]]]:
    c = SyntheticCorpus(PROFILES[profile], VOCAB, seed=seed)
    ds = c.dataset(n)
    return [(p[:prompt_cap], a) for p, a in ds]


def v5e_projected_tokens_per_s(cfg: TransformerConfig, arch_params: int,
                               steps_compression: float) -> float:
    """Roofline step-time: decode is weight-stream bound (paper §1 analysis,
    redone with v5e constants): t_step ≈ bytes(weights)/HBM_bw; lookahead
    emits steps_compression tokens per step."""
    t_step = arch_params * 2 / HBM_BW     # bf16 weights
    return steps_compression / t_step


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
