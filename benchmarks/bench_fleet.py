"""Fleet serving: namespace-affinity routing vs round-robin, and
warm-restart draft-state recovery (repro.fleet, DESIGN.md §Fleet serving).

Retrieval drafting only pays when the trie has seen the request's traffic
before.  The workload here is three tenants, each replaying a small pool
of prompts over several rounds (the RAG/chat shape: repeats of a prompt
warm its chains; the low-reuse guided profile keeps cross-prompt
generalization weak, so WHERE the repeats land decides acceptance).
Submission order is shuffled per round so round-robin placement cannot
accidentally align a prompt's repeats onto one replica.  Cells:

  * ``single``       — one engine, the bit-identity reference;
  * ``affinity``     — N-replica fleet, consistent-hash namespace routing:
                       every tenant's repeats land on the replica whose
                       trie they warmed;
  * ``round_robin``  — same fleet, placement ignores namespaces: each
                       prompt's repeats scatter, most visits are cold;
  * ``gossip_spill`` — one tenant warms replica A; replica B (the spill
                       target) serves the same prompts cold, then again
                       after ONE gossip exchange — the acceptance jump is
                       what gossip buys a backpressure spill;
  * ``warm_restart`` — a donor engine (paged KV + prefix cache) serves the
                       workload cold and persists its draft state; a fresh
                       engine loads the file (trie + n-gram + primed
                       prefix keys) and serves the same stream.

Asserts: every fleet cell's outputs are bit-identical to the single
reference (I1 — routing/gossip are pure performance policies); affinity
beats round-robin on mean per-namespace trie acceptance; gossip lifts the
cold spill target's acceptance; the warm restart recovers >= 80% of the
donor's end-of-run acceptance.

    PYTHONPATH=src python -m benchmarks.bench_fleet --json-out BENCH_fleet.json

Output CSV: name,us_per_token,derived
"""
from __future__ import annotations

import json
import os
import tempfile
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks.common import (bench_model, emit, make_dataset,
                               make_guided_session_fns)
from repro.core import DraftPolicy, Request, SamplingParams
from repro.fleet import EngineReplica, FleetRouter, GossipCoordinator
from repro.serving.api import EngineConfig, ServingEngine

PREFILL_LEN = 64
LANES = 2
DECODING_LENGTH = 15          # device tree width = 16 slots
BRANCH_LENGTH = 8
# low-reuse guided profile ("dolly"): continuations are prompt-specific,
# so acceptance tracks whether THIS prompt's earlier repeats warmed the
# serving replica — the quantity routing controls.  A high-reuse profile
# saturates every trie after one round and hides the placement policy.
PHASE = 11
NAMESPACES = ("docs", "code", "chat")


# ------------------------------------------------------------------ workload
def make_workload(k_prompts: int, repeats: int,
                  max_new: int) -> List[Request]:
    """``repeats`` rounds over three tenants, each replaying its own pool
    of ``k_prompts`` prompts.  Each round's submission order is shuffled
    (seeded) — with a fixed order, a round length divisible by the replica
    count would hand round-robin accidental per-prompt affinity."""
    ds = make_dataset("antrag", len(NAMESPACES) * k_prompts,
                      prompt_cap=PREFILL_LEN - 8)
    pools: Dict[str, List[List[int]]] = {}
    for i, ns in enumerate(NAMESPACES):
        pools[ns] = [list(p) for p, _ in
                     ds[i * k_prompts:(i + 1) * k_prompts]]
    reqs: List[Request] = []
    for rnd in range(repeats):
        round_reqs: List[Request] = []
        for ns in NAMESPACES:
            policy = DraftPolicy(sources=("trie",), namespace=ns).validate()
            for prompt in pools[ns]:
                round_reqs.append(Request(
                    prompt=list(prompt),
                    params=SamplingParams(max_new_tokens=max_new,
                                          draft=policy)))
        np.random.RandomState(1000 + rnd).shuffle(round_reqs)
        reqs.extend(round_reqs)
    return reqs


# ------------------------------------------------------------- acceptance
def _acceptance_by_ns(snap: dict, before: Optional[dict] = None
                      ) -> Dict[str, Dict[str, float]]:
    """Per-namespace per-source acceptance from a SchedulerStats snapshot,
    optionally as a delta over an earlier snapshot (so prefix-priming
    requests issued by ``load_draft_state`` never dilute the measurement)."""
    out: Dict[str, Dict[str, float]] = {}
    for ns, s in snap.get("namespaces", {}).items():
        b = (before or {}).get("namespaces", {}).get(
            ns, {"source_drafted": {}, "source_accepted": {}})
        drafted = {k: int(v) - int(b["source_drafted"].get(k, 0))
                   for k, v in dict(s["source_drafted"]).items()}
        accepted = {k: int(v) - int(b["source_accepted"].get(k, 0))
                    for k, v in dict(s["source_accepted"]).items()}
        out[ns] = {k: accepted.get(k, 0) / max(v, 1)
                   for k, v in drafted.items() if v > 0}
    return out


def _mean_trie_acceptance(acc_by_ns: Dict[str, Dict[str, float]]) -> float:
    rates = [acc_by_ns[ns]["trie"] for ns in NAMESPACES
             if ns in acc_by_ns and "trie" in acc_by_ns[ns]]
    return sum(rates) / max(len(rates), 1)


# ----------------------------------------------------------------- drivers
def _run_single(fns, ecfg: EngineConfig, reqs: List[Request]
                ) -> Tuple[List[List[int]], ServingEngine, float]:
    eng = ServingEngine(fns, ecfg)
    handles = [eng.submit(Request(prompt=list(r.prompt), params=r.params))
               for r in reqs]
    t0 = time.perf_counter()
    eng.run()
    wall = time.perf_counter() - t0
    return [h.result().tokens for h in handles], eng, wall


def _run_fleet(fns, ecfg: EngineConfig, reqs: List[Request], *,
               policy: str, n_replicas: int, gossip_every: int = 0
               ) -> Tuple[List[List[int]], "FleetStats", int, float]:
    """One fleet generation.  ``max_queue_depth`` is set above the whole
    workload so no request spills — the cells compare pure placement
    policies (backpressure spill is exercised by tests/test_fleet.py)."""
    replicas = [EngineReplica(lambda: ServingEngine(fns, ecfg),
                              replica_id=f"r{i}")
                for i in range(n_replicas)]
    router = FleetRouter(replicas, policy=policy,
                         max_queue_depth=len(reqs) + 1)
    gossip = GossipCoordinator(replicas, every=gossip_every)
    t0 = time.perf_counter()
    for r in reqs:
        router.submit(r.prompt, r.params)
        router.step_all()              # keep lanes busy while admitting
        gossip.tick()
    while not router.idle:
        router.step_all()
        gossip.tick()
    wall = time.perf_counter() - t0
    tokens = [res["tokens"] for res in router.results()]
    fs = router.fleet_stats()
    router.close()
    return tokens, fs, gossip.exchanges, wall


# ------------------------------------------------------------------- cells
def run_routing(k_prompts: int = 4, repeats: int = 4, max_new: int = 16,
                n_replicas: int = 3) -> dict:
    cfg, params = bench_model()
    ecfg = EngineConfig(lanes=LANES, prefill_len=PREFILL_LEN,
                        decoding_length=DECODING_LENGTH,
                        branch_length=BRANCH_LENGTH)
    fns = make_guided_session_fns(cfg, params, phase=PHASE,
                                  slots=ecfg.slots,
                                  prefill_len=PREFILL_LEN)
    reqs = make_workload(k_prompts, repeats, max_new)
    _run_single(fns, ecfg, reqs[:LANES])                   # compile warmup

    doc: dict = {"k_prompts": k_prompts, "repeats": repeats,
                 "max_new": max_new, "replicas": n_replicas,
                 "namespaces": list(NAMESPACES), "cells": {}}

    ref_tokens, ref_eng, ref_wall = _run_single(fns, ecfg, reqs)
    ref_acc = _acceptance_by_ns(ref_eng.scheduler.stats.snapshot())
    ref_tok = sum(len(t) for t in ref_tokens)
    doc["cells"]["single"] = {
        "tokens_per_s": round(ref_tok / ref_wall, 2),
        "mean_trie_acceptance": round(_mean_trie_acceptance(ref_acc), 4)}
    emit("fleet[single]", ref_wall / max(ref_tok, 1) * 1e6,
         f"{ref_tok / ref_wall:.1f} tok/s | "
         f"trie-acc {_mean_trie_acceptance(ref_acc):.0%}")

    accs: Dict[str, float] = {}
    for name in ("affinity", "round_robin"):
        tokens, fs, exchanges, wall = _run_fleet(
            fns, ecfg, reqs, policy=name, n_replicas=n_replicas)
        assert tokens == ref_tokens, \
            f"fleet cell {name!r} changed an output (I1 violation)"
        acc = _mean_trie_acceptance(fs.source_acceptance())
        accs[name] = acc
        tok = sum(len(t) for t in tokens)
        doc["cells"][name] = {
            "tokens_per_s": round(tok / wall, 2),
            "mean_trie_acceptance": round(acc, 4),
            "per_namespace": {ns: round(r.get("trie", 0.0), 4)
                              for ns, r in fs.source_acceptance().items()},
            "affinity_hits": fs.affinity_hits, "spills": fs.spills,
            "trie_nodes": [s["trie_nodes"] for s in fs.replicas]}
        emit(f"fleet[{name}]", wall / max(tok, 1) * 1e6,
             f"{tok / wall:.1f} tok/s | trie-acc {acc:.0%} | "
             f"{fs.affinity_hits} affinity / {fs.spills} spills | "
             "lossless ✓")

    assert accs["affinity"] > accs["round_robin"], \
        (f"affinity routing did not beat round-robin on mean trie "
         f"acceptance: {accs['affinity']:.3f} vs {accs['round_robin']:.3f}")
    doc["affinity_vs_round_robin"] = round(
        accs["affinity"] / max(accs["round_robin"], 1e-9), 4)
    emit("fleet_acceptance[affinity/round_robin]", 0.0,
         f"{doc['affinity_vs_round_robin']:.2f}x")
    return doc


def run_gossip_spill(k_prompts: int = 4, warm_rounds: int = 2,
                     max_new: int = 16) -> dict:
    """What gossip buys a backpressure spill: replica A serves a tenant
    for ``warm_rounds`` rounds; replica B — the spill target — serves the
    same prompts cold, then again after ONE gossip exchange.  All three
    B-side waves must be bit-identical (I1); the post-gossip wave's trie
    acceptance must beat the cold wave's."""
    cfg, params = bench_model()
    ecfg = EngineConfig(lanes=LANES, prefill_len=PREFILL_LEN,
                        decoding_length=DECODING_LENGTH,
                        branch_length=BRANCH_LENGTH)
    fns = make_guided_session_fns(cfg, params, phase=PHASE,
                                  slots=ecfg.slots,
                                  prefill_len=PREFILL_LEN)
    ds = make_dataset("antrag", k_prompts, prompt_cap=PREFILL_LEN - 8)
    policy = DraftPolicy(sources=("trie",), namespace="docs").validate()

    def wave() -> List[Request]:
        return [Request(prompt=list(p),
                        params=SamplingParams(max_new_tokens=max_new,
                                              draft=policy))
                for p, _ in ds]

    rep_a = EngineReplica(lambda: ServingEngine(fns, ecfg), replica_id="rA")
    rep_b = EngineReplica(lambda: ServingEngine(fns, ecfg), replica_id="rB")

    def serve(rep: EngineReplica, reqs: List[Request]):
        before = rep.stats_snapshot()
        rids = [rep.submit(r.prompt, r.params) for r in reqs]
        rep.drain()
        tokens = [rep.result(rid)["tokens"] for rid in rids]
        acc = _mean_trie_acceptance(
            _acceptance_by_ns(rep.stats_snapshot(), before))
        return tokens, acc

    for _ in range(warm_rounds):
        ref_tokens, _ = serve(rep_a, wave())
    cold_tokens, cold_acc = serve(rep_b, wave())
    GossipCoordinator([rep_a, rep_b]).exchange()
    warm_tokens, warm_acc = serve(rep_b, wave())
    rep_a.close()
    rep_b.close()

    assert cold_tokens == ref_tokens == warm_tokens, \
        "gossip changed an output (I1 violation)"
    assert warm_acc > cold_acc, \
        (f"gossip did not lift the spill target's acceptance: "
         f"{cold_acc:.3f} cold vs {warm_acc:.3f} after exchange")
    cell = {"cold_acceptance": round(cold_acc, 4),
            "post_gossip_acceptance": round(warm_acc, 4),
            "lift": round(warm_acc / max(cold_acc, 1e-9), 4)}
    emit("fleet[gossip_spill]", 0.0,
         f"spill-target acc {cold_acc:.0%} -> {warm_acc:.0%} after one "
         f"exchange ({cell['lift']:.2f}x) | lossless ✓")
    return cell


def run_warm_restart(k_prompts: int = 4, repeats: int = 4,
                     max_new: int = 16) -> dict:
    """Donor serves cold (paged KV + prefix cache), persists draft state;
    a fresh engine loads the file and serves the same stream.  The warm
    engine must recover >= 80% of the donor's acceptance and produce
    bit-identical tokens."""
    from repro.serving.block_allocator import demand_blocks

    block_size = 16
    cfg, params = bench_model()
    slots = 1 + DECODING_LENGTH
    per_lane = demand_blocks(PREFILL_LEN, max_new, slots,
                             cfg.max_seq_len, block_size)
    # pool headroom for the primed prefix keys: every distinct prompt's
    # chain must stay resident through the serving run, or priming is
    # evicted before the first lookup can hit it
    prime_blocks = (len(NAMESPACES) * k_prompts
                    * (-(-(PREFILL_LEN + max_new) // block_size) + 1))
    n_blocks = 1 + (LANES + 2) * per_lane + prime_blocks
    ecfg = EngineConfig(lanes=LANES, prefill_len=PREFILL_LEN,
                        decoding_length=DECODING_LENGTH,
                        branch_length=BRANCH_LENGTH, kv_layout="paged",
                        block_size=block_size, n_blocks=n_blocks,
                        prefix_cache=True)
    fns = make_guided_session_fns(cfg, params, phase=PHASE, slots=slots,
                                  prefill_len=PREFILL_LEN,
                                  kv_layout="paged", block_size=block_size,
                                  n_blocks=n_blocks)
    reqs = make_workload(k_prompts, repeats, max_new)
    _run_single(fns, ecfg, reqs[:LANES])                   # compile warmup

    donor_tokens, donor, donor_wall = _run_single(fns, ecfg, reqs)
    donor_acc = _mean_trie_acceptance(
        _acceptance_by_ns(donor.scheduler.stats.snapshot()))
    donor_tok = sum(len(t) for t in donor_tokens)

    fd, path = tempfile.mkstemp(suffix=".json", prefix="repro-warm-")
    os.close(fd)
    try:
        donor.save_draft_state(path)
        size_kb = os.path.getsize(path) / 1024

        warm = ServingEngine(fns, ecfg)
        warm.load_draft_state(path)          # trie+ngram + primed prefix
        base = warm.scheduler.stats.snapshot()
        handles = [warm.submit(Request(prompt=list(r.prompt),
                                       params=r.params)) for r in reqs]
        t0 = time.perf_counter()
        warm.run()
        warm_wall = time.perf_counter() - t0
        warm_tokens = [h.result().tokens for h in handles]
        warm_snap = warm.scheduler.stats.snapshot()
        warm_acc = _mean_trie_acceptance(_acceptance_by_ns(warm_snap, base))
    finally:
        os.unlink(path)

    assert warm_tokens == donor_tokens, \
        "warm restart changed an output (I1 violation)"
    recovery = warm_acc / max(donor_acc, 1e-9)
    assert recovery >= 0.8, \
        (f"warm restart recovered only {recovery:.0%} of donor acceptance "
         f"({warm_acc:.3f} vs {donor_acc:.3f}; expected >= 80%)")

    warm_tok = sum(len(t) for t in warm_tokens)
    hits = int(warm_snap["prefix_hits"]) - int(base["prefix_hits"])
    lookups = int(warm_snap["prefix_lookups"]) - int(base["prefix_lookups"])
    cell = {"donor_trie_acceptance": round(donor_acc, 4),
            "warm_trie_acceptance": round(warm_acc, 4),
            "recovery": round(recovery, 4),
            "donor_tokens_per_s": round(donor_tok / donor_wall, 2),
            "warm_tokens_per_s": round(warm_tok / warm_wall, 2),
            "state_file_kb": round(size_kb, 1),
            "warm_prefix_hits": hits, "warm_prefix_lookups": lookups}
    emit("fleet[warm_restart]", warm_wall / max(warm_tok, 1) * 1e6,
         f"acc {donor_acc:.0%} -> {warm_acc:.0%} ({recovery:.2f}x) | "
         f"{size_kb:.1f} KiB state | prefix {hits}/{lookups} | lossless ✓")
    return cell


def run(k_prompts: int = 4, repeats: int = 4, max_new: int = 16,
        n_replicas: int = 3,
        json_out: Optional[str] = None) -> dict:
    doc = {"bench": "fleet", **run_routing(
        k_prompts=k_prompts, repeats=repeats, max_new=max_new,
        n_replicas=n_replicas)}
    doc["cells"]["gossip_spill"] = run_gossip_spill(
        k_prompts=k_prompts, max_new=max_new)
    doc["cells"]["warm_restart"] = run_warm_restart(
        k_prompts=k_prompts, repeats=repeats, max_new=max_new)
    doc["warm_recovery"] = doc["cells"]["warm_restart"]["recovery"]
    doc["gossip_lift"] = doc["cells"]["gossip_spill"]["lift"]
    if json_out:
        with open(json_out, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {json_out}")
    return doc


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--k-prompts", type=int, default=4,
                    help="distinct prompts per tenant pool")
    ap.add_argument("--repeats", type=int, default=4,
                    help="replay rounds over each tenant's pool")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--json-out", default=None,
                    help="write all cells to this JSON file "
                         "(the BENCH_fleet seed)")
    args = ap.parse_args()
    run(k_prompts=args.k_prompts, repeats=args.repeats,
        max_new=args.max_new, n_replicas=args.replicas,
        json_out=args.json_out)
