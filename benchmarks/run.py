"""Benchmark driver — one benchmark per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only table2] [--quick]

Output: ``name,us_per_call,derived`` CSV lines.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

BENCHES = [
    ("table2", "benchmarks.bench_table2"),
    ("fig45", "benchmarks.bench_fig45_edl"),
    ("table3", "benchmarks.bench_table3_ablation"),
    ("table4", "benchmarks.bench_table4_capacity"),
    ("table5", "benchmarks.bench_table5_memory"),
    ("table12", "benchmarks.bench_table12_batch"),
    ("contbatch", "benchmarks.bench_continuous_batch"),
    ("fig1", "benchmarks.bench_fig1_cdl"),
    ("fig6", "benchmarks.bench_fig6_warmup"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="fewer queries per benchmark")
    ap.add_argument("--backends", default=None,
                    help="backend-matrix smoke mode: comma-separated "
                         "attention backends (or 'all') passed to benchmarks "
                         "that accept them — contbatch then reports tok/s "
                         "per backend")
    ap.add_argument("--kv-layout", default=None,
                    help="KV-layout matrix mode: comma-separated layouts "
                         "(dense, paged) or 'all', passed to benchmarks "
                         "that accept them — the contbatch backend sweep "
                         "then covers both layouts")
    args = ap.parse_args()
    backends = None
    if args.backends:
        if args.backends == "all":
            from repro.models.attention import available_backends
            backends = available_backends()
        else:
            backends = tuple(args.backends.split(","))
    kv_layouts = None
    if args.kv_layout:
        kv_layouts = (("dense", "paged") if args.kv_layout == "all"
                      else tuple(args.kv_layout.split(",")))
    failures = 0
    for name, module in BENCHES:
        if args.only and args.only != name:
            continue
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            mod = importlib.import_module(module)
            varnames = mod.run.__code__.co_varnames
            kw = {}
            if args.quick and "n_queries" in varnames:
                kw.update(n_queries=4, max_new=32)
            if backends is not None and "backends" in varnames:
                kw["backends"] = backends
            if kv_layouts is not None and "kv_layouts" in varnames:
                kw["kv_layouts"] = kv_layouts
            mod.run(**kw)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
