"""Paper Table 3: trie-updating procedure ablation — w/o prompt branches,
w/o output branches, w/o pruning, w/o eliminating, vs. full lookahead."""
from __future__ import annotations

import dataclasses

from repro.core import LookaheadConfig

from .common import bench_model, emit, make_dataset, run_serving

BASE = LookaheadConfig(strategy="hierarchical", decoding_length=32,
                       branch_length=8)
VARIANTS = {
    "full": BASE,
    "wo_prompt": dataclasses.replace(BASE, insert_prompt=False),
    "wo_output": dataclasses.replace(BASE, insert_output=False),
    "wo_pruning": dataclasses.replace(BASE, prune=False),
    "wo_eliminating": dataclasses.replace(BASE, eliminate=False),
}


def run(n_queries: int = 10, max_new: int = 48) -> None:
    cfg, params = bench_model()
    ds = make_dataset("antrag", n_queries + 4)
    for name, la in VARIANTS.items():
        r = run_serving(cfg, params, la, ds[4:], max_new=max_new, phase=2,
                        warm_with_outputs=4, n_queries=n_queries)
        emit(f"table3/{name}", 1e6 * r.wall_s / max(r.total_tokens, 1),
             f"steps_compression={r.steps_compression:.2f}x edl={r.edl:.2f}")


if __name__ == "__main__":
    run()
