"""Paper Table 3: trie-updating procedure ablation — w/o prompt branches,
w/o output branches, w/o pruning, w/o eliminating, vs. full lookahead —
plus the draft-SOURCE matrix (DESIGN.md §Draft sources): the same serving
run under trie-only, prompt-copy-only and trie+ngram policies, each
asserted bit-identical to ``reference_decode`` (any source combination is
lossless; only tok/s and per-source acceptance move).

    PYTHONPATH=src python -m benchmarks.bench_table3_ablation \
        [--draft-sources trie,prompt_copy,trie+ngram]
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

from repro.core import DraftPolicy, LookaheadConfig, reference_decode

from .common import (bench_model, emit, make_dataset,
                     make_guided_session_fns, run_serving)

BASE = LookaheadConfig(strategy="hierarchical", decoding_length=32,
                       branch_length=8)
VARIANTS = {
    "full": BASE,
    "wo_prompt": dataclasses.replace(BASE, insert_prompt=False),
    "wo_output": dataclasses.replace(BASE, insert_output=False),
    "wo_pruning": dataclasses.replace(BASE, prune=False),
    "wo_eliminating": dataclasses.replace(BASE, eliminate=False),
}


def parse_combos(spec: str) -> Dict[str, Tuple[str, ...]]:
    """'trie,prompt_copy,trie+ngram' -> {name: source tuple} ('+' merges)."""
    return {c: tuple(c.split("+")) for c in spec.split(",") if c}


def run(n_queries: int = 10, max_new: int = 48) -> None:
    cfg, params = bench_model()
    ds = make_dataset("antrag", n_queries + 4)
    for name, la in VARIANTS.items():
        r = run_serving(cfg, params, la, ds[4:], max_new=max_new, phase=2,
                        warm_with_outputs=4, n_queries=n_queries)
        emit(f"table3/{name}", 1e6 * r.wall_s / max(r.total_tokens, 1),
             f"steps_compression={r.steps_compression:.2f}x edl={r.edl:.2f}")


def run_sources(n_queries: int = 10, max_new: int = 48,
                combos: Dict[str, Tuple[str, ...]] = None) -> None:
    """Draft-source matrix cell: one run per source combination, outputs
    asserted bit-identical to plain step-by-step decoding per query."""
    combos = combos or parse_combos("trie,prompt_copy,trie+ngram")
    cfg, params = bench_model()
    ds = make_dataset("antrag", n_queries)
    prompts = [p for p, _ in ds]
    fns = make_guided_session_fns(cfg, params, phase=2, slots=BASE.slots)
    refs = [reference_decode(fns, p, max_new) for p in prompts]
    for name, srcs in combos.items():
        policy = DraftPolicy(sources=srcs)
        r = run_serving(cfg, params, BASE, ds, max_new=max_new, fns=fns,
                        n_queries=n_queries, draft_policy=policy)
        # losslessness: re-run outside the timed loop to compare per query
        # (run_serving only aggregates) — same engine config, fresh trie
        from repro.core import LookaheadEngine
        outs = LookaheadEngine(fns, BASE,
                               draft_policy=policy).generate_batch(
            prompts, max_new)
        for q, (o, ref) in enumerate(zip(outs, refs)):
            assert o.tokens == ref, \
                f"draft sources {srcs} changed query {q}'s output"
        emit(f"table3/sources/{name}",
             1e6 * r.wall_s / max(r.total_tokens, 1),
             f"{r.tokens_per_s:.1f} tok/s | edl={r.edl:.2f} | "
             f"acc {r.source_summary() or '-'} | lossless ✓")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=10)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--draft-sources", default="trie,prompt_copy,trie+ngram",
                    help="comma-separated combinations; '+' merges sources "
                         "within one policy (e.g. trie+ngram)")
    args = ap.parse_args()
    run(n_queries=args.queries, max_new=args.max_new)
    run_sources(n_queries=args.queries, max_new=args.max_new,
                combos=parse_combos(args.draft_sources))
