"""Paper Table 4: trie node capacity (n × decoding_length) vs speed, plus
retrieve/update wall times."""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import LookaheadConfig
from repro.core.trie import TrieTree
from repro.training.data import PROFILES, SyntheticCorpus

from .common import VOCAB, bench_model, emit, make_dataset, run_serving


def run(n_queries: int = 8, max_new: int = 48) -> None:
    cfg, params = bench_model()
    ds = make_dataset("antrag", n_queries + 4)
    for factor in (1, 4, 16, 64):
        la = LookaheadConfig(strategy="hierarchical", decoding_length=32,
                             branch_length=8, capacity_factor=factor)
        r = run_serving(cfg, params, la, ds[4:], max_new=max_new, phase=2,
                        warm_with_outputs=4, n_queries=n_queries)
        # measure raw trie op latencies at this capacity
        trie = TrieTree(capacity=la.trie_capacity)
        corpus = SyntheticCorpus(PROFILES["antrag"], VOCAB, seed=3)
        for _ in range(30):
            p, a = corpus.sample()
            trie.insert_ngrams(a, la.branch_length)
        ctxs = [corpus.sample()[1][:12] for _ in range(64)]
        t0 = time.perf_counter()
        for c in ctxs:
            trie.retrieve(c, decoding_length=32)
        retrieve_ms = (time.perf_counter() - t0) / len(ctxs) * 1e3
        t0 = time.perf_counter()
        for c in ctxs:
            trie.insert_ngrams(c, la.branch_length)
        update_ms = (time.perf_counter() - t0) / len(ctxs) * 1e3
        emit(f"table4/cap{factor}xDL",
             1e6 * r.wall_s / max(r.total_tokens, 1),
             f"steps_compression={r.steps_compression:.2f}x "
             f"retrieve_ms={retrieve_ms:.3f} update_ms={update_ms:.3f} "
             f"trie_nodes={len(trie)}")


if __name__ == "__main__":
    run()
