"""Paper Figures 4+5: decoding length × branch length → inference speed and
EDL, for single/parallel/hierarchical strategies."""
from __future__ import annotations

from repro.core import LookaheadConfig

from .common import bench_model, emit, make_dataset, run_serving


def run(n_queries: int = 8, max_new: int = 48) -> None:
    cfg, params = bench_model()
    ds = make_dataset("antrag", n_queries + 4)
    for strategy in ("single", "parallel", "hierarchical"):
        for dl in (8, 16, 32, 64):
            for bl in (4, 8, 16):
                la = LookaheadConfig(strategy=strategy, decoding_length=dl,
                                     branch_length=bl)
                r = run_serving(cfg, params, la, ds[4:], max_new=max_new, phase=2,
                                warm_with_outputs=4, n_queries=n_queries)
                emit(f"fig45/{strategy}/dl{dl}/bl{bl}",
                     1e6 * r.wall_s / max(r.total_tokens, 1),
                     f"edl={r.edl:.2f} "
                     f"steps_compression={r.steps_compression:.2f}x")


if __name__ == "__main__":
    run()
