"""Paper Table 2: inference speed of baseline / LLMA(single-branch) /
lookahead-parallel / lookahead-hierarchical across dataset profiles.

Reported: CPU tokens/s (this box), steps-compression (the IO-bound speedup,
hardware-independent), mean EDL, and v5e-projected tokens/s for a 10B-class
model (AntGLM row of the paper)."""
from __future__ import annotations

from repro.core import LookaheadConfig

from .common import (PROFILE_PHASE, bench_model, emit, make_dataset,
                     run_serving, v5e_projected_tokens_per_s)

METHODS = {
    "baseline": LookaheadConfig(strategy="none", decoding_length=0),
    # LLMA w/ output-stream references (prompt-only retrieval finds nothing
    # on the guided bench model, which does not copy its prompt)
    "llma": LookaheadConfig(strategy="single", decoding_length=16,
                            branch_length=16),
    "la-parallel": LookaheadConfig(strategy="parallel", decoding_length=48,
                                   branch_length=16),
    "la-hier": LookaheadConfig(strategy="hierarchical", decoding_length=48,
                               branch_length=16),
}
DATASETS = ["antrag", "dolly", "gsm8k", "humaneval"]


def run(n_queries: int = 10, max_new: int = 48) -> None:
    cfg, params = bench_model()
    for ds_name in DATASETS:
        ds = make_dataset(ds_name, n_queries + 4)
        base = None
        for m_name, la in METHODS.items():
            r = run_serving(cfg, params, la, ds[:n_queries + 4],
                            max_new=max_new, n_queries=n_queries,
                            phase=PROFILE_PHASE[ds_name],
                            warm_with_outputs=4)
            if m_name == "baseline":
                base = r
            speedup = r.steps_compression / base.steps_compression
            proj = v5e_projected_tokens_per_s(cfg, 10.14e9,
                                              r.steps_compression)
            emit(f"table2/{ds_name}/{m_name}",
                 1e6 * r.wall_s / max(r.total_tokens, 1),
                 f"steps_compression={r.steps_compression:.2f}x "
                 f"edl={r.edl:.2f} cpu_tok_s={r.tokens_per_s:.1f} "
                 f"v5e_proj_10b_tok_s={proj:.0f} rel_speedup={speedup:.2f}x")


if __name__ == "__main__":
    run()
