"""Paper Figure 6 (Appendix D): inference speed vs warm-up sample count."""
from __future__ import annotations

from repro.core import LookaheadConfig

from .common import bench_model, emit, make_dataset, run_serving


def run(n_queries: int = 8, max_new: int = 48) -> None:
    cfg, params = bench_model()
    ds = make_dataset("antrag", 40)
    la = LookaheadConfig(strategy="hierarchical", decoding_length=32,
                         branch_length=8)
    for n_warm in (0, 2, 8, 16):
        r = run_serving(cfg, params, la, ds[:n_queries + n_warm],
                        max_new=max_new, phase=2,
                        warm_with_outputs=n_warm, n_queries=n_queries)
        emit(f"fig6/warm{n_warm}", 1e6 * r.wall_s / max(r.total_tokens, 1),
             f"steps_compression={r.steps_compression:.2f}x edl={r.edl:.2f}")


if __name__ == "__main__":
    run()
