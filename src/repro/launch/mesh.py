"""Production mesh builders.

Single pod: (data=16, model=16) = 256 chips (v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; ``pod`` is an outer
data-parallel axis whose collectives ride DCI between pods.

Functions (never module-level constants) so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"))


# v5e hardware constants (roofline)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~4 links/chip on v5e 2D torus)
HBM_PER_CHIP = 16 * 1024 ** 3   # 16 GiB
