"""Recompute roofline terms for existing dry-run records after a metric
model change (floors) — reuses stored cost + collective bytes, no recompile.

    PYTHONPATH=src python -m repro.launch.fixup_rooflines dryrun_results.json
"""
import json
import sys

from repro import configs as cfgreg
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def main(path: str) -> None:
    with open(path) as f:
        results = json.load(f)
    meta_cache = {}
    for key, rec in results.items():
        if not rec.get("ok"):
            continue
        arch, shape = rec["arch"], rec["shape"]
        mk = (arch, shape)
        if mk not in meta_cache:
            cell = cfgreg.get_arch(arch).build_cell(shape, fast=True)
            meta_cache[mk] = cell.meta
        meta = meta_cache[mk]
        rec["meta"] = meta
        r = rec["roofline"]
        n = rec["n_chips"]
        flops = r["hlo_flops_per_chip"] + meta.get("flops_correction", 0.0) / n
        floor = meta.get("bytes_floor", 0.0) / n
        t_c = flops / PEAK_FLOPS_BF16
        t_mf = floor / HBM_BW if floor else r["hlo_bytes_per_chip"] / HBM_BW
        t_cl = r["collective_bytes_per_chip"] / (4 * ICI_BW)
        terms = {"compute_s": t_c, "memory_s": t_mf, "collective_s": t_cl}
        r.update(terms)
        r["memory_raw_s"] = r["hlo_bytes_per_chip"] / HBM_BW
        r["bottleneck"] = max(terms, key=terms.get).replace("_s", "")
        if meta.get("model_flops"):
            mfpc = meta["model_flops"] / n
            r["useful_flops_ratio"] = mfpc / max(flops, 1.0)
            r["roofline_fraction"] = (mfpc / PEAK_FLOPS_BF16) / max(
                max(terms.values()), 1e-12)
    with open(path, "w") as f:
        json.dump(results, f, indent=1)
    print(f"fixed {len(results)} records")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json")
