"""Serving launcher: drive the request-centric serving engine (or the legacy
lock-step loop) over an arch config with a synthetic arrival stream.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --smoke --requests 16 --lanes 4 --rate 8 --mixed-sampling

Reports throughput (tokens/s), EDL, lane occupancy and per-request latency
percentiles (p50/p95/p99) plus time-to-first-token.  ``--rate 0`` submits
every request at t=0 (closed-loop batch mode); a positive rate draws Poisson
inter-arrival gaps (open-loop mode — the scheduler admits mid-flight).

All engine knobs are one validated ``EngineConfig``
(repro.serving.api.build_engine); requests are ``Request`` objects with
per-request ``SamplingParams``: ``--mixed-sampling`` alternates greedy and
sampled traffic (distinct temperatures/seeds) inside the same lane pool, and
``--cancel-every N`` cancels every Nth request mid-flight through its
``RequestHandle`` — both exercises of the production API surface.

On real hardware drop --smoke to load the full config (weights from
--ckpt-dir via training.checkpoint) onto the production mesh.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List

import jax
import numpy as np

from repro import configs as cfgreg
from repro.core import (DraftPolicy, LookaheadEngine, Request,
                        SamplingParams)
from repro.core.draft_sources import available_sources
from repro.models import attention as attn_backends
from repro.models import transformer as tx
from repro.serving.api import EngineConfig, build_engine
from repro.training.checkpoint import CheckpointManager
from repro.training.data import PROFILES, SyntheticCorpus


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


def _request_params(args, i: int) -> SamplingParams:
    """Per-request SamplingParams for request i of the synthetic stream."""
    max_new = args.max_new if (not args.mixed or i % 2) else \
        max(args.max_new // 4, 2)
    if args.mixed_sampling:
        # alternate greedy / sampled at cycling temperatures, one seed per
        # request — a co-batched mix the per-lane param vectors must honor
        if i % 2:
            return SamplingParams(max_new_tokens=max_new, sample=True,
                                  temperature=(0.5, 0.8, 1.1)[i % 3],
                                  seed=1000 + i)
        return SamplingParams(max_new_tokens=max_new)
    return SamplingParams(max_new_tokens=max_new, sample=args.sample,
                          temperature=args.temperature, seed=0)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--lanes", type=int, default=4,
                    help="KV-cache slots held on device (continuous mode)")
    ap.add_argument("--mode", choices=["continuous", "lockstep"],
                    default="continuous")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="mean arrivals/s (Poisson); 0 = all at t0")
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--mixed", action="store_true",
                    help="mixed-length workload: alternate max_new/4 and "
                         "max_new budgets (the continuous-batching case)")
    ap.add_argument("--mixed-sampling", action="store_true",
                    help="mixed per-request sampling: alternate greedy and "
                         "sampled (distinct temperatures/seeds) requests in "
                         "the same lane pool")
    ap.add_argument("--cancel-every", type=int, default=0,
                    help="cancel every Nth request mid-flight through its "
                         "RequestHandle (0 = never)")
    ap.add_argument("--overlap-drafts", action="store_true",
                    help="overlap host work with the in-flight device step "
                         "(deferred retirement + admission settles after "
                         "draft building); bit-identical outputs to the "
                         "serial path")
    ap.add_argument("--prefill-len", type=int, default=128,
                    help="fixed prompt pad length (compile prefill once)")
    ap.add_argument("--decoding-length", type=int, default=32)
    ap.add_argument("--branch-length", type=int, default=12)
    ap.add_argument("--draft-sources", default="trie",
                    help="comma-separated draft sources feeding every "
                         "request's trees, in merge-priority order "
                         f"(registry: {', '.join(available_sources())})")
    ap.add_argument("--adaptive-draft", action="store_true",
                    help="per-lane adaptive draft budget from the "
                         "accepted-length EMA (paper §5.2 warmup/CDL)")
    ap.add_argument("--trie-namespace-key", default=None,
                    help="request-metadata key whose value scopes the trie "
                         "namespace (per-scenario tries, isolated branch "
                         "frequencies; the synthetic stream tags requests "
                         "with 'tenant')")
    ap.add_argument("--lane-shares", default=None,
                    help="per-namespace lane shares as ns=frac,... (e.g. "
                         "t0=0.5,t1=0.5): weighted-fair admission across "
                         "tenants with a lane-occupancy cap of "
                         "ceil(lanes*frac) each; unlisted namespaces are "
                         "uncapped at the lowest listed weight")
    ap.add_argument("--draft-budget-caps", default=None,
                    help="per-namespace draft budget caps as ns=int,... — "
                         "bounds speculative tokens per tree for that "
                         "tenant's requests")
    ap.add_argument("--autotune", action="store_true",
                    help="per-namespace draft-source auto-tuning: drive a "
                         "source's quota to zero on namespaces where it "
                         "never verifies (EMA acceptance controller; "
                         "outputs stay bit-identical)")
    ap.add_argument("--sanitize", action="store_true",
                    help="runtime sanitizer: shadow block-ownership "
                         "ledger, per-request lifecycle state machine, "
                         "retrace monitor (repro.analysis.sanitizer). "
                         "Raises on any invariant violation; adds host "
                         "overhead, outputs unchanged")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--sample", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--eos-id", type=int, default=-1,
                    help="EOS token id ending generation early (-1 = the "
                         "arch defines none; synthetic corpora avoid one)")
    ap.add_argument("--backend", default=None,
                    choices=attn_backends.available_backends(),
                    help="attention backend for BOTH phases (registry: "
                         f"{', '.join(attn_backends.available_backends())})")
    ap.add_argument("--prefill-backend", default=None,
                    choices=attn_backends.available_backends(),
                    help="prefill-phase attention backend override")
    ap.add_argument("--decode-backend", default=None,
                    choices=attn_backends.available_backends(),
                    help="tree-decode-phase attention backend override")
    ap.add_argument("--kv-layout", default="dense",
                    choices=["dense", "paged"],
                    help="KV-cache layout: dense (lanes, max_seq_len) rows "
                         "or a paged block pool with per-lane block tables")
    ap.add_argument("--block-size", type=int, default=64,
                    help="paged layout: KV rows per block")
    ap.add_argument("--kv-blocks", type=int, default=0,
                    help="paged layout: total pool blocks (0 = size the "
                         "pool to the workload's worst-case footprint; the "
                         "dense-equivalent is lanes*ceil(max_seq_len/"
                         "block_size)+1)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix-tree prefix caching on the paged pool: "
                         "admissions whose prompt prefix is already "
                         "resident skip that portion of prefill "
                         "(copy-on-write block sharing; bit-identical "
                         "outputs)")
    ap.add_argument("--prefix-cache-blocks", type=int, default=0,
                    help="cap on blocks the prefix cache may keep resident "
                         "(0 = bounded only by pool pressure)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="give every request a shared system-prompt prefix "
                         "of this many tokens (prefix-heavy traffic for "
                         "--prefix-cache)")
    # ---- fleet serving (repro.fleet; DESIGN.md §Fleet serving)
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve through N in-process engine replicas behind "
                         "the namespace-affinity router (1 = single engine)")
    ap.add_argument("--routing", default="affinity",
                    choices=["affinity", "round_robin"],
                    help="fleet placement policy: consistent-hash namespace "
                         "affinity (warm tries keep their traffic) or "
                         "round-robin (the cold baseline)")
    ap.add_argument("--gossip-every", type=int, default=0,
                    help="fleet rounds between all-to-all draft-state "
                         "merges (0 = gossip off)")
    ap.add_argument("--fleet-queue-depth", type=int, default=8,
                    help="per-replica queue depth at which affinity "
                         "routing spills to the least-loaded replica")
    ap.add_argument("--warm-state", default=None,
                    help="draft-state file: loaded at startup when it "
                         "exists (warm restart), saved at exit")
    ap.add_argument("--verify-fleet", action="store_true",
                    help="re-run the fleet workload on one reference "
                         "engine and assert bit-identical outputs")
    args = ap.parse_args()

    def _ns_map(spec, cast):
        if not spec:
            return None
        out = {}
        for cell in spec.split(","):
            ns, _, val = cell.partition("=")
            if not _:
                raise SystemExit(f"bad ns=value cell {cell!r}")
            out[ns] = cast(val)
        return out

    lane_shares = _ns_map(args.lane_shares, float)
    draft_caps = _ns_map(args.draft_budget_caps, int)
    if (lane_shares or draft_caps) and not args.trie_namespace_key:
        raise SystemExit("--lane-shares/--draft-budget-caps key on the "
                         "request namespace; set --trie-namespace-key "
                         "(e.g. tenant) so requests carry one")
    if args.prefix_cache and args.kv_layout != "paged":
        raise SystemExit("--prefix-cache requires --kv-layout paged")
    if args.kv_layout == "paged" and args.mode == "lockstep":
        raise SystemExit("--kv-layout paged requires --mode continuous "
                         "(the scheduler owns the block allocator)")
    draft_policy = DraftPolicy(
        sources=tuple(args.draft_sources.split(",")),
        adaptive=args.adaptive_draft).validate()
    if args.mode == "lockstep" and (
            draft_policy.sources != ("trie",) or draft_policy.adaptive
            or args.trie_namespace_key or args.autotune):
        raise SystemExit("--draft-sources/--adaptive-draft/"
                         "--trie-namespace-key/--autotune require --mode "
                         "continuous (the lock-step loop is the "
                         "hardwired-trie baseline)")

    mod = cfgreg.get_arch(args.arch)
    cfg = mod.smoke_config() if args.smoke else mod.full_config()
    if not hasattr(cfg, "n_layers"):
        raise SystemExit(f"{args.arch} is not an LM arch; serving loop is "
                         "for autoregressive decoders (see DESIGN.md "
                         "§Arch-applicability)")
    cfg = type(cfg)(**{**cfg.__dict__, "max_seq_len": 768}) \
        if args.smoke else cfg
    params = tx.init_params(cfg, jax.random.key(0))
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        state, step = mgr.restore({"params": params})
        params = state["params"]
        print(f"restored checkpoint step {step}")

    n_blocks = None
    slots = 1 + args.decoding_length
    if args.kv_layout == "paged":
        # size the pool to the workload's worst-case footprint instead of
        # lanes * max_seq_len (the paged memory win), with the SAME formula
        # the scheduler admits by
        from repro.serving.block_allocator import worst_case_pool_blocks
        n_blocks = args.kv_blocks or worst_case_pool_blocks(
            args.lanes, args.prefill_len, args.max_new, slots,
            cfg.max_seq_len, args.block_size)
    # ---- one validated spec instead of kwargs threaded through four layers
    ecfg = EngineConfig(
        lanes=args.lanes, prefill_len=args.prefill_len,
        decoding_length=args.decoding_length,
        branch_length=args.branch_length,
        eos_id=args.eos_id,
        backend=args.backend, prefill_backend=args.prefill_backend,
        decode_backend=args.decode_backend,
        kv_layout=args.kv_layout, block_size=args.block_size,
        n_blocks=n_blocks,
        default_params=SamplingParams(
            max_new_tokens=args.max_new, sample=args.sample,
            temperature=args.temperature),
        draft_policy=draft_policy,
        overlap_drafts=args.overlap_drafts,
        prefix_cache=args.prefix_cache,
        prefix_cache_blocks=args.prefix_cache_blocks or None,
        lane_shares=lane_shares,
        draft_budget_caps=draft_caps,
        autotune=args.autotune, sanitize=args.sanitize)

    corpus = SyntheticCorpus(PROFILES["antrag"], cfg.vocab_size, seed=0)
    prompt_cap = min(96, args.prefill_len)
    system_prompt = (corpus.sample()[0][:min(args.shared_prefix, prompt_cap)]
                     if args.shared_prefix > 0 else [])
    def _prompt():
        tail_cap = max(prompt_cap - len(system_prompt), 1)
        return list(system_prompt) + corpus.sample()[0][:tail_cap]
    reqs = [Request(prompt=_prompt(),
                    params=_request_params(args, i),
                    metadata={"i": i, "tenant": f"t{i % 2}"})
            for i in range(args.requests)]
    if args.trie_namespace_key:
        # scenario-scoped tries: each request speculates inside the trie
        # namespace its metadata names (per-request DraftPolicy override)
        for r in reqs:
            ns = str(r.metadata.get(args.trie_namespace_key, ""))
            r.params = dataclasses.replace(
                r.params,
                draft=dataclasses.replace(draft_policy, namespace=ns))

    if args.replicas > 1:
        if args.mode != "continuous":
            raise SystemExit("--replicas requires --mode continuous")
        if args.cancel_every:
            raise SystemExit("--cancel-every is a single-engine exercise; "
                             "drop it with --replicas")
        _run_fleet(args, ecfg, cfg, params, reqs, lane_shares)
        return

    engine = build_engine(ecfg, cfg, params)
    if args.warm_state:
        import os
        if os.path.exists(args.warm_state):
            engine.load_draft_state(args.warm_state)
            print(f"warm state loaded from {args.warm_state} "
                  f"(trie={len(engine.scheduler.sources['trie'].forest)} "
                  "nodes)")

    if args.mode == "lockstep":
        lock = LookaheadEngine(engine.fns, ecfg.lookahead(),
                               eos_id=ecfg.eos_id)
        t0 = time.time()
        tok = steps = 0
        for i in range(0, len(reqs), args.lanes):
            chunk = reqs[i:i + args.lanes]
            outs = lock.generate_batch_lockstep(
                [r.prompt for r in chunk],
                params=[r.params for r in chunk])
            for o in outs:
                tok += len(o.tokens)
                steps += o.stats.steps
        dt = time.time() - t0
        print(f"lockstep: {tok} tokens / {steps} steps "
              f"(EDL {tok/max(steps,1):.2f}) in {dt:.1f}s "
              f"-> {tok/dt:.1f} tok/s; trie={len(lock.trie)} nodes")
        return

    # ---------------------------------------------------- continuous serving
    rng = np.random.RandomState(0)
    if args.rate > 0:
        gaps = rng.exponential(1.0 / args.rate, size=len(reqs))
        arrivals = np.cumsum(gaps)
    else:
        arrivals = np.zeros(len(reqs))

    streamed = [0]          # tokens observed through handle callbacks
    handles = []
    cancelled = []

    t0 = time.time()
    nxt = 0
    while nxt < len(reqs) or not engine.idle:
        now = time.time() - t0
        while nxt < len(reqs) and arrivals[nxt] <= now:
            h = engine.submit(reqs[nxt])
            h.on_token(lambda delta: streamed.__setitem__(
                0, streamed[0] + len(delta)))
            handles.append(h)
            if args.cancel_every and (nxt % args.cancel_every
                                      == args.cancel_every - 1):
                cancelled.append(h)
            nxt += 1
        if engine.idle:
            # open-loop gap: nothing in flight, wait for the next arrival
            time.sleep(min(max(arrivals[nxt] - now, 0.0), 0.05))
            continue
        engine.step()
        for h in cancelled:
            if not h.done:
                h.cancel()
    dt = time.time() - t0
    results = [h.result() for h in handles]

    live = [r for r in results if not r.cancelled]
    tok = sum(len(r.tokens) for r in live)
    steps = sum(r.stats.steps for r in live)
    lat = [r.latency_s for r in live]
    ttft = [r.ttft_s for r in live]
    st = engine.stats
    sched = engine.scheduler
    n_cancelled = sum(1 for r in results if r.cancelled)
    print(f"continuous: {tok} tokens / {len(live)} requests "
          f"({n_cancelled} cancelled, {streamed[0]} streamed deltas, "
          f"{st.decode_steps} device steps, EDL {tok/max(steps,1):.2f}, "
          f"occupancy {st.occupancy:.2f}) in {dt:.1f}s -> {tok/dt:.1f} tok/s")
    if sched.cache is not None:
        cache_mb = sum(v.nbytes for v in sched.cache.values()) / 2**20
        extra = (f", peak {st.peak_blocks} blocks, "
                 f"{st.block_waits} block-waits"
                 if args.kv_layout == "paged" else "")
        print(f"kv cache [{args.kv_layout}]: {cache_mb:.1f} MiB{extra}")
    if args.prefix_cache:
        print(f"prefix cache: {st.prefix_hits}/{st.prefix_lookups} hits "
              f"({st.prefix_hit_rate:.0%}), "
              f"{st.prefix_hit_tokens}/{st.prefix_prompt_tokens} prefill "
              f"tokens saved ({st.prefill_tokens_saved:.0%}), "
              f"{st.prefix_cow_forks} COW forks, "
              f"{sched.prefix.n_blocks} resident blocks, "
              f"{st.prefix_evicted_blocks} evicted")
    br = st.breakdown()
    mode = "overlap" if args.overlap_drafts else "serial"
    print(f"step breakdown [{mode}]: draft {br['host_draft_ms']:.2f} ms   "
          f"device {br['device_step_ms']:.2f} ms   "
          f"accept {br['accept_commit_ms']:.2f} ms   "
          f"hidden {br['hidden_host_ms']:.2f} ms   "
          f"{br['syncs_per_step']:.1f} sync/step")
    # per-tenant deployments report latency through namespace_summary():
    # pooled percentiles over all requests let a hot tenant's volume dilute
    # a cold tenant's p99 (the SLO the shares exist to protect), so the
    # pooled lines only headline single-tenant runs
    ns_sum = st.namespace_summary()
    multi_tenant = bool(lane_shares) or len(ns_sum) > 1
    if not multi_tenant:
        print(f"latency  p50 {_pct(lat, 50)*1e3:7.1f} ms   "
              f"p95 {_pct(lat, 95)*1e3:7.1f} ms   "
              f"p99 {_pct(lat, 99)*1e3:7.1f} ms")
    else:
        print("latency: per-tenant percentiles below (pooled percentiles "
              "would dilute cold-tenant p99 under hot-tenant volume)")
    forest = engine.scheduler.sources["trie"].forest
    if not multi_tenant:
        print(f"ttft     p50 {_pct(ttft, 50)*1e3:7.1f} ms   "
              f"p95 {_pct(ttft, 95)*1e3:7.1f} ms   "
              f"p99 {_pct(ttft, 99)*1e3:7.1f} ms")
    print(f"trie={len(forest)} nodes "
          f"across {len(forest.namespaces())} namespace(s)")
    # per-draft-source speculation telemetry (paper Table 3-style): how many
    # draft tokens each source placed and how many the model verified
    drafted: dict = {}
    accepted: dict = {}
    for r in results:
        for k, v in r.stats.source_drafted.items():
            drafted[k] = drafted.get(k, 0) + v
        for k, v in r.stats.source_accepted.items():
            accepted[k] = accepted.get(k, 0) + v
    if drafted:
        cells = [f"{name} {accepted.get(name, 0)}/{n} "
                 f"({accepted.get(name, 0) / max(n, 1):.0%})"
                 for name, n in sorted(drafted.items())]
        print(f"draft sources (accepted/drafted): {'   '.join(cells)}")
    # per-tenant SLO telemetry: latency percentiles, occupancy share and the
    # controller's per-source verdicts for every namespace seen this run
    if multi_tenant or args.autotune:
        for ns, row in ns_sum.items():
            print(f"tenant {ns or '<default>'!s:10s} "
                  f"fin {row['finished']:3d}/{row['submitted']:3d} "
                  f"({row['cancelled']} cancelled) "
                  f"occ {row['occupancy']:.2f}  "
                  f"p50 {row['p50_latency_s']*1e3:7.1f} ms  "
                  f"p99 {row['p99_latency_s']*1e3:7.1f} ms  "
                  f"ttft-p99 {row['p99_ttft_s']*1e3:7.1f} ms  "
                  f"queue-p99 {row['p99_queue_s']*1e3:7.1f} ms")
    if sched.sanitizer is not None:
        # reaching this line means every shadow check passed (violations
        # raise); report the audit so smoke logs show it actually ran
        n_tracked = len(sched.sanitizer.lifecycle._state)
        print(f"sanitizer: clean — {n_tracked} request lifecycles "
              "drained, block ledger and retrace manifest verified")
    if sched.autotuner is not None:
        for ns, srcs in sorted(sched.autotuner.snapshot().items()):
            cells = [f"{name} {'on' if s['enabled'] else 'OFF'} "
                     f"ema {s['ema']:.2f} "
                     f"({s['accepted']}/{s['drafted']}, "
                     f"{s['probes']} probes)"
                     for name, s in sorted(srcs.items())]
            print(f"autotune [{ns or '<default>'}]: {'   '.join(cells)}")
    if args.warm_state:
        engine.save_draft_state(args.warm_state)
        print(f"warm state saved to {args.warm_state}")


# -------------------------------------------------------------- fleet serving
def _run_fleet(args, ecfg, cfg, params, reqs, lane_shares) -> None:
    """Drive the synthetic arrival stream through an N-replica fleet
    (repro.fleet): namespace-affinity or round-robin routing, optional
    gossip cadence, warm-state load-at-start / save-at-exit, and an
    optional bit-identity verification against one reference engine."""
    import os

    from repro.fleet import EngineReplica, FleetRouter, GossipCoordinator

    def _builder():
        return build_engine(ecfg, cfg, params)

    replicas = [EngineReplica(_builder, replica_id=f"r{i}")
                for i in range(args.replicas)]
    if args.warm_state and os.path.exists(args.warm_state):
        for rep in replicas:
            rep.load_draft_state(args.warm_state)
        print(f"warm state loaded from {args.warm_state} "
              f"(all {args.replicas} replicas)")
    router = FleetRouter(replicas, policy=args.routing,
                         max_queue_depth=args.fleet_queue_depth)
    gossip = GossipCoordinator(replicas, every=args.gossip_every)

    rng = np.random.RandomState(0)
    arrivals = (np.cumsum(rng.exponential(1.0 / args.rate, size=len(reqs)))
                if args.rate > 0 else np.zeros(len(reqs)))
    t0 = time.time()
    nxt = 0
    while nxt < len(reqs) or not router.idle:
        now = time.time() - t0
        while nxt < len(reqs) and arrivals[nxt] <= now:
            r = reqs[nxt]
            router.submit(r.prompt, r.params)
            nxt += 1
        if router.idle:
            time.sleep(min(max(arrivals[nxt] - now, 0.0), 0.05))
            continue
        router.step_all()
        gossip.tick()
    dt = time.time() - t0

    results = router.results()
    tok = sum(len(r["tokens"]) for r in results)
    fs = router.fleet_stats()
    print(f"fleet [{args.replicas}x {args.routing}]: {tok} tokens / "
          f"{len(results)} requests in {dt:.1f}s -> {tok/dt:.1f} tok/s; "
          f"routed {fs.routed} ({fs.affinity_hits} affinity, "
          f"{fs.spills} spills), {gossip.exchanges} gossip exchanges")
    for i, snap in enumerate(fs.replicas):
        print(f"  replica r{i}: {snap['finished']} finished / "
              f"{snap['admitted']} admitted, {snap['decode_steps']} device "
              f"steps, trie={snap['trie_nodes']} nodes")
    # fleet rollup reuses namespace_summary(): per-tenant percentiles over
    # the UNION of every replica's raw samples (never pooled across
    # tenants, never averaged across replicas)
    for ns, row in fs.namespace_summary().items():
        print(f"tenant {ns or '<default>'!s:10s} "
              f"fin {row['finished']:3d}/{row['submitted']:3d} "
              f"occ {row['occupancy']:.2f}  "
              f"p50 {row['p50_latency_s']*1e3:7.1f} ms  "
              f"p99 {row['p99_latency_s']*1e3:7.1f} ms  "
              f"ttft-p99 {row['p99_ttft_s']*1e3:7.1f} ms")
    for ns, accs in sorted(fs.source_acceptance().items()):
        cells = [f"{name} {rate:.0%}" for name, rate in sorted(accs.items())]
        print(f"acceptance [{ns or '<default>'}]: {'   '.join(cells)}")

    if args.verify_fleet:
        single = _builder()
        handles = [single.submit(Request(prompt=list(r.prompt),
                                         params=r.params)) for r in reqs]
        single.run()
        bad = sum(1 for h, res in zip(handles, results)
                  if h.result().tokens != res["tokens"])
        if bad:
            raise SystemExit(f"fleet outputs differ from the single-replica "
                             f"reference on {bad}/{len(reqs)} requests "
                             "(losslessness violation)")
        print(f"verify: fleet outputs bit-identical to the single-replica "
              f"reference ({len(reqs)} requests)")

    if args.warm_state:
        if len(replicas) > 1:
            gossip.exchange()   # fold every replica's warmth into one file
        replicas[0].save_draft_state(args.warm_state)
        print(f"warm state saved to {args.warm_state}")


if __name__ == "__main__":
    main()
