"""Serving launcher: drive the continuous-batching scheduler (or the legacy
lock-step loop) over an arch config with a synthetic arrival stream.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --smoke --requests 16 --lanes 4 --rate 8

Reports throughput (tokens/s), EDL, lane occupancy and per-request latency
percentiles (p50/p95/p99) plus time-to-first-token.  ``--rate 0`` submits
every request at t=0 (closed-loop batch mode); a positive rate draws Poisson
inter-arrival gaps (open-loop mode — the scheduler admits mid-flight).

On real hardware drop --smoke to load the full config (weights from
--ckpt-dir via training.checkpoint) onto the production mesh.
"""
from __future__ import annotations

import argparse
import time
from typing import List

import jax
import numpy as np

from repro import configs as cfgreg
from repro.core import LookaheadConfig, LookaheadEngine
from repro.models import attention as attn_backends
from repro.models import transformer as tx
from repro.serving.scheduler import ContinuousScheduler
from repro.serving.session import make_session_fns
from repro.training.checkpoint import CheckpointManager
from repro.training.data import PROFILES, SyntheticCorpus


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--lanes", type=int, default=4,
                    help="KV-cache slots held on device (continuous mode)")
    ap.add_argument("--mode", choices=["continuous", "lockstep"],
                    default="continuous")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="mean arrivals/s (Poisson); 0 = all at t0")
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--mixed", action="store_true",
                    help="mixed-length workload: alternate max_new/4 and "
                         "max_new budgets (the continuous-batching case)")
    ap.add_argument("--prefill-len", type=int, default=128,
                    help="fixed prompt pad length (compile prefill once)")
    ap.add_argument("--decoding-length", type=int, default=32)
    ap.add_argument("--branch-length", type=int, default=12)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--sample", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--backend", default=None,
                    choices=attn_backends.available_backends(),
                    help="attention backend for BOTH phases (registry: "
                         f"{', '.join(attn_backends.available_backends())})")
    ap.add_argument("--prefill-backend", default=None,
                    choices=attn_backends.available_backends(),
                    help="prefill-phase attention backend override")
    ap.add_argument("--decode-backend", default=None,
                    choices=attn_backends.available_backends(),
                    help="tree-decode-phase attention backend override")
    ap.add_argument("--kv-layout", default="dense",
                    choices=["dense", "paged"],
                    help="KV-cache layout: dense (lanes, max_seq_len) rows "
                         "or a paged block pool with per-lane block tables")
    ap.add_argument("--block-size", type=int, default=64,
                    help="paged layout: KV rows per block")
    ap.add_argument("--kv-blocks", type=int, default=0,
                    help="paged layout: total pool blocks (0 = size the "
                         "pool to the workload's worst-case footprint; the "
                         "dense-equivalent is lanes*ceil(max_seq_len/"
                         "block_size)+1)")
    args = ap.parse_args()
    if args.kv_layout == "paged" and args.mode == "lockstep":
        raise SystemExit("--kv-layout paged requires --mode continuous "
                         "(the scheduler owns the block allocator)")

    mod = cfgreg.get_arch(args.arch)
    cfg = mod.smoke_config() if args.smoke else mod.full_config()
    if not hasattr(cfg, "n_layers"):
        raise SystemExit(f"{args.arch} is not an LM arch; serving loop is "
                         "for autoregressive decoders (see DESIGN.md "
                         "§Arch-applicability)")
    cfg = type(cfg)(**{**cfg.__dict__, "max_seq_len": 768}) \
        if args.smoke else cfg
    params = tx.init_params(cfg, jax.random.key(0))
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        state, step = mgr.restore({"params": params})
        params = state["params"]
        print(f"restored checkpoint step {step}")

    la = LookaheadConfig(decoding_length=args.decoding_length,
                         branch_length=args.branch_length,
                         sample=args.sample, temperature=args.temperature)
    n_blocks = None
    if args.kv_layout == "paged":
        # size the pool to the workload's worst-case footprint instead of
        # lanes * max_seq_len (the paged memory win), with the SAME formula
        # the scheduler admits by
        from repro.serving.block_allocator import worst_case_pool_blocks
        n_blocks = args.kv_blocks or worst_case_pool_blocks(
            args.lanes, args.prefill_len, args.max_new, la.slots,
            cfg.max_seq_len, args.block_size)
    fns = make_session_fns(cfg, params, sample=args.sample,
                           temperature=args.temperature,
                           base_key=jax.random.key(0), slots=la.slots,
                           prefill_len=args.prefill_len,
                           backend=args.backend,
                           prefill_backend=args.prefill_backend,
                           decode_backend=args.decode_backend,
                           kv_layout=args.kv_layout,
                           block_size=args.block_size, n_blocks=n_blocks)
    corpus = SyntheticCorpus(PROFILES["antrag"], cfg.vocab_size, seed=0)
    prompt_cap = min(96, args.prefill_len)
    reqs = [corpus.sample()[0][:prompt_cap] for _ in range(args.requests)]
    budgets = [args.max_new if (not args.mixed or i % 2) else
               max(args.max_new // 4, 2) for i in range(args.requests)]

    if args.mode == "lockstep":
        engine = LookaheadEngine(fns, la)
        t0 = time.time()
        tok = steps = 0
        for i in range(0, len(reqs), args.lanes):
            outs = engine.generate_batch_lockstep(
                reqs[i:i + args.lanes], budgets[i:i + args.lanes])
            for o in outs:
                tok += len(o.tokens)
                steps += o.stats.steps
        dt = time.time() - t0
        print(f"lockstep: {tok} tokens / {steps} steps "
              f"(EDL {tok/max(steps,1):.2f}) in {dt:.1f}s "
              f"-> {tok/dt:.1f} tok/s; trie={len(engine.trie)} nodes")
        return

    # ---------------------------------------------------- continuous serving
    sched = ContinuousScheduler(fns, la, lanes=args.lanes,
                                prefill_len=args.prefill_len)
    rng = np.random.RandomState(0)
    if args.rate > 0:
        gaps = rng.exponential(1.0 / args.rate, size=len(reqs))
        arrivals = np.cumsum(gaps)
    else:
        arrivals = np.zeros(len(reqs))

    t0 = time.time()
    nxt = 0
    results = []
    while nxt < len(reqs) or not sched.idle:
        now = time.time() - t0
        while nxt < len(reqs) and arrivals[nxt] <= now:
            sched.submit(reqs[nxt], budgets[nxt])
            nxt += 1
        if sched.idle:
            # open-loop gap: nothing in flight, wait for the next arrival
            time.sleep(min(max(arrivals[nxt] - now, 0.0), 0.05))
            continue
        results.extend(sched.step())
    dt = time.time() - t0

    tok = sum(len(r.tokens) for r in results)
    steps = sum(r.stats.steps for r in results)
    lat = [r.latency_s for r in results]
    ttft = [r.ttft_s for r in results]
    st = sched.stats
    print(f"continuous: {tok} tokens / {len(results)} requests "
          f"({st.decode_steps} device steps, EDL {tok/max(steps,1):.2f}, "
          f"occupancy {st.occupancy:.2f}) in {dt:.1f}s -> {tok/dt:.1f} tok/s")
    if sched.cache is not None:
        cache_mb = sum(v.nbytes for v in sched.cache.values()) / 2**20
        extra = (f", peak {st.peak_blocks} blocks, "
                 f"{st.block_waits} block-waits"
                 if args.kv_layout == "paged" else "")
        print(f"kv cache [{args.kv_layout}]: {cache_mb:.1f} MiB{extra}")
    print(f"latency  p50 {_pct(lat, 50)*1e3:7.1f} ms   "
          f"p95 {_pct(lat, 95)*1e3:7.1f} ms   "
          f"p99 {_pct(lat, 99)*1e3:7.1f} ms")
    print(f"ttft     p50 {_pct(ttft, 50)*1e3:7.1f} ms   "
          f"p95 {_pct(ttft, 95)*1e3:7.1f} ms   "
          f"p99 {_pct(ttft, 99)*1e3:7.1f} ms; trie={len(sched.trie)} nodes")


if __name__ == "__main__":
    main()
