"""Serving launcher: run a LookaheadEngine over an arch config.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --smoke --requests 8

On real hardware drop --smoke to load the full config (weights from
--ckpt-dir via training.checkpoint) onto the production mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs as cfgreg
from repro.core import LookaheadConfig, LookaheadEngine
from repro.distributed.sharding import DEFAULT_RULES, sharding_ctx
from repro.models import transformer as tx
from repro.serving.session import make_session_fns
from repro.training.checkpoint import CheckpointManager
from repro.training.data import PROFILES, SyntheticCorpus


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--decoding-length", type=int, default=32)
    ap.add_argument("--branch-length", type=int, default=12)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--sample", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    mod = cfgreg.get_arch(args.arch)
    cfg = mod.smoke_config() if args.smoke else mod.full_config()
    if not hasattr(cfg, "n_layers"):
        raise SystemExit(f"{args.arch} is not an LM arch; serving loop is "
                         "for autoregressive decoders (see DESIGN.md "
                         "§Arch-applicability)")
    cfg = type(cfg)(**{**cfg.__dict__, "max_seq_len": 768}) \
        if args.smoke else cfg
    params = tx.init_params(cfg, jax.random.key(0))
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        state, step = mgr.restore({"params": params})
        params = state["params"]
        print(f"restored checkpoint step {step}")

    la = LookaheadConfig(decoding_length=args.decoding_length,
                         branch_length=args.branch_length,
                         sample=args.sample, temperature=args.temperature)
    fns = make_session_fns(cfg, params, sample=args.sample,
                           temperature=args.temperature,
                           base_key=jax.random.key(0), slots=la.slots)
    engine = LookaheadEngine(fns, la)
    corpus = SyntheticCorpus(PROFILES["antrag"], cfg.vocab_size, seed=0)
    reqs = [corpus.sample()[0][:96] for _ in range(args.requests)]
    t0 = time.time()
    tok = steps = 0
    for i in range(0, len(reqs), args.batch):
        outs = engine.generate_batch(reqs[i:i + args.batch], args.max_new)
        for o in outs:
            tok += len(o.tokens)
            steps += o.stats.steps
    dt = time.time() - t0
    print(f"{tok} tokens / {steps} steps (EDL {tok/max(steps,1):.2f}) "
          f"in {dt:.1f}s -> {tok/dt:.1f} tok/s; trie={len(engine.trie)} nodes")


if __name__ == "__main__":
    main()
