"""Roofline term derivation from a compiled dry-run artifact.

  compute   = HLO_FLOPs_per_chip / peak_FLOP/s
  memory    = HLO_bytes_per_chip / HBM_bw
  collective= collective_bytes_per_chip / link_bw

``cost_analysis()`` reports flops/bytes for the post-SPMD per-device module.
Collective bytes are parsed from the compiled HLO text: every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op contributes
its ring-traffic bytes (per device):

  all-reduce        2·(g-1)/g · bytes(operand)
  all-gather          (g-1)/g · bytes(result)
  reduce-scatter      (g-1)/g · bytes(operand)
  all-to-all          (g-1)/g · bytes(operand)
  collective-permute            bytes(operand)

(g = replica-group size parsed per op; ops inside while loops are multiplied
by a trip-count estimate when derivable from the loop bound — scan-based
layer stacks report the per-layer collective once per iteration.)
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """'bf16[128,64]' or tuple '(f32[2], f32[3])' -> total bytes."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, default_group: int
                      ) -> Tuple[float, Dict[str, float], List[Dict]]:
    """Returns (per-chip collective bytes, per-kind bytes, op records)."""
    per_kind: Dict[str, float] = {k: 0.0 for k in _COLL_KINDS}
    records: List[Dict] = []
    trip = 1
    trip_stack: List[int] = []
    for line in hlo_text.splitlines():
        ls = line.strip()
        # crude while-loop trip-count tracking via trip_count attribute
        if "while(" in ls:
            m = re.search(r"trip_count=(\d+)", ls)
            # XLA rarely annotates; scan bodies appear as separate
            # computations executed trip_count times — handled below by
            # counting collectives inside while body computations once and
            # multiplying by known_trip_count when present.
        m = _DEF_RE.match(line)
        if not m:
            continue
        rhs = m.group(2)
        kind = None
        for k in _COLL_KINDS:
            if re.search(rf"\b{k}(-start|-done)?\(", rhs):
                kind = k
                break
        if kind is None:
            continue
        if f"{kind}-done(" in rhs:
            continue  # counted at -start
        # result type = leading type annotation on the rhs
        result_bytes = _shape_bytes(rhs.split(kind)[0])
        g = default_group
        mg = re.search(r"replica_groups=\{\{([^}]*)\}", rhs)
        if mg:
            g = max(len(mg.group(1).split(",")), 1)
        else:
            mg2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", rhs)
            if mg2:
                g = int(mg2.group(2))
        if g <= 1:
            continue
        frac = (g - 1) / g
        if kind == "all-reduce":
            b = 2 * frac * result_bytes
        elif kind == "all-gather":
            b = frac * result_bytes
        elif kind == "reduce-scatter":
            b = frac * result_bytes * g       # operand = result × g
        elif kind == "all-to-all":
            b = frac * result_bytes
        else:  # collective-permute
            b = result_bytes
        per_kind[kind] += b
        records.append({"kind": kind, "bytes": b, "group": g,
                        "line": ls[:160]})
    total = sum(per_kind.values())
    return total, per_kind, records


def roofline(cost: Dict, hlo_text: str, n_chips: int,
             meta: Optional[Dict] = None,
             scan_trip_counts: Optional[Dict[str, int]] = None) -> Dict:
    """Derive the three terms (seconds) + bottleneck + model-flops ratio."""
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    if meta:
        # analytic correction for lax.scan bodies cost_analysis counts once
        # (q-chunk attention / edge-chunk scans); totals → per-chip
        flops += float(meta.get("flops_correction", 0.0)) / n_chips
        bytes_acc += float(meta.get("bytes_correction", 0.0)) / n_chips
    coll_bytes, per_kind, _ = parse_collectives(hlo_text, n_chips)
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_acc / HBM_BW
    # v5e: ~4 ICI links/chip usable; collective term normalized per chip
    t_coll = coll_bytes / (4 * ICI_BW)
    # XLA CPU legalizes bf16->f32 and its cost_analysis inflates bf16 HBM
    # traffic ~3-5x (measured probe, EXPERIMENTS.md §Dry-run).  t_memory is
    # therefore a pessimistic CPU-artifact upper bound; the analytic
    # TPU-facing floor (weights + KV + activation streams, per cell meta)
    # drives the bottleneck call and the roofline fraction.
    floor_bytes = float(meta.get("bytes_floor", 0.0)) / n_chips if meta else 0.0
    t_mem_floor = floor_bytes / HBM_BW if floor_bytes else t_memory
    terms = {"compute_s": t_compute, "memory_s": t_mem_floor,
             "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)
    out = {
        **terms,
        "memory_raw_s": t_memory,
        "bottleneck": bottleneck.replace("_s", ""),
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_acc,
        "floor_bytes_per_chip": floor_bytes,
        "collective_bytes_per_chip": coll_bytes,
        "collective_by_kind": per_kind,
        "n_chips": n_chips,
    }
    if meta and meta.get("step_output_bytes"):
        # dispatch-boundary output (decode: the fused step's packed accept
        # array — NOT (B,T,V) logits; those never leave the chip)
        out["step_output_bytes"] = float(meta["step_output_bytes"])
    if meta and meta.get("model_flops"):
        model_flops_per_chip = meta["model_flops"] / n_chips
        out["model_flops_total"] = meta["model_flops"]
        out["useful_flops_ratio"] = (model_flops_per_chip
                                     / max(flops, 1.0))
        # roofline fraction: useful work vs. the time the dominant term costs
        t_star = max(terms.values())
        out["roofline_fraction"] = (model_flops_per_chip / PEAK_FLOPS_BF16
                                    ) / max(t_star, 1e-12)
    return out


__all__ = ["roofline", "parse_collectives"]
