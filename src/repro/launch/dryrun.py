import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.

import argparse       # noqa: E402
import json           # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402
from typing import Dict, List, Optional  # noqa: E402

import jax            # noqa: E402

from repro import configs as cfgreg                      # noqa: E402
from repro.configs.common import Cell                    # noqa: E402
from repro.distributed.sharding import DEFAULT_RULES, sharding_ctx  # noqa: E402
from repro.launch.mesh import make_production_mesh       # noqa: E402
from repro.launch.roofline import roofline               # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes, record memory/cost analysis + roofline terms.

  PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-mini-3.8b \
      --shape decode_32k [--multi-pod] [--out out.json]

Without --arch: sweeps all 40 assigned cells (plus antglm-10b), writing
incremental results so an interrupted sweep resumes where it stopped.
"""


def _compile_cell(cell: Cell, mesh):
    with sharding_ctx(mesh, cell.rules):
        in_shardings = cell.shardings(mesh, cell.rules)
        fn = jax.jit(cell.fn, in_shardings=in_shardings,
                     donate_argnums=cell.donate)
        with mesh:
            lowered = fn.lower(*cell.args)
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            cost_list = compiled.cost_analysis()
    cost = cost_list if isinstance(cost_list, dict) else (
        cost_list[0] if cost_list else {})
    return compiled, mem, cost


def run_cell(arch: str, shape: str, multi_pod: bool) -> Dict:
    """Compile a cell.  Single-pod runs TWO builds: the production build
    (lax.scan layer loop) provides the memory analysis — that's what runs on
    hardware — and an unrolled build provides cost/collective analysis (XLA
    cost_analysis counts while-loop bodies once; see EXPERIMENTS.md §Dry-run).
    The multi-pod leg compiles the production build only (sharding proof)."""
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    mod = cfgreg.get_arch(arch)

    cell_fast: Cell = mod.build_cell(shape, mesh, fast=True)
    compiled_f, mem, cost_f = _compile_cell(cell_fast, mesh)
    if multi_pod:
        cost, hlo, cell = cost_f, compiled_f.as_text(), cell_fast
    else:
        cell = mod.build_cell(shape, mesh, fast=False)
        compiled_a, _, cost = _compile_cell(cell, mesh)
        hlo = compiled_a.as_text()

    n_chips = mesh.size
    rf = roofline({k: cost.get(k, 0.0) for k in ("flops", "bytes accessed")},
                  hlo, n_chips, meta=cell.meta)
    mem_rec = {}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            mem_rec[attr] = int(v)
    # XLA CPU ignores donation; on TPU donated args alias their outputs, so
    # subtract per-chip donated bytes once.
    donatable = cell_fast.donatable_bytes() // n_chips
    live = (mem_rec.get("argument_size_in_bytes", 0)
            - mem_rec.get("alias_size_in_bytes", 0)
            + mem_rec.get("output_size_in_bytes", 0)
            + mem_rec.get("temp_size_in_bytes", 0)
            - donatable)
    rec = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "ok": True,
        "compile_s": round(time.time() - t0, 1),
        "memory": mem_rec,
        "donated_alias_bytes_per_chip": donatable,
        "per_chip_live_bytes": live,
        "fits_16gb": live < 16 * 1024 ** 3,
        "cost": {k: float(v) for k, v in cost.items()
                 if isinstance(v, (int, float))},
        "roofline": rf,
        "meta": cell.meta,
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--include-antglm", action="store_true")
    args = ap.parse_args()

    results: Dict[str, Dict] = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    if args.arch:
        shapes = [args.shape] if args.shape else \
            cfgreg.get_arch(args.arch).SHAPES
        cells = [(args.arch, s) for s in shapes]
    else:
        cells = cfgreg.assigned_cells()
        if args.include_antglm:
            cells += [("antglm_10b", s)
                      for s in cfgreg.get_arch("antglm_10b").SHAPES]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch, shape in cells:
        for mp in meshes:
            key = f"{arch}/{shape}/{'2x16x16' if mp else '16x16'}"
            if results.get(key, {}).get("ok"):
                print(f"[skip] {key}")
                continue
            print(f"[run ] {key} ...", flush=True)
            try:
                rec = run_cell(arch, shape, mp)
                print(f"[ ok ] {key}: compile={rec['compile_s']}s "
                      f"bottleneck={rec['roofline']['bottleneck']} "
                      f"live={rec['per_chip_live_bytes']/2**30:.2f}GiB",
                      flush=True)
            except Exception as e:  # noqa: BLE001
                rec = {"arch": arch, "shape": shape, "ok": False,
                       "mesh": "2x16x16" if mp else "16x16",
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
                print(f"[FAIL] {key}: {rec['error'][:200]}", flush=True)
            results[key] = rec
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results.values() if r.get("ok"))
    print(f"\n{n_ok}/{len(results)} cells OK -> {args.out}")


if __name__ == "__main__":
    main()
