"""Training launcher for LM archs with the full fault-tolerance loop:
checkpoint/resume, preemption handling, straggler timeout, elastic restart.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
        --steps 40

On a real fleet: run under the production mesh (remove --smoke), point
--ckpt-dir at durable storage, and let the wrapper scripts re-exec this
module after preemptions — it resumes from the latest checkpoint and, if the
device count changed, reshards via the checkpoint's logical axes
(training.checkpoint.CheckpointManager.restore(mesh=...)).

XLA flags worth setting on TPU for collective overlap (documented here, not
forced): --xla_tpu_enable_async_collective_fusion=true
         --xla_tpu_overlap_compute_collective_tc=true
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro import configs as cfgreg
from repro.distributed.sharding import DEFAULT_RULES, sharding_ctx
from repro.models import transformer as tx
from repro.training.checkpoint import CheckpointManager
from repro.training.data import lm_train_batches
from repro.training.fault_tolerance import (PreemptionHandler,
                                            run_with_timeout)
from repro.training.optimizer import adamw_init
from repro.training.train_step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--step-timeout", type=float, default=600.0)
    args = ap.parse_args()

    mod = cfgreg.get_arch(args.arch)
    cfg = mod.smoke_config() if args.smoke else mod.full_config()
    print(f"{args.arch}: {cfg.n_params()/1e6:.0f}M params")
    loss = lambda p, b: tx.lm_loss(cfg, p, b["tokens"], b["labels"])
    step = jax.jit(make_train_step(loss, lr=args.lr,
                                   accum_steps=args.accum),
                   donate_argnums=())
    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    params = tx.init_params(cfg, jax.random.key(0))
    opt = adamw_init(params)
    start = 0
    if mgr.latest_step() is not None:
        state, start = mgr.restore({"p": params, "o": opt})
        params, opt = state["p"], state["o"]
        print(f"resumed from step {start}")
    handler = PreemptionHandler().install()
    axes = tx.param_logical_axes(cfg)
    batches = lm_train_batches(cfg.vocab_size, args.batch, args.seq,
                               seed=start)
    for i in range(start, args.steps):
        b = {k: jnp.asarray(v) for k, v in next(batches).items()}
        params, opt, m = run_with_timeout(step, args.step_timeout,
                                          params, opt, b, retries=1)
        if (i + 1) % 10 == 0 or i == start:
            print(f"step {i+1}: loss {float(m['loss']):.4f}")
        if (i + 1) % args.ckpt_every == 0 or handler.preempted:
            mgr.save(i + 1, {"p": params, "o": opt}, logical_axes={
                "p": axes, "o": None}, blocking=handler.preempted)
        if handler.preempted:
            print("preempted — checkpointed, exiting for restart")
            break
    mgr.wait()
    handler.uninstall()
    print(f"checkpoints: {mgr.all_steps()}")


if __name__ == "__main__":
    main()
