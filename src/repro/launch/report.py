"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun_results.json.

    PYTHONPATH=src python -m repro.launch.report dryrun_results.json
"""
from __future__ import annotations

import json
import sys
from typing import Dict


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}µs"
    if x < 1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.2f}s"


def fmt_b(x: float) -> str:
    for unit, div in (("GiB", 2 ** 30), ("MiB", 2 ** 20), ("KiB", 2 ** 10)):
        if x >= div:
            return f"{x/div:.2f}{unit}"
    return f"{x:.0f}B"


def render(results: Dict) -> str:
    single = {k: v for k, v in results.items() if v.get("mesh") == "16x16"}
    multi = {k: v for k, v in results.items() if v.get("mesh") == "2x16x16"}

    out = []
    out.append("### Dry-run summary\n")
    n_ok_s = sum(1 for v in single.values() if v.get("ok"))
    n_ok_m = sum(1 for v in multi.values() if v.get("ok"))
    out.append(f"- single-pod 16×16 (256 chips): **{n_ok_s}/{len(single)}** "
               "cells lower+compile OK")
    out.append(f"- multi-pod 2×16×16 (512 chips): **{n_ok_m}/{len(multi)}** "
               "cells lower+compile OK\n")
    fails = [(k, v.get("error", "")) for k, v in results.items()
             if not v.get("ok")]
    if fails:
        out.append("Failures:")
        for k, e in fails:
            out.append(f"- `{k}`: {e[:160]}")
        out.append("")

    out.append("\n#### Per-cell memory (multi-pod mesh, per chip; donation-"
               "adjusted — see §Dry-run notes)\n")
    out.append("| arch | shape | live/chip | fits 16GiB | args | temps |")
    out.append("|---|---|---:|:--:|---:|---:|")
    for k, v in multi.items():
        if not v.get("ok"):
            continue
        m = v["memory"]
        out.append(
            f"| {v['arch']} | {v['shape']} | "
            f"{fmt_b(v['per_chip_live_bytes'])} | "
            f"{'✓' if v['fits_16gb'] else '✗'} | "
            f"{fmt_b(m.get('argument_size_in_bytes', 0))} | "
            f"{fmt_b(m.get('temp_size_in_bytes', 0))} |")

    out.append("\n### Roofline (single-pod 16×16, 256 chips; per-step)\n")
    out.append("| arch | shape | compute | memory(floor) | memory(raw*) | "
               "collective | step-out† | bottleneck | useful-flops ratio | "
               "roofline frac |")
    out.append("|---|---|---:|---:|---:|---:|---:|---|---:|---:|")
    for k, v in single.items():
        if not v.get("ok"):
            continue
        r = v["roofline"]
        ufr = r.get("useful_flops_ratio")
        rff = r.get("roofline_fraction")
        sob = r.get("step_output_bytes")
        out.append(
            f"| {v['arch']} | {v['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r.get('memory_raw_s', 0))} | "
            f"{fmt_s(r['collective_s'])} | "
            f"{'' if sob is None else fmt_b(sob)} | "
            f"{r['bottleneck']} | "
            f"{'' if ufr is None else f'{ufr:.3f}'} | "
            f"{'' if rff is None else f'{rff:.4f}'} |")
    out.append("\n† dispatch-boundary output per step: decode cells hand "
               "back the fused step's packed (B,1+2T) accept array — the "
               "(B,T,V) logits never leave the chip.")

    out.append("\n#### Collective breakdown (single-pod; per-chip bytes/step)\n")
    out.append("| arch | shape | all-reduce | all-gather | reduce-scatter | "
               "all-to-all | permute |")
    out.append("|---|---|---:|---:|---:|---:|---:|")
    for k, v in single.items():
        if not v.get("ok"):
            continue
        c = v["roofline"]["collective_by_kind"]
        out.append(
            f"| {v['arch']} | {v['shape']} | {fmt_b(c['all-reduce'])} | "
            f"{fmt_b(c['all-gather'])} | {fmt_b(c['reduce-scatter'])} | "
            f"{fmt_b(c['all-to-all'])} | {fmt_b(c['collective-permute'])} |")
    return "\n".join(out)


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    with open(path) as f:
        print(render(json.load(f)))
