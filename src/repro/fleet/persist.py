"""Warm draft-state persistence (DESIGN.md §Fleet serving).

Serializes the *host-side* statistics that make a replica fast — trie
forests (per-namespace node arrays + frequencies), n-gram backoff tables,
and the hottest prefix-cache token keys — into one versioned, checksummed
JSON document.  Device state (KV blocks) is deliberately absent: it cannot
survive a restart, and a warm replica re-prefills the persisted prefix keys
once instead of trusting foreign KV bytes.

File format (version 1)::

    {"format": "repro-draft-state", "version": 1,
     "checksum": "<sha256 of the canonical payload JSON>",
     "payload": {"sources": {"trie": {...}, "ngram": {...}},
                 "prefix": {"<namespace>": [[tok, ...], ...]}}}

Writes are atomic (temp file + ``os.replace``) so a reader can never see a
torn file; the checksum rejects silent corruption, the version field
rejects format drift — both raise ``DraftStateError`` instead of loading
garbage statistics into a serving engine.

Losslessness: everything here only changes what the engine *proposes*; the
device verifier guarantees outputs (I1), so a corrupt-but-undetected state
file could cost speed, never correctness.  The checks protect performance
and determinism, not safety.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Dict, Optional

from repro.core.draft_sources import make_source

STATE_FORMAT = "repro-draft-state"
STATE_VERSION = 1


class DraftStateError(RuntimeError):
    """A warm-state file is unreadable, corrupt, or version-mismatched."""


def _canonical(payload: Dict[str, object]) -> str:
    return json.dumps(payload, sort_keys=True, separators=(",", ":"))


def _checksum(payload: Dict[str, object]) -> str:
    return hashlib.sha256(_canonical(payload).encode("utf-8")).hexdigest()


# ------------------------------------------------------------------- collect
def collect_draft_state(scheduler, *,
                        max_prefix_keys: Optional[int] = 64
                        ) -> Dict[str, object]:
    """Snapshot a scheduler's shared draft state into a plain-data payload.

    Sources with nothing to persist (``state_dict() == {}``) are skipped so
    a stateless source's name never collides with a donor's stateful one.
    """
    sources: Dict[str, object] = {}
    for name, src in scheduler.sources.items():
        state = src.state_dict()
        if state:
            sources[name] = state
    payload: Dict[str, object] = {"sources": sources}
    if scheduler.prefix is not None:
        prefix = scheduler.prefix.hot_keys(max_prefix_keys)
        if prefix:
            payload["prefix"] = prefix
    return payload


# ------------------------------------------------------------------- install
def _validate_payload(payload) -> Dict[str, object]:
    if not isinstance(payload, dict):
        raise DraftStateError("draft-state payload is not a dict")
    sources = payload.get("sources", {})
    if not isinstance(sources, dict):
        raise DraftStateError("draft-state 'sources' is not a dict")
    prefix = payload.get("prefix", {})
    if not isinstance(prefix, dict):
        raise DraftStateError("draft-state 'prefix' is not a dict")
    return payload


def install_draft_state(scheduler, payload: Dict[str, object], *,
                        merge: bool = False) -> None:
    """Load (or gossip-merge) a payload into a scheduler's draft sources.

    Source instances named by the payload are created through the registry
    if the scheduler has not touched them yet — an n-gram table loads even
    before the first n-gram request arrives.  Unknown source names and
    per-source shape errors raise ``DraftStateError`` (a clean reject, the
    engine's state untouched by the failing entry).
    """
    payload = _validate_payload(payload)
    for name, state in payload.get("sources", {}).items():
        src = scheduler.sources.get(name)
        if src is None:
            try:
                src = make_source(name, scheduler.config)
            except KeyError as e:
                raise DraftStateError(
                    f"draft-state names unknown source {name!r}: {e}"
                ) from e
            scheduler.sources[name] = src
        try:
            if merge:
                src.merge_state(state)
            else:
                src.load_state_dict(state)
        except ValueError as e:
            raise DraftStateError(
                f"draft-state for source {name!r} is malformed: {e}") from e


# ----------------------------------------------------------------- file I/O
def save_draft_state(path: str, payload: Dict[str, object]) -> None:
    """Atomically write ``payload`` as a versioned, checksummed document."""
    doc = {"format": STATE_FORMAT, "version": STATE_VERSION,
           "checksum": _checksum(payload), "payload": payload}
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(doc, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def load_draft_state(path: str) -> Dict[str, object]:
    """Read + verify a state file; returns its payload.

    Raises ``DraftStateError`` on unparsable JSON, a foreign format tag, a
    version this reader does not speak, or a checksum mismatch (bit rot /
    truncation / hand edits).
    """
    try:
        with open(path, "r", encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise DraftStateError(f"cannot read draft state {path!r}: {e}") from e
    if not isinstance(doc, dict) or doc.get("format") != STATE_FORMAT:
        raise DraftStateError(f"{path!r} is not a {STATE_FORMAT} file")
    version = doc.get("version")
    if version != STATE_VERSION:
        raise DraftStateError(
            f"{path!r} is draft-state version {version!r}; this reader "
            f"speaks version {STATE_VERSION}")
    payload = _validate_payload(doc.get("payload"))
    if doc.get("checksum") != _checksum(payload):
        raise DraftStateError(f"{path!r} failed its checksum (corrupt or "
                              "hand-edited)")
    return payload


__all__ = ["DraftStateError", "STATE_FORMAT", "STATE_VERSION",
           "collect_draft_state", "install_draft_state", "save_draft_state",
           "load_draft_state"]
