"""EngineReplica — one ServingEngine behind a uniform command surface.

The router and gossip coordinator speak to replicas through a small
message-shaped API (submit / step / drain / queue_depth / result / stats /
draft-state ops) so the same fleet code drives two execution modes:

  * ``mode="inproc"`` — the engine lives in this process.  Deterministic
    and cheap: tests and CI smokes run whole fleets in one interpreter,
    and bit-identity against a single-replica reference is exact.
  * ``mode="subprocess"`` — the engine lives in a spawned worker process
    (its own device context), commands travel over a pipe.  The builder
    callable must be picklable (a module-level function or
    ``functools.partial`` of one); the engine is constructed inside the
    child, so device buffers never cross the process boundary.

Results and stats cross the boundary as plain dicts — the same shapes the
in-process mode returns, so callers never branch on the mode.
"""
from __future__ import annotations

import multiprocessing as mp
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.request import Request, RequestResult, SamplingParams


class ReplicaError(RuntimeError):
    """A replica worker failed executing a command."""


def _result_payload(res: RequestResult) -> Dict[str, Any]:
    return {"rid": res.rid, "tokens": list(res.tokens),
            "finish_reason": res.finish_reason, "cancelled": res.cancelled,
            "latency_s": res.latency_s, "ttft_s": res.ttft_s,
            "queue_s": res.queue_s}


def _dispatch(engine, cmd: str, args: tuple):
    """Execute one replica command against an engine (both modes share
    this, so inproc and subprocess can never drift apart)."""
    sch = engine.scheduler
    if cmd == "submit":
        prompt, params = args
        return sch.submit_request(Request(prompt=list(prompt),
                                          params=params)).rid
    if cmd == "step":
        return [r.rid for r in engine.step()]
    if cmd == "drain":
        return [r.rid for r in engine.run()]
    if cmd == "queue_depth":
        return sch.n_queued + sch.n_active + len(sch._pending)
    if cmd == "idle":
        return engine.idle
    if cmd == "result":
        (rid,) = args
        res = sch.results.get(rid)
        if res is None:
            raise ReplicaError(f"no result for rid {rid} yet")
        return _result_payload(res)
    if cmd == "stats":
        snap = sch.stats.snapshot()
        snap["trie_nodes"] = len(sch.sources["trie"].forest)
        return snap
    if cmd == "draft_state":
        (max_prefix_keys,) = args
        return engine.draft_state(max_prefix_keys=max_prefix_keys)
    if cmd == "merge_draft_state":
        (payload,) = args
        engine.merge_draft_state(payload)
        return None
    if cmd == "save_draft_state":
        (path,) = args
        engine.save_draft_state(path)
        return None
    if cmd == "load_draft_state":
        path, prime_prefix = args
        engine.load_draft_state(path, prime_prefix=prime_prefix)
        return None
    raise ReplicaError(f"unknown replica command {cmd!r}")


def _worker(conn, builder: Callable[[], Any]) -> None:
    """Subprocess loop: build the engine, serve commands until 'close'."""
    try:
        engine = builder()
        conn.send(("ok", None))
    except BaseException as e:          # construction failed: report + exit
        conn.send(("err", f"{type(e).__name__}: {e}"))
        return
    while True:
        try:
            cmd, args = conn.recv()
        except EOFError:
            return
        if cmd == "close":
            conn.send(("ok", None))
            return
        try:
            conn.send(("ok", _dispatch(engine, cmd, args)))
        except Exception as e:
            conn.send(("err", f"{type(e).__name__}: {e}"))


class EngineReplica:
    """One engine of a fleet, addressable through replica commands."""

    def __init__(self, builder: Callable[[], Any], *,
                 replica_id: str = "r0", mode: str = "inproc"):
        if mode not in ("inproc", "subprocess"):
            raise ValueError(f"mode={mode!r}: expected 'inproc' or "
                             "'subprocess'")
        self.replica_id = str(replica_id)
        self.mode = mode
        self.engine = None
        self._conn = None
        self._proc = None
        if mode == "inproc":
            self.engine = builder()
        else:
            ctx = mp.get_context("spawn")   # fresh interpreter: device-safe
            self._conn, child = ctx.Pipe()
            self._proc = ctx.Process(target=_worker, args=(child, builder),
                                     daemon=True)
            self._proc.start()
            child.close()
            self._check(self._conn.recv())  # construction ack

    # ------------------------------------------------------------- plumbing
    def _check(self, reply):
        status, value = reply
        if status != "ok":
            raise ReplicaError(f"replica {self.replica_id}: {value}")
        return value

    def _call(self, cmd: str, *args):
        if self.engine is not None:
            return _dispatch(self.engine, cmd, args)
        self._conn.send((cmd, args))
        return self._check(self._conn.recv())

    # -------------------------------------------------------------- surface
    def submit(self, prompt: Sequence[int],
               params: Optional[SamplingParams] = None) -> int:
        """Queue a request; returns its replica-local rid."""
        return self._call("submit", list(prompt), params)

    def step(self) -> List[int]:
        """One scheduler iteration; returns rids finished by it."""
        return self._call("step")

    def drain(self) -> List[int]:
        """Run until idle; returns every finished rid in submit order."""
        return self._call("drain")

    @property
    def queue_depth(self) -> int:
        """Requests held right now (queued + active + pending admissions)
        — the router's backpressure signal."""
        return self._call("queue_depth")

    @property
    def idle(self) -> bool:
        return self._call("idle")

    def result(self, rid: int) -> Dict[str, Any]:
        return self._call("result", rid)

    def stats_snapshot(self) -> Dict[str, Any]:
        return self._call("stats")

    # ---- warm state / gossip
    def draft_state(self, *, max_prefix_keys: Optional[int] = 64
                    ) -> Dict[str, Any]:
        return self._call("draft_state", max_prefix_keys)

    def merge_draft_state(self, payload: Dict[str, Any]) -> None:
        self._call("merge_draft_state", payload)

    def save_draft_state(self, path: str) -> None:
        self._call("save_draft_state", path)

    def load_draft_state(self, path: str, *,
                         prime_prefix: bool = True) -> None:
        self._call("load_draft_state", path, prime_prefix)

    # ---- lifecycle
    def close(self) -> None:
        if self._proc is not None:
            try:
                self._conn.send(("close", ()))
                self._conn.recv()
            except (OSError, EOFError):
                pass
            self._proc.join(timeout=10)
            if self._proc.is_alive():
                self._proc.terminate()
            self._conn.close()
            self._proc = None
            self._conn = None


__all__ = ["EngineReplica", "ReplicaError"]
