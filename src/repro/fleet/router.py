"""Namespace-affinity admission router (DESIGN.md §Fleet serving).

Retrieval-based lossless acceleration lives or dies on the warmth of its
reference store: a trie only accelerates traffic whose branch statistics
it has already seen.  Round-robin across replicas splits every scenario's
traffic N ways — N lukewarm tries instead of one hot one.  The router
therefore places requests by *namespace affinity*:

  * consistent hashing maps each trie namespace onto the replica ring
    (virtual nodes smooth the assignment; SHA-256, never Python's
    per-process-salted ``hash``), so a scenario's requests always land on
    the replica whose trie they warmed — and adding a replica only moves
    the namespaces that hash next to it;
  * backpressure: when the home replica's queue depth reaches
    ``max_queue_depth``, the request spills to the least-loaded replica
    (lowest queue depth, ties to the lowest index).  A spill trades draft
    acceptance for admission latency — gossip (repro.fleet.gossip) warms
    the spill target so repeated spills stop being cold.

Routing never affects outputs: every replica runs the same verifier, so a
request generates bit-identical tokens wherever it lands (I1) — the router
is purely a throughput/latency policy.
"""
from __future__ import annotations

import bisect
import hashlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

from repro.core.request import SamplingParams
from repro.fleet.replica import EngineReplica
from repro.serving.scheduler import NamespaceStats


def _stable_hash(key: str) -> int:
    """Process-independent 64-bit hash (routing must agree across runs
    and across replicas; builtin ``hash`` is salted per process)."""
    return int.from_bytes(
        hashlib.sha256(key.encode("utf-8")).digest()[:8], "big")


@dataclass
class Placement:
    """One routed request: where it went and why."""
    index: int            # fleet-wide submission index
    namespace: str
    replica: int          # replica index it landed on
    rid: int              # replica-local request id
    spilled: bool = False


@dataclass
class FleetStats:
    """Rollup of routing counters + per-replica scheduler snapshots."""
    routed: int = 0
    affinity_hits: int = 0
    spills: int = 0
    round_robin: int = 0
    ns_routed: Dict[str, int] = field(default_factory=dict)
    replicas: List[Dict[str, Any]] = field(default_factory=list)

    def namespace_summary(self) -> Dict[str, Dict[str, float]]:
        """Fleet-wide per-tenant SLO summary: raw latency samples from
        every replica are pooled per namespace, then summarized once —
        a fleet p99 over the union, never an average of per-replica
        percentiles."""
        merged: Dict[str, NamespaceStats] = {}
        for snap in self.replicas:
            for nsn, ns_snap in snap.get("namespaces", {}).items():
                st = merged.get(nsn)
                if st is None:
                    st = merged[nsn] = NamespaceStats()
                st.merge(ns_snap)
        # occupancy denominator: Σ decode_steps·lanes over replicas
        capacity = sum(int(s.get("decode_steps", 0)) * int(s.get("lanes", 1))
                       for s in self.replicas)
        return {nsn: st.summary(max(capacity, 1), 1)
                for nsn, st in sorted(merged.items())}

    def source_acceptance(self) -> Dict[str, Dict[str, float]]:
        """namespace -> source -> fleet-wide acceptance rate."""
        out: Dict[str, Dict[str, float]] = {}
        for nsn, summ in self._merged_counts().items():
            drafted, accepted = summ
            out[nsn] = {n: accepted.get(n, 0) / max(d, 1)
                        for n, d in drafted.items()}
        return out

    def _merged_counts(self):
        merged: Dict[str, tuple] = {}
        for snap in self.replicas:
            for nsn, ns_snap in snap.get("namespaces", {}).items():
                drafted, accepted = merged.setdefault(nsn, ({}, {}))
                for k, v in dict(ns_snap["source_drafted"]).items():
                    drafted[k] = drafted.get(k, 0) + int(v)
                for k, v in dict(ns_snap["source_accepted"]).items():
                    accepted[k] = accepted.get(k, 0) + int(v)
        return merged


class FleetRouter:
    """Places requests onto replicas; drives and rolls up the fleet."""

    def __init__(self, replicas: Sequence[EngineReplica], *,
                 policy: str = "affinity", max_queue_depth: int = 8,
                 vnodes: int = 64):
        if not replicas:
            raise ValueError("a fleet needs >= 1 replica")
        if policy not in ("affinity", "round_robin"):
            raise ValueError(f"policy={policy!r}: expected 'affinity' or "
                             "'round_robin'")
        if max_queue_depth < 1:
            raise ValueError(f"max_queue_depth={max_queue_depth}: need >= 1")
        self.replicas = list(replicas)
        self.policy = policy
        self.max_queue_depth = int(max_queue_depth)
        self.placements: List[Placement] = []
        self._rr = 0
        self._routed = 0
        self._affinity_hits = 0
        self._spills = 0
        self._ns_routed: Dict[str, int] = {}
        # consistent-hash ring: vnodes points per replica, keyed by the
        # replica's id so ring layout is stable across fleet restarts
        ring = []
        for idx, rep in enumerate(self.replicas):
            for v in range(int(vnodes)):
                ring.append((_stable_hash(f"{rep.replica_id}#{v}"), idx))
        ring.sort()
        self._ring_keys = [h for h, _ in ring]
        self._ring_vals = [i for _, i in ring]

    # -------------------------------------------------------------- placement
    def home_replica(self, namespace: str) -> int:
        """Pure affinity assignment (no load considered): the first ring
        point at or after the namespace's hash, wrapping."""
        h = _stable_hash(namespace)
        i = bisect.bisect_left(self._ring_keys, h)
        if i == len(self._ring_keys):
            i = 0
        return self._ring_vals[i]

    def _least_loaded(self) -> int:
        return min(range(len(self.replicas)),
                   key=lambda i: (self.replicas[i].queue_depth, i))

    def route(self, namespace: str) -> Placement:
        """Pick a replica for one request of ``namespace`` (no submit)."""
        ns = str(namespace)
        spilled = False
        if self.policy == "round_robin":
            idx = self._rr % len(self.replicas)
            self._rr += 1
        else:
            idx = self.home_replica(ns)
            if self.replicas[idx].queue_depth >= self.max_queue_depth:
                alt = self._least_loaded()
                if alt != idx:
                    idx, spilled = alt, True
        self._routed += 1
        self._ns_routed[ns] = self._ns_routed.get(ns, 0) + 1
        if self.policy == "affinity":
            if spilled:
                self._spills += 1
            else:
                self._affinity_hits += 1
        return Placement(index=len(self.placements), namespace=ns,
                         replica=idx, rid=-1, spilled=spilled)

    @staticmethod
    def namespace_of(params: Optional[SamplingParams],
                     default: str = "") -> str:
        if params is not None and params.draft is not None:
            return params.draft.namespace
        return default

    def submit(self, prompt: Sequence[int],
               params: Optional[SamplingParams] = None, *,
               namespace: Optional[str] = None) -> Placement:
        """Route + submit one request; returns its ``Placement`` (the
        fleet-wide index keys ``result``/``results``)."""
        ns = (str(namespace) if namespace is not None
              else self.namespace_of(params))
        p = self.route(ns)
        p.rid = self.replicas[p.replica].submit(prompt, params)
        self.placements.append(p)
        return p

    # ------------------------------------------------------------------ drive
    def step_all(self) -> List[Placement]:
        """One scheduler iteration on every replica; returns placements
        finished by this sweep."""
        done: List[Placement] = []
        for ridx, rep in enumerate(self.replicas):
            finished = set(rep.step())
            if finished:
                done.extend(p for p in self.placements
                            if p.replica == ridx and p.rid in finished)
        return done

    def drain(self) -> None:
        """Drive every replica until the whole fleet is idle."""
        for rep in self.replicas:
            rep.drain()

    @property
    def idle(self) -> bool:
        return all(rep.idle for rep in self.replicas)

    # ---------------------------------------------------------------- results
    def result(self, index: int) -> Dict[str, Any]:
        p = self.placements[index]
        return self.replicas[p.replica].result(p.rid)

    def results(self) -> List[Dict[str, Any]]:
        """Every routed request's result, in fleet submission order."""
        return [self.result(i) for i in range(len(self.placements))]

    # ------------------------------------------------------------------ stats
    def fleet_stats(self) -> FleetStats:
        return FleetStats(routed=self._routed,
                          affinity_hits=self._affinity_hits,
                          spills=self._spills,
                          round_robin=(self._routed if self.policy ==
                                       "round_robin" else 0),
                          ns_routed=dict(self._ns_routed),
                          replicas=[rep.stats_snapshot()
                                    for rep in self.replicas])

    def close(self) -> None:
        for rep in self.replicas:
            rep.close()


__all__ = ["FleetRouter", "FleetStats", "Placement"]
