"""repro.fleet — replicated serving (DESIGN.md §Fleet serving).

The paper's headline numbers come from a fleet deployment: many engine
replicas per scenario, each fast only because its trie has already seen
traffic like the request in front of it.  This package adds the three
pieces a single-process engine lacks:

  * ``persist`` — versioned, checksummed serialization of warm draft state
    (trie forests, n-gram tables, hot prefix-cache keys) so a restarted or
    newly spawned replica resumes with a donor's branch statistics — the
    continuous version of the paper's Appendix D warmup.
  * ``replica`` — ``EngineReplica``: one ``ServingEngine`` behind a uniform
    command surface, in-process (deterministic tests/CI) or in a
    subprocess.
  * ``router`` — ``FleetRouter``: namespace-affinity admission (consistent
    hashing keeps a scenario's traffic on the replica whose trie it
    warmed; queue-depth backpressure spills to the least-loaded replica),
    with a ``FleetStats`` rollup over per-replica ``SchedulerStats``.
  * ``gossip`` — ``GossipCoordinator``: periodic freq-summing merge of
    per-namespace draft state between replicas, so spilled traffic warms a
    cold replica instead of being wasted on it.

None of this touches the device step: draft state only ever *proposes*
tokens and the verifier guarantees outputs (I1), so any routing decision,
any merge, and any warm/cold state produce bit-identical generations.
"""
from repro.fleet.gossip import GossipCoordinator
from repro.fleet.persist import (DraftStateError, collect_draft_state,
                                 install_draft_state, load_draft_state,
                                 save_draft_state)
from repro.fleet.replica import EngineReplica
from repro.fleet.router import FleetRouter, FleetStats

__all__ = ["DraftStateError", "collect_draft_state", "install_draft_state",
           "load_draft_state", "save_draft_state", "EngineReplica",
           "FleetRouter", "FleetStats", "GossipCoordinator"]
