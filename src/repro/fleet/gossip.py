"""Gossip: periodic snapshot-merge of draft state between replicas.

Affinity routing keeps a namespace's traffic on one warm replica — until
backpressure spills it onto a cold one, where acceptance collapses to the
cold-start rate.  Gossip closes that gap: every ``every`` fleet rounds,
each replica's shared draft state (trie forests, n-gram tables) is
snapshotted and freq-sum merged into every other replica.  The merge rides
the structures' own hygiene — the trie forest re-enforces its shared
capacity budget with decay-pruning after the merge, the n-gram table its
entry cap — so gossip warms a replica instead of flooding it.

Prefix-cache keys are NOT gossiped: they point at device-resident KV
blocks that exist only on the donor, and re-prefilling them mid-serving
would steal lanes from live traffic.  They travel only through the
persist/restart path, where the engine is idle.

Losslessness: merged state only changes what a replica *proposes*; the
verifier guarantees outputs (I1), so gossip on/off/any-cadence produces
bit-identical generations — it moves acceptance rate, not tokens.
"""
from __future__ import annotations

from typing import List, Sequence

from repro.fleet.replica import EngineReplica


class GossipCoordinator:
    """All-to-all draft-state exchange on a fixed round cadence."""

    def __init__(self, replicas: Sequence[EngineReplica], *,
                 every: int = 0):
        if every < 0:
            raise ValueError(f"every={every}: need >= 0 (0 = disabled)")
        self.replicas = list(replicas)
        self.every = int(every)
        self.rounds = 0
        self.exchanges = 0

    def tick(self) -> bool:
        """Count one fleet round; runs an exchange when the cadence hits.
        Returns True if an exchange ran."""
        self.rounds += 1
        if self.every > 0 and len(self.replicas) > 1 \
                and self.rounds % self.every == 0:
            self.exchange()
            return True
        return False

    def exchange(self) -> None:
        """Snapshot every replica once, then merge each snapshot into every
        OTHER replica (snapshots are taken up front so a merge never feeds
        back into a donor's own snapshot within one exchange)."""
        snaps: List[dict] = [rep.draft_state(max_prefix_keys=0)
                             for rep in self.replicas]
        for i, rep in enumerate(self.replicas):
            for j, payload in enumerate(snaps):
                if i != j and payload.get("sources"):
                    rep.merge_draft_state(payload)
        self.exchanges += 1


__all__ = ["GossipCoordinator"]
