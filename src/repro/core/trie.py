"""Trie tree for lossless draft retrieval (paper §4.3).

The trie records n-grams of prompt tokens and generated tokens.  Each node is a
token id; a root→node path is a candidate draft branch.  Node frequencies drive
branch ranking; prompt-derived branches carry a separate per-request frequency
so they can be *eliminated* when the request finishes (paper: "Branch
Eliminating") while output-derived branches persist across requests.

Pure host-side data structure: retrieval/update cost is O(branch_length) per
op and measured in microseconds (paper Table 4: ~1ms for much larger tries).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass
class _Node:
    token: int
    # Persistent frequency (from generated outputs and retained statistics).
    freq: float = 0.0
    # Per-request prompt frequency keyed by request id; removed on eliminate().
    prompt_freq: Dict[int, float] = field(default_factory=dict)
    children: Dict[int, "_Node"] = field(default_factory=dict)

    def total_freq(self, prompt_boost: float) -> float:
        return self.freq + prompt_boost * sum(self.prompt_freq.values())


class TrieTree:
    """Global trie with insert / eliminate / decay-prune / retrieve.

    Parameters
    ----------
    capacity: max node count before pruning triggers (paper: 16 * decoding_len).
    prompt_boost: multiplier applied to prompt-branch frequencies when ranking
        (paper §4.3.2 "Branch Weighting": amplify prompt branches).
    decay: multiplicative frequency decay applied during pruning.
    """

    def __init__(self, capacity: int = 1024, prompt_boost: float = 8.0,
                 decay: float = 0.5):
        self.root = _Node(token=-1)
        self.capacity = int(capacity)
        self.prompt_boost = float(prompt_boost)
        self.decay = float(decay)
        self._n_nodes = 0

    # ------------------------------------------------------------------ sizes
    def __len__(self) -> int:
        return self._n_nodes

    # ---------------------------------------------------------------- updates
    def insert(self, tokens: Sequence[int], *, request_id: Optional[int] = None,
               freq: float = 1.0) -> None:
        """Insert one branch.  request_id=None → persistent (output) branch;
        otherwise a prompt branch attributed to that request."""
        node = self.root
        for t in tokens:
            t = int(t)
            child = node.children.get(t)
            if child is None:
                child = _Node(token=t)
                node.children[t] = child
                self._n_nodes += 1
            if request_id is None:
                child.freq += freq
            else:
                child.prompt_freq[request_id] = (
                    child.prompt_freq.get(request_id, 0.0) + freq)
            node = child
        if self._n_nodes > self.capacity:
            self.prune()

    def insert_ngrams(self, tokens: Sequence[int], branch_length: int, *,
                      request_id: Optional[int] = None, stride: int = 1) -> None:
        """Slide a window of ``branch_length`` over ``tokens`` and insert every
        n-gram (paper Algorithm 1 lines 5-9)."""
        toks = [int(t) for t in tokens]
        for i in range(0, max(len(toks) - 1, 0), stride):
            self.insert(toks[i:i + branch_length], request_id=request_id)

    def eliminate(self, request_id: int) -> None:
        """Branch Eliminating: drop the prompt frequencies of a finished
        request; nodes whose every frequency reaches zero are removed."""
        self._eliminate(self.root, request_id)

    def _eliminate(self, node: _Node, request_id: int) -> None:
        dead: List[int] = []
        for tok, child in node.children.items():
            child.prompt_freq.pop(request_id, None)
            self._eliminate(child, request_id)
            if child.freq <= 0.0 and not child.prompt_freq and not child.children:
                dead.append(tok)
        for tok in dead:
            del node.children[tok]
            self._n_nodes -= 1

    def prune(self) -> None:
        """Node Pruning: decay frequencies and drop nodes with freq < 1
        (paper §4.3.1).  Prompt frequencies of live requests are preserved."""
        self._decay_prune(self.root)

    def _decay_prune(self, node: _Node) -> None:
        dead: List[int] = []
        for tok, child in node.children.items():
            child.freq *= self.decay
            self._decay_prune(child)
            if (child.freq < 1.0 and not child.prompt_freq
                    and not child.children):
                dead.append(tok)
        for tok in dead:
            del node.children[tok]
            self._n_nodes -= 1

    # -------------------------------------------------------------- retrieval
    def match(self, prefix: Sequence[int]) -> Optional[_Node]:
        """Walk ``prefix``; return the node it lands on (sub-trie root)."""
        node = self.root
        for t in prefix:
            node = node.children.get(int(t))
            if node is None:
                return None
        return node

    def retrieve(self, context: Sequence[int], *, decoding_length: int,
                 max_prefix_len: int = 8, min_matched_tokens: int = 2,
                 ) -> Tuple[List[List[int]], List[float]]:
        """Multi-stage retrieval (paper §4.3.2).

        Try the longest suffix of ``context`` as a prefix; shorten until the
        matched sub-trie holds enough tokens.  Returns up to
        ``decoding_length`` draft tokens organised as branches
        (list of token-id lists, each a root-path *excluding* the prefix)
        plus a parallel list of branch scores.
        """
        ctx = [int(t) for t in context]
        best: Optional[_Node] = None
        for plen in range(min(max_prefix_len, len(ctx)), 0, -1):
            node = self.match(ctx[-plen:])
            if node is None or not node.children:
                continue
            size = self._subtree_token_count(node, decoding_length)
            best = node
            if size >= min(min_matched_tokens, decoding_length):
                # Enough tokens behind this (longer ⇒ more relevant) prefix.
                break
        if best is None:
            return [], []
        return self._top_branches(best, decoding_length)

    def _subtree_token_count(self, node: _Node, cap: int) -> int:
        n, stack = 0, list(node.children.values())
        while stack and n < cap:
            cur = stack.pop()
            n += 1
            stack.extend(cur.children.values())
        return n

    def _top_branches(self, node: _Node, budget: int
                      ) -> Tuple[List[List[int]], List[float]]:
        """Greedy highest-frequency expansion of the sub-trie under ``node``
        into ≤ ``budget`` tokens, returned as branches sorted by score."""
        # Expand nodes in order of frequency until the token budget is used.
        # Each selected trie-node = one draft token.
        import heapq
        boost = self.prompt_boost
        counter = 0
        # order: high frequency first; on ties prefer DEPTH (deep chains
        # dominate EDL for low-entropy continuations — single-branch drafts
        # become a strict subset of the hierarchical draft)
        heap: List[Tuple[float, int, int, _Node, Tuple[int, ...]]] = []
        for ch in node.children.values():
            heap.append((-ch.total_freq(boost), -1, counter, ch,
                         (ch.token,)))
            counter += 1
        heapq.heapify(heap)
        chosen: List[Tuple[Tuple[int, ...], float]] = []
        taken = 0
        while heap and taken < budget:
            negf, negd, _, cur, path = heapq.heappop(heap)
            chosen.append((path, -negf))
            taken += 1
            for ch in cur.children.values():
                heapq.heappush(
                    heap, (-ch.total_freq(boost), negd - 1, counter, ch,
                           path + (ch.token,)))
                counter += 1
        # Keep only maximal paths as branches but remember every selected node;
        # the draft builder needs the *set* of selected nodes (tree), so return
        # all selected paths — draft.py reconstructs the tree from them.
        branches = [list(p) for p, _ in chosen]
        scores = [s for _, s in chosen]
        return branches, scores

    # ---------------------------------------------------------- serialization
    def state_dict(self) -> Dict[str, list]:
        """Flatten the persistent trie into parallel arrays.

        Nodes are emitted in preorder, children in dict-insertion order —
        ``_top_branches`` breaks frequency ties by heap insertion order, so
        a rebuilt trie must iterate children in the same order as the live
        one for retrieval to stay bit-identical.  Per-request prompt
        frequencies are transient (eliminated at retire) and are not
        serialized.
        """
        tokens: List[int] = []
        parents: List[int] = []
        freqs: List[float] = []
        # Explicit stack; push children reversed so pops preserve insertion
        # order.  parent == -1 means "child of root".
        stack: List[Tuple[_Node, int]] = [
            (ch, -1) for ch in reversed(list(self.root.children.values()))]
        while stack:
            node, parent = stack.pop()
            idx = len(tokens)
            tokens.append(int(node.token))
            parents.append(int(parent))
            freqs.append(float(node.freq))
            for ch in reversed(list(node.children.values())):
                stack.append((ch, idx))
        return {"tokens": tokens, "parents": parents, "freqs": freqs}

    @staticmethod
    def _validate_state(state: Dict[str, list]) -> Tuple[list, list, list]:
        if not isinstance(state, dict):
            raise ValueError("trie state must be a dict")
        try:
            tokens, parents, freqs = (
                state["tokens"], state["parents"], state["freqs"])
        except (KeyError, TypeError) as e:
            raise ValueError(f"trie state missing array: {e}") from e
        if not (len(tokens) == len(parents) == len(freqs)):
            raise ValueError("trie state arrays have mismatched lengths")
        for i, p in enumerate(parents):
            if not (-1 <= int(p) < i):
                raise ValueError(
                    f"trie state is not preorder (parents[{i}]={p})")
        return tokens, parents, freqs

    def load_state_dict(self, state: Dict[str, list]) -> None:
        """Rebuild from ``state_dict`` output, replacing current contents.

        Raises ``ValueError`` on malformed arrays (wrong lengths, parent
        index out of preorder range, duplicate siblings).
        """
        tokens, parents, freqs = self._validate_state(state)
        root = _Node(token=-1)
        nodes: List[_Node] = []
        n = 0
        for t, p, f in zip(tokens, parents, freqs):
            parent = root if p == -1 else nodes[int(p)]
            tok = int(t)
            if tok in parent.children:
                raise ValueError("trie state has duplicate sibling tokens")
            child = _Node(token=tok, freq=float(f))
            parent.children[tok] = child
            nodes.append(child)
            n += 1
        self.root = root
        self._n_nodes = n

    def merge_state(self, state: Dict[str, list]) -> None:
        """Freq-max merge of a serialized trie into this one (gossip).

        Element-wise max is a CRDT join: idempotent, commutative and
        associative, so repeated all-to-all gossip converges instead of
        double-counting (a sum-merge re-adds A's own frequencies every
        time they echo back through B, inflating them exponentially with
        the exchange count — which drowns the prompt-frequency boost and
        stalls decay-pruning).  Walks the arrays directly instead of going
        through ``insert`` so a single bulk merge does not fire the
        per-insert prune trigger midway (callers enforce capacity once,
        after the merge).
        """
        tokens, parents, freqs = self._validate_state(state)
        nodes: List[_Node] = []
        for t, p, f in zip(tokens, parents, freqs):
            parent = self.root if p == -1 else nodes[int(p)]
            tok = int(t)
            child = parent.children.get(tok)
            if child is None:
                child = _Node(token=tok)
                parent.children[tok] = child
                self._n_nodes += 1
            child.freq = max(child.freq, float(f))
            nodes.append(child)

    # -------------------------------------------------------------- estimates
    def memory_bytes(self) -> int:
        """Rough host memory estimate of the trie."""
        # dict entry ≈ 100B, node object ≈ 120B
        return self._n_nodes * 220


class TrieForest:
    """Scenario-scoped tries under ONE shared node-capacity budget.

    The paper deploys *per-scenario* tries at Alipay: co-resident tenants
    must not cross-contaminate branch frequencies (tenant A's hot responses
    would otherwise outrank tenant B's own continuations), but host memory
    is still one budget.  The forest maps a namespace string to an isolated
    ``TrieTree`` — insert / retrieve / eliminate never cross namespaces —
    while capacity accounting sums nodes over every namespace and pruning
    decays all of them together.

    The default namespace ``""`` is THE trie of a single-tenant deployment:
    with no other namespace ever touched, every operation is bit-identical
    to driving that ``TrieTree`` directly (the forest adds no extra prune
    triggers on a single tree — see ``check_capacity``).
    """

    def __init__(self, capacity: int = 1024, prompt_boost: float = 8.0,
                 decay: float = 0.5, root: Optional[TrieTree] = None):
        self.capacity = int(root.capacity if root is not None else capacity)
        self.prompt_boost = float(root.prompt_boost if root is not None
                                  else prompt_boost)
        self.decay = float(root.decay if root is not None else decay)
        self._tries: Dict[str, TrieTree] = {
            "": root if root is not None else TrieTree(
                capacity=self.capacity, prompt_boost=self.prompt_boost,
                decay=self.decay)}

    # ------------------------------------------------------------- namespaces
    def tree(self, namespace: str = "") -> TrieTree:
        """The namespace's trie, created on first touch.  Every namespace
        inherits the shared capacity so the per-insert prune trigger of an
        individual trie still bounds pathological single-tenant growth."""
        t = self._tries.get(namespace)
        if t is None:
            t = self._tries[namespace] = TrieTree(
                capacity=self.capacity, prompt_boost=self.prompt_boost,
                decay=self.decay)
        return t

    def get(self, namespace: str = "") -> Optional[TrieTree]:
        """The namespace's trie, or None if never touched (retrieval from an
        unknown namespace must not create state)."""
        return self._tries.get(namespace)

    def namespaces(self) -> Tuple[str, ...]:
        return tuple(sorted(self._tries))

    # --------------------------------------------------------------- capacity
    def __len__(self) -> int:
        """Total node count across every namespace (the shared budget)."""
        return sum(len(t) for t in self._tries.values())

    def prune_all(self) -> None:
        for t in self._tries.values():
            t.prune()

    def check_capacity(self) -> None:
        """Shared accounting: when the SUM of namespace nodes exceeds the
        one capacity, decay-prune every namespace.  Single-namespace forests
        skip this — ``TrieTree.insert`` already prunes at the same capacity,
        and an extra trigger here would change the default deployment's trie
        evolution (it must stay bit-identical to the pre-forest scheduler)."""
        if len(self._tries) > 1 and len(self) > self.capacity:
            self.prune_all()

    def memory_bytes(self) -> int:
        return sum(t.memory_bytes() for t in self._tries.values())

    # ---------------------------------------------------------- serialization
    def state_dict(self) -> Dict[str, object]:
        """Per-namespace serialized tries (empty namespaces are skipped)."""
        return {"namespaces": {ns: t.state_dict()
                               for ns, t in self._tries.items() if len(t)}}

    @staticmethod
    def _state_namespaces(state: Dict[str, object]) -> Dict[str, dict]:
        if not isinstance(state, dict):
            raise ValueError("forest state must be a dict")
        ns_map = state.get("namespaces")
        if not isinstance(ns_map, dict):
            raise ValueError("forest state missing 'namespaces' map")
        return ns_map

    def load_state_dict(self, state: Dict[str, object]) -> None:
        """Replace every namespace with the serialized forest's contents.
        The local capacity/boost/decay configuration wins over the donor's."""
        ns_map = self._state_namespaces(state)
        self._tries = {"": TrieTree(capacity=self.capacity,
                                    prompt_boost=self.prompt_boost,
                                    decay=self.decay)}
        for ns, tree_state in ns_map.items():
            self.tree(str(ns)).load_state_dict(tree_state)

    def merge_state(self, state: Dict[str, object]) -> None:
        """Gossip merge: freq-max each donor namespace into the local forest,
        then decay-prune until the shared capacity budget holds again."""
        ns_map = self._state_namespaces(state)
        for ns, tree_state in ns_map.items():
            self.tree(str(ns)).merge_state(tree_state)
        # Merged branches carry no live prompt_freq, so repeated decay always
        # makes progress on them; the no-progress guard covers a forest pinned
        # by live requests' prompt branches.
        while len(self) > self.capacity:
            before = len(self)
            self.prune_all()
            if len(self) >= before:
                break


__all__ = ["TrieTree", "TrieForest"]
