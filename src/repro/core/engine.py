"""LookaheadEngine — the legacy serving entry point tying trie, draft, model
and VA together.

The engine is model-agnostic: it drives jitted device functions built by
``repro.serving.session.make_session_fns`` (or any object satisfying
``StepFns``), and owns the host-side state (trie, per-request bookkeeping,
statistics).  One engine instance serves many requests and keeps its trie warm
across them (paper Appendix D).

Step anatomy (greedy; sample mode replaces argmax with position-keyed sample):

    root r at position m   (cache holds KV for positions < m)
    tree  = draft(trie.retrieve(output_suffix))           # host, ~µs
    chosen = tree_step(cache, m, [r, draft...], pos, mask)  # device
    accepted, kv_slots = verify_accept(tree, chosen)       # host walk, O(L_d)
    cache = commit(cache, m, kv_slots)                     # device gather
    m += len(accepted); r = accepted[-1]

Worst case: no draft matched ⇒ accepted == [chosen[root]] ⇒ identical to
step-by-step decoding.  Best case: len(accepted) == 1 + draft tree depth.

``generate`` / ``generate_batch`` are thin *compat wrappers* over the
request-centric API (``repro.serving.api``): each prompt becomes a
``Request`` with per-request ``SamplingParams``, served by the slot-based
``ContinuousScheduler``; ``generate_batch_lockstep`` keeps the legacy
all-requests-step-together loop (the baseline the continuous-batching
benchmark compares against).  Both loops share the per-request primitives in
core/request.py — including the token-granular ``cache_token_limit``
retirement bound — so losslessness AND the cache-overflow truncation point
hold identically on either path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from .draft_sources import DraftPolicy, DraftSource, TrieSource
from .request import (GenStats, Request, RequestResult, RequestState,
                      SamplingParams, StepFns, build_draft_tree,
                      cache_token_limit, idle_tree, trie_admit, trie_retire,
                      trie_stream)
from .strategies import LookaheadConfig
from .trie import TrieTree
from .verify import verify_accept_batch

MaxNew = Union[int, Sequence[int]]
ParamSpec = Union[SamplingParams, Sequence[SamplingParams], None]


def _budgets(max_new_tokens: MaxNew, n: int) -> List[int]:
    if isinstance(max_new_tokens, (int, np.integer)):
        return [int(max_new_tokens)] * n
    budgets = [int(m) for m in max_new_tokens]
    if len(budgets) != n:
        raise ValueError(
            f"max_new_tokens lists one budget per prompt: got "
            f"{len(budgets)} budgets for {n} prompts")
    return budgets


def _per_request_params(fns: StepFns, n: int, max_new_tokens: Optional[MaxNew],
                        params: ParamSpec) -> List[SamplingParams]:
    """Normalize the compat surface to one ``SamplingParams`` per request:
    explicit params win; otherwise the session defaults with the per-call
    budgets."""
    if params is None:
        if max_new_tokens is None:
            raise ValueError("pass max_new_tokens or per-request params")
        defaults = fns.default_params
        return [dataclasses.replace(defaults, max_new_tokens=b)
                for b in _budgets(max_new_tokens, n)]
    if max_new_tokens is not None:
        raise ValueError("pass either max_new_tokens or params, not both "
                         "(params carry their own max_new_tokens)")
    if isinstance(params, SamplingParams):
        return [params.validate()] * n
    plist = list(params)
    if len(plist) != n:
        raise ValueError(f"params lists one spec per prompt: got "
                         f"{len(plist)} specs for {n} prompts")
    return [p.validate() for p in plist]


class LookaheadEngine:
    def __init__(self, fns: StepFns, config: LookaheadConfig,
                 eos_id: int = -1,
                 draft_policy: Optional[DraftPolicy] = None):
        self.fns = fns
        self.config = config
        self.eos_id = eos_id
        self.trie = TrieTree(capacity=config.trie_capacity,
                             prompt_boost=config.prompt_boost,
                             decay=config.decay)
        # default speculation policy for the scheduler-backed generate paths
        # (the lock-step loop stays on the hardwired trie — it is the legacy
        # baseline the continuous-batching benchmarks compare against).
        # Source instances persist across generate_batch calls so adaptive
        # sources (trie, ngram) stay warm like the trie always has.
        self.draft_policy = (draft_policy if draft_policy is not None
                             else DraftPolicy()).validate()
        self._sources: Dict[str, DraftSource] = {
            "trie": TrieSource(config, trie=self.trie)}
        self._next_request_id = 0

    # ------------------------------------------------------------------ warm
    def warmup(self, corpora: Sequence[Sequence[int]]) -> None:
        """Pre-load responses into the trie (paper Appendix D)."""
        if not self.config.insert_output:
            return
        for toks in corpora:
            self.trie.insert_ngrams(toks, self.config.branch_length)

    # ------------------------------------------------------------------ width
    @property
    def tree_width(self) -> int:
        """Device step width T the engine drives (1 in plain-decoding mode)."""
        cfg = self.config
        if cfg.strategy == "none" or cfg.decoding_length == 0:
            return 1
        return self.fns.slots

    # --------------------------------------------------------------- generate
    def generate(self, prompt: Sequence[int],
                 max_new_tokens: Optional[int] = None,
                 params: Optional[SamplingParams] = None) -> RequestResult:
        res = self.generate_batch([prompt], max_new_tokens, params=params)
        return res[0]

    def generate_batch(self, prompts: Sequence[Sequence[int]],
                       max_new_tokens: Optional[MaxNew] = None,
                       params: ParamSpec = None) -> List[RequestResult]:
        """Serve ``prompts`` to completion; per-request budgets or full
        per-request ``SamplingParams`` allowed.

        Compat wrapper over the request-centric API: each prompt becomes a
        ``Request`` submitted to the continuous scheduler (one lane per
        prompt, all admitted up front) when the StepFns support slot
        serving; otherwise falls back to the legacy lock-step loop.  Output
        tokens are identical either way (lossless per request).
        """
        plist = _per_request_params(self.fns, len(prompts), max_new_tokens,
                                    params)
        if not self.fns.supports_slot_serving:
            return self.generate_batch_lockstep(prompts, params=plist)
        prefill_len = self.fns.prefill_len or max(len(p) for p in prompts)
        if prefill_len + self.tree_width > self.fns.max_seq_len:
            # near-max-length prompts: the scheduler refuses admission
            # (no room for a tree step); the lock-step loop degrades
            # gracefully to a 1-token result instead
            if getattr(self.fns, "kv_layout", "dense") == "paged":
                raise ValueError(
                    f"prompts padded to {prefill_len} leave no room for a "
                    f"{self.tree_width}-slot tree step within max_seq_len="
                    f"{self.fns.max_seq_len}, and the paged layout has no "
                    "lock-step fallback — shorten the prompt, raise "
                    "max_seq_len, or use kv_layout='dense'")
            return self.generate_batch_lockstep(prompts, params=plist)
        from repro.serving.scheduler import ContinuousScheduler
        sched = ContinuousScheduler(
            self.fns, self.config, lanes=len(prompts), trie=self.trie,
            eos_id=self.eos_id, prefill_len=prefill_len,
            rid_start=self._next_request_id,
            draft_policy=self.draft_policy, sources=self._sources)
        handles = [sched.submit_request(Request(prompt=list(p), params=pp))
                   for p, pp in zip(prompts, plist)]
        sched.run()
        self._next_request_id = sched.next_rid
        return [h.result() for h in handles]

    # --------------------------------------------------------------- lockstep
    def generate_batch_lockstep(self, prompts: Sequence[Sequence[int]],
                                max_new_tokens: Optional[MaxNew] = None,
                                params: ParamSpec = None
                                ) -> List[RequestResult]:
        """Legacy loop: all requests step together; finished requests idle in
        their slot until the slowest request of the batch drains."""
        cfg, fns = self.config, self.fns
        if getattr(fns, "kv_layout", "dense") == "paged":
            raise ValueError(
                "the lock-step loop drives the dense KV layout only; paged "
                "sessions are served by ContinuousScheduler (which owns the "
                "block allocator)")
        B = len(prompts)
        W = self.tree_width
        plist = _per_request_params(fns, B, max_new_tokens, params)
        states = [RequestState(rid=self._next_request_id + i,
                               prompt=list(prompts[i]),
                               max_new_tokens=plist[i].max_new_tokens,
                               eos_id=self.eos_id, params=plist[i],
                               token_limit=cache_token_limit(
                                   fns.max_seq_len, W, len(prompts[i])))
                  for i in range(B)]
        self._next_request_id += B

        for rs in states:
            trie_admit(self.trie, cfg, rs.rid, rs.prompt)

        # per-lane sampling vectors (lane i <-> request i, fixed for the
        # whole batch); legacy StepFns without per-lane support fall back to
        # their session-level constants
        lane_kw = {}
        if fns.per_lane_params:
            lane_kw["lane_params"] = {
                "greedy": np.asarray([not p.sample for p in plist]),
                "temp": np.asarray([p.temperature for p in plist],
                                   dtype=np.float32),
                "seed": np.asarray([np.uint32(p.seed) for p in plist],
                                   dtype=np.uint32)}

        # --- prefill (pad to a common fixed length when configured)
        S = fns.prefill_len or max(len(p) for p in prompts)
        toks = np.full((B, S), fns.pad_id, dtype=np.int32)
        lens = np.zeros((B,), dtype=np.int32)
        for b, p in enumerate(prompts):
            if len(p) > S:
                raise ValueError(
                    f"prompt {b} has {len(p)} tokens but the session pads "
                    f"prompts to prefill_len={S}; shorten the prompt or "
                    "rebuild the session with a larger prefill_len")
            toks[b, :len(p)] = np.asarray(p, dtype=np.int32)
            lens[b] = len(p)
        cache, chosen_root = fns.prefill(toks, lens, **lane_kw)
        chosen_root = np.asarray(chosen_root)
        cache_lens = lens.copy()
        for b, rs in enumerate(states):
            rs.start(int(chosen_root[b]))
            # backstop (cache_token_limit already caps the budget): a first
            # tree step would scatter past the cache end — stop at the
            # prefill token rather than commit garbage
            if cache_lens[b] + W > fns.max_seq_len:
                rs.done = True
                rs.finish_reason = rs.finish_reason or "cache"

        while any(not rs.done for rs in states):
            trees = [build_draft_tree(self.trie, cfg, rs.context,
                                      fns.pad_id, W)
                     if not rs.done else idle_tree(W, fns.pad_id)
                     for rs in states]
            tok = np.stack([t.tokens for t in trees])                 # (B,W)
            pos = (cache_lens[:, None]
                   + np.stack([t.depth for t in trees])).astype(np.int32)
            mask = np.stack([t.tree_mask for t in trees])             # (B,W,W)
            cache, chosen = fns.tree_step(cache, cache_lens, tok, pos, mask,
                                          **lane_kw)
            chosen = np.asarray(chosen)

            accepted, kv_slots = verify_accept_batch(trees, chosen)
            gather = np.zeros((B, W), dtype=np.int32)
            n_acc = np.zeros((B,), dtype=np.int32)
            stepped = [b for b in range(B) if not states[b].done]
            for b in stepped:
                ks = states[b].accept(accepted[b], kv_slots[b],
                                      trees[b].n_slots,
                                      slot_sources=trees[b].slot_source)
                gather[b, :len(ks)] = np.asarray(ks, dtype=np.int32)
                n_acc[b] = len(ks)
            cache, cache_lens = fns.commit(cache, cache_lens, gather, n_acc)
            cache_lens = np.asarray(cache_lens)

            for b in stepped:
                trie_stream(self.trie, cfg, states[b])
                # backstop: token_limit retires before overflow is possible
                if cache_lens[b] + W >= fns.max_seq_len \
                        and not states[b].done:
                    states[b].done = True
                    states[b].finish_reason = \
                        states[b].finish_reason or "cache"

        for rs in states:
            trie_retire(self.trie, cfg, rs.rid, prune=False)
        if cfg.prune and len(self.trie) > self.trie.capacity:
            self.trie.prune()

        return [rs.result() for rs in states]


def reference_decode(fns: StepFns, prompt: Sequence[int],
                     max_new_tokens: Optional[int] = None,
                     eos_id: int = -1, pad_id: int = 0,
                     params: Optional[SamplingParams] = None) -> List[int]:
    """Plain step-by-step decoding through the *same* device functions
    (width-1 step with an empty draft), honoring the request's own
    ``SamplingParams``.  Ground truth for lossless tests."""
    cfg = LookaheadConfig(strategy="none", decoding_length=0)
    engine = LookaheadEngine(fns, cfg, eos_id=eos_id)
    return engine.generate(prompt, max_new_tokens, params=params).tokens


__all__ = ["LookaheadEngine", "StepFns", "GenStats", "RequestResult",
           "RequestState", "reference_decode"]
