"""LookaheadEngine — the serving loop tying trie, draft, model and VA together.

The engine is model-agnostic: it drives three jitted device functions built by
``repro.serving.session.make_session_fns`` (or any object satisfying
``StepFns``), and owns the host-side state (trie, per-request bookkeeping,
statistics).  One engine instance serves many requests and keeps its trie warm
across them (paper Appendix D).

Step anatomy (greedy; sample mode replaces argmax with position-keyed sample):

    root r at position m   (cache holds KV for positions < m)
    tree  = draft(trie.retrieve(output_suffix))           # host, ~µs
    chosen = tree_step(cache, m, [r, draft...], pos, mask)  # device
    accepted, kv_slots = verify_accept(tree, chosen)       # host walk, O(L_d)
    cache = commit(cache, m, kv_slots)                     # device gather
    m += len(accepted); r = accepted[-1]

Worst case: no draft matched ⇒ accepted == [chosen[root]] ⇒ identical to
step-by-step decoding.  Best case: len(accepted) == 1 + draft tree depth.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .draft import BUILDERS, DraftTree, _finalize
from .strategies import LookaheadConfig
from .trie import TrieTree
from .verify import verify_accept_batch


@dataclass
class StepFns:
    """Device functions the engine drives (all jit-compiled, fixed shapes).

    prefill(tokens(B,S) i32, lens(B,) i32) -> (cache, chosen_root(B,) i32)
    tree_step(cache, cache_lens(B,), tokens(B,T), pos(B,T), mask(B,T,T))
        -> (cache, chosen(B,T) i32)
    commit(cache, cache_lens(B,), gather_idx(B,T), n_accept(B,))
        -> (cache, new_lens(B,))
    """
    prefill: Callable
    tree_step: Callable
    commit: Callable
    slots: int            # T = 1 + decoding_length
    max_seq_len: int
    pad_id: int = 0


@dataclass
class GenStats:
    steps: int = 0
    tokens: int = 0
    dropped_slots: int = 0    # draft tokens computed but rejected

    @property
    def edl(self) -> float:
        """Mean accepted tokens per step (paper: effective decoding length)."""
        return self.tokens / max(self.steps, 1)


@dataclass
class RequestResult:
    tokens: List[int]
    stats: GenStats


class LookaheadEngine:
    def __init__(self, fns: StepFns, config: LookaheadConfig,
                 eos_id: int = -1):
        self.fns = fns
        self.config = config
        self.eos_id = eos_id
        self.trie = TrieTree(capacity=config.trie_capacity,
                             prompt_boost=config.prompt_boost,
                             decay=config.decay)
        self._next_request_id = 0

    # ------------------------------------------------------------------ warm
    def warmup(self, corpora: Sequence[Sequence[int]]) -> None:
        """Pre-load responses into the trie (paper Appendix D)."""
        if not self.config.insert_output:
            return
        for toks in corpora:
            self.trie.insert_ngrams(toks, self.config.branch_length)

    # ----------------------------------------------------------------- drafts
    def _build_tree(self, output: Sequence[int]) -> DraftTree:
        cfg = self.config
        root = int(output[-1])
        if cfg.strategy == "none" or cfg.decoding_length == 0:
            return _finalize([root], [-1], 1, self.fns.pad_id)
        branches, scores = self.trie.retrieve(
            output, decoding_length=cfg.decoding_length,
            max_prefix_len=cfg.max_prefix_len,
            min_matched_tokens=cfg.min_matched_tokens)
        return BUILDERS[cfg.strategy](root, branches, scores,
                                      cfg.decoding_length, self.fns.pad_id)

    # --------------------------------------------------------------- generate
    def generate(self, prompt: Sequence[int], max_new_tokens: int,
                 ) -> RequestResult:
        res = self.generate_batch([prompt], max_new_tokens)
        return res[0]

    def generate_batch(self, prompts: Sequence[Sequence[int]],
                       max_new_tokens: int) -> List[RequestResult]:
        cfg, fns = self.config, self.fns
        B = len(prompts)
        T = fns.slots
        req_ids = [self._next_request_id + i for i in range(B)]
        self._next_request_id += B

        # --- trie: prompt-branch inserting (per request id, eliminable)
        if cfg.insert_prompt:
            for rid, p in zip(req_ids, prompts):
                self.trie.insert_ngrams(p, cfg.branch_length, request_id=rid)

        # --- prefill (pad to common length)
        S = max(len(p) for p in prompts)
        toks = np.full((B, S), fns.pad_id, dtype=np.int32)
        lens = np.zeros((B,), dtype=np.int32)
        for b, p in enumerate(prompts):
            toks[b, :len(p)] = np.asarray(p, dtype=np.int32)
            lens[b] = len(p)
        cache, chosen_root = fns.prefill(toks, lens)
        chosen_root = np.asarray(chosen_root)
        cache_lens = lens.copy()

        outputs: List[List[int]] = [[int(chosen_root[b])] for b in range(B)]
        stats = [GenStats(steps=1, tokens=1) for _ in range(B)]
        done = np.array([outputs[b][0] == self.eos_id
                         or max_new_tokens <= 1 for b in range(B)])
        # context fed to retrieval = prompt ⧺ generated
        contexts = [list(prompts[b]) + outputs[b] for b in range(B)]
        # tokens already inserted into the trie from the output stream
        inserted_upto = [0 for _ in range(B)]

        while (~done).any():
            trees: List[DraftTree] = []
            for b in range(B):
                trees.append(self._build_tree(contexts[b]))
            tok = np.stack([t.tokens for t in trees])                 # (B,T)
            pos = (cache_lens[:, None]
                   + np.stack([t.depth for t in trees])).astype(np.int32)
            mask = np.stack([t.tree_mask for t in trees])             # (B,T,T)
            cache, chosen = fns.tree_step(cache, cache_lens, tok, pos, mask)
            chosen = np.asarray(chosen)

            accepted, kv_slots = verify_accept_batch(trees, chosen)
            gather = np.zeros((B, T), dtype=np.int32)
            n_acc = np.zeros((B,), dtype=np.int32)
            for b in range(B):
                if done[b]:
                    n_acc[b] = 0
                    continue
                # truncate at EOS / budget
                budget = max_new_tokens - len(outputs[b])
                acc = accepted[b][:budget]
                if self.eos_id in acc:
                    acc = acc[:acc.index(self.eos_id) + 1]
                ks = kv_slots[b][:len(acc)]
                gather[b, :len(ks)] = np.asarray(ks, dtype=np.int32)
                n_acc[b] = len(ks)
                outputs[b].extend(acc)
                contexts[b].extend(acc)
                stats[b].steps += 1
                stats[b].tokens += len(acc)
                stats[b].dropped_slots += trees[b].n_slots - len(ks)
                if acc and acc[-1] == self.eos_id:
                    done[b] = True
                if len(outputs[b]) >= max_new_tokens:
                    done[b] = True
            cache, cache_lens = fns.commit(cache, cache_lens, gather, n_acc)
            cache_lens = np.asarray(cache_lens)

            # --- trie: generated-branch inserting on-the-fly
            if cfg.insert_output:
                for b in range(B):
                    out = outputs[b]
                    lo = max(inserted_upto[b] - cfg.branch_length, 0)
                    if len(out) - lo >= 2:
                        self.trie.insert_ngrams(out[lo:], cfg.branch_length)
                        inserted_upto[b] = len(out)
            # safety: cache overflow → stop
            for b in range(B):
                if cache_lens[b] + T >= fns.max_seq_len:
                    done[b] = True

        # --- trie: branch eliminating for finished requests
        if cfg.eliminate:
            for rid in req_ids:
                self.trie.eliminate(rid)
        if cfg.prune and len(self.trie) > self.trie.capacity:
            self.trie.prune()

        return [RequestResult(tokens=outputs[b], stats=stats[b])
                for b in range(B)]


def reference_decode(fns: StepFns, prompt: Sequence[int], max_new_tokens: int,
                     eos_id: int = -1, pad_id: int = 0) -> List[int]:
    """Plain step-by-step decoding through the *same* device functions
    (T-wide step with an empty draft).  Ground truth for lossless tests."""
    cfg = LookaheadConfig(strategy="none", decoding_length=0)
    engine = LookaheadEngine(fns, cfg, eos_id=eos_id)
    return engine.generate(prompt, max_new_tokens).tokens


__all__ = ["LookaheadEngine", "StepFns", "GenStats", "RequestResult",
           "reference_decode"]
