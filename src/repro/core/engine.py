"""LookaheadEngine — the serving entry point tying trie, draft, model and VA
together.

The engine is model-agnostic: it drives jitted device functions built by
``repro.serving.session.make_session_fns`` (or any object satisfying
``StepFns``), and owns the host-side state (trie, per-request bookkeeping,
statistics).  One engine instance serves many requests and keeps its trie warm
across them (paper Appendix D).

Step anatomy (greedy; sample mode replaces argmax with position-keyed sample):

    root r at position m   (cache holds KV for positions < m)
    tree  = draft(trie.retrieve(output_suffix))           # host, ~µs
    chosen = tree_step(cache, m, [r, draft...], pos, mask)  # device
    accepted, kv_slots = verify_accept(tree, chosen)       # host walk, O(L_d)
    cache = commit(cache, m, kv_slots)                     # device gather
    m += len(accepted); r = accepted[-1]

Worst case: no draft matched ⇒ accepted == [chosen[root]] ⇒ identical to
step-by-step decoding.  Best case: len(accepted) == 1 + draft tree depth.

``generate`` / ``generate_batch`` are thin wrappers over the slot-based
``ContinuousScheduler`` (serving/scheduler.py) whenever the StepFns support
per-slot admission; ``generate_batch_lockstep`` keeps the legacy all-requests
-step-together loop (the baseline the continuous-batching benchmark compares
against).  Both loops share the per-request primitives in core/request.py, so
losslessness holds identically on either path.
"""
from __future__ import annotations

from typing import List, Sequence, Union

import numpy as np

from .request import (GenStats, RequestResult, RequestState, StepFns,
                      build_draft_tree, idle_tree, trie_admit, trie_retire,
                      trie_stream)
from .strategies import LookaheadConfig
from .trie import TrieTree
from .verify import verify_accept_batch

MaxNew = Union[int, Sequence[int]]


def _budgets(max_new_tokens: MaxNew, n: int) -> List[int]:
    if isinstance(max_new_tokens, (int, np.integer)):
        return [int(max_new_tokens)] * n
    budgets = [int(m) for m in max_new_tokens]
    assert len(budgets) == n, (len(budgets), n)
    return budgets


class LookaheadEngine:
    def __init__(self, fns: StepFns, config: LookaheadConfig,
                 eos_id: int = -1):
        self.fns = fns
        self.config = config
        self.eos_id = eos_id
        self.trie = TrieTree(capacity=config.trie_capacity,
                             prompt_boost=config.prompt_boost,
                             decay=config.decay)
        self._next_request_id = 0

    # ------------------------------------------------------------------ warm
    def warmup(self, corpora: Sequence[Sequence[int]]) -> None:
        """Pre-load responses into the trie (paper Appendix D)."""
        if not self.config.insert_output:
            return
        for toks in corpora:
            self.trie.insert_ngrams(toks, self.config.branch_length)

    # ------------------------------------------------------------------ width
    @property
    def tree_width(self) -> int:
        """Device step width T the engine drives (1 in plain-decoding mode)."""
        cfg = self.config
        if cfg.strategy == "none" or cfg.decoding_length == 0:
            return 1
        return self.fns.slots

    # --------------------------------------------------------------- generate
    def generate(self, prompt: Sequence[int], max_new_tokens: int,
                 ) -> RequestResult:
        res = self.generate_batch([prompt], max_new_tokens)
        return res[0]

    def generate_batch(self, prompts: Sequence[Sequence[int]],
                       max_new_tokens: MaxNew) -> List[RequestResult]:
        """Serve ``prompts`` to completion; per-request budgets allowed.

        Routes through the continuous scheduler (one lane per prompt, all
        admitted up front) when the StepFns support slot serving; otherwise
        falls back to the legacy lock-step loop.  Output tokens are identical
        either way (lossless per request).
        """
        if not self.fns.supports_slot_serving:
            return self.generate_batch_lockstep(prompts, max_new_tokens)
        prefill_len = self.fns.prefill_len or max(len(p) for p in prompts)
        if prefill_len + self.tree_width > self.fns.max_seq_len:
            # near-max-length prompts: the scheduler refuses admission
            # (no room for a tree step); the lock-step loop degrades
            # gracefully to a 1-token result instead
            if getattr(self.fns, "kv_layout", "dense") == "paged":
                raise ValueError(
                    f"prompts padded to {prefill_len} leave no room for a "
                    f"{self.tree_width}-slot tree step within max_seq_len="
                    f"{self.fns.max_seq_len}, and the paged layout has no "
                    "lock-step fallback — shorten the prompt, raise "
                    "max_seq_len, or use kv_layout='dense'")
            return self.generate_batch_lockstep(prompts, max_new_tokens)
        from repro.serving.scheduler import ContinuousScheduler
        budgets = _budgets(max_new_tokens, len(prompts))
        sched = ContinuousScheduler(
            self.fns, self.config, lanes=len(prompts), trie=self.trie,
            eos_id=self.eos_id, prefill_len=prefill_len,
            rid_start=self._next_request_id)
        for p, m in zip(prompts, budgets):
            sched.submit(p, m)
        results = sched.run()
        self._next_request_id = sched.next_rid
        return results

    # --------------------------------------------------------------- lockstep
    def generate_batch_lockstep(self, prompts: Sequence[Sequence[int]],
                                max_new_tokens: MaxNew) -> List[RequestResult]:
        """Legacy loop: all requests step together; finished requests idle in
        their slot until the slowest request of the batch drains."""
        cfg, fns = self.config, self.fns
        if getattr(fns, "kv_layout", "dense") == "paged":
            raise ValueError(
                "the lock-step loop drives the dense KV layout only; paged "
                "sessions are served by ContinuousScheduler (which owns the "
                "block allocator)")
        B = len(prompts)
        W = self.tree_width
        budgets = _budgets(max_new_tokens, B)
        states = [RequestState(rid=self._next_request_id + i,
                               prompt=list(prompts[i]),
                               max_new_tokens=budgets[i], eos_id=self.eos_id)
                  for i in range(B)]
        self._next_request_id += B

        for rs in states:
            trie_admit(self.trie, cfg, rs.rid, rs.prompt)

        # --- prefill (pad to a common fixed length when configured)
        S = fns.prefill_len or max(len(p) for p in prompts)
        toks = np.full((B, S), fns.pad_id, dtype=np.int32)
        lens = np.zeros((B,), dtype=np.int32)
        for b, p in enumerate(prompts):
            assert len(p) <= S, (len(p), S)
            toks[b, :len(p)] = np.asarray(p, dtype=np.int32)
            lens[b] = len(p)
        cache, chosen_root = fns.prefill(toks, lens)
        chosen_root = np.asarray(chosen_root)
        cache_lens = lens.copy()
        for b, rs in enumerate(states):
            rs.start(int(chosen_root[b]))
            # a first tree step would scatter past the cache end: stop at
            # the prefill token rather than commit garbage
            if cache_lens[b] + W > fns.max_seq_len:
                rs.done = True

        while any(not rs.done for rs in states):
            trees = [build_draft_tree(self.trie, cfg, rs.context,
                                      fns.pad_id, W)
                     if not rs.done else idle_tree(W, fns.pad_id)
                     for rs in states]
            tok = np.stack([t.tokens for t in trees])                 # (B,W)
            pos = (cache_lens[:, None]
                   + np.stack([t.depth for t in trees])).astype(np.int32)
            mask = np.stack([t.tree_mask for t in trees])             # (B,W,W)
            cache, chosen = fns.tree_step(cache, cache_lens, tok, pos, mask)
            chosen = np.asarray(chosen)

            accepted, kv_slots = verify_accept_batch(trees, chosen)
            gather = np.zeros((B, W), dtype=np.int32)
            n_acc = np.zeros((B,), dtype=np.int32)
            stepped = [b for b in range(B) if not states[b].done]
            for b in stepped:
                ks = states[b].accept(accepted[b], kv_slots[b],
                                      trees[b].n_slots)
                gather[b, :len(ks)] = np.asarray(ks, dtype=np.int32)
                n_acc[b] = len(ks)
            cache, cache_lens = fns.commit(cache, cache_lens, gather, n_acc)
            cache_lens = np.asarray(cache_lens)

            for b in stepped:
                trie_stream(self.trie, cfg, states[b])
                # safety: cache overflow → stop
                if cache_lens[b] + W >= fns.max_seq_len:
                    states[b].done = True

        for rs in states:
            trie_retire(self.trie, cfg, rs.rid, prune=False)
        if cfg.prune and len(self.trie) > self.trie.capacity:
            self.trie.prune()

        return [rs.result() for rs in states]


def reference_decode(fns: StepFns, prompt: Sequence[int], max_new_tokens: int,
                     eos_id: int = -1, pad_id: int = 0) -> List[int]:
    """Plain step-by-step decoding through the *same* device functions
    (width-1 step with an empty draft).  Ground truth for lossless tests."""
    cfg = LookaheadConfig(strategy="none", decoding_length=0)
    engine = LookaheadEngine(fns, cfg, eos_id=eos_id)
    return engine.generate(prompt, max_new_tokens).tokens


__all__ = ["LookaheadEngine", "StepFns", "GenStats", "RequestResult",
           "RequestState", "reference_decode"]
