"""Hierarchical / parallel / single-branch draft construction (paper §4.2).

Converts retrieved trie branches into the fixed-shape tensors a jitted
tree-decode step consumes:

  slot 0                : the last committed token (the "root"),
  slots 1..decoding_len : draft tokens arranged as a tree,
  parent[i]             : slot index of i's parent (root's parent = -1),
  depth[i]              : tree depth (0 for root) → position_id offset,
  tree_mask[i, j]       : 1 iff j is an ancestor of i or j == i.

Three strategies (paper Figure 2/3):
  * hierarchical — shared prefixes merged (one trie node = one slot),
  * parallel     — branches laid out independently (no prefix sharing),
  * single       — one branch only (LLMA-style baseline).

All outputs are padded to a fixed ``1 + decoding_length`` so the device step
compiles once.  Padded slots have ``parent = 0``, ``token = pad_id``, mask =
self+root only, and are never matched during verification (they are excluded
via ``n_slots``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class DraftTree:
    """Host-side draft tree, ready to be shipped to the device step."""
    tokens: np.ndarray      # (T,) int32  — slot 0 = root token
    parent: np.ndarray      # (T,) int32  — -1 for root, else parent slot
    depth: np.ndarray       # (T,) int32  — 0 for root
    tree_mask: np.ndarray   # (T, T) bool — ancestor-closure (incl. self)
    n_slots: int            # live slots (<= T), root included
    children: List[List[int]]  # adjacency (host verification walk)
    # provenance: the draft-source name that contributed each slot (None for
    # the root and padded slots).  Host-side only — never shipped to the
    # device — and feeds the per-source acceptance telemetry.
    slot_source: List[Optional[str]] = None

    @property
    def size(self) -> int:
        return int(self.tokens.shape[0])


def _finalize(tokens: List[int], parent: List[int], total: int,
              pad_id: int, slot_src: Optional[List[Optional[str]]] = None
              ) -> DraftTree:
    n = len(tokens)
    assert n >= 1 and n <= total, (n, total)
    tok = np.full((total,), pad_id, dtype=np.int32)
    par = np.zeros((total,), dtype=np.int32)
    tok[:n] = np.asarray(tokens, dtype=np.int32)
    par[:n] = np.asarray(parent, dtype=np.int32)
    par[0] = -1
    depth = np.zeros((total,), dtype=np.int32)
    for i in range(1, n):
        depth[i] = depth[par[i]] + 1
    # padded slots: children of root at depth 1 (harmless, never verified)
    depth[n:] = 1
    mask = np.zeros((total, total), dtype=bool)
    for i in range(total):
        mask[i, i] = True
        j = par[i] if i < n else 0
        while j >= 0:
            mask[i, j] = True
            j = par[j] if j > 0 else -1
    children: List[List[int]] = [[] for _ in range(total)]
    for i in range(1, n):
        children[par[i]].append(i)
    src_full: List[Optional[str]] = [None] * total
    if slot_src is not None:
        for i in range(min(len(slot_src), n)):
            src_full[i] = slot_src[i]
    return DraftTree(tokens=tok, parent=par, depth=depth, tree_mask=mask,
                     n_slots=n, children=children, slot_source=src_full)


def build_hierarchical(root_token: int, branches: Sequence[Sequence[int]],
                       scores: Optional[Sequence[float]],
                       decoding_length: int, pad_id: int = 0, *,
                       sources: Optional[Sequence[Optional[str]]] = None
                       ) -> DraftTree:
    """Merge shared prefixes: one slot per distinct trie node (paper §4.2.2).

    ``branches`` are root-paths from retrieval (may be prefixes of each
    other); insertion order respects ``scores`` (already sorted by retrieval).
    Token budget: at most ``decoding_length`` draft slots beyond the root.
    ``sources`` optionally names the draft source of each branch; a shared
    slot keeps the first contributor (merge order = priority).
    """
    total = 1 + decoding_length
    tokens: List[int] = [int(root_token)]
    parent: List[int] = [-1]
    srcs: List[Optional[str]] = [None]
    # map path-prefix -> slot
    slot_of: Dict[Tuple[int, ...], int] = {(): 0}
    order = range(len(branches))
    for bi in order:
        path = tuple(int(t) for t in branches[bi])
        tag = sources[bi] if sources is not None else None
        for d in range(len(path)):
            key = path[:d + 1]
            if key in slot_of:
                continue
            if len(tokens) >= total:
                break
            parent_slot = slot_of.get(key[:-1])
            if parent_slot is None:
                break  # budget cut the prefix earlier; skip the tail
            slot_of[key] = len(tokens)
            tokens.append(key[-1])
            parent.append(parent_slot)
            srcs.append(tag)
        if len(tokens) >= total:
            break
    return _finalize(tokens, parent, total, pad_id, slot_src=srcs)


def build_parallel(root_token: int, branches: Sequence[Sequence[int]],
                   scores: Optional[Sequence[float]],
                   decoding_length: int, pad_id: int = 0, *,
                   sources: Optional[Sequence[Optional[str]]] = None
                   ) -> DraftTree:
    """Parallel multi-branch: no prefix merging (paper §4.2.1).

    Branch lists coming from trie retrieval include every prefix path; keep
    only maximal paths so parallel layout does not duplicate pure prefixes.
    """
    total = 1 + decoding_length
    paths = [tuple(int(t) for t in b) for b in branches]
    src_of: Dict[Tuple[int, ...], Optional[str]] = {}
    if sources is not None:
        for p, s in zip(paths, sources):
            src_of.setdefault(p, s)
    maximal = _maximal_paths(paths)
    tokens: List[int] = [int(root_token)]
    parent: List[int] = [-1]
    srcs: List[Optional[str]] = [None]
    for path in maximal:
        tag = src_of.get(path)
        if len(tokens) + len(path) > total:
            path = path[: max(0, total - len(tokens))]
        prev = 0
        for t in path:
            tokens.append(t)
            parent.append(prev)
            srcs.append(tag)
            prev = len(tokens) - 1
        if len(tokens) >= total:
            break
    return _finalize(tokens, parent, total, pad_id, slot_src=srcs)


def build_single(root_token: int, branches: Sequence[Sequence[int]],
                 scores: Optional[Sequence[float]],
                 decoding_length: int, pad_id: int = 0, *,
                 sources: Optional[Sequence[Optional[str]]] = None
                 ) -> DraftTree:
    """Single-branch (LLMA-style): longest/highest-score single chain."""
    total = 1 + decoding_length
    all_paths = [tuple(int(t) for t in b) for b in branches]
    paths = _maximal_paths(all_paths)
    tokens: List[int] = [int(root_token)]
    parent: List[int] = [-1]
    srcs: List[Optional[str]] = [None]
    if paths:
        best = paths[0]
        tag = None
        if sources is not None:
            for p, s in zip(all_paths, sources):
                if p == best:
                    tag = s
                    break
        for i, t in enumerate(best[:decoding_length]):
            tokens.append(t)
            parent.append(i)  # chain: slot i+1's parent is slot i
            srcs.append(tag)
    return _finalize(tokens, parent, total, pad_id, slot_src=srcs)


def repad(tree: DraftTree, total: int, pad_id: int = 0) -> DraftTree:
    """Re-pad a draft tree to exactly ``total`` slots (fixed device shapes).

    The serving loops compile their tree step for one width T; a config whose
    ``decoding_length`` is smaller than the compiled width just carries extra
    padded slots (never verified, mask = self+root only).
    """
    if tree.size == total:
        return tree
    n = min(tree.n_slots, total)
    src = tree.slot_source[:n] if tree.slot_source is not None else None
    return _finalize(list(tree.tokens[:n]), list(tree.parent[:n]), total,
                     pad_id, slot_src=src)


def _maximal_paths(paths: Sequence[Tuple[int, ...]]) -> List[Tuple[int, ...]]:
    """Drop paths that are proper prefixes of another path; keep input order.

    Prefix-set walk: one pass collects every proper prefix of every path,
    a second keeps the paths absent from that set — O(total tokens) hash
    work instead of the all-pairs O(n²·len) scan (this runs per lane per
    decode step on the host hot path of both serving loops)."""
    prefixes = set()
    for p in paths:
        for d in range(1, len(p)):
            prefixes.add(p[:d])
    out: List[Tuple[int, ...]] = []
    seen = set()
    for p in paths:
        if p and p not in seen and p not in prefixes:
            seen.add(p)
            out.append(p)
    return out


BUILDERS = {
    "hierarchical": build_hierarchical,
    "parallel": build_parallel,
    "single": build_single,
}

__all__ = ["DraftTree", "build_hierarchical", "build_parallel",
           "build_single", "repad", "BUILDERS"]
