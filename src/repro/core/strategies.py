"""Configuration for the Lookahead decoding strategies."""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class LookaheadConfig:
    """Hyper-parameters of the lookahead generation mode (paper §4, §5.2.2/5.2.3).

    strategy:
      * "hierarchical" — trie-merged multi-branch draft (the paper's method)
      * "parallel"     — multi-branch without prefix merging (ablation)
      * "single"       — single-branch (LLMA-style baseline)
      * "none"         — plain step-by-step decoding (baseline)
    """
    decoding_length: int = 64        # L_d: draft token budget per step (<= CDL)
    branch_length: int = 12          # L_b: n-gram length inserted into the trie
    strategy: str = "hierarchical"
    # trie
    capacity_factor: int = 16        # node capacity = factor * decoding_length
    prompt_boost: float = 8.0        # branch-weighting amplifier for prompt branches
    decay: float = 0.5               # pruning frequency decay
    max_prefix_len: int = 8          # multi-stage retrieval: longest suffix tried
    min_matched_tokens: int = 2      # retry with shorter prefix below this
    # draft-source retrieval tuning (core/draft_sources.py); which sources a
    # request actually uses is the per-request DraftPolicy, these shape HOW
    # each source retrieves once selected
    copy_min_match: int = 2          # PromptCopySource: shortest suffix matched
    copy_max_branches: int = 4       # PromptCopySource: copy sites per retrieve
    ngram_order: int = 3             # NgramSource: max conditioning order k
    ngram_max_entries: int = 65536   # NgramSource: count-table cap before decay
    # ablation switches (paper Table 3)
    insert_prompt: bool = True
    insert_output: bool = True
    eliminate: bool = True
    prune: bool = True
    # sampling
    sample: bool = False             # False = greedy; True = position-keyed sample
    temperature: float = 1.0

    @property
    def trie_capacity(self) -> int:
        # capacity_factor × decoding_length *n-grams* (each up to
        # branch_length nodes); floor keeps one prompt+response resident.
        return max(self.capacity_factor * max(self.decoding_length, 1)
                   * max(self.branch_length, 1), 2048)

    @property
    def slots(self) -> int:
        """Device step width: root + draft budget."""
        return 1 + (self.decoding_length if self.strategy != "none" else 0)


__all__ = ["LookaheadConfig"]
