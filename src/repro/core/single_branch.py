"""Single-branch (LLMA-style) baseline configuration (paper Table 2 column).

LLMA [Yang et al. 2023] retrieves a single draft by prefix-matching against
the input prompt (or a document store).  In this framework it is exactly the
lookahead engine with ``strategy="single"`` and output-branch insertion
disabled only if one wants the strict prompt-copy variant; the default below
matches the paper's LLMA baseline setting (prompt branches only are what LLMA
can see, single chain per step).
"""
from __future__ import annotations

from .strategies import LookaheadConfig


def llma_config(branch_length: int = 16, decoding_length: int = 16,
                strict_prompt_only: bool = True) -> LookaheadConfig:
    return LookaheadConfig(
        strategy="single",
        decoding_length=decoding_length,
        branch_length=branch_length,
        insert_prompt=True,
        insert_output=not strict_prompt_only,
    )


def baseline_config() -> LookaheadConfig:
    """Plain step-by-step decoding (transformers baseline in Table 2)."""
    return LookaheadConfig(strategy="none", decoding_length=0)


__all__ = ["llma_config", "baseline_config"]
