"""Pluggable draft sources (DESIGN.md §Draft sources).

The paper's trie retrieval is ONE member of a family of *lossless* draft
generators: any procedure that proposes candidate continuations is safe,
because the device tree step verifies every draft token against the model's
own choices (core/verify.py) — a bad draft costs slots, never correctness.
This module turns the speculation layer into a registry of such generators,
mirroring the attention-backend registry (repro.models.attention):

  * ``DraftSource`` — the protocol: ``retrieve(rid, context, budget)``
    returns candidate branches, ``observe_prompt`` / ``observe_output`` feed
    it tokens, ``retire(rid)`` drops per-request state.
  * ``TrieSource`` — wraps the paper's ``TrieTree`` behind a namespace-scoped
    ``TrieForest`` (per-scenario tries, shared node-capacity accounting).
    The default source; with one namespace it is bit-identical to the old
    hardwired trie path.
  * ``PromptCopySource`` — LLMA-style ("Inference with Reference", Yang et
    al.): copy the continuation of the longest context-suffix match found
    earlier in the request's OWN prompt/output.  Strong on RAG /
    summarization workloads, and inherently per-request — nothing leaks into
    a shared structure.
  * ``NgramSource`` — ANPD-style (Ou et al.) adaptive order-k n-gram model
    with backoff, shared across requests; a cheap fallback when neither the
    trie nor the prompt has a match.
  * ``merge_branches`` — interleaves branches from several sources into one
    candidate list under the shared ``decoding_length`` token budget with
    per-source quotas and dedup against already-merged prefixes.
  * ``AdaptiveBudget`` — per-lane controller shrinking/growing a request's
    effective draft budget from its accepted-length EMA (paper §5.2
    warmup/CDL behavior; the compiled step width never changes).
  * ``DraftPolicy`` — the per-request spec (sources, quotas, trie namespace,
    adaptive on/off) carried on ``SamplingParams`` / ``EngineConfig``.

Everything here is host-side: the device ``StepFns`` are untouched, so every
source and every combination inherits the existing verification
losslessness (I1) and the compile-once shapes (I2).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .draft import BUILDERS, DraftTree, _finalize, repad
from .strategies import LookaheadConfig
from .trie import TrieForest, TrieTree

# (branches, scores): each branch is a root-path of draft tokens (excluding
# the committed context), scores rank branches for budget truncation — the
# contract of TrieTree.retrieve, now shared by every source.
Branches = Tuple[List[List[int]], List[float]]


# ----------------------------------------------------------------- DraftPolicy
@dataclass(frozen=True)
class DraftPolicy:
    """Per-request speculation spec (the API surface of this module).

    sources:   draft-source names tried in priority order (merge order).
    quotas:    per-source cap on NEW draft tokens contributed to one tree;
               () = every source may fill the whole budget (first come,
               first served under the round-robin interleave).
    namespace: trie scenario scope — requests in different namespaces never
               see each other's branches (TrieSource only; per-request and
               global sources ignore it).
    adaptive:  per-lane adaptive draft budget from the accepted-length EMA
               (paper §5.2 warmup/CDL); off = the full decoding_length every
               step.  min_budget / ema_alpha / headroom tune the controller.
    """
    sources: Tuple[str, ...] = ("trie",)
    quotas: Tuple[int, ...] = ()
    namespace: str = ""
    adaptive: bool = False
    min_budget: int = 4
    ema_alpha: float = 0.3
    headroom: float = 1.5

    def __post_init__(self):
        object.__setattr__(self, "sources",
                           tuple(str(s) for s in self.sources))
        object.__setattr__(self, "quotas",
                           tuple(int(q) for q in self.quotas))

    def validate(self) -> "DraftPolicy":
        if not self.sources:
            raise ValueError("DraftPolicy.sources is empty; every request "
                             "needs at least one draft source (use "
                             "strategy='none' for plain decoding)")
        if len(set(self.sources)) != len(self.sources):
            raise ValueError(f"duplicate draft sources in {self.sources}")
        known = available_sources()
        for name in self.sources:
            if name not in known:
                raise ValueError(f"unknown draft source {name!r} "
                                 f"(registry: {', '.join(known)})")
        if self.quotas and len(self.quotas) != len(self.sources):
            raise ValueError(
                f"quotas lists one cap per source: got {len(self.quotas)} "
                f"quotas for {len(self.sources)} sources")
        for q in self.quotas:
            if q < 1:
                raise ValueError(f"quota {q}: each source needs >= 1 slot "
                                 "(drop the source instead)")
        if self.min_budget < 1:
            raise ValueError(f"min_budget={self.min_budget}: need >= 1")
        if not 0.0 < self.ema_alpha <= 1.0:
            raise ValueError(f"ema_alpha={self.ema_alpha}: need (0, 1]")
        if self.headroom <= 0.0:
            raise ValueError(f"headroom={self.headroom}: need > 0")
        return self

    def quota(self, i: int, budget: int) -> int:
        """Source i's new-token cap for one tree of ``budget`` slots."""
        return min(self.quotas[i], budget) if self.quotas else budget


# ------------------------------------------------------------------- protocol
class DraftSource:
    """Base class / protocol of a lossless draft generator.

    Lifecycle (driven by the serving loop, slot-agnostic like the trie
    bookkeeping it generalizes):

        observe_prompt(rid, prompt)   at admission
        observe_output(rid, output)   after each accept (full output so far)
        retrieve(rid, context, budget=..)  before each tree step
        retire(rid)                   at retirement (free per-request state)

    ``namespace`` scopes shared state per scenario; sources without shared
    state may ignore it.  Implementations must be deterministic pure
    functions of their observed-token history — branch CONTENT never affects
    outputs (verification is lossless), but determinism keeps perf runs
    reproducible.
    """

    name = "null"

    def __init__(self, config: LookaheadConfig):
        self.config = config

    # ---- lifecycle
    def observe_prompt(self, rid: int, prompt: Sequence[int],
                       namespace: str = "") -> None:
        pass

    def observe_output(self, rid: int, output: Sequence[int],
                       namespace: str = "") -> None:
        pass

    def retire(self, rid: int, namespace: str = "") -> None:
        pass

    # ---- retrieval
    def retrieve(self, rid: int, context: Sequence[int], *, budget: int,
                 namespace: str = "") -> Branches:
        return [], []

    # ---- warm-state persistence (repro.fleet)
    # Shared (cross-request) statistics only: per-request state dies with the
    # request and must never be serialized.  Sources with no shared state
    # return {} and accept only {} back — a stateless source presented with a
    # donor payload signals a source-name collision, not a silent no-op.
    def state_dict(self) -> Dict[str, object]:
        return {}

    def load_state_dict(self, state: Dict[str, object]) -> None:
        if state:
            raise ValueError(
                f"draft source {self.name!r} holds no shared state but was "
                f"given a non-empty warm-state payload")

    def merge_state(self, state: Dict[str, object]) -> None:
        self.load_state_dict(state)


# ------------------------------------------------------------------ TrieSource
class TrieSource(DraftSource):
    """The paper's trie retrieval behind the DraftSource protocol.

    Wraps a ``TrieForest``: the default namespace ``""`` is the old global
    trie (bit-identical behavior — same inserts, same windows, same
    retire-time prune trigger), additional namespaces isolate co-resident
    scenarios while sharing the one node-capacity budget.
    """

    name = "trie"

    def __init__(self, config: LookaheadConfig,
                 trie: Optional[TrieTree] = None):
        super().__init__(config)
        self.forest = TrieForest(capacity=config.trie_capacity,
                                 prompt_boost=config.prompt_boost,
                                 decay=config.decay, root=trie)
        self._upto: Dict[int, int] = {}   # rid -> output tokens streamed in

    @property
    def trie(self) -> TrieTree:
        """Default-namespace trie (compat: warmup, stats, tests)."""
        return self.forest.tree("")

    def observe_prompt(self, rid, prompt, namespace=""):
        if self.config.insert_prompt:
            self.forest.tree(namespace).insert_ngrams(
                prompt, self.config.branch_length, request_id=rid)
            self.forest.check_capacity()

    def observe_output(self, rid, output, namespace=""):
        """Generated-branch streaming (paper Algorithm 1 lines 5-9): insert
        the window since the last high-water mark, overlapped by one branch
        length so n-grams straddling the previous boundary exist too."""
        if not self.config.insert_output:
            return
        lo = max(self._upto.get(rid, 0) - self.config.branch_length, 0)
        if len(output) - lo >= 2:
            self.forest.tree(namespace).insert_ngrams(
                output[lo:], self.config.branch_length)
            self._upto[rid] = len(output)
            self.forest.check_capacity()

    def retire(self, rid, namespace=""):
        """Branch Eliminating within the request's own namespace, then the
        shared capacity-triggered prune (identical cadence to the old
        ``trie_retire`` when one namespace exists)."""
        self._upto.pop(rid, None)
        if self.config.eliminate:
            t = self.forest.get(namespace)
            if t is not None:
                t.eliminate(rid)
        if self.config.prune and len(self.forest) > self.forest.capacity:
            self.forest.prune_all()

    def retrieve(self, rid, context, *, budget, namespace=""):
        t = self.forest.get(namespace)
        if t is None:
            return [], []
        return t.retrieve(context, decoding_length=budget,
                          max_prefix_len=self.config.max_prefix_len,
                          min_matched_tokens=self.config.min_matched_tokens)

    # ---- warm-state persistence
    def state_dict(self):
        return {"kind": self.name, "forest": self.forest.state_dict()}

    def _forest_state(self, state) -> Dict[str, object]:
        if not isinstance(state, dict) or state.get("kind") != self.name:
            raise ValueError(f"not a {self.name!r} source state: "
                             f"{type(state).__name__}")
        forest = state.get("forest")
        if not isinstance(forest, dict):
            raise ValueError("trie source state missing 'forest'")
        return forest

    def load_state_dict(self, state):
        self.forest.load_state_dict(self._forest_state(state))

    def merge_state(self, state):
        self.forest.merge_state(self._forest_state(state))


# ------------------------------------------------------------ PromptCopySource
class PromptCopySource(DraftSource):
    """LLMA-style longest-suffix copy from the request's own prompt/context.

    RAG and summarization responses quote their reference documents — which
    already sit in the request's context.  Retrieval matches the longest
    suffix of the context (down to ``copy_min_match`` tokens) against every
    EARLIER occurrence in that same context and proposes each occurrence's
    continuation as a branch, most recent sites first.

    Entirely per-request: nothing is inserted into any shared structure, so
    a prompt-copy tenant can never pollute the trie of its co-residents.
    The context passed to ``retrieve`` is prompt ⧺ output, so no observe
    state is needed at all — the request carries its own reference.
    """

    name = "prompt_copy"

    def retrieve(self, rid, context, *, budget, namespace=""):
        cfg = self.config
        ctx = [int(t) for t in context]
        n = len(ctx)
        min_match = max(cfg.copy_min_match, 1)
        if n < min_match + 1:
            return [], []
        branch_len = min(cfg.branch_length, budget)
        if branch_len < 1:
            return [], []
        # ONE pass over the context: find every site where the min-match
        # suffix ends (j == n is the suffix itself — search strictly
        # earlier), then extend each match backward up to max_prefix_len.
        # This runs per lane per decode step; the per-length rescans of the
        # naive multi-stage search are O(max_prefix_len) passes too many.
        max_match = min(cfg.max_prefix_len, n - 1)
        last = ctx[n - 1]
        sites: List[Tuple[int, int]] = []      # (match_len, end position)
        for j in range(n - 1, min_match - 1, -1):
            if ctx[j - 1] != last:             # cheap reject before slicing
                continue
            if ctx[j - min_match:j] != ctx[n - min_match:]:
                continue
            length = min_match
            while (length < max_match and j - length - 1 >= 0
                   and ctx[j - length - 1] == ctx[n - length - 1]):
                length += 1
            sites.append((length, j))
        if not sites:
            return [], []
        # longest match first (most context agreement), then most recent
        sites.sort(key=lambda s: (-s[0], -s[1]))
        branches, scores = [], []
        for rank, (length, j) in enumerate(sites[:cfg.copy_max_branches]):
            cont = ctx[j:j + branch_len]
            if cont:
                branches.append(cont)
                # small recency tie-break keeps ordering deterministic
                scores.append(float(length) - 1e-3 * rank)
        return (branches, scores) if branches else ([], [])


# ----------------------------------------------------------------- NgramSource
class NgramSource(DraftSource):
    """ANPD-style adaptive n-gram fallback (shared across requests).

    Maintains backoff count tables of order 1..k-1 over every observed
    prompt/output token and drafts one greedy highest-count chain.  Where
    the trie needs an exact suffix hit and prompt-copy needs a literal
    earlier occurrence, the n-gram model generalizes across requests — a
    low-precision, always-available source meant to ride along under a
    small quota.  The count table is capped (``ngram_max_entries``) with
    halving decay, mirroring the trie's node pruning.
    """

    name = "ngram"

    def __init__(self, config: LookaheadConfig):
        super().__init__(config)
        self.order = max(int(config.ngram_order), 2)
        self._counts: Dict[Tuple[int, ...], Dict[int, float]] = {}
        self._upto: Dict[int, int] = {}

    def _decay(self) -> None:
        for key in list(self._counts):
            d = self._counts[key]
            for t in list(d):
                d[t] *= 0.5
                if d[t] < 1.0:
                    del d[t]
            if not d:
                del self._counts[key]

    def _absorb(self, tokens: Sequence[int], start: int = 1) -> None:
        """Count every n-gram ENDING at index >= ``start`` (conditioning
        contexts may reach before it — that is why callers pass an
        overlapped window — but each ending position is counted once)."""
        toks = [int(t) for t in tokens]
        k = self.order
        for i in range(max(int(start), 1), len(toks)):
            for o in range(1, k):
                if i - o < 0:
                    break
                key = tuple(toks[i - o:i])
                d = self._counts.get(key)
                if d is None:
                    if len(self._counts) >= self.config.ngram_max_entries:
                        self._decay()
                    d = self._counts.setdefault(key, {})
                d[toks[i]] = d.get(toks[i], 0.0) + 1.0

    def observe_prompt(self, rid, prompt, namespace=""):
        self._absorb(prompt)

    def observe_output(self, rid, output, namespace=""):
        # window back by ``order`` so grams straddling the previous boundary
        # get their full conditioning context, but count only NEW endings
        # (>= the high-water mark — unlike the trie's frequency semantics,
        # a count table must not double-count the overlap)
        upto = self._upto.get(rid, 0)
        if len(output) <= max(upto, 1):
            return
        lo = max(upto - self.order, 0)
        self._absorb(output[lo:], start=upto - lo)
        self._upto[rid] = len(output)

    def retire(self, rid, namespace=""):
        self._upto.pop(rid, None)   # the model itself persists (adaptivity)

    def _predict(self, ctx: List[int]) -> Optional[int]:
        for o in range(self.order - 1, 0, -1):
            if len(ctx) < o:
                continue
            d = self._counts.get(tuple(ctx[-o:]))
            if d:
                # deterministic: highest count, lowest token id on ties
                return max(d.items(), key=lambda kv: (kv[1], -kv[0]))[0]
        return None

    def retrieve(self, rid, context, *, budget, namespace=""):
        cur = [int(t) for t in context]
        chain: List[int] = []
        for _ in range(min(self.config.branch_length, budget)):
            nxt = self._predict(cur)
            if nxt is None:
                break
            chain.append(nxt)
            cur.append(nxt)
        if not chain:
            return [], []
        return [chain], [1.0]

    # ---- warm-state persistence
    def state_dict(self):
        # tuple keys -> nested lists (JSON-portable); insertion order kept
        return {"kind": self.name, "order": self.order,
                "entries": [[list(key), [[int(t), float(c)]
                                         for t, c in d.items()]]
                            for key, d in self._counts.items()]}

    @staticmethod
    def _state_entries(state) -> List[list]:
        if not isinstance(state, dict) or state.get("kind") != "ngram":
            raise ValueError(f"not an ngram source state: "
                             f"{type(state).__name__}")
        entries = state.get("entries")
        if not isinstance(entries, list):
            raise ValueError("ngram source state missing 'entries'")
        return entries

    def load_state_dict(self, state):
        entries = self._state_entries(state)
        counts: Dict[Tuple[int, ...], Dict[int, float]] = {}
        for key, pairs in entries:
            counts[tuple(int(t) for t in key)] = {
                int(t): float(c) for t, c in pairs}
        self._counts = counts

    def merge_state(self, state):
        """Count-max merge (the same CRDT-join semantics as the trie, so
        repeated gossip echoes never inflate counts); halving decay
        restores the entry cap (the same pressure valve ``_absorb``
        applies to organic growth)."""
        entries = self._state_entries(state)
        for key, pairs in entries:
            d = self._counts.setdefault(tuple(int(t) for t in key), {})
            for t, c in pairs:
                d[int(t)] = max(d.get(int(t), 0.0), float(c))
        while len(self._counts) > self.config.ngram_max_entries:
            before = len(self._counts)
            self._decay()
            if len(self._counts) >= before:
                break


# ------------------------------------------------------------------- registry
_REGISTRY: Dict[str, Callable[..., DraftSource]] = {}


def register_source(name: str, factory: Callable[..., DraftSource]) -> None:
    """Register a source factory ``factory(config) -> DraftSource`` under
    ``name`` (last wins, like the attention-backend registry)."""
    _REGISTRY[name] = factory


def make_source(name: str, config: LookaheadConfig, **kwargs) -> DraftSource:
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown draft source {name!r}; registered: "
            f"{', '.join(sorted(_REGISTRY))}") from None
    return factory(config, **kwargs)


def available_sources() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


register_source("trie", TrieSource)
register_source("prompt_copy", PromptCopySource)
register_source("ngram", NgramSource)


# --------------------------------------------------------------------- merger
def _known_prefix_len(path: Tuple[int, ...], prefixes: set) -> int:
    """Longest leading prefix of ``path`` already merged (prefix membership
    is monotone — every merged branch registered ALL its prefixes)."""
    d = len(path)
    while d > 0 and path[:d] not in prefixes:
        d -= 1
    return d


def merge_branches(per_source: Sequence[Tuple[str, List[List[int]],
                                              List[float]]],
                   budget: int, quotas: Sequence[int]
                   ) -> Tuple[List[List[int]], List[float], List[str]]:
    """Interleave branches from several sources into one candidate list.

    Round-robin over sources in policy order: each turn a source contributes
    its next branch that still adds NEW tokens (dedup against every
    already-merged prefix — a trie branch that prompt-copy already proposed
    costs nothing and is skipped).  A branch's cost is its new-token count;
    it is charged against the source's ``quota`` and the shared ``budget``,
    and truncated to whatever still fits.  Returns (branches, scores,
    source_tags) ready for the tree builders.
    """
    S = len(per_source)
    prefixes: set = set()
    out_b: List[List[int]] = []
    out_s: List[float] = []
    out_t: List[str] = []
    ptr = [0] * S
    used = [0] * S
    total = 0
    progressed = True
    while total < budget and progressed:
        progressed = False
        for si in range(S):
            if total >= budget:
                break
            name, branches, scores = per_source[si]
            while ptr[si] < len(branches):
                path = tuple(int(t) for t in branches[ptr[si]])
                score = (float(scores[ptr[si]])
                         if ptr[si] < len(scores) else 0.0)
                ptr[si] += 1
                known = _known_prefix_len(path, prefixes)
                cost = len(path) - known
                if cost == 0:
                    continue            # fully covered already — dedup skip
                allow = min(quotas[si] - used[si], budget - total)
                if allow <= 0:
                    ptr[si] = len(branches)     # quota spent: source done
                    break
                if cost > allow:
                    path = path[:known + allow]
                    cost = allow
                for d in range(known + 1, len(path) + 1):
                    prefixes.add(path[:d])
                out_b.append(list(path))
                out_s.append(score)
                out_t.append(name)
                used[si] += cost
                total += cost
                progressed = True
                break                   # one contribution per turn
    return out_b, out_s, out_t


# ----------------------------------------------------------- adaptive budget
class AdaptiveBudget:
    """Per-lane draft-budget controller (paper §5.2 warmup/CDL behavior).

    The compiled step width T never changes — the controller only bounds how
    many draft tokens the HOST builds into the tree; the remaining slots
    ride as padding (never verified).  Shrinking therefore never retraces
    (I2) and never changes outputs (I1: verification is lossless for any
    draft) — it trades draft-build/verify work and acceptance odds.

    Warmup: start at ``min_budget`` (a cold trie earns nothing from a wide
    tree).  After each step the accepted-length EMA scales the budget by
    ``headroom`` — accept runs near the budget push it up toward
    ``max_budget``; dry steps decay it back toward the floor.
    """

    def __init__(self, max_budget: int, *, min_budget: int = 4,
                 alpha: float = 0.3, headroom: float = 1.5):
        self.max_budget = max(int(max_budget), 1)
        self.min_budget = min(max(int(min_budget), 1), self.max_budget)
        self.alpha = float(alpha)
        self.headroom = float(headroom)
        self.ema: Optional[float] = None
        self.value = self.min_budget
        # autotune quota ceiling (see ``cap``); None = unconstrained
        self.quota_cap: Optional[int] = None

    @classmethod
    def from_policy(cls, policy: DraftPolicy,
                    max_budget: int) -> "AdaptiveBudget":
        return cls(max_budget, min_budget=policy.min_budget,
                   alpha=policy.ema_alpha, headroom=policy.headroom)

    def update(self, accepted_len: int) -> int:
        a = float(accepted_len)
        self.ema = a if self.ema is None else (
            (1.0 - self.alpha) * self.ema + self.alpha * a)
        want = int(math.ceil(self.ema * self.headroom))
        self.value = min(max(want, self.min_budget), self.max_budget)
        if self.quota_cap is not None:
            self.value = min(self.value, self.quota_cap)
        return self.value

    def cap(self, quota_total: int) -> int:
        """Clamp the lane's width to the autotune bandit's kept-quota total.

        A namespace whose sources are mostly gated off cannot fill a wide
        tree — the kept sources' quotas bound the useful slot count, so the
        lane shrinks instead of padding dead slots.  The ceiling overrides
        ``min_budget`` (a probe-only lane should draft exactly the probe
        quota) and is refreshed every gated build, so a recovering source
        lifts it again.  Host-side only: outputs stay bit-identical (I1).
        """
        self.quota_cap = max(int(quota_total), 1)
        self.value = min(self.value, self.quota_cap)
        return self.value


# ----------------------------------------------------------------- tree build
def build_draft_from_policy(sources: Sequence[DraftSource],
                            policy: DraftPolicy, cfg: LookaheadConfig,
                            rid: int, context: Sequence[int], pad_id: int,
                            width: int,
                            budget: Optional[int] = None,
                            quotas: Optional[Sequence[int]] = None
                            ) -> DraftTree:
    """Retrieve from every policy source, merge, and build one ``DraftTree``
    padded to exactly ``width`` slots.

    The single-source path feeds retrieval straight into the strategy
    builder — for the default policy (TrieSource alone, full budget) the
    produced tree is identical, slot for slot, to the old hardwired
    ``build_draft_tree``.

    ``quotas`` overrides the policy's per-source caps (parallel to
    ``sources``) — the autotune controller passes the gated subset of a
    policy's sources with its own quota decisions (core/autotune.py).
    """
    root = int(context[-1])
    eff = cfg.decoding_length if budget is None else int(budget)
    eff = min(eff, max(width - 1, 0))
    if cfg.strategy == "none" or eff <= 0 or width <= 1:
        return _finalize([root], [-1], max(width, 1), pad_id)
    ns = policy.namespace
    if len(sources) == 1:
        src = sources[0]
        # a single-source quota still caps the tree (same semantics as the
        # merge path, where the quota bounds the source's new-token spend)
        eff = min(eff, policy.quota(0, eff) if quotas is None
                  else min(int(quotas[0]), eff))
        branches, scores = src.retrieve(rid, context, budget=eff,
                                        namespace=ns)
        tags: List[str] = [src.name] * len(branches)
    else:
        per = [(s.name,) + tuple(s.retrieve(rid, context, budget=eff,
                                            namespace=ns))
               for s in sources]
        caps = ([policy.quota(i, eff) for i in range(len(sources))]
                if quotas is None
                else [min(int(q), eff) for q in quotas])
        branches, scores, tags = merge_branches(per, eff, caps)
    tree = BUILDERS[cfg.strategy](root, branches, scores, eff, pad_id,
                                  sources=tags)
    return repad(tree, width, pad_id)


__all__ = ["DraftPolicy", "DraftSource", "TrieSource", "PromptCopySource",
           "NgramSource", "register_source", "make_source",
           "available_sources", "merge_branches", "AdaptiveBudget",
           "build_draft_from_policy"]
