"""Lookahead core: trie-based lossless multi-branch speculative decoding."""
from .draft import BUILDERS, DraftTree, build_hierarchical, build_parallel, build_single
from .engine import GenStats, LookaheadEngine, RequestResult, StepFns, reference_decode
from .single_branch import baseline_config, llma_config
from .strategies import LookaheadConfig
from .trie import TrieTree
from .verify import verify_accept, verify_accept_batch

__all__ = [
    "BUILDERS", "DraftTree", "build_hierarchical", "build_parallel",
    "build_single", "GenStats", "LookaheadEngine", "RequestResult", "StepFns",
    "reference_decode", "baseline_config", "llma_config", "LookaheadConfig",
    "TrieTree", "verify_accept", "verify_accept_batch",
]
