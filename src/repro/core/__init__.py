"""Lookahead core: trie-based lossless multi-branch speculative decoding."""
from .autotune import AutoTuneConfig, AutoTuner, NamespaceController
from .draft import (BUILDERS, DraftTree, build_hierarchical, build_parallel,
                    build_single, repad)
from .draft_sources import (AdaptiveBudget, DraftPolicy, DraftSource,
                            NgramSource, PromptCopySource, TrieSource,
                            available_sources, build_draft_from_policy,
                            make_source, merge_branches, register_source)
from .engine import LookaheadEngine, reference_decode
from .request import (GenStats, Request, RequestResult, RequestState,
                      SamplingParams, StepFns, build_draft_tree,
                      cache_token_limit, idle_tree, trie_admit, trie_retire,
                      trie_stream)
from .single_branch import baseline_config, llma_config
from .strategies import LookaheadConfig
from .trie import TrieForest, TrieTree
from .verify import verify_accept, verify_accept_batch

__all__ = [
    "BUILDERS", "DraftTree", "build_hierarchical", "build_parallel",
    "build_single", "repad", "GenStats", "LookaheadEngine", "Request",
    "RequestResult", "RequestState", "SamplingParams", "StepFns",
    "build_draft_tree", "cache_token_limit", "idle_tree", "trie_admit",
    "trie_retire", "trie_stream", "reference_decode", "baseline_config",
    "llma_config", "LookaheadConfig", "TrieTree", "TrieForest",
    "verify_accept", "verify_accept_batch",
    "AdaptiveBudget", "DraftPolicy", "DraftSource", "NgramSource",
    "PromptCopySource", "TrieSource", "available_sources",
    "build_draft_from_policy", "make_source", "merge_branches",
    "register_source",
    "AutoTuneConfig", "AutoTuner", "NamespaceController",
]
