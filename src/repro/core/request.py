"""Per-request serving primitives shared by the lock-step loop and the
continuous-batching scheduler.

The lookahead step decomposes into host-side pieces that are *per request*
(draft build, verify/accept bookkeeping, trie updates) and device pieces
that are *per batch* (``StepFns``).  ``RequestState`` owns the former so a
request can live in any slot of any serving loop: the lock-step
``LookaheadEngine.generate_batch_lockstep`` and the slot-based
``repro.serving.scheduler.ContinuousScheduler`` drive the exact same state
transitions, which is what makes per-request losslessness independent of
batch composition (see DESIGN.md §Scheduler).

Lifecycle::

    submitted --admit--> prefilled (start) --accept*--> done (retire)

``start`` consumes the prefill's chosen root token; every subsequent
``accept`` consumes the verified tokens of one tree step and returns the KV
slot indices to commit (truncated at the request's budget / EOS).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import numpy as np

from .draft import BUILDERS, DraftTree, _finalize, repad
from .strategies import LookaheadConfig
from .trie import TrieTree


@dataclass
class StepFns:
    """Device functions the serving loops drive (all jit-compiled, fixed
    shapes — one compile per engine; see DESIGN.md §Compile-once shapes).

    prefill(tokens(B,S) i32, lens(B,) i32) -> (cache, chosen_root(B,) i32)
    tree_step(cache, cache_lens(B,), tokens(B,T), pos(B,T), mask(B,T,T))
        -> (cache, chosen(B,T) i32)
    commit(cache, cache_lens(B,), gather_idx(B,T), n_accept(B,))
        -> (cache, new_lens(B,))

    Slot-serving extensions (optional; required by ContinuousScheduler):

    init_cache(lanes) -> cache                      — allocate a B-lane cache
    prefill_into_slot(cache, lane, tokens(1,S), lens(1,))
        -> (cache, chosen_root(1,))                 — admit one request
    reset_slot(cache, lane) -> cache                — zero a freed lane
    prefill_len: fixed prompt pad length (compile prefill once); None keeps
        the legacy pad-to-batch-max behaviour.

    Paged-KV extensions (kv_layout == "paged"; DESIGN.md §Paged KV cache):
    the cache dict additionally carries per-lane ``block_tables`` the
    scheduler maintains through a host-side BlockAllocator; ``prefill``
    takes them as a third argument (the cache does not exist yet at cohort
    admission), and lane-keyed ``reset_slot`` is replaced by the
    block-keyed ``reset_blocks(cache, block_ids) -> cache`` (scrubbing by
    lane after a table was reused would destroy the next request's KV).
    """
    prefill: Callable
    tree_step: Callable
    commit: Callable
    slots: int            # T = 1 + decoding_length
    max_seq_len: int
    pad_id: int = 0
    init_cache: Optional[Callable] = None
    prefill_into_slot: Optional[Callable] = None
    reset_slot: Optional[Callable] = None
    prefill_len: Optional[int] = None
    kv_layout: str = "dense"
    block_size: int = 0               # paged: KV rows per block
    n_blocks: Optional[int] = None    # paged: pool size (None = dense-equiv)
    reset_blocks: Optional[Callable] = None

    @property
    def supports_slot_serving(self) -> bool:
        return (self.prefill_into_slot is not None
                and self.init_cache is not None)

    @property
    def blocks_per_lane(self) -> int:
        """Block-table width for the paged layout (0 when dense)."""
        if self.kv_layout != "paged" or not self.block_size:
            return 0
        return -(-self.max_seq_len // self.block_size)


@dataclass
class GenStats:
    steps: int = 0
    tokens: int = 0
    dropped_slots: int = 0    # draft tokens computed but rejected

    @property
    def edl(self) -> float:
        """Mean accepted tokens per step (paper: effective decoding length)."""
        return self.tokens / max(self.steps, 1)


@dataclass
class RequestResult:
    tokens: List[int]
    stats: GenStats
    rid: int = -1
    latency_s: float = 0.0    # submit -> finish (scheduler runs only)
    ttft_s: float = 0.0       # submit -> first token (scheduler runs only)
    queue_s: float = 0.0      # submit -> admission (scheduler runs only)


@dataclass
class RequestState:
    """Host-side state of one in-flight request (slot-agnostic)."""
    rid: int
    prompt: List[int]
    max_new_tokens: int
    eos_id: int = -1
    output: List[int] = field(default_factory=list)
    context: List[int] = field(default_factory=list)   # prompt ⧺ output
    stats: GenStats = field(default_factory=GenStats)
    done: bool = False
    inserted_upto: int = 0    # output tokens already streamed into the trie
    lane: int = -1            # scheduler slot currently occupied (-1 = none)
    submit_t: float = 0.0
    admit_t: float = 0.0
    first_token_t: float = 0.0
    finish_t: float = 0.0

    def start(self, first_token: int) -> None:
        """Consume the prefill's chosen root (the first output token)."""
        first_token = int(first_token)
        self.output = [first_token]
        self.context = list(self.prompt) + [first_token]
        self.stats.steps += 1
        self.stats.tokens += 1
        if first_token == self.eos_id or self.max_new_tokens <= 1:
            self.done = True

    def accept(self, accepted: Sequence[int], kv_slots: Sequence[int],
               n_tree_slots: int) -> List[int]:
        """Absorb one verified step; returns the KV slots to commit.

        Truncates at the remaining token budget, then at EOS, exactly like
        step-by-step decoding would — the committed prefix therefore never
        depends on how many draft tokens happened to verify.
        """
        budget = self.max_new_tokens - len(self.output)
        acc = list(accepted[:budget])
        if self.eos_id in acc:
            acc = acc[:acc.index(self.eos_id) + 1]
        ks = list(kv_slots[:len(acc)])
        self.output.extend(acc)
        self.context.extend(acc)
        self.stats.steps += 1
        self.stats.tokens += len(acc)
        self.stats.dropped_slots += n_tree_slots - len(ks)
        if acc and acc[-1] == self.eos_id:
            self.done = True
        if len(self.output) >= self.max_new_tokens:
            self.done = True
        return ks

    def result(self) -> RequestResult:
        return RequestResult(
            tokens=self.output, stats=self.stats, rid=self.rid,
            latency_s=max(self.finish_t - self.submit_t, 0.0),
            ttft_s=max(self.first_token_t - self.submit_t, 0.0),
            queue_s=max(self.admit_t - self.submit_t, 0.0))


# ------------------------------------------------------------------- drafting
def build_draft_tree(trie: TrieTree, cfg: LookaheadConfig,
                     context: Sequence[int], pad_id: int,
                     width: int) -> DraftTree:
    """Retrieve + build a draft tree padded to exactly ``width`` slots."""
    root = int(context[-1])
    if cfg.strategy == "none" or cfg.decoding_length == 0 or width <= 1:
        return _finalize([root], [-1], max(width, 1), pad_id)
    branches, scores = trie.retrieve(
        context, decoding_length=cfg.decoding_length,
        max_prefix_len=cfg.max_prefix_len,
        min_matched_tokens=cfg.min_matched_tokens)
    tree = BUILDERS[cfg.strategy](root, branches, scores,
                                  cfg.decoding_length, pad_id)
    return repad(tree, width, pad_id)


@functools.lru_cache(maxsize=16)
def idle_tree(width: int, pad_id: int) -> DraftTree:
    """Placeholder tree for an empty slot (masked out: n_accept == 0)."""
    return _finalize([pad_id], [-1], max(width, 1), pad_id)


# ------------------------------------------------------------ trie bookkeeping
def trie_admit(trie: TrieTree, cfg: LookaheadConfig, rid: int,
               prompt: Sequence[int]) -> None:
    """Prompt-branch inserting at admission (per request id, eliminable)."""
    if cfg.insert_prompt:
        trie.insert_ngrams(prompt, cfg.branch_length, request_id=rid)


def trie_stream(trie: TrieTree, cfg: LookaheadConfig,
                state: RequestState) -> None:
    """Generated-branch inserting on-the-fly (paper Algorithm 1 lines 5-9)."""
    if not cfg.insert_output:
        return
    out = state.output
    lo = max(state.inserted_upto - cfg.branch_length, 0)
    if len(out) - lo >= 2:
        trie.insert_ngrams(out[lo:], cfg.branch_length)
        state.inserted_upto = len(out)


def trie_retire(trie: TrieTree, cfg: LookaheadConfig, rid: int, *,
                prune: bool = True) -> None:
    """Branch eliminating for a finished request (+ capacity pruning)."""
    if cfg.eliminate:
        trie.eliminate(rid)
    if prune and cfg.prune and len(trie) > trie.capacity:
        trie.prune()


__all__ = ["StepFns", "GenStats", "RequestResult", "RequestState",
           "build_draft_tree", "idle_tree", "trie_admit", "trie_stream",
           "trie_retire"]
