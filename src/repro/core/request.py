"""Per-request serving primitives shared by the lock-step loop and the
continuous-batching scheduler.

The lookahead step decomposes into host-side pieces that are *per request*
(draft build, verify/accept bookkeeping, trie updates) and device pieces
that are *per batch* (``StepFns``).  ``RequestState`` owns the former so a
request can live in any slot of any serving loop: the lock-step
``LookaheadEngine.generate_batch_lockstep`` and the slot-based
``repro.serving.scheduler.ContinuousScheduler`` drive the exact same state
transitions, which is what makes per-request losslessness independent of
batch composition (see DESIGN.md §Scheduler).

Lifecycle::

    submitted --admit--> prefilled (start) --accept*--> done (retire)

``start`` consumes the prefill's chosen root token; every subsequent
``accept`` consumes the verified tokens of one tree step and returns the KV
slot indices to commit (truncated at the request's budget / EOS).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .draft import BUILDERS, DraftTree, _finalize, repad
from .draft_sources import AdaptiveBudget, DraftPolicy
from .strategies import LookaheadConfig
from .trie import TrieTree


# ----------------------------------------------------------- request surface
@dataclass(frozen=True)
class SamplingParams:
    """Per-request generation parameters (the request-centric API surface).

    One co-batched scheduler run may mix greedy and sampled requests at
    distinct temperatures/seeds: the device step takes per-lane
    (greedy, temperature, seed) vectors as traced inputs, so honoring these
    never retraces (I2).  Sampled streams are position-keyed off ``seed``
    (Gumbel key = fold_in(key(seed), absolute position)), which keeps
    losslessness (I1): the token at output position p is a pure function of
    (seed, p, logits), independent of batching or accept granularity.

    ``stop_token_ids`` behave like extra EOS ids (the stop token is kept in
    the output).  ``stop_sequences`` are token-id subsequences matched
    against the *generated output* host-side, token by token, AFTER each
    multi-token accept — a tree step may verify past the match, but the
    output is truncated to exactly what step-by-step decoding through the
    same params would have emitted (the matched sequence is kept).
    """
    max_new_tokens: int = 64
    sample: bool = False
    temperature: float = 1.0
    seed: int = 0
    stop_token_ids: Tuple[int, ...] = ()
    stop_sequences: Tuple[Tuple[int, ...], ...] = ()
    # speculation spec: which draft sources feed this request's trees, their
    # quotas, the trie namespace, adaptive budget on/off.  None = the
    # engine's default policy.  Drafts never change outputs (verification is
    # lossless), so this knob is pure performance/isolation — it is safe to
    # vary per request inside one lane pool.
    draft: Optional[DraftPolicy] = None

    def __post_init__(self):
        # normalize list inputs so params hash/compare by value
        object.__setattr__(self, "stop_token_ids",
                           tuple(int(t) for t in self.stop_token_ids))
        object.__setattr__(self, "stop_sequences",
                           tuple(tuple(int(t) for t in s)
                                 for s in self.stop_sequences))

    def validate(self) -> "SamplingParams":
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens={self.max_new_tokens}: must be >= 1 (the "
                "prefill itself emits the first token)")
        if self.sample and self.temperature <= 0:
            raise ValueError(
                f"temperature={self.temperature}: sampled requests need a "
                "positive temperature (use sample=False for greedy)")
        for s in self.stop_sequences:
            if not s:
                raise ValueError("empty stop sequence (would match "
                                 "everywhere); drop it or pass tokens")
        if self.draft is not None:
            self.draft.validate()
        return self


@dataclass
class Request:
    """A serving request: prompt + params + caller metadata.

    ``params=None`` means "the engine's session defaults" — resolved at
    submit time, so the same Request object is portable across engines.
    ``rid`` is assigned by the scheduler at submit; ``metadata`` is carried
    through untouched (SLO tags, trace ids, ...).
    """
    prompt: List[int]
    params: Optional[SamplingParams] = None
    metadata: Dict[str, Any] = field(default_factory=dict)
    rid: int = -1


@dataclass
class StepFns:
    """Device functions the serving loops drive (all jit-compiled, fixed
    shapes — one compile per engine; see DESIGN.md §Compile-once shapes).

    prefill(tokens(B,S) i32, lens(B,) i32) -> (cache, chosen_root(B,) i32)
    tree_step(cache, cache_lens(B,), tokens(B,T), pos(B,T), mask(B,T,T))
        -> (cache, chosen(B,T) i32)
    commit(cache, cache_lens(B,), gather_idx(B,T), n_accept(B,))
        -> (cache, new_lens(B,))
    fused_step(cache, cache_lens(B,), tokens(B,T), pos(B,T), mask(B,T,T),
               parent(B,T), n_live(B,)) -> (cache, packed(B, 1+2T) i32)
        — optional single-dispatch decode step: tree forward + token choice
        + device accept walk + commit, returning one packed array
        ``[n_acc | acc_tokens(T) | kv_slots(T)]`` per lane instead of
        logits/chosen crossing the host boundary (DESIGN.md §Step
        pipeline).  ``n_live`` is the lane's live draft-slot count
        (0 = idle placeholder lane, accepts nothing).  The scheduler
        prefers it when present; ``tree_step``/``commit`` stay as the
        unfused parity oracle and the lock-step loop's surface.

    Slot-serving extensions (optional; required by ContinuousScheduler):

    init_cache(lanes) -> cache                      — allocate a B-lane cache
    prefill_into_slot(cache, lane, tokens(1,S), lens(1,))
        -> (cache, chosen_root(1,))                 — admit one request
    reset_slot(cache, lane) -> cache                — zero a freed lane
    prefill_len: fixed prompt pad length (compile prefill once); None keeps
        the legacy pad-to-batch-max behaviour.

    Paged-KV extensions (kv_layout == "paged"; DESIGN.md §Paged KV cache):
    the cache dict additionally carries per-lane ``block_tables`` the
    scheduler maintains through a host-side BlockAllocator; ``prefill``
    takes them as a third argument (the cache does not exist yet at cohort
    admission), and lane-keyed ``reset_slot`` is replaced by the
    block-keyed ``reset_blocks(cache, block_ids) -> cache`` (scrubbing by
    lane after a table was reused would destroy the next request's KV).
    """
    prefill: Callable
    tree_step: Callable
    commit: Callable
    slots: int            # T = 1 + decoding_length
    max_seq_len: int
    pad_id: int = 0
    fused_step: Optional[Callable] = None
    init_cache: Optional[Callable] = None
    prefill_into_slot: Optional[Callable] = None
    reset_slot: Optional[Callable] = None
    prefill_len: Optional[int] = None
    kv_layout: str = "dense"
    block_size: int = 0               # paged: KV rows per block
    n_blocks: Optional[int] = None    # paged: pool size (None = dense-equiv)
    reset_blocks: Optional[Callable] = None
    # Prefix-cache extensions (paged only; DESIGN.md §Prefix cache):
    # prefill_suffix(cache, lane, tokens(1,n), offset) -> (cache, chosen(1,))
    #     — prefill only the uncached prompt tail, attending the shared
    #     prefix blocks already wired into the lane's block table; the
    #     wrapper pads n up to a fixed suffix bucket (compile-once).
    # copy_block(cache, src, dst) -> cache — COW fork of a boundary block.
    prefill_suffix: Optional[Callable] = None
    copy_block: Optional[Callable] = None
    suffix_buckets: Tuple[int, ...] = ()
    # --- request-centric API extensions
    # per_lane_params: prefill/prefill_into_slot/tree_step accept a trailing
    # ``lane_params`` dict of (B,) device vectors {greedy, temp, seed} so one
    # co-batched step honors mixed per-request SamplingParams without
    # retracing.  False = legacy session-level constants only; the scheduler
    # then rejects requests whose params deviate from ``session_defaults``.
    per_lane_params: bool = False
    # session-level defaults applied to requests submitted without params
    # (max_new_tokens is a per-call override; see scheduler.submit)
    session_defaults: Optional["SamplingParams"] = None
    # "mixed" = per-request greedy/sample honored; "greedy" = argmax-only
    # session (skips the sampling lane entirely — fastest pure-greedy path)
    sampling: str = "mixed"

    @property
    def default_params(self) -> "SamplingParams":
        return self.session_defaults or SamplingParams()

    @property
    def supports_slot_serving(self) -> bool:
        return (self.prefill_into_slot is not None
                and self.init_cache is not None)

    @property
    def blocks_per_lane(self) -> int:
        """Block-table width for the paged layout (0 when dense)."""
        if self.kv_layout != "paged" or not self.block_size:
            return 0
        return -(-self.max_seq_len // self.block_size)


@dataclass
class GenStats:
    steps: int = 0
    tokens: int = 0
    dropped_slots: int = 0    # draft tokens computed but rejected
    # per-draft-source speculation telemetry (paper Table 3-style reporting
    # + the adaptive controller's input): how many draft tokens each source
    # placed into trees, and how many of those the model verified.  The one
    # free token per step (the model's own root prediction) belongs to no
    # source, so sum(source_accepted) == tokens - steps when every slot is
    # tagged.
    source_drafted: Dict[str, int] = field(default_factory=dict)
    source_accepted: Dict[str, int] = field(default_factory=dict)
    # per-step latency breakdown (scheduler runs only): each decode step's
    # measured wall-clock split accrues onto EVERY request riding that step
    # — exact per-step sums, not batch-level means, so co-resident requests
    # of different lengths report their own step mix.  host_syncs counts
    # device->host pulls attributed to it (fused path: exactly one per
    # decode step it participated in).
    host_draft_ms: float = 0.0     # draft build + tree packing per step
    device_step_ms: float = 0.0    # dispatch -> packed result on host
    accept_commit_ms: float = 0.0  # accept bookkeeping + retire + tables
    hidden_host_ms: float = 0.0    # deferred retirement drained behind the
    #                                step's device flight window (overlap)
    host_syncs: int = 0
    # prompt tokens served from the prefix cache (prefill compute skipped)
    cached_prompt_tokens: int = 0

    @property
    def edl(self) -> float:
        """Mean accepted tokens per step (paper: effective decoding length)."""
        return self.tokens / max(self.steps, 1)

    def source_acceptance(self) -> Dict[str, float]:
        """Accepted / drafted rate per source (0.0 when nothing drafted)."""
        return {name: self.source_accepted.get(name, 0) / max(n, 1)
                for name, n in self.source_drafted.items()}


@dataclass
class RequestResult:
    tokens: List[int]
    stats: GenStats
    rid: int = -1
    latency_s: float = 0.0    # submit -> finish (scheduler runs only)
    ttft_s: float = 0.0       # submit -> first token (scheduler runs only)
    queue_s: float = 0.0      # submit -> admission (scheduler runs only)
    # why generation ended: "eos" | "stop" (stop token/sequence) | "length"
    # (max_new_tokens) | "cache" (KV capacity) | "cancelled"
    finish_reason: str = ""
    cancelled: bool = False


@dataclass
class RequestState:
    """Host-side state of one in-flight request (slot-agnostic)."""
    rid: int
    prompt: List[int]
    max_new_tokens: int
    eos_id: int = -1
    params: Optional[SamplingParams] = None
    # token-granular KV-capacity budget: max output tokens the cache can
    # commit before the next tree step would scatter past max_seq_len
    # (= max_seq_len - width - len(prompt) + 1, set by the serving loop).
    # Retirement at this cap is per-TOKEN, so the truncation point is
    # identical across serving disciplines regardless of how many draft
    # tokens the final step happened to verify (the lockstep-vs-continuous
    # overflow divergence fix).  None = no cache cap (budget/EOS only).
    token_limit: Optional[int] = None
    # resolved per-request speculation policy (set by the serving loop at
    # submit; None = the loop's trie-only legacy path) and, when the policy
    # asks for it, the per-lane adaptive draft-budget controller
    draft: Optional[DraftPolicy] = None
    budget_ctl: Optional[AdaptiveBudget] = None
    output: List[int] = field(default_factory=list)
    context: List[int] = field(default_factory=list)   # prompt ⧺ output
    stats: GenStats = field(default_factory=GenStats)
    done: bool = False
    cancelled: bool = False
    finish_reason: str = ""
    inserted_upto: int = 0    # output tokens already streamed into the trie
    lane: int = -1            # scheduler slot currently occupied (-1 = none)
    submit_t: float = 0.0
    admit_t: float = 0.0
    first_token_t: float = 0.0
    finish_t: float = 0.0

    @property
    def _limit(self) -> int:
        """Effective output-token budget: caller budget ∧ cache capacity
        (floor 1 — the prefill emits a token without needing tree scratch)."""
        lim = self.max_new_tokens
        if self.token_limit is not None:
            lim = min(lim, self.token_limit)
        return max(lim, 1)

    def _stop_reason_at(self, token: int) -> Optional[str]:
        """Stop classification for the just-appended ``token`` (output
        already includes it) — checked token-by-token so truncation matches
        step-by-step decoding exactly."""
        if token == self.eos_id:
            return "eos"
        p = self.params
        if p is None:
            return None
        if token in p.stop_token_ids:
            return "stop"
        for seq in p.stop_sequences:
            if (len(self.output) >= len(seq)
                    and self.output[-len(seq):] == list(seq)):
                return "stop"
        return None

    def _finish_if_exhausted(self) -> None:
        if not self.done and len(self.output) >= self._limit:
            self.done = True
            self.finish_reason = ("length"
                                  if self._limit >= self.max_new_tokens
                                  else "cache")

    def start(self, first_token: int) -> None:
        """Consume the prefill's chosen root (the first output token)."""
        first_token = int(first_token)
        self.output = [first_token]
        self.context = list(self.prompt) + [first_token]
        self.stats.steps += 1
        self.stats.tokens += 1
        reason = self._stop_reason_at(first_token)
        if reason:
            self.done = True
            self.finish_reason = reason
        self._finish_if_exhausted()

    def accept(self, accepted: Sequence[int], kv_slots: Sequence[int],
               n_tree_slots: int,
               slot_sources: Optional[Sequence[Optional[str]]] = None
               ) -> List[int]:
        """Absorb one verified step; returns the KV slots to commit.

        Tokens are absorbed one at a time against the budget / cache cap /
        EOS / stop conditions, exactly like step-by-step decoding would —
        the committed prefix (and the truncation point) therefore never
        depends on how many draft tokens happened to verify.

        ``slot_sources`` is the tree's per-slot provenance
        (``DraftTree.slot_source``); when given, per-source drafted/accepted
        counters accrue on ``stats`` (slot 0 is the model's own root
        prediction — no source gets credit for it).
        """
        limit = self._limit
        n = 0
        for t in accepted:
            if len(self.output) >= limit:
                break
            t = int(t)
            self.output.append(t)
            self.context.append(t)
            n += 1
            reason = self._stop_reason_at(t)
            if reason:
                self.done = True
                self.finish_reason = reason
                break
        ks = list(kv_slots[:n])
        st = self.stats
        st.steps += 1
        st.tokens += n
        st.dropped_slots += n_tree_slots - n
        if slot_sources is not None:
            for i in range(1, n_tree_slots):
                src = slot_sources[i]
                if src is not None:
                    st.source_drafted[src] = st.source_drafted.get(src, 0) + 1
            for slot in ks[1:]:
                src = slot_sources[slot]
                if src is not None:
                    st.source_accepted[src] = (
                        st.source_accepted.get(src, 0) + 1)
        if self.budget_ctl is not None:
            self.budget_ctl.update(n)
        self._finish_if_exhausted()
        return ks

    def cancel(self) -> None:
        """Mark the request cancelled (the serving loop releases its lane /
        blocks through the regular retire path)."""
        self.done = True
        self.cancelled = True
        self.finish_reason = "cancelled"

    def result(self) -> RequestResult:
        return RequestResult(
            tokens=self.output, stats=self.stats, rid=self.rid,
            latency_s=max(self.finish_t - self.submit_t, 0.0),
            ttft_s=max(self.first_token_t - self.submit_t, 0.0),
            queue_s=max(self.admit_t - self.submit_t, 0.0),
            finish_reason=self.finish_reason, cancelled=self.cancelled)


def cache_token_limit(max_seq_len: int, width: int, prompt_len: int) -> int:
    """Output tokens a request can commit before the next ``width``-slot
    tree step would scatter past ``max_seq_len``.  THE retirement bound both
    serving loops set as ``RequestState.token_limit`` — sharing it is what
    makes overflow truncation identical across disciplines."""
    return max(int(max_seq_len) - int(width) - int(prompt_len) + 1, 1)


# ------------------------------------------------------------------- drafting
def build_draft_tree(trie: TrieTree, cfg: LookaheadConfig,
                     context: Sequence[int], pad_id: int,
                     width: int) -> DraftTree:
    """Retrieve + build a draft tree padded to exactly ``width`` slots."""
    root = int(context[-1])
    if cfg.strategy == "none" or cfg.decoding_length == 0 or width <= 1:
        return _finalize([root], [-1], max(width, 1), pad_id)
    branches, scores = trie.retrieve(
        context, decoding_length=cfg.decoding_length,
        max_prefix_len=cfg.max_prefix_len,
        min_matched_tokens=cfg.min_matched_tokens)
    tree = BUILDERS[cfg.strategy](root, branches, scores,
                                  cfg.decoding_length, pad_id,
                                  sources=["trie"] * len(branches))
    return repad(tree, width, pad_id)


@functools.lru_cache(maxsize=16)
def idle_tree(width: int, pad_id: int) -> DraftTree:
    """Placeholder tree for an empty slot (masked out: n_accept == 0)."""
    return _finalize([pad_id], [-1], max(width, 1), pad_id)


# ------------------------------------------------------------ trie bookkeeping
def trie_admit(trie: TrieTree, cfg: LookaheadConfig, rid: int,
               prompt: Sequence[int]) -> None:
    """Prompt-branch inserting at admission (per request id, eliminable)."""
    if cfg.insert_prompt:
        trie.insert_ngrams(prompt, cfg.branch_length, request_id=rid)


def trie_stream(trie: TrieTree, cfg: LookaheadConfig,
                state: RequestState) -> None:
    """Generated-branch inserting on-the-fly (paper Algorithm 1 lines 5-9)."""
    if not cfg.insert_output:
        return
    out = state.output
    lo = max(state.inserted_upto - cfg.branch_length, 0)
    if len(out) - lo >= 2:
        trie.insert_ngrams(out[lo:], cfg.branch_length)
        state.inserted_upto = len(out)


def trie_retire(trie: TrieTree, cfg: LookaheadConfig, rid: int, *,
                prune: bool = True) -> None:
    """Branch eliminating for a finished request (+ capacity pruning)."""
    if cfg.eliminate:
        trie.eliminate(rid)
    if prune and cfg.prune and len(trie) > trie.capacity:
        trie.prune()


__all__ = ["SamplingParams", "Request", "StepFns", "GenStats",
           "RequestResult", "RequestState", "cache_token_limit",
           "build_draft_tree", "idle_tree", "trie_admit", "trie_stream",
           "trie_retire", "DraftPolicy"]
