"""Verification-and-Accept (paper §4.1, Algorithm 1 line 21).

Given per-slot *chosen* token ids (greedy argmax, or deterministic
position-keyed sample — computed on device, shipped as a tiny int array) and
the host-side draft tree, find the longest root-path whose node tokens match
the chosen id of their parent.  Acceptance rules:

  * the chosen id of slot 0 (the root = last committed token) is ALWAYS
    accepted — this is the model's own next-token prediction, so the step
    never emits fewer tokens than step-by-step decoding (worst case == 1);
  * a draft node ``c`` (child of ``p``) is verified iff
    ``tokens[c] == chosen[p]``; walking matched nodes extends the output by
    ``chosen[c]`` and commits slot ``c``'s KV entry.

Returns both the accepted tokens and the slot indices whose KV entries must
be compacted into the cache (slot 0 plus every matched node, in path order).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from .draft import DraftTree


def verify_accept(tree: DraftTree, chosen: np.ndarray
                  ) -> Tuple[List[int], List[int]]:
    """Longest-match walk.

    Parameters
    ----------
    tree:    host draft tree (slot 0 = root).
    chosen:  (T,) int array — model-chosen token per slot.

    Returns
    -------
    accepted_tokens: the new output tokens (len >= 1).
    kv_slots:        slot indices whose KV becomes part of the committed
                     context, in order (always starts with 0).  Note
                     ``len(kv_slots) == len(accepted_tokens)``: the last
                     accepted token has no KV yet — it is next step's root.
    """
    chosen = np.asarray(chosen)
    accepted = [int(chosen[0])]
    kv_slots = [0]
    cur = 0
    while True:
        nxt = -1
        want = int(chosen[cur])
        for c in tree.children[cur]:
            if c < tree.n_slots and int(tree.tokens[c]) == want:
                nxt = c
                break
        if nxt < 0:
            break
        cur = nxt
        kv_slots.append(cur)
        accepted.append(int(chosen[cur]))
    return accepted, kv_slots


def verify_accept_batch(trees: Sequence[DraftTree], chosen: np.ndarray
                        ) -> Tuple[List[List[int]], List[List[int]]]:
    """Batched wrapper: ``chosen`` is (B, T)."""
    acc, slots = [], []
    for b, tree in enumerate(trees):
        a, s = verify_accept(tree, chosen[b])
        acc.append(a)
        slots.append(s)
    return acc, slots


__all__ = ["verify_accept", "verify_accept_batch"]
