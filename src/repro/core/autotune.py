"""Per-namespace draft-source auto-tuning (DESIGN.md §Multi-tenant SLOs).

The per-source drafted/accepted telemetry on ``GenStats`` (PR 5) measures
which draft sources actually verify on which workload — the paper's Alipay
deployment serves many *scenarios* from one process, and a source that pays
off on one (prompt-copy on RAG traffic, say) can be pure host-side overhead
on another.  This module closes the loop: an ``AutoTuner`` keeps one
``NamespaceController`` per trie namespace, folds every retiring request's
per-source counters into an acceptance-rate EMA, and *gates* retrieval —
sources whose EMA stays under ``drop_rate`` after ``min_trials`` drafted
tokens get their quota driven to zero and their ``retrieve`` call skipped
entirely.  A deterministic counter-based probe re-admits a disabled source
with a tiny quota every ``probe_period`` gate decisions, so a source that
starts verifying again (workload drift, a now-warm trie) earns its quota
back.

Everything here is host-side policy over which draft tokens get *built*:
the device step verifies whatever tree it is handed, so gating can never
change an output token (I1), and no shape depends on the controller's
state, so it can never retrace (I2).  Decisions are pure functions of the
observed token history — no wall clock, no RNG — which keeps perf runs
reproducible and lets the lossless fuzz assert autotune-on == autotune-off
bit-for-bit.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class AutoTuneConfig:
    """Controller knobs (shared by every namespace of one AutoTuner).

    min_trials:   drafted tokens a source must accumulate on a namespace
                  before it may be disabled (cold-start protection).
    drop_rate:    acceptance-rate EMA below which a trialed source is
                  disabled (and above-or-equal which a probe re-enables it).
    ema_alpha:    weight of each retiring request's acceptance rate.
    probe_period: gate decisions between probes of a disabled source.
    probe_quota:  new-token quota a probe grants (small: the probe must be
                  cheap when the source is still useless).
    """
    min_trials: int = 64
    drop_rate: float = 0.05
    ema_alpha: float = 0.2
    probe_period: int = 32
    probe_quota: int = 1

    def validate(self) -> "AutoTuneConfig":
        if self.min_trials < 1:
            raise ValueError(f"min_trials={self.min_trials}: need >= 1")
        if not 0.0 <= self.drop_rate < 1.0:
            raise ValueError(f"drop_rate={self.drop_rate}: need [0, 1)")
        if not 0.0 < self.ema_alpha <= 1.0:
            raise ValueError(f"ema_alpha={self.ema_alpha}: need (0, 1]")
        if self.probe_period < 1:
            raise ValueError(f"probe_period={self.probe_period}: need >= 1")
        if self.probe_quota < 1:
            raise ValueError(f"probe_quota={self.probe_quota}: need >= 1")
        return self


@dataclass
class SourceStat:
    """Per-(namespace, source) controller state."""
    drafted: int = 0          # draft tokens placed into trees (lifetime)
    accepted: int = 0         # of those, tokens the model verified
    ema: Optional[float] = None   # acceptance-rate EMA over observations
    enabled: bool = True
    disables: int = 0         # times the controller zeroed the quota
    probes: int = 0           # probe retrievals granted while disabled
    _since_probe: int = 0     # gate decisions since the last probe

    @property
    def rate(self) -> float:
        """Lifetime acceptance rate (EMA drives decisions; this is for
        reporting)."""
        return self.accepted / max(self.drafted, 1)


class NamespaceController:
    """EMA bandit over one namespace's draft sources."""

    def __init__(self, config: AutoTuneConfig):
        self.config = config
        self.sources: Dict[str, SourceStat] = {}
        self.observations = 0

    def stat(self, name: str) -> SourceStat:
        s = self.sources.get(name)
        if s is None:
            s = self.sources[name] = SourceStat()
        return s

    # ------------------------------------------------------------- observe
    def observe(self, drafted: Dict[str, int],
                accepted: Dict[str, int]) -> None:
        """Fold one retiring request's per-source counters in.  Sources the
        request never drafted through contribute nothing (a disabled
        source's EMA only moves when a probe actually drafts)."""
        cfg = self.config
        moved = False
        for name, d in drafted.items():
            if d <= 0:
                continue
            moved = True
            st = self.stat(name)
            a = accepted.get(name, 0)
            st.drafted += int(d)
            st.accepted += int(a)
            r = a / d
            st.ema = r if st.ema is None else (
                (1.0 - cfg.ema_alpha) * st.ema + cfg.ema_alpha * r)
            if st.enabled:
                if st.drafted >= cfg.min_trials and st.ema < cfg.drop_rate:
                    st.enabled = False
                    st.disables += 1
                    st._since_probe = 0
            elif st.ema >= cfg.drop_rate:
                st.enabled = True      # a probe paid off: quota restored
        if moved:
            self.observations += 1

    # ---------------------------------------------------------------- gate
    def gate(self, names: Sequence[str],
             quotas: Sequence[int]) -> Tuple[List[int], List[int]]:
        """One retrieval decision: which of ``names`` draft this tree, at
        what new-token quota.  Returns (kept indices, kept quotas).

        Enabled sources keep their policy quota.  Disabled sources are
        skipped — their retrieve cost is not paid — except every
        ``probe_period``-th decision, when they ride along at
        ``probe_quota`` so recovery stays possible.  If everything is
        disabled the first source is kept at full quota: a request must
        never be stripped of speculation entirely by its own controller.
        """
        cfg = self.config
        keep: List[int] = []
        kq: List[int] = []
        for i, name in enumerate(names):
            st = self.stat(name)
            if st.enabled:
                keep.append(i)
                kq.append(int(quotas[i]))
                continue
            st._since_probe += 1
            if st._since_probe >= cfg.probe_period:
                st._since_probe = 0
                st.probes += 1
                keep.append(i)
                kq.append(min(cfg.probe_quota, int(quotas[i])))
        if not keep:
            keep, kq = [0], [int(quotas[0])]
        return keep, kq

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        return {name: {"drafted": st.drafted, "accepted": st.accepted,
                       "rate": st.rate,
                       "ema": st.ema if st.ema is not None else -1.0,
                       "enabled": st.enabled, "disables": st.disables,
                       "probes": st.probes}
                for name, st in self.sources.items()}


class AutoTuner:
    """Per-namespace controller registry the scheduler drives.

    ``observe`` at request retirement (the per-request counters are
    complete and the call is deterministic — no mid-flight sampling),
    ``select`` before each tree build (filters the policy's source list and
    quotas down to what this namespace has earned).
    """

    def __init__(self, config: Optional[AutoTuneConfig] = None):
        self.config = (config if config is not None
                       else AutoTuneConfig()).validate()
        self.namespaces: Dict[str, NamespaceController] = {}

    def controller(self, namespace: str) -> NamespaceController:
        c = self.namespaces.get(namespace)
        if c is None:
            c = self.namespaces[namespace] = NamespaceController(self.config)
        return c

    def observe(self, namespace: str, drafted: Dict[str, int],
                accepted: Dict[str, int]) -> None:
        self.controller(namespace).observe(drafted, accepted)

    def select(self, namespace: str, names: Sequence[str],
               quotas: Sequence[int]) -> Tuple[List[int], List[int]]:
        """Gate one tree build; see ``NamespaceController.gate``."""
        return self.controller(namespace).gate(names, quotas)

    def snapshot(self) -> Dict[str, Dict[str, Dict[str, float]]]:
        """namespace -> source -> controller state (stats/serving surface)."""
        return {ns: ctl.snapshot() for ns, ctl in self.namespaces.items()}


__all__ = ["AutoTuneConfig", "AutoTuner", "NamespaceController",
           "SourceStat"]
