"""Synthetic data pipelines.

The LM side reproduces the *statistics that drive Lookahead*: RAG-style
prompts whose answers copy spans from the reference document (AntRAG), QA
answers with cross-query phrase reuse (Dolly), chain-y math (GSM8k) and
code with heavy token repetition (HumanEval-x) — each a profile with a
controllable copy rate / phrase-pool reuse, matched to paper Table 8 length
statistics.  Also: LM training batches, recsys batch generators, and graph
generators for the GNN cells.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np


# ------------------------------------------------------------------ LM corpus
@dataclass(frozen=True)
class CorpusProfile:
    """Controls the n-gram structure a Lookahead trie can exploit."""
    name: str
    prompt_len: int            # mean prompt tokens (paper Table 8)
    answer_len: int            # mean answer tokens
    copy_from_prompt: float    # P(next phrase is copied from the prompt)
    pool_reuse: float          # P(next phrase comes from the shared pool)
    phrase_len: int = 8
    pool_size: int = 64


PROFILES = {
    # paper Table 8 statistics; copy rates tuned to reproduce Table 2 ordering
    "antrag": CorpusProfile("antrag", 241, 82, 0.70, 0.20),
    "dolly": CorpusProfile("dolly", 301, 105, 0.15, 0.25),
    "gsm8k": CorpusProfile("gsm8k", 68, 132, 0.10, 0.45),
    "humaneval": CorpusProfile("humaneval", 140, 82, 0.25, 0.55),
}


class SyntheticCorpus:
    """Generates (prompt, answer) token pairs with profile-controlled reuse."""

    def __init__(self, profile: CorpusProfile, vocab_size: int,
                 seed: int = 0, reserved: int = 2):
        self.p = profile
        self.vocab = vocab_size
        self.rng = np.random.RandomState(seed)
        self.reserved = reserved   # 0 = pad, 1 = eos
        self.pool = [self._rand_phrase() for _ in range(profile.pool_size)]

    def _rand_phrase(self) -> List[int]:
        return list(self.rng.randint(self.reserved, self.vocab,
                                     size=self.p.phrase_len))

    def sample(self) -> Tuple[List[int], List[int]]:
        p = self.p
        prompt: List[int] = []
        # prompt = mixture of pool phrases (shared doc store) + noise
        while len(prompt) < p.prompt_len:
            if self.rng.rand() < 0.5:
                prompt += self.pool[self.rng.randint(len(self.pool))]
            else:
                prompt += self._rand_phrase()
        prompt = prompt[:p.prompt_len]
        answer: List[int] = []
        while len(answer) < p.answer_len:
            r = self.rng.rand()
            if r < p.copy_from_prompt and len(prompt) > p.phrase_len:
                s = self.rng.randint(0, len(prompt) - p.phrase_len)
                answer += prompt[s:s + p.phrase_len]
            elif r < p.copy_from_prompt + p.pool_reuse:
                answer += self.pool[self.rng.randint(len(self.pool))]
            else:
                answer += self._rand_phrase()
        return prompt, answer[:p.answer_len]

    def dataset(self, n: int) -> List[Tuple[List[int], List[int]]]:
        return [self.sample() for _ in range(n)]


def lm_train_batches(vocab: int, batch: int, seq: int, seed: int = 0,
                     corpus: Optional[SyntheticCorpus] = None
                     ) -> Iterator[Dict[str, np.ndarray]]:
    """Next-token LM batches; if a corpus is given, streams its documents."""
    rng = np.random.RandomState(seed)
    while True:
        if corpus is None:
            toks = rng.randint(2, vocab, size=(batch, seq + 1))
        else:
            rows = []
            for _ in range(batch):
                doc: List[int] = []
                while len(doc) < seq + 1:
                    pr, ans = corpus.sample()
                    doc += pr + ans + [1]
                rows.append(doc[:seq + 1])
            toks = np.asarray(rows)
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}


# ------------------------------------------------------------------- recsys
def wide_deep_batch(rng: np.random.RandomState, batch: int, n_sparse: int,
                    rows: int, multi_hot: int, n_dense: int
                    ) -> Dict[str, np.ndarray]:
    return {
        "sparse_ids": rng.randint(0, rows, (batch, n_sparse, multi_hot)
                                  ).astype(np.int32),
        "sparse_mask": (rng.rand(batch, n_sparse, multi_hot) > 0.25),
        "dense": rng.randn(batch, n_dense).astype(np.float32),
        "labels": rng.randint(0, 2, (batch,)).astype(np.float32),
    }


def two_tower_batch(rng: np.random.RandomState, batch: int, n_user: int,
                    n_item: int, rows: int) -> Dict[str, np.ndarray]:
    return {"user_ids": rng.randint(0, rows, (batch, n_user)).astype(np.int32),
            "item_ids": rng.randint(0, rows, (batch, n_item)).astype(np.int32)}


def seq_rec_batch(rng: np.random.RandomState, batch: int, seq: int,
                  n_items: int, causal: bool, n_neg: int = 64
                  ) -> Dict[str, np.ndarray]:
    ids = rng.randint(2, n_items, (batch, seq)).astype(np.int32)
    pad = np.ones((batch, seq), bool)
    negatives = rng.randint(2, n_items, (n_neg,)).astype(np.int32)
    if causal:   # sasrec: next-item labels + shared negatives
        labels = np.concatenate([ids[:, 1:], -np.ones((batch, 1), np.int32)],
                                axis=1).astype(np.int32)
        return {"ids": ids, "labels": labels, "negatives": negatives,
                "pad_mask": pad}
    # bert4rec: cloze — fixed count of masked slots per row
    M = max(seq // 5, 1)
    mpos = np.stack([rng.choice(seq, M, replace=False)
                     for _ in range(batch)]).astype(np.int32)
    mlab = np.take_along_axis(ids, mpos, axis=1).astype(np.int32)
    ids_masked = ids.copy()
    np.put_along_axis(ids_masked, mpos, 1, axis=1)   # [MASK]=1
    return {"ids": ids_masked, "masked_pos": mpos, "masked_labels": mlab,
            "negatives": negatives, "pad_mask": pad}


# --------------------------------------------------------------------- graph
def random_geometric_graph(rng: np.random.RandomState, n_nodes: int,
                           d_feat: int, cutoff: float = 0.5, box: float = 2.0,
                           max_edges: Optional[int] = None
                           ) -> Dict[str, np.ndarray]:
    pos = rng.rand(n_nodes, 3).astype(np.float32) * box
    d2 = np.sum((pos[:, None] - pos[None, :]) ** 2, axis=-1)
    src, dst = np.nonzero((d2 < cutoff ** 2) & ~np.eye(n_nodes, dtype=bool))
    edges = np.stack([src, dst], axis=1).astype(np.int32)
    if max_edges is not None:
        pad = max(0, max_edges - len(edges))
        mask = np.concatenate([np.ones(min(len(edges), max_edges), bool),
                               np.zeros(pad, bool)])
        edges = np.concatenate(
            [edges[:max_edges], np.zeros((pad, 2), np.int32)], axis=0)
    else:
        mask = np.ones(len(edges), bool)
    return {"node_feat": rng.randn(n_nodes, d_feat).astype(np.float32),
            "positions": pos, "edges": edges, "edge_mask": mask}


def batched_molecules(rng: np.random.RandomState, n_graphs: int,
                      nodes_per: int, d_feat: int, edges_per: int
                      ) -> Dict[str, np.ndarray]:
    """Disjoint union of small graphs (molecule cell)."""
    gs = [random_geometric_graph(rng, nodes_per, d_feat, cutoff=0.9,
                                 max_edges=edges_per) for _ in range(n_graphs)]
    N = nodes_per
    batch = {
        "node_feat": np.concatenate([g["node_feat"] for g in gs]),
        "positions": np.concatenate([g["positions"] for g in gs]),
        "edges": np.concatenate(
            [g["edges"] + i * N for i, g in enumerate(gs)]).astype(np.int32),
        "edge_mask": np.concatenate([g["edge_mask"] for g in gs]),
        "graph_ids": np.repeat(np.arange(n_graphs), N).astype(np.int32),
        "energies": rng.randn(n_graphs).astype(np.float32),
    }
    return batch


__all__ = ["CorpusProfile", "PROFILES", "SyntheticCorpus", "lm_train_batches",
           "wide_deep_batch", "two_tower_batch", "seq_rec_batch",
           "random_geometric_graph", "batched_molecules"]
