"""Fault-tolerant checkpointing: atomic writes, retention, resume-latest,
and ELASTIC resharding — checkpoints store logical axis names per leaf so a
restore can target a different mesh shape than the save (scale up/down).

Format: one .npz per checkpoint (flat {path: array}) + a JSON manifest with
step, tree structure, logical axes, and a content digest.  Writes go to
``<dir>/tmp.<step>`` then ``os.replace`` to ``<dir>/step_<step>`` — a crash
mid-write never corrupts the latest checkpoint.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif tree is None:
        out[prefix[:-1] + "#none"] = np.zeros((0,))
    else:
        out[prefix[:-1]] = np.asarray(jax.device_get(tree))
    return out


def _unflatten(flat: Dict[str, np.ndarray], template: Any, prefix: str = ""
               ) -> Any:
    if isinstance(template, dict):
        return {k: _unflatten(flat, template[k], f"{prefix}{k}/")
                for k in template}
    if isinstance(template, (list, tuple)):
        vals = [_unflatten(flat, v, f"{prefix}{i}/")
                for i, v in enumerate(template)]
        return type(template)(vals) if not hasattr(template, "_fields") \
            else type(template)(*vals)
    if template is None:
        return None
    return flat[prefix[:-1]]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()
        self._async_thread: Optional[threading.Thread] = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree: Any, *, logical_axes: Any = None,
             blocking: bool = True) -> str:
        flat = _flatten(tree)
        if self._async_thread is not None:
            self._async_thread.join()        # one in-flight write max
            self._async_thread = None
        if blocking:
            return self._write(step, flat, logical_axes)
        self._async_thread = threading.Thread(
            target=self._write, args=(step, flat, logical_axes), daemon=True)
        self._async_thread.start()
        return os.path.join(self.dir, f"step_{step:010d}")

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def _write(self, step: int, flat: Dict[str, np.ndarray],
               logical_axes: Any) -> str:
        with self._lock:
            tmp = os.path.join(self.dir, f"tmp.{step}")
            final = os.path.join(self.dir, f"step_{step:010d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            digest = hashlib.sha256()
            for k in sorted(flat):
                digest.update(k.encode())
                digest.update(np.ascontiguousarray(flat[k]).tobytes())
            manifest = {
                "step": step,
                "keys": sorted(flat.keys()),
                "digest": digest.hexdigest(),
                "logical_axes": _flatten_axes(logical_axes),
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._retain()
            return final

    def _retain(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                mesh=None, rules=None, verify: bool = True) -> Tuple[Any, int]:
        """Restore into the structure of ``template``.  If ``mesh`` is given,
        each leaf is device_put with the sharding derived from the saved
        logical axes — THIS is the elastic-resharding path: the saved mesh
        shape is irrelevant, only logical names matter."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = dict(np.load(os.path.join(path, "arrays.npz"),
                            allow_pickle=False))
        if verify:
            digest = hashlib.sha256()
            for k in sorted(data):
                digest.update(k.encode())
                digest.update(np.ascontiguousarray(data[k]).tobytes())
            if digest.hexdigest() != manifest["digest"]:
                raise IOError(f"checkpoint {path} failed integrity check")
        tree = _unflatten(data, template)
        if mesh is not None and manifest.get("logical_axes"):
            from repro.distributed.sharding import named_sharding, DEFAULT_RULES
            axes = manifest["logical_axes"]

            def put(path_key, leaf):
                if leaf is None:
                    return None
                ax = axes.get(path_key)
                if ax is None:
                    return jax.device_put(leaf)
                sh = named_sharding(mesh, ax, leaf.shape,
                                    rules or DEFAULT_RULES)
                return jax.device_put(leaf, sh)

            flat = _flatten(tree)
            placed = {k: put(k, v) for k, v in flat.items()}
            tree = _unflatten(placed, template)
        return tree, step


def _flatten_axes(axes: Any) -> Optional[Dict[str, Any]]:
    if axes is None:
        return None
    flat = {}

    def rec(t, prefix=""):
        if isinstance(t, dict):
            for k in sorted(t):
                rec(t[k], f"{prefix}{k}/")
        elif isinstance(t, (list,)) or (isinstance(t, tuple)
                                        and t and isinstance(t[0], (dict,
                                                                    list))):
            for i, v in enumerate(t):
                rec(v, f"{prefix}{i}/")
        else:
            flat[prefix[:-1]] = list(t) if isinstance(t, tuple) else t

    rec(axes)
    return flat


__all__ = ["CheckpointManager"]
