"""Generic train step factory: loss+grad+AdamW with optional microbatch
gradient accumulation (lax.scan) and bf16 gradient compression on the
cross-replica reduce path."""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .optimizer import AdamWState, adamw_update


def make_train_step(loss_fn: Callable[[Any, Dict], jax.Array], *,
                    lr: float = 1e-3, weight_decay: float = 0.01,
                    max_grad_norm: float = 1.0, accum_steps: int = 1,
                    grad_dtype: Optional[str] = None) -> Callable:
    """loss_fn(params, batch) -> scalar.

    Returns step(params, opt_state, batch) -> (params, opt_state, metrics).
    With accum_steps > 1, every leading batch-dim array in ``batch`` is split
    into ``accum_steps`` microbatches scanned sequentially (activation memory
    / global batch trade-off).  ``grad_dtype`` (e.g. "bfloat16") casts the
    accumulated gradient — a 2× reduction of cross-replica all-reduce bytes.
    """

    def grads_of(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return loss, grads

    def step(params, opt_state: AdamWState, batch: Dict):
        if accum_steps == 1:
            loss, grads = grads_of(params, batch)
        else:
            def split(x):
                return x.reshape((accum_steps, x.shape[0] // accum_steps)
                                 + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def body(carry, mb):
                acc, ls = carry
                loss, g = grads_of(params, mb)
                acc = jax.tree.map(lambda a, b: a + b, acc, g)
                return (acc, ls + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32)
                if jnp.issubdtype(p.dtype, jnp.floating) else
                jnp.zeros(p.shape, p.dtype), params)
            (grads, loss), _ = jax.lax.scan(body, (zeros, jnp.zeros(())),
                                            micro)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
        if grad_dtype is not None:
            dt = jnp.dtype(grad_dtype)
            grads = jax.tree.map(
                lambda g: g.astype(dt)
                if jnp.issubdtype(g.dtype, jnp.floating) else g, grads)
        params, opt_state, om = adamw_update(
            params, grads, opt_state, lr=lr, weight_decay=weight_decay,
            max_grad_norm=max_grad_norm)
        return params, opt_state, {"loss": loss, **om}

    return step


__all__ = ["make_train_step"]
