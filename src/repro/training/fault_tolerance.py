"""Fault-tolerance runtime pieces:

* PreemptionHandler — SIGTERM/SIGINT sets a flag; the train loop checkpoints
  at the next step boundary and exits cleanly (TPU preemption notice).
* run_with_timeout — straggler mitigation: a step that exceeds its deadline
  is abandoned and retried (on real fleets: after re-forming the mesh without
  the straggler; here the retry path is exercised directly).
* retry — transient-failure wrapper with exponential backoff for collectives
  that died mid-flight.
* elastic_world — recompute the largest usable (pods, data, model) mesh from
  a surviving device count, preserving the model axis (TP degree must match
  the checkpointed layout; data/pod axes absorb the loss).
"""
from __future__ import annotations

import signal
import threading
import time
from concurrent.futures import ThreadPoolExecutor, TimeoutError as _Timeout
from typing import Callable, Optional, Tuple


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM,)):
        self._flag = threading.Event()
        self._prev = {}
        self._signals = signals

    def install(self) -> "PreemptionHandler":
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._on_signal)
        return self

    def uninstall(self) -> None:
        for s, h in self._prev.items():
            signal.signal(s, h)
        self._prev.clear()

    def _on_signal(self, signum, frame):
        self._flag.set()

    @property
    def preempted(self) -> bool:
        return self._flag.is_set()

    def trigger(self) -> None:         # test hook
        self._flag.set()


class StragglerTimeout(Exception):
    pass


def run_with_timeout(fn: Callable, timeout_s: float, *args, retries: int = 1,
                     on_timeout: Optional[Callable] = None, **kwargs):
    """Run fn; if it exceeds timeout_s, call on_timeout() and retry.
    Raises StragglerTimeout after ``retries`` consecutive timeouts."""
    for attempt in range(retries + 1):
        with ThreadPoolExecutor(max_workers=1) as ex:
            fut = ex.submit(fn, *args, **kwargs)
            try:
                return fut.result(timeout=timeout_s)
            except _Timeout:
                if on_timeout is not None:
                    on_timeout()
                if attempt == retries:
                    raise StragglerTimeout(
                        f"step exceeded {timeout_s}s x{retries + 1}")


def retry(fn: Callable, *args, attempts: int = 3, base_delay: float = 0.05,
          retriable=(RuntimeError, IOError), **kwargs):
    for i in range(attempts):
        try:
            return fn(*args, **kwargs)
        except retriable:
            if i == attempts - 1:
                raise
            time.sleep(base_delay * (2 ** i))


def elastic_world(n_devices: int, model_parallel: int,
                  prefer_pods: int = 1) -> Tuple[int, int, int]:
    """Largest (pods, data, model) with pods*data*model <= n_devices, model
    fixed (checkpoint TP layout), data a power of two."""
    if n_devices < model_parallel:
        raise ValueError(
            f"{n_devices} devices cannot host model_parallel={model_parallel}")
    rest = n_devices // model_parallel
    pods = prefer_pods
    while pods > 1 and rest % pods != 0:
        pods -= 1
    per_pod = rest // pods
    data = 1
    while data * 2 <= per_pod:
        data *= 2
    return pods, data, model_parallel


__all__ = ["PreemptionHandler", "StragglerTimeout", "run_with_timeout",
           "retry", "elastic_world"]
