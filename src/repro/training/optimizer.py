"""AdamW + global-norm clipping, pure JAX pytree implementation.

Optimizer state moments inherit the parameter sharding (pjit shards them
identically), which combined with fsdp-sharded params gives ZeRO-ish
optimizer-state sharding for free.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params: Any) -> AdamWState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        if jnp.issubdtype(p.dtype, jnp.floating) else None, params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(lambda z: None if z is None
                                      else jnp.zeros_like(z), zeros))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    leaves = [g for g in jax.tree.leaves(grads) if g is not None]
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: None if g is None else g * scale,
                        grads, is_leaf=lambda x: x is None), gn


def adamw_update(params: Any, grads: Any, state: AdamWState, *,
                 lr: float = 1e-3, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.01,
                 max_grad_norm: Optional[float] = 1.0
                 ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    if max_grad_norm is not None:
        grads, gn = clip_by_global_norm(grads, max_grad_norm)
    else:
        gn = jnp.zeros(())
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        if g is None or m is None:
            return p, m, v
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m,
                                                 flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), {"grad_norm": gn}


__all__ = ["AdamWState", "adamw_init", "adamw_update", "clip_by_global_norm"]
