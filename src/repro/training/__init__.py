from .optimizer import adamw_init, adamw_update, clip_by_global_norm
from .train_step import make_train_step
from .checkpoint import CheckpointManager

__all__ = ["adamw_init", "adamw_update", "clip_by_global_norm",
           "make_train_step", "CheckpointManager"]
