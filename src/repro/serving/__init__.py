from .block_allocator import BlockAllocator, NULL_BLOCK
from .session import make_session_fns
from .sampler import choose_tokens, choose_tokens_lanes
from .scheduler import ContinuousScheduler, SchedulerStats
from .api import (EngineConfig, Request, RequestHandle, SamplingParams,
                  ServingEngine, build_engine, build_session_fns)

__all__ = ["make_session_fns", "choose_tokens", "choose_tokens_lanes",
           "ContinuousScheduler", "SchedulerStats", "BlockAllocator",
           "NULL_BLOCK", "EngineConfig", "Request", "RequestHandle",
           "SamplingParams", "ServingEngine", "build_engine",
           "build_session_fns"]
