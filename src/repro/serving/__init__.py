from .block_allocator import BlockAllocator, NULL_BLOCK
from .session import make_session_fns
from .sampler import choose_tokens
from .scheduler import ContinuousScheduler, SchedulerStats

__all__ = ["make_session_fns", "choose_tokens", "ContinuousScheduler",
           "SchedulerStats", "BlockAllocator", "NULL_BLOCK"]
