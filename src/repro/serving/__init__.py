from .session import make_session_fns
from .sampler import choose_tokens

__all__ = ["make_session_fns", "choose_tokens"]
