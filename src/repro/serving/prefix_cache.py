"""Radix-tree prefix cache over the paged KV block pool.

Maps token prefixes to resident KV blocks so a request whose prompt prefix
was already prefilled by an earlier request skips that portion of prefill
(SGLang-style RadixAttention on a vLLM-style paged pool).

Structure
---------
One tree per namespace (composing with ``TrieForest`` scenario scoping: the
``DraftPolicy.namespace`` that isolates draft tries also isolates prefix
reuse, so co-resident tenants never share KV).  Each node covers exactly one
KV block: its ``key`` is the token chunk written into that block (full
``block_size`` tokens for interior nodes, possibly fewer for a leaf holding
a partially-filled boundary block).  Children are keyed by their first
token; a parent chain of full nodes spells out a block-aligned prefix.

Ownership
---------
The cache holds exactly one allocator reference per resident block
(``BlockAllocator.cache_ref``).  Blocks shared into a live request's table
additionally carry that request's reference, so LRU eviction of a node can
never free KV a live request still attends (the refcount just drops).
Eviction only touches *leaves* with ``lock == 0`` — ``lookup`` pins every
matched node so an admission-triggered eviction pass cannot evict the very
blocks it is about to share.

Lookup semantics
----------------
``lookup`` walks full-block exact matches, then inspects one more child for
a partially-matching boundary block: if the child's key and the remaining
prompt share a non-empty common prefix, the child's block is returned as a
copy-on-write fork source (the request copies it into a fresh block of its
own and overwrites rows past the match).  The total match is capped at
``len(tokens) - 1`` — at least one real token must run through prefill to
produce next-token logits.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .block_allocator import BlockAllocator


class _Node:
    __slots__ = ("key", "block", "children", "parent", "last_access", "lock")

    def __init__(self, key: Tuple[int, ...], block: int,
                 parent: Optional["_Node"]):
        self.key = key
        self.block = block
        self.children: Dict[int, "_Node"] = {}
        self.parent = parent
        self.last_access = 0
        self.lock = 0


@dataclass
class PrefixMatch:
    """Result of a cache lookup.

    ``blocks``: full shared blocks covering ``len(blocks) * block_size``
    prompt tokens (adopt via ``BlockAllocator.alloc(shared=...)``).
    ``cow_block``/``cow_tokens``: optional partially-matched boundary block
    to fork (device copy) plus how many of its rows are valid prompt KV.
    ``nodes``: the matched (and pinned) tree nodes — release with
    ``PrefixCache.unpin`` once the blocks are adopted or the admission is
    abandoned.
    """
    blocks: List[int] = field(default_factory=list)
    cow_block: Optional[int] = None
    cow_tokens: int = 0
    nodes: List[_Node] = field(default_factory=list)
    n_tokens: int = 0


@dataclass
class PrefixCacheStats:
    lookups: int = 0
    hits: int = 0              # lookups matching >= 1 token
    hit_tokens: int = 0        # prompt tokens served from cache (== prefill saved)
    lookup_tokens: int = 0     # prompt tokens presented to lookup
    inserts: int = 0
    inserted_blocks: int = 0   # novel blocks adopted by the tree
    evicted_blocks: int = 0
    cow_forks: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.lookups, 1)

    @property
    def token_hit_rate(self) -> float:
        return self.hit_tokens / max(self.lookup_tokens, 1)


class PrefixCache:
    """Namespace-scoped radix tree of resident prompt-prefix KV blocks."""

    def __init__(self, allocator: BlockAllocator, *,
                 max_blocks: Optional[int] = None):
        self.allocator = allocator
        self.block_size = allocator.block_size
        # None = bounded only by pool pressure (admission-driven eviction).
        self.max_blocks = max_blocks
        self._roots: Dict[str, _Node] = {}
        self._tick = 0
        self.n_blocks = 0          # blocks the cache holds a reference on
        self.stats = PrefixCacheStats()

    # ------------------------------------------------------------------ utils
    def _root(self, namespace: str) -> _Node:
        root = self._roots.get(namespace)
        if root is None:
            root = _Node((), -1, None)
            self._roots[namespace] = root
        return root

    def _touch(self, node: _Node) -> None:
        self._tick += 1
        node.last_access = self._tick

    @staticmethod
    def _lcp(a: Sequence[int], b: Sequence[int]) -> int:
        n = 0
        for x, y in zip(a, b):
            if x != y:
                break
            n += 1
        return n

    # ----------------------------------------------------------------- lookup
    def lookup(self, tokens: Sequence[int],
               namespace: str = "") -> PrefixMatch:
        """Match the longest cached prefix of ``tokens`` (capped one short
        of the full prompt).  Matched nodes are pinned against eviction —
        call ``unpin(match)`` after adopting the blocks."""
        tokens = [int(t) for t in tokens]
        bs = self.block_size
        self.stats.lookups += 1
        self.stats.lookup_tokens += len(tokens)
        match = PrefixMatch()
        node = self._root(namespace)
        i = 0
        cap = len(tokens) - 1  # leave >= 1 token to prefill for logits
        while i + bs <= cap:
            child = node.children.get(tokens[i])
            if child is None or len(child.key) != bs or \
                    tuple(tokens[i:i + bs]) != child.key:
                break
            node = child
            node.lock += 1
            self._touch(node)
            match.nodes.append(node)
            match.blocks.append(node.block)
            i += bs
        # Boundary: one more child may cover part of the remaining tokens —
        # either a partial leaf, or a full block we cannot consume whole
        # (divergence mid-block, or the cap).  Fork it copy-on-write.
        if i <= cap:
            child = node.children.get(tokens[i])
            if child is not None:
                p = self._lcp(child.key, tokens[i:i + len(child.key)])
                p = min(p, cap - i)
                if p > 0:
                    child.lock += 1
                    self._touch(child)
                    match.nodes.append(child)
                    match.cow_block = child.block
                    match.cow_tokens = p
        match.n_tokens = len(match.blocks) * bs + match.cow_tokens
        if match.n_tokens > 0:
            self.stats.hits += 1
            self.stats.hit_tokens += match.n_tokens
        return match

    def unpin(self, match: PrefixMatch) -> None:
        """Release the eviction pins taken by ``lookup``."""
        for node in match.nodes:
            assert node.lock > 0
            node.lock -= 1
        match.nodes = []

    # ----------------------------------------------------------------- insert
    def insert(self, tokens: Sequence[int], blocks: Sequence[int],
               namespace: str = "") -> List[int]:
        """Promote a retiring request's prompt into the tree.  ``blocks`` is
        the request's block table covering at least ``tokens`` (extra tail
        entries ignored).  Novel blocks are adopted by ``cache_ref`` —
        sharing them with the (still-live) request until its ``free`` drops
        its own reference.  Dedup keeps the tree's existing block where the
        path already exists; a partial leaf whose key is a prefix of ours
        is *upgraded* in place to our fuller block.  Divergence inside a
        partial block cannot be represented (one block, two token chunks),
        so insertion stops there.  Returns blocks freed by upgrades or by
        the post-insert capacity trim (caller must scrub them)."""
        tokens = [int(t) for t in tokens]
        bs = self.block_size
        self.stats.inserts += 1
        freed: List[int] = []
        node = self._root(namespace)
        i = 0
        while i < len(tokens):
            chunk = tuple(tokens[i:i + bs])
            blk = int(blocks[i // bs])
            child = node.children.get(chunk[0])
            if child is None:
                child = _Node(chunk, blk, node)
                self.allocator.cache_ref([blk])
                self.n_blocks += 1
                self.stats.inserted_blocks += 1
                node.children[chunk[0]] = child
                self._touch(child)
                node = child
            elif child.key == chunk:
                self._touch(child)          # dedup: keep the tree's block
                node = child
            elif len(child.key) < len(chunk) and \
                    chunk[:len(child.key)] == child.key and not child.children:
                # Upgrade a shorter partial leaf to our fuller block.  Any
                # live sharer of the old block keeps its own reference.
                freed.extend(self.allocator.cache_unref([child.block]))
                self.allocator.cache_ref([blk])
                self.stats.inserted_blocks += 1
                del node.children[child.key[0]]
                child.key, child.block = chunk, blk
                node.children[chunk[0]] = child
                self._touch(child)
                node = child
            else:
                break  # intra-block divergence (or longer existing partial)
            i += bs
        freed.extend(self._trim())
        return freed

    # --------------------------------------------------------------- eviction
    def _leaves(self) -> List[_Node]:
        out = []
        stack = list(self._roots.values())
        while stack:
            n = stack.pop()
            kids = list(n.children.values())
            stack.extend(kids)
            if not kids and n.parent is not None:
                out.append(n)
        return out

    def _evict_node(self, node: _Node) -> List[int]:
        assert node.lock == 0 and not node.children
        del node.parent.children[node.key[0]]
        self.n_blocks -= 1
        freed = self.allocator.cache_unref([node.block])
        self.stats.evicted_blocks += 1
        return freed

    def evict(self, n_needed: int) -> List[int]:
        """LRU-evict unlocked leaves until the allocator can hand out
        ``n_needed`` more reservation blocks (or nothing evictable is
        left).  Returns freed block ids for the caller to scrub."""
        freed: List[int] = []
        while self.allocator.available < n_needed:
            victims = [n for n in self._leaves() if n.lock == 0]
            if not victims:
                break
            freed.extend(self._evict_node(
                min(victims, key=lambda n: n.last_access)))
        return freed

    def _trim(self) -> List[int]:
        """Enforce the ``max_blocks`` cap after an insert."""
        freed: List[int] = []
        while self.max_blocks is not None and self.n_blocks > self.max_blocks:
            victims = [n for n in self._leaves() if n.lock == 0]
            if not victims:
                break
            freed.extend(self._evict_node(
                min(victims, key=lambda n: n.last_access)))
        return freed

    # ------------------------------------------------------------- warm state
    def hot_keys(self, max_keys: Optional[int] = None
                 ) -> Dict[str, List[List[int]]]:
        """Hottest resident prefix token-chains per namespace, most recently
        used first (``max_keys`` caps each namespace's list).

        Warm-state persistence (repro.fleet) serializes KEYS only: the KV
        blocks behind them are device-resident and cannot survive a restart.
        A warm-restarted engine re-prefills each key once (priming requests)
        and the retire-time insert repopulates the tree through the regular
        machinery — recovering hit rate without trusting foreign KV bytes.
        """
        out: Dict[str, List[List[int]]] = {}
        for ns, root in self._roots.items():
            chains: List[Tuple[int, List[int]]] = []
            stack: List[Tuple[_Node, List[int]]] = [
                (ch, list(ch.key)) for ch in root.children.values()]
            while stack:
                node, toks = stack.pop()
                kids = list(node.children.values())
                if not kids:
                    chains.append((node.last_access, toks))
                    continue
                stack.extend((ch, toks + list(ch.key)) for ch in kids)
            chains.sort(key=lambda c: -c[0])
            if max_keys is not None:
                chains = chains[:max_keys]
            if chains:
                out[ns] = [toks for _, toks in chains]
        return out

    def clear(self) -> List[int]:
        """Drop every cached prefix (all namespaces); returns freed ids.
        Post-order: repeatedly strip unlocked leaves."""
        freed: List[int] = []
        while True:
            victims = [n for n in self._leaves() if n.lock == 0]
            if not victims:
                break
            for v in victims:
                freed.extend(self._evict_node(v))
        return freed


__all__ = ["PrefixCache", "PrefixMatch", "PrefixCacheStats"]
