"""Build the jitted StepFns driving a LookaheadEngine for a transformer LM.

Compile-once contract (DESIGN.md §Compile-once shapes): for one session every
device function is traced for exactly one shape —

  * ``tree_step`` / ``fused_step`` / ``commit`` at the engine's tree width T
    and lane count B,
  * ``prefill`` at ``(B, prefill_len)`` for the initial admission cohort,
  * ``prefill_into_slot`` at ``(1, prefill_len)`` (lane index is a traced
    scalar, so admission into any slot reuses the same executable).

Without ``prefill_len`` the legacy pad-to-batch-max behaviour retraces per
distinct prompt length.

Per-request sampling (DESIGN.md §Serving API): every token-choosing member
additionally takes a trailing ``lane_params`` dict of per-lane device vectors
``{"greedy": (B,) bool, "temp": (B,) f32, "seed": (B,) u32}`` — traced
*inputs*, so one executable serves a lane pool mixing greedy and sampled
requests at distinct temperatures/seeds.  Call sites that omit it (legacy
tests, one-shot scripts) get the session-level defaults.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.request import SamplingParams, StepFns
from repro.models import attention as attn_backends
from repro.models import transformer as tx
from repro.serving.sampler import choose_tokens, choose_tokens_lanes


def _seed_from_key(base_key) -> int:
    """Legacy ``base_key`` compat: collapse a typed PRNG key to the u32 seed
    the per-lane mechanism derives its keys from.  XORs every key word so
    distinct keys (e.g. fold_in/split siblings differing only in the high
    word) keep distinct seeds; the sampled stream still changes across the
    upgrade — only determinism-per-session is preserved, which is all the
    lossless property needs."""
    words = np.asarray(jax.random.key_data(base_key)).ravel()
    return int(np.bitwise_xor.reduce(words.astype(np.uint32)))


def _expose(wrapper: Callable, jitted: Callable) -> Callable:
    """Give a thin python wrapper the jit introspection surface the
    compile-once tests (and resume tooling) rely on."""
    wrapper._cache_size = jitted._cache_size
    wrapper._jitted = jitted
    return wrapper


def make_session_fns(cfg: tx.TransformerConfig, params: tx.Params, *,
                     sample: bool = False, temperature: float = 1.0,
                     base_key: Optional[jax.Array] = None,
                     seed: Optional[int] = None,
                     sampling: str = "mixed",
                     slots: int = 1, pad_id: int = 0,
                     prefill_len: Optional[int] = None,
                     logits_transform: Optional[Callable] = None,
                     backend: Optional[str] = None,
                     prefill_backend: Optional[str] = None,
                     decode_backend: Optional[str] = None,
                     kv_layout: Optional[str] = None,
                     block_size: Optional[int] = None,
                     n_blocks: Optional[int] = None) -> StepFns:
    """Jitted prefill / prefill_into_slot / tree_step / commit closures over
    ``params``.

    ``slots`` is the tree width T = 1 + decoding_length the serving loop pads
    every draft to.  ``prefill_len`` fixes the prompt pad length so prefill
    paths compile once; prompts longer than it are rejected at submit time.
    ``logits_transform(logits, tokens, positions)`` optionally rewrites the
    step logits before token choice (the benchmarks' guided model) — it must
    stay a pure function of (token, position) to preserve losslessness.

    ``sample`` / ``temperature`` / ``seed`` set the *session defaults* a
    request inherits when submitted without its own ``SamplingParams``
    (``base_key`` is the deprecated spelling of ``seed``).  ``sampling``
    selects the token-choice lane: "mixed" (default) honors per-request
    params via traced per-lane vectors; "greedy" compiles an argmax-only
    session — fastest pure-greedy path, sampled requests are rejected at
    submit.

    ``backend`` overrides both attention phases at once;
    ``prefill_backend`` / ``decode_backend`` override one phase (names are
    resolved against the repro.models.attention registry — bad names fail
    here, not at trace time).

    ``kv_layout`` ("dense" | "paged") / ``block_size`` override the config's
    KV-cache layout; for the paged layout ``n_blocks`` sizes the shared
    block pool (None = the dense-equivalent worst case of
    lanes * ceil(max_seq_len / block_size) + 1 NULL block — serving stacks
    pass a smaller pool sized to the workload, which is the memory win).
    """
    overrides = {}
    if backend is not None:
        overrides["prefill_backend"] = backend
        overrides["decode_backend"] = backend
    if prefill_backend is not None:
        overrides["prefill_backend"] = prefill_backend
    if decode_backend is not None:
        overrides["decode_backend"] = decode_backend
    if kv_layout is not None:
        overrides["kv_layout"] = kv_layout
    if block_size is not None:
        overrides["kv_block_size"] = int(block_size)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    attn_backends.get_backend(cfg.prefill_backend)
    attn_backends.get_backend(cfg.decode_backend)
    if cfg.kv_layout not in ("dense", "paged"):
        raise ValueError(f"unknown kv_layout {cfg.kv_layout!r}")
    if cfg.kv_layout == "paged" and cfg.kv_block_size < 1:
        raise ValueError(f"kv_block_size={cfg.kv_block_size}")
    if sampling not in ("mixed", "greedy"):
        raise ValueError(f"sampling={sampling!r}: expected 'mixed' or "
                         "'greedy'")
    if sampling == "greedy" and sample:
        raise ValueError("sampling='greedy' builds an argmax-only session; "
                         "it cannot default to sample=True")
    if seed is None:
        seed = _seed_from_key(base_key) if base_key is not None else 0
    defaults = SamplingParams(sample=sample, temperature=float(temperature),
                              seed=int(seed)).validate()

    if sampling == "greedy":
        def _choose(logits, pred_positions, lane_params):
            del lane_params   # argmax-only session: params carry no entropy
            return choose_tokens(logits, pred_positions)
    else:
        def _choose(logits, pred_positions, lane_params):
            return choose_tokens_lanes(logits, pred_positions, lane_params)

    def _default_lane_params(n: int):
        return {
            "greedy": np.full((n,), not defaults.sample),
            "temp": np.full((n,), defaults.temperature, dtype=np.float32),
            "seed": np.full((n,), defaults.seed, dtype=np.uint32),
        }

    def _choose_last(tokens, lens, last_logits, lane_params):
        lg = last_logits[:, None, :]
        if logits_transform is not None:
            last_tok = jnp.take_along_axis(tokens, (lens - 1)[:, None],
                                           axis=1)
            lg = logits_transform(lg, last_tok, (lens - 1)[:, None])
        return _choose(lg, lens[:, None], lane_params)[:, 0]

    if cfg.kv_layout == "paged":
        @functools.partial(jax.jit, donate_argnums=())
        def _prefill(tokens, lens, block_tables, lane_params):
            cache = tx.init_paged_cache(cfg, tokens.shape[0], n_blocks)
            cache["block_tables"] = jnp.asarray(block_tables, jnp.int32)
            cache, last_logits = tx.prefill_paged(cfg, params, tokens, lens,
                                                  cache)
            return cache, _choose_last(tokens, lens, last_logits,
                                       lane_params)

        @functools.partial(jax.jit, donate_argnums=(0,))
        def _prefill_into_slot(cache, slot, tokens, lens, lane_params):
            cache, last_logits = tx.prefill_into_slot_paged(
                cfg, params, cache, slot, tokens, lens)
            return cache, _choose_last(tokens, lens, last_logits,
                                       lane_params)

        @functools.partial(jax.jit, donate_argnums=(0,))
        def _tree_step(cache, cache_lens, tokens, pos, mask, lane_params):
            cache, logits = tx.tree_step_paged(cfg, params, cache,
                                               cache_lens, tokens, pos, mask)
            if logits_transform is not None:
                logits = logits_transform(logits, tokens, pos)
            chosen = _choose(logits, pos + 1, lane_params)
            return cache, chosen

        @functools.partial(jax.jit, donate_argnums=(0,))
        def _commit(cache, cache_lens, gather_idx, n_accept):
            return tx.commit_paged_cache(cfg, cache, cache_lens, gather_idx,
                                         n_accept)

        @functools.partial(jax.jit, donate_argnums=(0,))
        def _fused_step(cache, cache_lens, tokens, pos, mask, parent, n_live,
                        lane_params):
            cache, logits = tx.tree_step_paged(cfg, params, cache,
                                               cache_lens, tokens, pos, mask)
            if logits_transform is not None:
                logits = logits_transform(logits, tokens, pos)
            chosen = _choose(logits, pos + 1, lane_params)
            n_acc, acc_tok, kv_slots = tx.verify_accept_device(
                tokens, parent, n_live, chosen)
            cache, _ = tx.commit_paged_cache(cfg, cache, cache_lens,
                                             kv_slots, n_acc)
            return cache, tx.pack_step_result(n_acc, acc_tok, kv_slots)

        @functools.partial(jax.jit, donate_argnums=(0,))
        def _reset_blocks(cache, block_ids):
            return tx.reset_blocks(cache, block_ids)

        # Prefix-cache device surface.  ONE jitted suffix prefill serves
        # every bucket: jax.jit keys its executable cache on the padded
        # token shape, so the compile count equals the number of distinct
        # buckets actually used — never the number of requests (lane and
        # offset are traced scalars).
        @functools.partial(jax.jit, donate_argnums=(0,))
        def _prefill_suffix(cache, slot, tokens, offset, slen, lane_params):
            cache, last_logits = tx.prefill_from_offset_paged(
                cfg, params, cache, slot, tokens, offset, slen)
            lg = last_logits[:, None, :]
            if logits_transform is not None:
                last_tok = jnp.take_along_axis(tokens, (slen - 1)[:, None],
                                               axis=1)
                lg = logits_transform(lg, last_tok,
                                      (offset + slen - 1)[:, None])
            return cache, _choose(lg, (offset + slen)[:, None],
                                  lane_params)[:, 0]

        @functools.partial(jax.jit, donate_argnums=(0,))
        def _copy_block(cache, src, dst):
            return tx.copy_paged_block(cache, src, dst)

        _cap = int(prefill_len) if prefill_len else cfg.max_seq_len
        suffix_buckets, _b = [], 8
        while _b < _cap:
            suffix_buckets.append(_b)
            _b *= 2
        suffix_buckets.append(_cap)
        suffix_buckets = tuple(suffix_buckets)

        # preallocated staging buffers: jax copies numpy inputs at
        # dispatch, so reusing host scratch across calls is safe and
        # avoids three fresh allocations per suffix prefill
        _pad_bufs = {b: np.full((1, b), pad_id, np.int32)
                     for b in suffix_buckets}
        _off_buf = np.zeros((1,), np.int32)
        _len_buf = np.zeros((1,), np.int32)

        def prefill_suffix(cache, slot, tokens, offset, lane_params=None):
            """tokens (1, n): the UN-padded prompt suffix; offset: cached
            prefix length.  Pads n up to the smallest suffix bucket."""
            tokens = np.asarray(tokens, np.int32)
            n = tokens.shape[1]
            bucket = next(b for b in suffix_buckets if b >= n)
            padded = _pad_bufs[bucket]
            padded[0, :n] = tokens[0]
            padded[0, n:] = pad_id
            _off_buf[0] = offset
            _len_buf[0] = n
            if lane_params is None:
                lane_params = _default_lane_params(1)
            return _prefill_suffix(cache, slot, padded,
                                   _off_buf, _len_buf, lane_params)

        def copy_block(cache, src, dst):
            return _copy_block(cache, np.int32(src), np.int32(dst))

        def _init_cache(lanes: int):
            return tx.init_paged_cache(cfg, lanes, n_blocks)

        def prefill(tokens, lens, block_tables, lane_params=None):
            if lane_params is None:
                lane_params = _default_lane_params(tokens.shape[0])
            return _prefill(tokens, lens, block_tables, lane_params)

        def prefill_into_slot(cache, slot, tokens, lens, lane_params=None):
            if lane_params is None:
                lane_params = _default_lane_params(tokens.shape[0])
            return _prefill_into_slot(cache, slot, tokens, lens, lane_params)

        def tree_step(cache, cache_lens, tokens, pos, mask,
                      lane_params=None):
            if lane_params is None:
                lane_params = _default_lane_params(tokens.shape[0])
            return _tree_step(cache, cache_lens, tokens, pos, mask,
                              lane_params)

        def fused_step(cache, cache_lens, tokens, pos, mask, parent, n_live,
                       lane_params=None):
            if lane_params is None:
                lane_params = _default_lane_params(tokens.shape[0])
            return _fused_step(cache, cache_lens, tokens, pos, mask,
                               parent, n_live, lane_params)

        return StepFns(prefill=_expose(prefill, _prefill),
                       tree_step=_expose(tree_step, _tree_step),
                       fused_step=_expose(fused_step, _fused_step),
                       commit=_commit, slots=slots,
                       max_seq_len=cfg.max_seq_len, pad_id=pad_id,
                       init_cache=_init_cache,
                       prefill_into_slot=_expose(prefill_into_slot,
                                                 _prefill_into_slot),
                       reset_slot=None, prefill_len=prefill_len,
                       kv_layout="paged", block_size=cfg.kv_block_size,
                       n_blocks=n_blocks, reset_blocks=_reset_blocks,
                       prefill_suffix=_expose(prefill_suffix,
                                              _prefill_suffix),
                       copy_block=_expose(copy_block, _copy_block),
                       suffix_buckets=suffix_buckets,
                       per_lane_params=True, session_defaults=defaults,
                       sampling=sampling)

    @functools.partial(jax.jit, donate_argnums=())
    def _prefill(tokens, lens, lane_params):
        cache = tx.init_cache(cfg, tokens.shape[0])
        cache, last_logits = tx.prefill(cfg, params, tokens, lens, cache)
        return cache, _choose_last(tokens, lens, last_logits, lane_params)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def _prefill_into_slot(cache, slot, tokens, lens, lane_params):
        cache, last_logits = tx.prefill_into_slot(cfg, params, cache, slot,
                                                  tokens, lens)
        return cache, _choose_last(tokens, lens, last_logits, lane_params)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def _tree_step(cache, cache_lens, tokens, pos, mask, lane_params):
        cache, logits = tx.tree_step(cfg, params, cache, cache_lens,
                                     tokens, pos, mask)
        if logits_transform is not None:
            logits = logits_transform(logits, tokens, pos)
        chosen = _choose(logits, pos + 1, lane_params)
        return cache, chosen

    @functools.partial(jax.jit, donate_argnums=(0,))
    def _commit(cache, cache_lens, gather_idx, n_accept):
        return tx.commit_cache(cache, cache_lens, gather_idx, n_accept)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def _fused_step(cache, cache_lens, tokens, pos, mask, parent, n_live,
                    lane_params):
        cache, logits = tx.tree_step(cfg, params, cache, cache_lens,
                                     tokens, pos, mask)
        if logits_transform is not None:
            logits = logits_transform(logits, tokens, pos)
        chosen = _choose(logits, pos + 1, lane_params)
        n_acc, acc_tok, kv_slots = tx.verify_accept_device(
            tokens, parent, n_live, chosen)
        cache, _ = tx.commit_cache(cache, cache_lens, kv_slots, n_acc)
        return cache, tx.pack_step_result(n_acc, acc_tok, kv_slots)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def _reset_slot(cache, slot):
        return tx.reset_slot(cache, slot)

    def _init_cache(lanes: int):
        return tx.init_cache(cfg, lanes)

    def prefill(tokens, lens, lane_params=None):
        if lane_params is None:
            lane_params = _default_lane_params(tokens.shape[0])
        return _prefill(tokens, lens, lane_params)

    def prefill_into_slot(cache, slot, tokens, lens, lane_params=None):
        if lane_params is None:
            lane_params = _default_lane_params(tokens.shape[0])
        return _prefill_into_slot(cache, slot, tokens, lens, lane_params)

    def tree_step(cache, cache_lens, tokens, pos, mask, lane_params=None):
        if lane_params is None:
            lane_params = _default_lane_params(tokens.shape[0])
        return _tree_step(cache, cache_lens, tokens, pos, mask, lane_params)

    def fused_step(cache, cache_lens, tokens, pos, mask, parent, n_live,
                   lane_params=None):
        if lane_params is None:
            lane_params = _default_lane_params(tokens.shape[0])
        return _fused_step(cache, cache_lens, tokens, pos, mask,
                           parent, n_live, lane_params)

    return StepFns(prefill=_expose(prefill, _prefill),
                   tree_step=_expose(tree_step, _tree_step),
                   fused_step=_expose(fused_step, _fused_step),
                   commit=_commit,
                   slots=slots, max_seq_len=cfg.max_seq_len, pad_id=pad_id,
                   init_cache=_init_cache,
                   prefill_into_slot=_expose(prefill_into_slot,
                                             _prefill_into_slot),
                   reset_slot=_reset_slot, prefill_len=prefill_len,
                   per_lane_params=True, session_defaults=defaults,
                   sampling=sampling)


__all__ = ["make_session_fns"]
