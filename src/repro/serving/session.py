"""Build the jitted StepFns driving a LookaheadEngine for a transformer LM."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.engine import StepFns
from repro.models import transformer as tx
from repro.serving.sampler import choose_tokens


def make_session_fns(cfg: tx.TransformerConfig, params: tx.Params, *,
                     sample: bool = False, temperature: float = 1.0,
                     base_key: Optional[jax.Array] = None,
                     slots: int = 1, pad_id: int = 0) -> StepFns:
    """Jitted prefill / tree_step / commit closures over ``params``.

    ``slots`` is informational (engine uses tree sizes dynamically; jit
    retraces per distinct T, which is 1 or 2 shapes in practice).
    """
    choose = functools.partial(choose_tokens, sample=sample,
                               temperature=temperature, base_key=base_key)

    @jax.jit
    def _prefill(tokens, lens):
        cache = tx.init_cache(cfg, tokens.shape[0])
        cache, last_logits = tx.prefill(cfg, params, tokens, lens, cache)
        chosen = choose(last_logits[:, None, :], lens[:, None])[:, 0]
        return cache, chosen

    @functools.partial(jax.jit, donate_argnums=(0,))
    def _tree_step(cache, cache_lens, tokens, pos, mask):
        cache, logits = tx.tree_step(cfg, params, cache, cache_lens,
                                     tokens, pos, mask)
        chosen = choose(logits, pos + 1)
        return cache, chosen

    @functools.partial(jax.jit, donate_argnums=(0,))
    def _commit(cache, cache_lens, gather_idx, n_accept):
        return tx.commit_cache(cache, cache_lens, gather_idx, n_accept)

    return StepFns(prefill=_prefill, tree_step=_tree_step, commit=_commit,
                   slots=slots, max_seq_len=cfg.max_seq_len, pad_id=pad_id)


__all__ = ["make_session_fns"]
