"""Host-side block allocator for the paged KV cache (vLLM-style).

The device cache is a pool of fixed-size blocks ``(n_blocks, block_size, K,
dh)`` per layer; each request owns an ordered list of physical block ids (its
*block table*) mapping logical token positions to cache rows:

    phys_row(p) = table[p // block_size] * block_size + p % block_size

Physical block 0 is reserved as the NULL/trash block: unallocated table
entries point at it, and device scatters of never-attended rows (prompt pad
rows, idle-lane draft slots) land there harmlessly.  The allocator therefore
hands out ids from ``[1, n_blocks)`` only.

Admission is *reservation-based* so serving stays preemption-free: a request
reserves its worst-case block demand up front (``can_admit``/``alloc``) but
takes physical blocks incrementally (``alloc`` then ``extend`` as the
sequence grows).  Because every physical block is interchangeable, the
reservation invariant

    sum(reserved demand over live requests) <= capacity

guarantees that ``extend`` can never fail mid-flight — a request admitted is
a request that finishes.  Requests whose demand cannot currently be reserved
wait in the scheduler queue (backpressure); since live requests retire in
finite time and ``free`` returns both blocks and reservation, the queue
always drains (no deadlock) as long as any single request's demand fits the
pool — which ``alloc`` enforces up front.

Fragmentation in this design is purely *internal* (a request's last block is
partially used); ``frag_rows``/``frag_rows_total`` account for it.

Prefix sharing (PR 7) adds per-block refcounts on top: a block may be owned
by several requests at once (same logical prefix positions in each table) and
by the radix prefix cache (``cache_ref``/``cache_unref``).  ``free`` then
returns only the blocks whose refcount actually dropped to zero — those are
the only ones the caller may scrub or that re-enter the free list.  Blocks
held *only* by the prefix cache (``n_cache_only``) are not reservable, so the
reservation invariant becomes

    sum(reserved demand) + n_cache_only <= capacity

Reservations deliberately over-count shared blocks (every sharer counts them
in full), which keeps the no-starvation guarantee conservative.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence

NULL_BLOCK = 0


def demand_blocks(prompt_len: int, max_new: int, width: int,
                  max_seq_len: int, block_size: int) -> int:
    """Worst-case block demand of one request: cache rows for its prompt
    plus its full token budget plus one tree width of draft slots, capped
    at max_seq_len (the scheduler's overflow-retirement bound).  This is
    THE admission/reservation formula — pool-sizing callers must use it so
    sizing and admission can never drift apart."""
    need = min(prompt_len + max_new + width, max_seq_len)
    return -(-max(int(need), 1) // block_size)


def worst_case_pool_blocks(lanes: int, prompt_len: int, max_new: int,
                           width: int, max_seq_len: int,
                           block_size: int) -> int:
    """Pool size letting ``lanes`` worst-case requests run concurrently,
    plus the reserved NULL block."""
    return 1 + lanes * demand_blocks(prompt_len, max_new, width,
                                     max_seq_len, block_size)


class BlockAllocator:
    """Free-list allocator over ``n_blocks`` KV-cache blocks of
    ``block_size`` token rows each (block 0 reserved as NULL)."""

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks < 2:
            raise ValueError(f"n_blocks={n_blocks}: need >= 2 (block 0 is "
                             "the reserved NULL block)")
        if block_size < 1:
            raise ValueError(f"block_size={block_size}")
        self.n_blocks = int(n_blocks)
        self.block_size = int(block_size)
        # LIFO free list: freshly freed blocks are re-used first, which keeps
        # the working set hot and makes free-then-alloc reuse easy to test.
        self._free: List[int] = list(range(self.n_blocks - 1, 0, -1))
        self._tables: Dict[int, List[int]] = {}
        self._reserved: Dict[int, int] = {}
        # Per-block owner count.  Owners are (a) each request whose table
        # contains the block and (b) the prefix cache (at most once per
        # block, tracked in _cache_held).  Absent key == free (refcount 0).
        self._ref: Dict[int, int] = {}
        self._cache_held: set = set()
        # Optional event sink (the runtime sanitizer's shadow ledger).
        # Pure observation: the allocator behaves identically with or
        # without one attached.
        self.observer = None

    def _emit(self, event: str, **kw) -> None:
        if self.observer is not None:
            self.observer.on_event(event, **kw)

    # ------------------------------------------------------------------ state
    @property
    def capacity(self) -> int:
        """Usable blocks (total minus the NULL block)."""
        return self.n_blocks - 1

    @property
    def n_free(self) -> int:
        """Physically free blocks right now."""
        return len(self._free)

    @property
    def n_allocated(self) -> int:
        return self.capacity - len(self._free)

    @property
    def n_reserved(self) -> int:
        """Blocks promised to live requests (>= n_allocated)."""
        return sum(self._reserved.values())

    @property
    def n_cache_only(self) -> int:
        """Blocks held *only* by the prefix cache (in no live table).  These
        occupy pool space without backing any reservation, so they reduce
        what new admissions may reserve; they become reservable again the
        moment the cache evicts them (or a live request shares them, at
        which point the sharer's reservation covers them)."""
        return sum(1 for b in self._cache_held if self._ref.get(b, 0) == 1)

    @property
    def available(self) -> int:
        """Blocks still reservable by new admissions."""
        return self.capacity - self.n_reserved - self.n_cache_only

    def refcount(self, block: int) -> int:
        """Current owner count of a physical block (0 == free)."""
        return self._ref.get(int(block), 0)

    def owns(self, rid: int) -> bool:
        """True while ``rid`` holds a block table (allocated, not freed)."""
        return rid in self._tables

    def table(self, rid: int) -> List[int]:
        return list(self._tables[rid])

    def n_blocks_of(self, rid: int) -> int:
        return len(self._tables[rid])

    def reserved_of(self, rid: int) -> int:
        return self._reserved[rid]

    def blocks_for_tokens(self, n_tokens: int) -> int:
        """ceil(n_tokens / block_size)."""
        return -(-max(int(n_tokens), 0) // self.block_size)

    # ------------------------------------------------------------- life cycle
    def can_admit(self, demand_blocks: int) -> bool:
        """True iff a request with this worst-case demand can be admitted
        without ever starving a live request's extend."""
        return 0 < demand_blocks <= self.available

    def alloc(self, rid: int, n_initial: int, *,
              reserve: Optional[int] = None,
              shared: Optional[Sequence[int]] = None) -> List[int]:
        """Admit ``rid``: reserve its worst-case demand and hand out the
        first ``n_initial`` physical blocks.  ``shared`` (prefix-cache hits)
        are adopted at the head of the table by refcount increment — they
        count against the reservation like any other block but consume no
        free-list entry.  Returns the freshly allocated ids only."""
        if rid in self._tables:
            raise ValueError(f"request {rid} already has a block table")
        shared = list(shared) if shared else []
        reserve = n_initial if reserve is None else int(reserve)
        if reserve < n_initial:
            raise ValueError(f"reserve={reserve} < n_initial={n_initial}")
        if reserve > self.capacity:
            raise ValueError(
                f"request {rid} demands {reserve} blocks; pool capacity is "
                f"{self.capacity} (n_blocks={self.n_blocks}, "
                f"block_size={self.block_size})")
        if not self.can_admit(reserve):
            raise RuntimeError(
                f"cannot admit request {rid}: demand {reserve} blocks, "
                f"available {self.available} (backpressure)")
        if n_initial < len(shared):
            raise ValueError(f"n_initial={n_initial} < {len(shared)} shared")
        self._reserved[rid] = reserve
        self._tables[rid] = []
        self._emit("alloc", rid=rid, reserve=reserve)
        if shared:
            self.share(rid, shared)
        return self.extend(rid, n_initial - len(shared))

    def share(self, rid: int, blocks: Sequence[int]) -> None:
        """Append already-resident blocks to ``rid``'s table (refcount++).
        The blocks must be live (refcount > 0) — sharing a free block would
        hand out rows another admission can claim."""
        table = self._tables.get(rid)
        if table is None:
            raise KeyError(f"unknown request {rid}")
        blocks = [int(b) for b in blocks]
        for b in blocks:
            if self._ref.get(b, 0) <= 0:
                raise ValueError(f"block {b} is not live; cannot share")
        if len(table) + len(blocks) > self._reserved[rid]:
            raise RuntimeError(
                f"request {rid}: sharing {len(blocks)} blocks exceeds its "
                f"reservation of {self._reserved[rid]}")
        for b in blocks:
            self._ref[b] += 1
            table.append(b)
        self._emit("share", rid=rid, blocks=list(blocks))

    def extend(self, rid: int, n_more: int) -> List[int]:
        """Grow ``rid``'s table by ``n_more`` physical blocks.  Never fails
        for an admitted request staying within its reservation (the
        reservation invariant keeps that many blocks physically free)."""
        table = self._tables.get(rid)
        if table is None:
            raise KeyError(f"unknown request {rid}")
        if n_more < 0:
            raise ValueError(f"n_more={n_more}")
        if len(table) + n_more > self._reserved[rid]:
            raise RuntimeError(
                f"request {rid}: extend to {len(table) + n_more} blocks "
                f"exceeds its reservation of {self._reserved[rid]}")
        assert n_more <= len(self._free), "reservation invariant violated"
        new = [self._free.pop() for _ in range(n_more)]
        for b in new:
            assert self._ref.get(b, 0) == 0, f"free-list block {b} is live"
            self._ref[b] = 1
        table.extend(new)
        self._emit("extend", rid=rid, blocks=list(new))
        return new

    def fork_cow(self, rid: int, src_block: int) -> int:
        """Copy-on-write fork: allocate a fresh block (from ``rid``'s own
        reservation) destined to receive a device copy of ``src_block`` — a
        partially-filled boundary block whose KV rows ``rid`` shares but
        must extend.  The source must be live (shared or cache-held); the
        caller performs the actual device copy and the suffix overwrite."""
        src_block = int(src_block)
        if self._ref.get(src_block, 0) <= 0:
            raise ValueError(f"block {src_block} is not live; nothing to fork")
        return self.extend(rid, 1)[0]

    def free(self, rid: int) -> List[int]:
        """Retire ``rid``: drop one reference on each of its physical blocks
        and release its reservation.  Returns ONLY the blocks whose refcount
        reached zero — blocks still shared with the prefix cache or with a
        co-resident request stay out of the free list, so the caller can
        never scrub or re-allocate KV another owner depends on.  Freed ids
        must be scrubbed BEFORE re-allocation (reset-slot hygiene)."""
        if rid in self._tables:
            self._emit("free_enter", rid=rid, table=list(self._tables[rid]))
        table = self._tables.pop(rid, None)
        if table is None:
            raise KeyError(f"unknown request {rid}")
        del self._reserved[rid]
        freed: List[int] = []
        for b in table:
            n = self._ref[b] - 1
            if n == 0:
                del self._ref[b]
                freed.append(b)
            else:
                self._ref[b] = n
        self._free.extend(freed)
        self._emit("free", rid=rid, freed=list(freed))
        return freed

    # ---------------------------------------------------------- prefix cache
    def cache_ref(self, blocks: Iterable[int]) -> None:
        """The prefix cache takes (at most one) ownership reference on each
        block, pinning it out of the free list across request retirement."""
        taken: List[int] = []
        for b in blocks:
            b = int(b)
            if b in self._cache_held:
                raise ValueError(f"block {b} already cache-held")
            if self._ref.get(b, 0) <= 0:
                raise ValueError(f"block {b} is not live; cannot cache_ref")
            self._ref[b] += 1
            self._cache_held.add(b)
            taken.append(b)
        self._emit("cache_ref", blocks=taken)

    def cache_unref(self, blocks: Iterable[int]) -> List[int]:
        """Release the prefix cache's reference (eviction).  Returns the
        blocks that became free as a result — the caller must scrub those
        before they can be re-allocated."""
        freed: List[int] = []
        dropped: List[int] = []
        for b in blocks:
            b = int(b)
            if b not in self._cache_held:
                raise ValueError(f"block {b} is not cache-held")
            self._cache_held.discard(b)
            dropped.append(b)
            n = self._ref[b] - 1
            if n == 0:
                del self._ref[b]
                freed.append(b)
            else:
                self._ref[b] = n
        self._free.extend(freed)
        self._emit("cache_unref", blocks=dropped, freed=list(freed))
        return freed

    # ---------------------------------------------------------- fragmentation
    def frag_rows(self, rid: int, used_rows: int) -> int:
        """Internal fragmentation of one request: allocated-but-unused token
        rows (its partially-filled tail block plus any pre-extended ones)."""
        return len(self._tables[rid]) * self.block_size - int(used_rows)

    def frag_rows_total(self, used_rows: Dict[int, int]) -> int:
        """Aggregate internal fragmentation over live requests; ``used_rows``
        maps rid -> committed token rows."""
        return sum(self.frag_rows(rid, used_rows.get(rid, 0))
                   for rid in self._tables)


__all__ = ["BlockAllocator", "NULL_BLOCK", "demand_blocks",
           "worst_case_pool_blocks"]
