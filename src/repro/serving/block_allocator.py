"""Host-side block allocator for the paged KV cache (vLLM-style).

The device cache is a pool of fixed-size blocks ``(n_blocks, block_size, K,
dh)`` per layer; each request owns an ordered list of physical block ids (its
*block table*) mapping logical token positions to cache rows:

    phys_row(p) = table[p // block_size] * block_size + p % block_size

Physical block 0 is reserved as the NULL/trash block: unallocated table
entries point at it, and device scatters of never-attended rows (prompt pad
rows, idle-lane draft slots) land there harmlessly.  The allocator therefore
hands out ids from ``[1, n_blocks)`` only.

Admission is *reservation-based* so serving stays preemption-free: a request
reserves its worst-case block demand up front (``can_admit``/``alloc``) but
takes physical blocks incrementally (``alloc`` then ``extend`` as the
sequence grows).  Because every physical block is interchangeable, the
reservation invariant

    sum(reserved demand over live requests) <= capacity

guarantees that ``extend`` can never fail mid-flight — a request admitted is
a request that finishes.  Requests whose demand cannot currently be reserved
wait in the scheduler queue (backpressure); since live requests retire in
finite time and ``free`` returns both blocks and reservation, the queue
always drains (no deadlock) as long as any single request's demand fits the
pool — which ``alloc`` enforces up front.

Fragmentation in this design is purely *internal* (a request's last block is
partially used); ``frag_rows``/``frag_rows_total`` account for it.
"""
from __future__ import annotations

from typing import Dict, List, Optional

NULL_BLOCK = 0


def demand_blocks(prompt_len: int, max_new: int, width: int,
                  max_seq_len: int, block_size: int) -> int:
    """Worst-case block demand of one request: cache rows for its prompt
    plus its full token budget plus one tree width of draft slots, capped
    at max_seq_len (the scheduler's overflow-retirement bound).  This is
    THE admission/reservation formula — pool-sizing callers must use it so
    sizing and admission can never drift apart."""
    need = min(prompt_len + max_new + width, max_seq_len)
    return -(-max(int(need), 1) // block_size)


def worst_case_pool_blocks(lanes: int, prompt_len: int, max_new: int,
                           width: int, max_seq_len: int,
                           block_size: int) -> int:
    """Pool size letting ``lanes`` worst-case requests run concurrently,
    plus the reserved NULL block."""
    return 1 + lanes * demand_blocks(prompt_len, max_new, width,
                                     max_seq_len, block_size)


class BlockAllocator:
    """Free-list allocator over ``n_blocks`` KV-cache blocks of
    ``block_size`` token rows each (block 0 reserved as NULL)."""

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks < 2:
            raise ValueError(f"n_blocks={n_blocks}: need >= 2 (block 0 is "
                             "the reserved NULL block)")
        if block_size < 1:
            raise ValueError(f"block_size={block_size}")
        self.n_blocks = int(n_blocks)
        self.block_size = int(block_size)
        # LIFO free list: freshly freed blocks are re-used first, which keeps
        # the working set hot and makes free-then-alloc reuse easy to test.
        self._free: List[int] = list(range(self.n_blocks - 1, 0, -1))
        self._tables: Dict[int, List[int]] = {}
        self._reserved: Dict[int, int] = {}

    # ------------------------------------------------------------------ state
    @property
    def capacity(self) -> int:
        """Usable blocks (total minus the NULL block)."""
        return self.n_blocks - 1

    @property
    def n_free(self) -> int:
        """Physically free blocks right now."""
        return len(self._free)

    @property
    def n_allocated(self) -> int:
        return self.capacity - len(self._free)

    @property
    def n_reserved(self) -> int:
        """Blocks promised to live requests (>= n_allocated)."""
        return sum(self._reserved.values())

    @property
    def available(self) -> int:
        """Blocks still reservable by new admissions."""
        return self.capacity - self.n_reserved

    def table(self, rid: int) -> List[int]:
        return list(self._tables[rid])

    def n_blocks_of(self, rid: int) -> int:
        return len(self._tables[rid])

    def reserved_of(self, rid: int) -> int:
        return self._reserved[rid]

    def blocks_for_tokens(self, n_tokens: int) -> int:
        """ceil(n_tokens / block_size)."""
        return -(-max(int(n_tokens), 0) // self.block_size)

    # ------------------------------------------------------------- life cycle
    def can_admit(self, demand_blocks: int) -> bool:
        """True iff a request with this worst-case demand can be admitted
        without ever starving a live request's extend."""
        return 0 < demand_blocks <= self.available

    def alloc(self, rid: int, n_initial: int, *,
              reserve: Optional[int] = None) -> List[int]:
        """Admit ``rid``: reserve its worst-case demand and hand out the
        first ``n_initial`` physical blocks."""
        if rid in self._tables:
            raise ValueError(f"request {rid} already has a block table")
        reserve = n_initial if reserve is None else int(reserve)
        if reserve < n_initial:
            raise ValueError(f"reserve={reserve} < n_initial={n_initial}")
        if reserve > self.capacity:
            raise ValueError(
                f"request {rid} demands {reserve} blocks; pool capacity is "
                f"{self.capacity} (n_blocks={self.n_blocks}, "
                f"block_size={self.block_size})")
        if not self.can_admit(reserve):
            raise RuntimeError(
                f"cannot admit request {rid}: demand {reserve} blocks, "
                f"available {self.available} (backpressure)")
        self._reserved[rid] = reserve
        self._tables[rid] = []
        return self.extend(rid, n_initial)

    def extend(self, rid: int, n_more: int) -> List[int]:
        """Grow ``rid``'s table by ``n_more`` physical blocks.  Never fails
        for an admitted request staying within its reservation (the
        reservation invariant keeps that many blocks physically free)."""
        table = self._tables.get(rid)
        if table is None:
            raise KeyError(f"unknown request {rid}")
        if n_more < 0:
            raise ValueError(f"n_more={n_more}")
        if len(table) + n_more > self._reserved[rid]:
            raise RuntimeError(
                f"request {rid}: extend to {len(table) + n_more} blocks "
                f"exceeds its reservation of {self._reserved[rid]}")
        assert n_more <= len(self._free), "reservation invariant violated"
        new = [self._free.pop() for _ in range(n_more)]
        table.extend(new)
        return new

    def free(self, rid: int) -> List[int]:
        """Retire ``rid``: return its physical blocks to the free list and
        release its reservation.  Returns the freed ids so the caller can
        scrub them BEFORE they are re-allocated (reset-slot hygiene: once a
        freed block is handed to a new request, zeroing it would destroy the
        new request's KV)."""
        table = self._tables.pop(rid, None)
        if table is None:
            raise KeyError(f"unknown request {rid}")
        del self._reserved[rid]
        self._free.extend(table)
        return table

    # ---------------------------------------------------------- fragmentation
    def frag_rows(self, rid: int, used_rows: int) -> int:
        """Internal fragmentation of one request: allocated-but-unused token
        rows (its partially-filled tail block plus any pre-extended ones)."""
        return len(self._tables[rid]) * self.block_size - int(used_rows)

    def frag_rows_total(self, used_rows: Dict[int, int]) -> int:
        """Aggregate internal fragmentation over live requests; ``used_rows``
        maps rid -> committed token rows."""
        return sum(self.frag_rows(rid, used_rows.get(rid, 0))
                   for rid in self._tables)


__all__ = ["BlockAllocator", "NULL_BLOCK", "demand_blocks",
           "worst_case_pool_blocks"]
