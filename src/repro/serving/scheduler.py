"""Slot-based continuous-batching scheduler (the production serving loop).

The paper's deployment setting ("serve heavy traffic" — Alipay production
since April 2023) needs the device batch to stay full: lock-step batching
leaves lanes idle as soon as the shortest request of a batch finishes, and
with mixed ``max_new_tokens`` most device steps run mostly-empty.  The
scheduler instead owns a fixed pool of ``lanes`` KV-cache slots plus an
admission queue:

  * a submitted request waits in the queue until a lane frees up,
  * the first admission batch-prefills one cohort (``StepFns.prefill`` at
    (lanes, prefill_len) — the dense-FLOPs phase keeps its batching);
    afterwards admission prefills the prompt *into* the freed lane only
    (``StepFns.prefill_into_slot`` — one (1, prefill_len) forward; every
    other lane keeps decoding, its cache untouched),
  * each decode step drives ALL lanes through one fixed-shape
    ``tree_step``/``commit`` pair; idle lanes carry a placeholder draft and
    commit zero tokens (masked out, never stalling anyone),
  * a request leaves its lane on EOS / budget / cache-overflow and the next
    queued request is admitted on the following scheduler iteration.  Stale
    KV rows of a freed lane are left in place — they are never attended
    (invariant I3); ``scrub_freed=True`` zeroes them at free time for
    debugging/inspection, not for correctness.

With a paged StepFns (``kv_layout == "paged"``; DESIGN.md §Paged KV cache)
the scheduler additionally owns a ``BlockAllocator``: admission requires a
free lane AND a reservable worst-case block demand (otherwise the FIFO
queue waits — preemption-free backpressure), block tables ride inside the
cache dict and are extended after each commit to cover the next tree step,
and a retiring request's blocks are freed — and, under ``scrub_freed``,
zeroed by physical id BEFORE they can be re-allocated (lane-keyed scrubbing
after reuse would destroy the next request's KV).

Slot lifecycle (DESIGN.md §Scheduler slot lifecycle):

    FREE --admit(prefill_into_slot)--> ACTIVE --accept*--> DRAINED --release--> FREE

Invariants the implementation maintains (and tests assert):

  I1  Losslessness is per-request: a request's tokens equal
      ``reference_decode`` output regardless of arrival order, lane
      assignment, or what else is co-batched (greedy and position-keyed
      sample mode alike — sampling keys fold the request's own absolute
      output position, never the lane or step index).
  I2  Fixed shapes: every device call after construction uses the same
      (lanes, T) / (1, prefill_len) shapes ⇒ each StepFns member compiles
      exactly once per scheduler.
  I3  A lane's committed cache prefix [0, lens[lane]) is always exactly the
      KV of its request's prompt ⧺ accepted tokens; rows beyond it are
      garbage and never attended.
  I4  Trie bookkeeping is slot-agnostic: prompt branches are inserted at
      admission and eliminated at retirement, output branches stream in as
      tokens are accepted — identical transitions to the lock-step loop.

Speculation is pluggable (DESIGN.md §Draft sources): each request's
resolved ``DraftPolicy`` names the draft sources feeding its trees
(default: the trie source alone — bit-identical to the old hardwired
path), the trie namespace isolating its scenario, and whether its draft
budget adapts to its accepted-length EMA.  All of it is host-side; the
device ``StepFns`` and every invariant above are untouched.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import TYPE_CHECKING, Deque, Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.autotune import AutoTuner
from repro.core.draft_sources import (AdaptiveBudget, DraftPolicy,
                                      DraftSource, TrieSource,
                                      build_draft_from_policy, make_source)
from repro.core.request import (Request, RequestResult, RequestState,
                                SamplingParams, StepFns, cache_token_limit,
                                idle_tree)
from repro.core.strategies import LookaheadConfig
from repro.core.trie import TrieTree
from repro.core.verify import verify_accept_batch
from repro.serving.block_allocator import BlockAllocator, demand_blocks
from repro.serving.prefix_cache import PrefixCache

if TYPE_CHECKING:   # avoid a load-time cycle: api.py imports the scheduler
    from repro.serving.api import RequestHandle


class NamespaceStats:
    """Per-tenant slice of the serving-loop statistics (SLO reporting:
    latency percentiles, lane occupancy, per-source acceptance)."""

    def __init__(self):
        self.submitted = 0
        self.finished = 0          # includes cancelled
        self.cancelled = 0
        self.tokens = 0
        self.lane_steps = 0        # decode steps x lanes this tenant held
        self.latencies: List[float] = []
        self.ttfts: List[float] = []
        self.queue_waits: List[float] = []
        self.source_drafted: Dict[str, int] = {}
        self.source_accepted: Dict[str, int] = {}

    @staticmethod
    def _pct(xs: List[float], q: float) -> float:
        if not xs:
            return 0.0
        ys = sorted(xs)
        return ys[min(int(round(q * (len(ys) - 1))), len(ys) - 1)]

    def p50_latency(self) -> float:
        return self._pct(self.latencies, 0.50)

    def p99_latency(self) -> float:
        return self._pct(self.latencies, 0.99)

    def source_acceptance(self) -> Dict[str, float]:
        return {n: self.source_accepted.get(n, 0) / max(d, 1)
                for n, d in self.source_drafted.items()}

    def summary(self, decode_steps: int, lanes: int) -> Dict[str, float]:
        return {"submitted": self.submitted, "finished": self.finished,
                "cancelled": self.cancelled, "tokens": self.tokens,
                "occupancy": self.lane_steps / max(decode_steps * lanes, 1),
                "p50_latency_s": self.p50_latency(),
                "p99_latency_s": self.p99_latency(),
                "p50_ttft_s": self._pct(self.ttfts, 0.50),
                "p99_ttft_s": self._pct(self.ttfts, 0.99),
                "p99_queue_s": self._pct(self.queue_waits, 0.99)}

    # ---- fleet rollup (repro.fleet): raw samples travel, not percentiles —
    # a fleet p99 must be computed over the union of every replica's
    # latencies, never averaged from per-replica percentiles.
    def snapshot(self) -> Dict[str, object]:
        return {"submitted": self.submitted, "finished": self.finished,
                "cancelled": self.cancelled, "tokens": self.tokens,
                "lane_steps": self.lane_steps,
                "latencies": list(self.latencies),
                "ttfts": list(self.ttfts),
                "queue_waits": list(self.queue_waits),
                "source_drafted": dict(self.source_drafted),
                "source_accepted": dict(self.source_accepted)}

    def merge(self, other: Dict[str, object]) -> None:
        """Accumulate another replica's snapshot of the same namespace."""
        self.submitted += int(other["submitted"])
        self.finished += int(other["finished"])
        self.cancelled += int(other["cancelled"])
        self.tokens += int(other["tokens"])
        self.lane_steps += int(other["lane_steps"])
        self.latencies.extend(float(x) for x in other["latencies"])
        self.ttfts.extend(float(x) for x in other["ttfts"])
        self.queue_waits.extend(float(x) for x in other["queue_waits"])
        for k, v in dict(other["source_drafted"]).items():
            self.source_drafted[k] = self.source_drafted.get(k, 0) + int(v)
        for k, v in dict(other["source_accepted"]).items():
            self.source_accepted[k] = self.source_accepted.get(k, 0) + int(v)


class SchedulerStats:
    """Aggregate serving-loop statistics (occupancy is the continuous-
    batching win: mean fraction of lanes doing useful work per step)."""

    def __init__(self, lanes: int):
        self.lanes = lanes
        self.decode_steps = 0
        self.active_lane_steps = 0
        self.admitted = 0
        self.finished = 0
        self.block_waits = 0     # admissions deferred for blocks, not lanes
        self.peak_blocks = 0     # max physical blocks allocated at once
        # ---- per-step latency breakdown (totals over decode steps)
        self.host_draft_ms = 0.0     # draft retrieval/merging + tree packing
        self.device_step_ms = 0.0    # dispatch -> packed result on the host
        self.accept_commit_ms = 0.0  # accept bookkeeping, retire, tables
        self.hidden_host_ms = 0.0    # host work run while a step was in
        #                              flight on device (overlap mode only)
        self.host_syncs = 0          # every device->host pull the loop makes
        self.decode_syncs = 0        # pulls on the decode hot path only
        # ---- prefix cache (zeros when disabled)
        self.prefix_lookups = 0
        self.prefix_hits = 0          # admissions with >= 1 cached token
        self.prefix_hit_tokens = 0    # prompt tokens whose prefill was skipped
        self.prefix_prompt_tokens = 0  # prompt tokens presented to lookup
        self.prefix_cow_forks = 0
        self.prefix_evicted_blocks = 0
        # ---- per-tenant slices (keyed by trie namespace); created lazily
        self.namespaces: Dict[str, NamespaceStats] = {}

    def ns(self, namespace: str) -> NamespaceStats:
        s = self.namespaces.get(namespace)
        if s is None:
            s = self.namespaces[namespace] = NamespaceStats()
        return s

    def namespace_summary(self) -> Dict[str, Dict[str, float]]:
        """namespace -> SLO summary (percentiles, occupancy, counts)."""
        return {name: st.summary(self.decode_steps, self.lanes)
                for name, st in sorted(self.namespaces.items())}

    def snapshot(self) -> Dict[str, object]:
        """Portable stats snapshot for the fleet rollup (plain data only —
        crosses the subprocess-replica boundary as JSON-able payload)."""
        return {"lanes": self.lanes, "decode_steps": self.decode_steps,
                "active_lane_steps": self.active_lane_steps,
                "admitted": self.admitted, "finished": self.finished,
                "prefix_lookups": self.prefix_lookups,
                "prefix_hits": self.prefix_hits,
                "prefix_hit_tokens": self.prefix_hit_tokens,
                "namespaces": {ns: st.snapshot()
                               for ns, st in self.namespaces.items()}}

    @property
    def occupancy(self) -> float:
        return self.active_lane_steps / max(self.decode_steps * self.lanes, 1)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of looked-up admissions that matched a cached prefix."""
        return self.prefix_hits / max(self.prefix_lookups, 1)

    @property
    def prefill_tokens_saved(self) -> float:
        """Fraction of presented prompt tokens served from the cache."""
        return self.prefix_hit_tokens / max(self.prefix_prompt_tokens, 1)

    @property
    def syncs_per_decode_step(self) -> float:
        """Host syncs per decode step (1.0 on the fused hot path)."""
        return self.decode_syncs / max(self.decode_steps, 1)

    def breakdown(self) -> Dict[str, float]:
        """Mean per-decode-step latency split in milliseconds."""
        d = max(self.decode_steps, 1)
        return {"host_draft_ms": self.host_draft_ms / d,
                "device_step_ms": self.device_step_ms / d,
                "accept_commit_ms": self.accept_commit_ms / d,
                "hidden_host_ms": self.hidden_host_ms / d,
                "syncs_per_step": self.syncs_per_decode_step}


class ContinuousScheduler:
    """Fixed-lane continuous-batching serving loop over ``StepFns``.

    Drive it either as a batch runner (``submit`` everything, then ``run()``)
    or as an online loop (``submit`` as requests arrive, call ``step()``
    repeatedly; each call returns the requests that finished in it).
    """

    def __init__(self, fns: StepFns, config: LookaheadConfig, *,
                 lanes: int, trie: Optional[TrieTree] = None,
                 eos_id: int = -1, prefill_len: Optional[int] = None,
                 rid_start: int = 0, scrub_freed: bool = False,
                 default_params: Optional[SamplingParams] = None,
                 draft_policy: Optional[DraftPolicy] = None,
                 sources: Optional[Dict[str, DraftSource]] = None,
                 overlap_drafts: bool = False,
                 record_breakdown: bool = False,
                 prefix_cache: bool = False,
                 prefix_cache_blocks: Optional[int] = None,
                 lane_shares: Optional[Dict[str, float]] = None,
                 draft_budget_caps: Optional[Dict[str, int]] = None,
                 autotune=False, sanitize: bool = False):
        if not fns.supports_slot_serving:
            raise ValueError("StepFns lack prefill_into_slot/init_cache; "
                             "continuous batching needs per-slot admission")
        if overlap_drafts and fns.fused_step is None:
            raise ValueError("overlap_drafts needs StepFns.fused_step (the "
                             "single-dispatch step the overlap window hides "
                             "host work behind)")
        self.overlap_drafts = bool(overlap_drafts)
        self.record_breakdown = bool(record_breakdown)
        self.step_breakdown: List[Dict[str, float]] = []
        # overlap mode: requests retired at step k whose heavy bookkeeping
        # (trie elimination, block free + scrub, handle finalize) is deferred
        # into step k+1's in-flight window, and admissions whose
        # prefill_into_slot was dispatched but whose first-token pull is
        # deferred until the other lanes' drafts are built
        self._retired: List[RequestState] = []
        self._pending: Dict[int, RequestState] = {}
        self._pending_chosen: Dict[int, object] = {}
        self.fns = fns
        self.config = config
        self.eos_id = eos_id
        self.lanes = int(lanes)
        self.scrub_freed = bool(scrub_freed)
        self.prefill_len = int(prefill_len or fns.prefill_len or 0)
        if self.prefill_len <= 0:
            raise ValueError("prefill_len must be set (fixed prompt pad "
                             "length; compile-once admission)")
        # ---- draft sources (DESIGN.md §Draft sources): requests speculate
        # through the sources their resolved DraftPolicy names; the trie
        # source always exists (the default policy and the compat ``trie``
        # surface), wrapping the passed trie when one is handed over so a
        # caller-owned trie stays warm across scheduler instances.
        self.default_policy = (draft_policy if draft_policy is not None
                               else DraftPolicy()).validate()
        self.sources: Dict[str, DraftSource] = (
            sources if sources is not None else {})
        if "trie" not in self.sources:
            self.sources["trie"] = TrieSource(config, trie=trie)
        if config.strategy == "none" or config.decoding_length == 0:
            self.width = 1
        else:
            self.width = fns.slots
        if self.prefill_len + self.width > fns.max_seq_len:
            # the first tree step after admitting a full-length prompt would
            # scatter draft KV past the cache end (silently dropped rows ⇒
            # garbage logits ⇒ a losslessness violation, not an error)
            raise ValueError(
                f"prefill_len={self.prefill_len} + tree width={self.width} "
                f"exceeds max_seq_len={fns.max_seq_len}")
        # ---- multi-tenant control layer (DESIGN.md §Multi-tenant SLOs):
        # per-namespace admission queues (each tenant's own queue stays FIFO
        # — I1 losslessness is per-request, so only cross-tenant order may
        # change), stride-scheduled when lane shares are configured, global
        # FIFO by rid otherwise (bit-identical to the single-queue code).
        self.lane_shares: Dict[str, float] = {
            str(k): float(v) for k, v in (lane_shares or {}).items()}
        for nsn, share in self.lane_shares.items():
            if not 0.0 < share <= 1.0:
                raise ValueError(f"lane share for namespace {nsn!r} is "
                                 f"{share}; need a pool fraction in (0, 1]")
        self.draft_budget_caps: Dict[str, int] = {
            str(k): int(v) for k, v in (draft_budget_caps or {}).items()}
        for nsn, cap in self.draft_budget_caps.items():
            if cap < 0:
                raise ValueError(f"draft budget cap for namespace {nsn!r} "
                                 f"is {cap}; need >= 0")
        self.autotuner: Optional[AutoTuner] = (
            autotune if isinstance(autotune, AutoTuner)
            else (AutoTuner() if autotune else None))
        self.queues: Dict[str, Deque[RequestState]] = {}
        self._q_pass: Dict[str, float] = {}   # stride pass per namespace
        self._vtime = 0.0                     # virtual time = last served pass
        self.cache = None          # allocated by the first admission batch
        self.lens = np.zeros((self.lanes,), dtype=np.int32)
        self.states: List[Optional[RequestState]] = [None] * self.lanes
        self.results: Dict[int, RequestResult] = {}
        self.handles: Dict[int, "RequestHandle"] = {}
        self._order: List[int] = []
        self.next_rid = int(rid_start)
        self.stats = SchedulerStats(self.lanes)
        # ---- per-lane sampling params (request-centric API): device-step
        # inputs, refreshed at admission; idle lanes keep the session default.
        # ``default_params`` (EngineConfig's) wins over the session-level
        # ones baked by make_session_fns (which carry no max_new_tokens)
        self._defaults = (default_params if default_params is not None
                          else fns.default_params)
        self.lane_greedy = np.full((self.lanes,), not self._defaults.sample)
        self.lane_temp = np.full((self.lanes,), self._defaults.temperature,
                                 dtype=np.float32)
        self.lane_seed = np.full((self.lanes,),
                                 np.uint32(self._defaults.seed),
                                 dtype=np.uint32)
        # ---- paged KV layout: host-side block tables + allocator
        self.kv_layout = getattr(fns, "kv_layout", "dense")
        self.allocator: Optional[BlockAllocator] = None
        if self.kv_layout == "paged":
            bpl = fns.blocks_per_lane
            nb = fns.n_blocks or 1 + self.lanes * bpl
            self.allocator = BlockAllocator(nb, fns.block_size)
            self.tables = np.zeros((self.lanes, bpl), dtype=np.int32)
            self._tables_dirty = True
        # ---- radix prefix cache (DESIGN.md §Prefix cache): lookup at
        # admission, insert at retire; shares pool blocks by refcount.
        self.prefix: Optional[PrefixCache] = None
        if prefix_cache:
            if self.allocator is None:
                raise ValueError("prefix_cache requires kv_layout='paged' "
                                 "(block sharing needs the paged pool)")
            if fns.prefill_suffix is None or fns.copy_block is None:
                raise ValueError("these StepFns lack prefill_suffix/"
                                 "copy_block; rebuild the session to enable "
                                 "the prefix cache")
            self.prefix = PrefixCache(self.allocator,
                                      max_blocks=prefix_cache_blocks)
        # transient per-admission hit info: rid -> (n_cached, cow_src,
        # cow_dst); written by _claim_blocks, consumed by the same _admit
        self._hits: Dict[int, tuple] = {}
        # block ids evicted before the first prefill created the cache:
        # scrubbing needs a cache to dispatch against, so the ids wait here
        # and flush right after cache creation (satellite: silent scrub skip)
        self._scrub_backlog: List[int] = []
        # ---- runtime sanitizer (DESIGN.md §Invariants & analysis): opt-in
        # shadow checks — request lifecycle machine, block-ownership ledger
        # on the allocator's observer hook, retrace monitor.  Default-off
        # costs nothing: the module is not even imported.
        self.sanitizer = None
        if sanitize:
            from repro.analysis.sanitizer import Sanitizer
            self.sanitizer = Sanitizer.attach(self)

    # ------------------------------------------------------------------ state
    @property
    def n_active(self) -> int:
        return sum(1 for s in self.states if s is not None)

    @property
    def n_queued(self) -> int:
        return sum(len(q) for q in self.queues.values())

    @property
    def queue(self) -> List[RequestState]:
        """Flat view of every queued request in global FIFO (rid) order
        (read-only compat/introspection surface; admission order itself is
        the per-namespace picker's business)."""
        return sorted((rs for q in self.queues.values() for rs in q),
                      key=lambda rs: rs.rid)

    @property
    def idle(self) -> bool:
        return (self.n_active == 0 and self.n_queued == 0
                and not self._pending and not self._retired)

    # -------------------------------------------------- weighted-fair picking
    def _ns_weight(self, nsn: str) -> float:
        """Stride weight of a namespace: its configured share, or — for a
        namespace the operator did not list — the smallest configured share
        (unlisted tenants never outweigh provisioned ones)."""
        w = self.lane_shares.get(nsn)
        if w is not None:
            return w
        return min(self.lane_shares.values()) if self.lane_shares else 1.0

    def _ns_lane_cap(self, nsn: str) -> int:
        """Hard cap on lanes a namespace may hold at once: ceil(lanes x
        share) for listed namespaces (floor 1 — a share never starves its
        own tenant outright), the whole pool for unlisted ones."""
        share = self.lane_shares.get(nsn)
        if share is None:
            return self.lanes
        return max(1, int(math.ceil(self.lanes * share)))

    def _lanes_in_use(self) -> Dict[str, int]:
        """Lanes currently held per namespace (active + in-flight pending)."""
        used: Dict[str, int] = {}
        for rs in self.states:
            if rs is not None:
                used[rs.draft.namespace] = used.get(rs.draft.namespace,
                                                    0) + 1
        for rs in self._pending.values():
            used[rs.draft.namespace] = used.get(rs.draft.namespace, 0) + 1
        return used

    def _pick_ns(self, in_use: Dict[str, int]) -> Optional[str]:
        """The namespace whose queue head admits next.

        No lane shares configured: global FIFO across tenants — the head
        with the lowest rid (rids are submit-monotonic), bit-identical to
        the old single-queue scheduler.  With shares: stride scheduling —
        the eligible non-empty queue with the smallest pass value (ties
        break by name, deterministically); namespaces at their lane cap are
        skipped.  Within a namespace order is always FIFO.
        """
        best = None
        for nsn, q in self.queues.items():
            if not q:
                continue
            if self.lane_shares:
                if in_use.get(nsn, 0) >= self._ns_lane_cap(nsn):
                    continue
                key = (self._q_pass.get(nsn, 0.0), nsn)
            else:
                key = (q[0].rid, nsn)
            if best is None or key < best[0]:
                best = (key, nsn)
        return None if best is None else best[1]

    def _take_queued(self, nsn: str) -> RequestState:
        """Dequeue the namespace's head and charge its stride pass."""
        rs = self.queues[nsn].popleft()
        if self.sanitizer is not None:
            self.sanitizer.transition(rs.rid, "admitted")
        if self.lane_shares:
            pas = max(self._q_pass.get(nsn, 0.0), self._vtime)
            self._vtime = pas
            self._q_pass[nsn] = pas + 1.0 / self._ns_weight(nsn)
        return rs

    def _pull(self, x, *, decode: bool = False) -> np.ndarray:
        """THE device->host transfer point: every pull the loop makes goes
        through here so tests can assert the per-step sync count (fused
        decode: exactly one packed pull per step)."""
        self.stats.host_syncs += 1
        if decode:
            self.stats.decode_syncs += 1
        return np.asarray(x)

    # ---------------------------------------------------------- draft sources
    @property
    def trie(self) -> TrieTree:
        """Default-namespace trie of the trie source (compat surface:
        engine warmup, stats printing, tests)."""
        return self.sources["trie"].trie

    def _resolve_sources(self, policy: DraftPolicy) -> List[DraftSource]:
        """The policy's source instances, instantiating registry entries on
        first use (shared across every request of this scheduler — and, when
        a ``sources`` dict was passed in, across schedulers)."""
        out = []
        for name in policy.sources:
            src = self.sources.get(name)
            if src is None:
                src = self.sources[name] = make_source(name, self.config)
            out.append(src)
        return out

    def _observe_prompt(self, rs: RequestState) -> None:
        for src in self._resolve_sources(rs.draft):
            src.observe_prompt(rs.rid, rs.prompt,
                               namespace=rs.draft.namespace)

    def _observe_output(self, rs: RequestState) -> None:
        for src in self._resolve_sources(rs.draft):
            src.observe_output(rs.rid, rs.output,
                               namespace=rs.draft.namespace)

    def _retire_sources(self, rs: RequestState) -> None:
        for src in self._resolve_sources(rs.draft):
            src.retire(rs.rid, namespace=rs.draft.namespace)

    # ------------------------------------------------------------------ paged
    def _demand_blocks(self, plen: int, max_new: int) -> int:
        """Worst-case block demand (the shared admission formula), reserved
        at admission so mid-flight ``extend`` can never fail
        (preemption-free backpressure; DESIGN.md §Paged KV cache)."""
        return demand_blocks(plen, max_new, self.width,
                             self.fns.max_seq_len, self.fns.block_size)

    def _claim_blocks(self, rs: RequestState, lane: int) -> bool:
        """Reserve + allocate initial blocks for ``rs``; False = not enough
        reservable blocks right now (request stays queued — backpressure).

        With the prefix cache enabled: look up the prompt first and PIN the
        matched nodes, so the eviction pass that makes room for this very
        admission cannot evict the blocks it is about to share; adopt
        matched full blocks into the table head by refcount, allocate a COW
        fork target for a partially-matched boundary block, and only then
        take fresh blocks for the uncached tail."""
        if self.sanitizer is not None:
            # poison-on-free: before blocks can be handed back out, every
            # freed+scrubbed block must still hold all-zero KV rows
            self.sanitizer.check_poison(self.cache)
        demand = self._demand_blocks(len(rs.prompt), rs.max_new_tokens)
        match = None
        if self.prefix is not None:
            match = self.prefix.lookup(rs.prompt,
                                       namespace=rs.draft.namespace)
            self.stats.prefix_lookups += 1
            self.stats.prefix_prompt_tokens += len(rs.prompt)
        if not self.allocator.can_admit(demand):
            # cache-only blocks are reclaimable: LRU-evict before declaring
            # backpressure (matched nodes are pinned, so a hit keeps its
            # shared blocks even under pool pressure)
            if self.prefix is not None:
                evicted = self.prefix.evict(demand)
                self.stats.prefix_evicted_blocks += len(evicted)
                self._scrub_blocks(evicted)
            if not self.allocator.can_admit(demand):
                if match is not None:
                    self.prefix.unpin(match)
                self.stats.block_waits += 1
                return False
        initial = min(self.allocator.blocks_for_tokens(
            len(rs.prompt) + self.width), demand)
        shared = match.blocks if match is not None else []
        cow_dst = None
        if match is not None and match.cow_block is not None:
            self.allocator.alloc(rs.rid, len(shared), reserve=demand,
                                 shared=shared)
            cow_dst = self.allocator.fork_cow(rs.rid, match.cow_block)
            self.allocator.extend(rs.rid, initial - len(shared) - 1)
        else:
            self.allocator.alloc(rs.rid, initial, reserve=demand,
                                 shared=shared)
        if match is not None:
            self.prefix.unpin(match)
            if match.n_tokens > 0:
                rs.stats.cached_prompt_tokens = match.n_tokens
                self.stats.prefix_hits += 1
                self.stats.prefix_hit_tokens += match.n_tokens
                self.stats.prefix_cow_forks += int(cow_dst is not None)
                self._hits[rs.rid] = (match.n_tokens, match.cow_block,
                                      cow_dst)
        table = self.allocator.table(rs.rid)
        self.tables[lane, :] = 0
        self.tables[lane, :len(table)] = table
        self._tables_dirty = True
        self.stats.peak_blocks = max(self.stats.peak_blocks,
                                     self.allocator.n_allocated)
        return True

    def _scrub_blocks(self, freed: Sequence[int]) -> None:
        """Zero freed blocks on device (hygiene) — only ids whose refcount
        actually reached zero may ever be passed here.  Chunked to the
        block-table width so one reset executable serves every call.

        Before the first prefill there is no cache to dispatch against:
        prefix-cache evictions made while claiming the initial cohort are
        queued and flushed right after cache creation (they used to be
        silently dropped under ``scrub_freed=True``)."""
        if not (self.scrub_freed and freed
                and self.fns.reset_blocks is not None):
            return
        if self.cache is None:
            self._scrub_backlog.extend(int(b) for b in freed)
            return
        bpl = self.fns.blocks_per_lane
        for i in range(0, len(freed), bpl):
            ids = np.zeros((bpl,), dtype=np.int32)
            chunk = freed[i:i + bpl]
            ids[:len(chunk)] = np.asarray(chunk, dtype=np.int32)
            self.cache = self.fns.reset_blocks(self.cache, ids)
        if self.sanitizer is not None:
            self.sanitizer.on_scrubbed(int(b) for b in freed)

    def _sync_tables(self) -> None:
        """Push host-side block-table edits into the device cache dict (the
        tables ride along as a regular input of every step fn).  Converted
        to a device array up front: a raw np array inside the donated cache
        pytree would change the donation mask and compile a second
        executable (I2)."""
        if (self.allocator is not None and self._tables_dirty
                and self.cache is not None):
            self.cache["block_tables"] = jnp.asarray(self.tables)
            self._tables_dirty = False

    # ------------------------------------------------------------ lane params
    def _set_lane_params(self, lane: int, params: SamplingParams) -> None:
        self.lane_greedy[lane] = not params.sample
        self.lane_temp[lane] = params.temperature
        self.lane_seed[lane] = np.uint32(params.seed)

    def _lane_params_all(self):
        """(lanes,) per-lane sampling vectors for a full-batch device step."""
        return {"greedy": self.lane_greedy.copy(),
                "temp": self.lane_temp.copy(),
                "seed": self.lane_seed.copy()}

    @staticmethod
    def _lane_params_one(params: SamplingParams):
        """(1,) vectors for a single-lane ``prefill_into_slot``."""
        return {"greedy": np.asarray([not params.sample]),
                "temp": np.asarray([params.temperature], dtype=np.float32),
                "seed": np.asarray([np.uint32(params.seed)],
                                   dtype=np.uint32)}

    # ----------------------------------------------------------------- submit
    def submit(self, prompt: Sequence[int], max_new_tokens: int) -> int:
        """Queue a request under the session's default params (legacy
        positional surface); returns its request id."""
        params = dataclasses.replace(self._defaults,
                                     max_new_tokens=int(max_new_tokens))
        return self.submit_request(Request(prompt=list(prompt),
                                           params=params)).rid

    def submit_request(self, request: Request) -> "RequestHandle":
        """Queue a ``Request`` and return its streaming ``RequestHandle``
        (incremental token deltas, ``.result()``, ``.cancel()``)."""
        from repro.serving.api import RequestHandle
        params = (request.params if request.params is not None
                  else self._defaults).validate()
        prompt = [int(t) for t in request.prompt]
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) > self.prefill_len:
            raise ValueError(f"prompt length {len(prompt)} exceeds "
                             f"prefill_len={self.prefill_len}")
        if params.sample and self.fns.sampling == "greedy":
            raise ValueError(
                "this session was built with sampling='greedy' (argmax-only"
                " executables); rebuild with sampling='mixed' to serve "
                "sampled requests")
        if not self.fns.per_lane_params and (
                params.sample != self._defaults.sample
                or (params.sample
                    and (params.temperature != self._defaults.temperature
                         or params.seed != self._defaults.seed))):
            raise ValueError(
                "these StepFns predate per-lane sampling params; requests "
                "must keep the session-level sample/temperature/seed")
        if self.allocator is not None:
            demand = self._demand_blocks(len(prompt), params.max_new_tokens)
            if demand > self.allocator.capacity:
                raise ValueError(
                    f"request demands {demand} KV blocks; pool capacity is "
                    f"{self.allocator.capacity} (it could never be admitted "
                    "— deadlock)")
        policy = (params.draft if params.draft is not None
                  else self.default_policy).validate()
        self._resolve_sources(policy)   # unknown names fail at submit time
        rid = self.next_rid
        self.next_rid += 1
        request.rid = rid
        rs = RequestState(rid=rid, prompt=prompt,
                          max_new_tokens=params.max_new_tokens,
                          eos_id=self.eos_id, params=params,
                          draft=policy,
                          token_limit=cache_token_limit(
                              self.fns.max_seq_len, self.width, len(prompt)))
        if policy.adaptive and self.width > 1:
            rs.budget_ctl = AdaptiveBudget.from_policy(
                policy, min(self.config.decoding_length, self.width - 1))
        rs.submit_t = time.perf_counter()
        if self.sanitizer is not None:
            self.sanitizer.transition(rid, "queued")
        nsn = policy.namespace
        q = self.queues.get(nsn)
        if q is None:
            q = self.queues[nsn] = deque()
        if not q:
            # empty -> backlogged: a returning tenant resumes at the current
            # virtual time, not at credit hoarded while it was idle
            self._q_pass[nsn] = max(self._q_pass.get(nsn, 0.0), self._vtime)
        q.append(rs)
        self.stats.ns(nsn).submitted += 1
        self._order.append(rid)
        handle = RequestHandle(rs, self)
        self.handles[rid] = handle
        return handle

    # ------------------------------------------------------------------- loop
    def step(self) -> List[RequestResult]:
        """One scheduler iteration: admit into free lanes, then one masked
        decode step across all lanes.  Returns requests finished this call."""
        finished = self._admit()
        finished.extend(self._decode())
        return finished

    def run(self) -> List[RequestResult]:
        """Drain queue + lanes; results in submission order."""
        while not self.idle:
            self.step()
        if self.sanitizer is not None:
            self.sanitizer.verify_idle(self)
        return [self.results[rid] for rid in self._order
                if rid in self.results]

    # -------------------------------------------------------------- admission
    def _admit(self) -> List[RequestResult]:
        if self.cache is None and self.n_queued:
            return self._admit_initial_cohort()
        finished: List[RequestResult] = []
        fns = self.fns
        in_use = self._lanes_in_use()
        for lane in range(self.lanes):
            if lane in self._pending:
                continue
            while self.states[lane] is None:
                nsn = self._pick_ns(in_use)
                if nsn is None:
                    break
                rs = self.queues[nsn][0]
                if self.allocator is not None and \
                        not self._claim_blocks(rs, lane):
                    # not enough reservable blocks: ALL admission waits (the
                    # blocked head keeps its turn — bounded wait; no
                    # overtaking within or across tenants under backpressure,
                    # so losslessness stays order-free and nothing starves)
                    return finished
                self._take_queued(nsn)
                in_use[nsn] = in_use.get(nsn, 0) + 1
                rs.lane = lane
                rs.admit_t = time.perf_counter()
                self._set_lane_params(lane, rs.params)
                self._observe_prompt(rs)
                self._sync_tables()
                hit = self._hits.pop(rs.rid, None)
                if hit is not None:
                    # prefix-cache hit: COW-fork the boundary block if the
                    # match ends mid-block, then prefill only the uncached
                    # suffix (the shared blocks are already wired into the
                    # lane's table, so attention sees the full prefix)
                    n_cached, cow_src, cow_dst = hit
                    if cow_dst is not None:
                        self.cache = fns.copy_block(self.cache, cow_src,
                                                    cow_dst)
                    suffix = np.asarray([rs.prompt[n_cached:]],
                                        dtype=np.int32)
                    self.cache, chosen = fns.prefill_suffix(
                        self.cache, lane, suffix, n_cached,
                        lane_params=self._lane_params_one(rs.params))
                else:
                    toks = np.full((1, self.prefill_len), fns.pad_id,
                                   dtype=np.int32)
                    toks[0, :len(rs.prompt)] = np.asarray(rs.prompt,
                                                          dtype=np.int32)
                    plen = np.asarray([len(rs.prompt)], dtype=np.int32)
                    if fns.per_lane_params:
                        self.cache, chosen = fns.prefill_into_slot(
                            self.cache, lane, toks, plen,
                            lane_params=self._lane_params_one(rs.params))
                    else:
                        self.cache, chosen = fns.prefill_into_slot(
                            self.cache, lane, toks, plen)
                if self.overlap_drafts:
                    # leave the prefill in flight: its first-token pull is
                    # deferred until _decode has built the other lanes'
                    # drafts (host draft work overlaps the prefill)
                    self._pending[lane] = rs
                    self._pending_chosen[lane] = chosen
                    break
                if not self._settle(rs, int(self._pull(chosen)[0]), lane):
                    finished.append(self._finish(rs))
                    in_use[nsn] -= 1   # finished at prefill: lane still free
        return finished

    def _admit_initial_cohort(self) -> List[RequestResult]:
        """First admission: one batched (lanes, prefill_len) prefill builds
        the cache and fills as many lanes as the queue covers — the
        FLOPs-dense phase keeps its batching; per-slot prefill only pays for
        mid-flight admissions."""
        fns = self.fns
        cohort: List[RequestState] = []
        in_use: Dict[str, int] = {}
        while len(cohort) < self.lanes:
            nsn = self._pick_ns(in_use)
            if nsn is None:
                break
            rs = self.queues[nsn][0]
            if self.allocator is not None and \
                    not self._claim_blocks(rs, len(cohort)):
                break
            self._take_queued(nsn)
            in_use[nsn] = in_use.get(nsn, 0) + 1
            cohort.append(rs)
        if not cohort:
            return []
        toks = np.full((self.lanes, self.prefill_len), fns.pad_id,
                       dtype=np.int32)
        lens = np.ones((self.lanes,), dtype=np.int32)   # dummy rows: 1 pad
        now = time.perf_counter()
        for lane, rs in enumerate(cohort):
            rs.lane = lane
            rs.admit_t = now
            self._set_lane_params(lane, rs.params)
            self._observe_prompt(rs)
            toks[lane, :len(rs.prompt)] = np.asarray(rs.prompt,
                                                     dtype=np.int32)
            lens[lane] = len(rs.prompt)
        lane_kw = ({"lane_params": self._lane_params_all()}
                   if fns.per_lane_params else {})
        if self.allocator is not None:
            self.cache, chosen = fns.prefill(toks, lens, self.tables.copy(),
                                             **lane_kw)
            self._tables_dirty = False
        else:
            self.cache, chosen = fns.prefill(toks, lens, **lane_kw)
        if self._scrub_backlog:
            # prefix-cache evictions made while claiming THIS cohort (no
            # cache existed to scrub against): flush now that it does.  Ids
            # the cohort itself re-allocated are skipped — their rows were
            # just prefilled and a scrub would destroy live KV; only
            # still-free blocks carry stale rows worth zeroing.
            backlog = [b for b in self._scrub_backlog
                       if self.allocator.refcount(b) == 0]
            self._scrub_backlog.clear()
            self._scrub_blocks(backlog)
        chosen = self._pull(chosen)
        finished: List[RequestResult] = []
        for lane, rs in enumerate(cohort):
            if not self._settle(rs, int(chosen[lane]), lane):
                finished.append(self._finish(rs))
        return finished

    def _settle(self, rs: RequestState, first_token: int, lane: int) -> bool:
        """Common post-prefill bookkeeping; returns False if the request
        already finished at prefill (budget 1 / instant EOS) — its lane
        stays free for the next scheduler iteration."""
        rs.start(first_token)
        rs.first_token_t = time.perf_counter()
        rs.stats.host_syncs += 1        # the first-token pull
        self.stats.admitted += 1
        self._emit(rs, rs.output)
        if rs.done:
            self._observe_output(rs)
            return False
        if self.sanitizer is not None:
            self.sanitizer.transition(rs.rid, "active")
        self.states[lane] = rs
        self.lens[lane] = len(rs.prompt)
        return True

    # ----------------------------------------------------------------- decode
    def _build_tree(self, rs: RequestState):
        # adaptive lanes draft at their controller's current budget; the
        # remaining slots ride as padding (fixed W — no retrace).  The
        # namespace's draft-budget cap bounds it further (a hot tenant's
        # wide trees are host cost co-residents pay for), and the autotune
        # controller gates which sources retrieve at all — every knob here
        # is host-side draft construction, so outputs never change (I1) and
        # no compiled shape moves (I2).
        budget = (rs.budget_ctl.value if rs.budget_ctl is not None
                  else None)
        cap = self.draft_budget_caps.get(rs.draft.namespace)
        if cap is not None:
            budget = min(self.config.decoding_length if budget is None
                         else budget, cap)
        sources = self._resolve_sources(rs.draft)
        quotas = None
        if self.autotuner is not None and len(sources) > 1:
            eff = (self.config.decoding_length if budget is None else budget)
            eff = max(min(eff, self.width - 1), 1)
            base = [rs.draft.quota(i, eff) for i in range(len(sources))]
            keep, quotas = self.autotuner.select(
                rs.draft.namespace, [s.name for s in sources], base)
            sources = [sources[i] for i in keep]
            # fold the bandit's kept-quota total into the lane width: a
            # namespace whose sources are mostly gated off shrinks its tree
            # instead of padding dead slots.  With no explicit quotas each
            # kept source may fill the whole budget (total >= eff — no
            # shrink), so only provisioned policies are affected.
            total = sum(int(q) for q in quotas)
            if total < eff:
                if rs.budget_ctl is not None:
                    budget = rs.budget_ctl.cap(total)
                else:
                    budget = min(eff if budget is None else budget, total)
            elif rs.budget_ctl is not None:
                rs.budget_ctl.quota_cap = None   # sources recovered
        return build_draft_from_policy(
            sources, rs.draft, self.config, rs.rid,
            rs.context, self.fns.pad_id, self.width, budget=budget,
            quotas=quotas)

    def _decode(self) -> List[RequestResult]:
        fns, W = self.fns, self.width
        finished: List[RequestResult] = []
        if self.n_active == 0 and not self._pending:
            # nothing to step: flush deferred retirements so run() can end
            self._drain_retired(finished)
            return finished
        fused = fns.fused_step is not None
        t0 = time.perf_counter()
        # ---- host draft building.  In overlap mode any admission prefill
        # dispatched by _admit is still in flight here: draft retrieval /
        # merging for the established lanes runs behind that device work.
        trees: List = [None] * self.lanes
        for l in range(self.lanes):
            if self.states[l] is not None:
                trees[l] = self._build_tree(self.states[l])
        # settle deferred admissions (their first-token pull was hidden
        # behind the draft building above); a request finishing at prefill
        # leaves its lane free until the next scheduler iteration
        for lane in sorted(self._pending):
            rs = self._pending.get(lane)
            if rs is None:
                # cancelled out of _pending by a co-resident's stream
                # callback earlier in this very loop; its teardown is done
                # and its block free already rides in _retired
                continue
            chosen = self._pending_chosen[lane]
            if self._settle(rs, int(self._pull(chosen)[0]), lane):
                trees[lane] = self._build_tree(rs)
            elif rs.rid not in self.results:
                finished.append(self._finish(rs))
            # else: cancel() finalized it mid-settle (a stream callback of
            # its own first token); only its deferred block free remains
        self._pending.clear()
        self._pending_chosen.clear()
        active = [l for l in range(self.lanes) if self.states[l] is not None]
        if not active:
            self._drain_retired(finished)
            return finished
        # requests riding THIS step (captured before retirement clears
        # lanes): each accrues the step's measured wall-clock split — exact
        # per-step sums, not global means (satellite: telemetry skew)
        riders = [self.states[l] for l in active]
        for l in range(self.lanes):
            if trees[l] is None:
                trees[l] = idle_tree(W, fns.pad_id)
        tok = np.stack([t.tokens for t in trees])                     # (B,W)
        pos = (self.lens[:, None]
               + np.stack([t.depth for t in trees])).astype(np.int32)
        mask = np.stack([t.tree_mask for t in trees])                 # (B,W,W)
        self._sync_tables()
        lane_kw = ({"lane_params": self._lane_params_all()}
                   if fns.per_lane_params else {})
        t1 = time.perf_counter()
        drained = 0.0
        new_lens = self.lens.copy()
        if fused:
            # ---- single-dispatch hot path: tree forward + token choice +
            # device accept walk + commit in ONE jitted call; ONE packed
            # (B, 1+2W) pull crosses the host boundary per step.  The
            # device accepts untruncated; host-side truncation (budget /
            # EOS / stop) always retires the lane, so the extra committed
            # rows are garbage that is never attended (I3).
            parent = np.stack([t.parent for t in trees]).astype(np.int32)
            n_live = np.asarray(
                [t.n_slots if self.states[l] is not None else 0
                 for l, t in enumerate(trees)], dtype=np.int32)
            self.cache, packed = fns.fused_step(
                self.cache, self.lens, tok, pos, mask, parent, n_live,
                **lane_kw)
            if self._retired:
                # overlap window: the step is in flight — run the previous
                # step's deferred heavy retirement behind it
                td = time.perf_counter()
                self._drain_retired(finished)
                drained = time.perf_counter() - td
                self.stats.hidden_host_ms += drained * 1e3
            packed = self._pull(packed, decode=True)   # THE one sync point
            t2 = time.perf_counter()
            accepted = [packed[l, 1:1 + packed[l, 0]]
                        for l in range(self.lanes)]
            kv_slots = [packed[l, 1 + W:1 + W + packed[l, 0]]
                        for l in range(self.lanes)]
            for l in active:
                rs = self.states[l]
                n_before = len(rs.output)
                ks = rs.accept(accepted[l], kv_slots[l], trees[l].n_slots,
                               slot_sources=trees[l].slot_source)
                new_lens[l] += len(ks)
                rs.stats.host_syncs += 1
                self._emit(rs, rs.output[n_before:])
        else:
            # ---- legacy two-dispatch path (StepFns without fused_step):
            # chosen pull -> host accept walk -> commit -> new_lens pull
            if fns.per_lane_params:
                self.cache, chosen = fns.tree_step(
                    self.cache, self.lens, tok, pos, mask, **lane_kw)
            else:
                self.cache, chosen = fns.tree_step(self.cache, self.lens,
                                                   tok, pos, mask)
            chosen = self._pull(chosen, decode=True)
            t2 = time.perf_counter()
            accepted, kv_slots = verify_accept_batch(trees, chosen)
            gather = np.zeros((self.lanes, W), dtype=np.int32)
            n_acc = np.zeros((self.lanes,), dtype=np.int32)
            for l in active:
                rs = self.states[l]
                n_before = len(rs.output)
                ks = rs.accept(accepted[l], kv_slots[l], trees[l].n_slots,
                               slot_sources=trees[l].slot_source)
                gather[l, :len(ks)] = np.asarray(ks, dtype=np.int32)
                n_acc[l] = len(ks)
                rs.stats.host_syncs += 2
                self._emit(rs, rs.output[n_before:])
            self.cache, lens_dev = fns.commit(self.cache, self.lens, gather,
                                              n_acc)
            new_lens = self._pull(lens_dev, decode=True).astype(
                np.int32).copy()
        self.lens = new_lens
        self.stats.decode_steps += 1
        self.stats.active_lane_steps += len(active)
        for rs in riders:
            self.stats.ns(rs.draft.namespace).lane_steps += 1

        for l in active:
            rs = self.states[l]
            self._observe_output(rs)
            # backstop: the token-granular ``token_limit`` retires a request
            # BEFORE the cache can overflow (cache_token_limit — shared with
            # the lock-step loop so both retire at the same token); this
            # device-safety check stays as a last line against a mis-set cap
            if self.lens[l] + W >= fns.max_seq_len and not rs.done:
                rs.done = True
                rs.finish_reason = rs.finish_reason or "cache"
            if rs.done:
                if self.overlap_drafts:
                    # free the lane now; the heavy bookkeeping runs in the
                    # next step's in-flight window (_drain_retired)
                    self._release_lane(rs, l)
                else:
                    finished.append(self._finish(rs))
                    self.states[l] = None
                    self.lens[l] = 0
        if self.allocator is not None:
            self._extend_tables(active)
        t3 = time.perf_counter()
        hd = (t1 - t0) * 1e3
        dv = (t2 - t1 - drained) * 1e3
        ac = (t3 - t2) * 1e3
        hh = drained * 1e3
        self.stats.host_draft_ms += hd
        self.stats.device_step_ms += dv
        self.stats.accept_commit_ms += ac
        # per-request breakdown: every rider of this step accrues the step's
        # actual split (a short request co-resident with long ones reports
        # only the steps it rode — not a whole-run mean — and the hidden
        # host work drained behind its flight window is no longer dropped)
        for rs in riders:
            rst = rs.stats
            rst.host_draft_ms += hd
            rst.device_step_ms += dv
            rst.accept_commit_ms += ac
            rst.hidden_host_ms += hh
        if self.record_breakdown:
            self.step_breakdown.append({
                "step": self.stats.decode_steps,
                "active": len(active),
                "host_draft_ms": hd,
                "device_step_ms": dv,
                "accept_commit_ms": ac,
                "hidden_host_ms": hh,
                "syncs": 1 if fused else 2})
        return finished

    def _extend_tables(self, active: List[int]) -> None:
        """Grow surviving lanes' block tables to cover the next tree step
        (lens + W rows).  Never fails: admission reserved each request's
        worst-case demand up front."""
        W = self.width
        for l in active:
            rs = self.states[l]
            if rs is None:
                continue
            needed = self.allocator.blocks_for_tokens(int(self.lens[l]) + W)
            cur = self.allocator.n_blocks_of(rs.rid)
            if needed > cur:
                new = self.allocator.extend(rs.rid, needed - cur)
                self.tables[l, cur:needed] = new
                self._tables_dirty = True
        self.stats.peak_blocks = max(self.stats.peak_blocks,
                                     self.allocator.n_allocated)

    # ------------------------------------------------------------- streaming
    def _emit(self, rs: RequestState, delta: Sequence[int]) -> None:
        """Push this step's accepted-token delta to the request's handle."""
        if not delta:
            return
        h = self.handles.get(rs.rid)
        if h is not None:
            h._push(list(delta))

    # ----------------------------------------------------------------- cancel
    def cancel(self, rid: int) -> bool:
        """Cancel a request mid-flight (or while queued).

        An active request leaves through the regular retire path — trie
        elimination, block free (+ scrub under ``scrub_freed``), lane
        release — so co-resident requests are untouched (I1 is per-request).
        Returns False if the request already finished.
        """
        for q in self.queues.values():           # still queued: nothing held
            for i, rs in enumerate(q):
                if rs.rid == rid:
                    del q[i]
                    rs.cancel()
                    if self.sanitizer is not None:
                        # held nothing: queued requests retire directly
                        self.sanitizer.transition(rid, "retiring")
                        self.sanitizer.transition(rid, "drained")
                    rs.finish_t = time.perf_counter()
                    res = rs.result()
                    self.results[rid] = res
                    nst = self.stats.ns(rs.draft.namespace)
                    nst.cancelled += 1
                    h = self.handles.pop(rid, None)
                    if h is not None:
                        h._finalize(res)
                    return True
        for lane in range(self.lanes):
            rs = self.states[lane]
            if rs is not None and rs.rid == rid:
                rs.cancel()
                self._finish(rs)
                self.states[lane] = None
                self.lens[lane] = 0
                return True
        for lane, rs in list(self._pending.items()):
            # overlap mode: the admission prefill may still be IN FLIGHT on
            # device.  Tear down the host-visible side now (the handle's
            # cancel() must return a finalized result) but route the block
            # free through _retired/_drain_retired: freeing here would let a
            # same-iteration re-admission be handed these very block ids
            # while the in-flight prefill still writes into them
            # (use-after-free window — satellite bugfix).  The lane-keyed
            # cleanup runs now, like _release_lane: the lane may be
            # re-admitted before the deferred free drains.
            if rs.rid == rid:
                del self._pending[lane]
                del self._pending_chosen[lane]
                rs.cancel()
                if self.sanitizer is not None:
                    # retiring, NOT drained: the blocks stay owned until
                    # the deferred drain (the in-flight prefill may still
                    # write into them — PR 8's use-after-free window)
                    self.sanitizer.transition(rid, "retiring")
                rs.finish_t = time.perf_counter()
                rs.lane = -1
                if self.allocator is not None:
                    self.tables[lane, :] = 0
                    self._tables_dirty = True
                elif (self.scrub_freed and self.fns.reset_slot is not None
                        and self.cache is not None):
                    self.cache = self.fns.reset_slot(self.cache, lane)
                self._retire_sources(rs)
                self._finalize_result(rs)
                self._retired.append(rs)
                return True
        for i, rs in enumerate(self._retired):
            # already done, heavy retirement still deferred: finalize now so
            # the caller sees a result immediately
            if rs.rid == rid:
                self._finish_retire(self._retired.pop(i))
                return False
        return False

    # ----------------------------------------------------------------- retire
    def _release_lane(self, rs: RequestState, lane: int) -> None:
        """Overlap mode: free the lane for next-iteration admission NOW;
        the heavy bookkeeping (trie elimination, block free + scrub, handle
        finalize) is deferred into the next step's in-flight window.

        The lane-keyed pieces must run here — the lane may be re-admitted
        before the deferred work drains: the table row is zeroed (the
        physical blocks stay owned by this rid until the deferred free, so
        they cannot be reallocated in between) and the dense lane scrub
        fires (a scrub after reuse would destroy the next request's KV)."""
        if self.sanitizer is not None:
            self.sanitizer.transition(rs.rid, "retiring")
        rs.finish_t = time.perf_counter()
        rs.lane = -1
        self.states[lane] = None
        self.lens[lane] = 0
        if self.allocator is not None:
            self.tables[lane, :] = 0
            self._tables_dirty = True
        elif (self.scrub_freed and self.fns.reset_slot is not None
                and self.cache is not None):
            self.cache = self.fns.reset_slot(self.cache, lane)
        self._retired.append(rs)

    def _drain_retired(self, finished: List[RequestResult]) -> None:
        """Run the deferred heavy retirement work (overlap mode).  Called
        while the next step is in flight on device — or, when no step is in
        flight, before run() can go idle."""
        while self._retired:
            finished.append(self._finish_retire(self._retired.pop(0)))

    def _finish(self, rs: RequestState) -> RequestResult:
        """Immediate retire (serial mode, cancel, finish-at-prefill)."""
        if self.sanitizer is not None:
            self.sanitizer.transition(rs.rid, "retiring")
        rs.finish_t = time.perf_counter()
        lane = rs.lane
        rs.lane = -1
        if self.allocator is not None and lane >= 0:
            self.tables[lane, :] = 0
            self._tables_dirty = True
        elif (self.scrub_freed and self.fns.reset_slot is not None
                and lane >= 0 and self.cache is not None):
            self.cache = self.fns.reset_slot(self.cache, lane)
        return self._finish_retire(rs)

    def _finish_retire(self, rs: RequestState) -> RequestResult:
        # cancel() of a pending overlap admission already finalized the
        # host-visible side (result, handle, telemetry) — only the deferred
        # block free and scrub reach here, once, via _drain_retired
        already = rs.rid in self.results
        if not already:
            self._retire_sources(rs)
        if self.allocator is not None and self.allocator.owns(rs.rid):
            # Promote the prompt's blocks into the prefix cache BEFORE the
            # free: the tree takes its own reference on each adopted block,
            # so the free below just drops this request's reference and the
            # cached KV stays resident.  Cancelled requests may have been
            # torn down before their prefill landed — skip them.
            if self.prefix is not None and not rs.cancelled and rs.prompt:
                nb_prompt = self.allocator.blocks_for_tokens(len(rs.prompt))
                table = self.allocator.table(rs.rid)
                self._scrub_blocks(self.prefix.insert(
                    rs.prompt, table[:nb_prompt],
                    namespace=rs.draft.namespace))
            # free-list first, scrub second — but always BEFORE the next
            # admission can reach the allocator, so a scrub can never hit a
            # block that already belongs to a newly admitted request.
            # ``free`` returns ONLY refcount-zero blocks: ids still shared
            # with the prefix cache or a co-resident request are never
            # scrubbed or re-allocated here (satellite: refcount-aware
            # deferred retirement).
            freed = self.allocator.free(rs.rid)
            self._scrub_blocks(freed)
        if self.sanitizer is not None:
            self.sanitizer.transition(rs.rid, "drained")
        if already:
            return self.results[rs.rid]
        return self._finalize_result(rs)

    def _finalize_result(self, rs: RequestState) -> RequestResult:
        """Build + record the result, accrue the namespace's SLO slice,
        feed the autotune controller, finalize the handle."""
        res = rs.result()
        self.results[rs.rid] = res
        self.stats.finished += 1
        nst = self.stats.ns(rs.draft.namespace)
        nst.finished += 1
        if rs.cancelled:
            nst.cancelled += 1
        nst.tokens += len(rs.output)
        nst.latencies.append(res.latency_s)
        nst.ttfts.append(res.ttft_s)
        nst.queue_waits.append(res.queue_s)
        for k, v in rs.stats.source_drafted.items():
            nst.source_drafted[k] = nst.source_drafted.get(k, 0) + v
        for k, v in rs.stats.source_accepted.items():
            nst.source_accepted[k] = nst.source_accepted.get(k, 0) + v
        if self.autotuner is not None:
            # retire-time observation: the request's per-source counters are
            # complete, and the call is a pure function of token history —
            # deterministic, so autotune on/off stays bit-identical (I1)
            self.autotuner.observe(rs.draft.namespace,
                                   rs.stats.source_drafted,
                                   rs.stats.source_accepted)
        h = self.handles.pop(rs.rid, None)   # pop: a long-running server
        if h is not None:                    # must not accrete dead handles
            h._finalize(res)
        return res


__all__ = ["ContinuousScheduler", "NamespaceStats", "SchedulerStats"]
