"""Slot-based continuous-batching scheduler (the production serving loop).

The paper's deployment setting ("serve heavy traffic" — Alipay production
since April 2023) needs the device batch to stay full: lock-step batching
leaves lanes idle as soon as the shortest request of a batch finishes, and
with mixed ``max_new_tokens`` most device steps run mostly-empty.  The
scheduler instead owns a fixed pool of ``lanes`` KV-cache slots plus an
admission queue:

  * a submitted request waits in the queue until a lane frees up,
  * the first admission batch-prefills one cohort (``StepFns.prefill`` at
    (lanes, prefill_len) — the dense-FLOPs phase keeps its batching);
    afterwards admission prefills the prompt *into* the freed lane only
    (``StepFns.prefill_into_slot`` — one (1, prefill_len) forward; every
    other lane keeps decoding, its cache untouched),
  * each decode step drives ALL lanes through one fixed-shape
    ``tree_step``/``commit`` pair; idle lanes carry a placeholder draft and
    commit zero tokens (masked out, never stalling anyone),
  * a request leaves its lane on EOS / budget / cache-overflow and the next
    queued request is admitted on the following scheduler iteration.  Stale
    KV rows of a freed lane are left in place — they are never attended
    (invariant I3); ``scrub_freed=True`` zeroes them at free time for
    debugging/inspection, not for correctness.

With a paged StepFns (``kv_layout == "paged"``; DESIGN.md §Paged KV cache)
the scheduler additionally owns a ``BlockAllocator``: admission requires a
free lane AND a reservable worst-case block demand (otherwise the FIFO
queue waits — preemption-free backpressure), block tables ride inside the
cache dict and are extended after each commit to cover the next tree step,
and a retiring request's blocks are freed — and, under ``scrub_freed``,
zeroed by physical id BEFORE they can be re-allocated (lane-keyed scrubbing
after reuse would destroy the next request's KV).

Slot lifecycle (DESIGN.md §Scheduler slot lifecycle):

    FREE --admit(prefill_into_slot)--> ACTIVE --accept*--> DRAINED --release--> FREE

Invariants the implementation maintains (and tests assert):

  I1  Losslessness is per-request: a request's tokens equal
      ``reference_decode`` output regardless of arrival order, lane
      assignment, or what else is co-batched (greedy and position-keyed
      sample mode alike — sampling keys fold the request's own absolute
      output position, never the lane or step index).
  I2  Fixed shapes: every device call after construction uses the same
      (lanes, T) / (1, prefill_len) shapes ⇒ each StepFns member compiles
      exactly once per scheduler.
  I3  A lane's committed cache prefix [0, lens[lane]) is always exactly the
      KV of its request's prompt ⧺ accepted tokens; rows beyond it are
      garbage and never attended.
  I4  Trie bookkeeping is slot-agnostic: prompt branches are inserted at
      admission and eliminated at retirement, output branches stream in as
      tokens are accepted — identical transitions to the lock-step loop.

Speculation is pluggable (DESIGN.md §Draft sources): each request's
resolved ``DraftPolicy`` names the draft sources feeding its trees
(default: the trie source alone — bit-identical to the old hardwired
path), the trie namespace isolating its scenario, and whether its draft
budget adapts to its accepted-length EMA.  All of it is host-side; the
device ``StepFns`` and every invariant above are untouched.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import TYPE_CHECKING, Deque, Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.draft_sources import (AdaptiveBudget, DraftPolicy,
                                      DraftSource, TrieSource,
                                      build_draft_from_policy, make_source)
from repro.core.request import (Request, RequestResult, RequestState,
                                SamplingParams, StepFns, cache_token_limit,
                                idle_tree)
from repro.core.strategies import LookaheadConfig
from repro.core.trie import TrieTree
from repro.core.verify import verify_accept_batch
from repro.serving.block_allocator import BlockAllocator, demand_blocks
from repro.serving.prefix_cache import PrefixCache

if TYPE_CHECKING:   # avoid a load-time cycle: api.py imports the scheduler
    from repro.serving.api import RequestHandle


class SchedulerStats:
    """Aggregate serving-loop statistics (occupancy is the continuous-
    batching win: mean fraction of lanes doing useful work per step)."""

    def __init__(self, lanes: int):
        self.lanes = lanes
        self.decode_steps = 0
        self.active_lane_steps = 0
        self.admitted = 0
        self.finished = 0
        self.block_waits = 0     # admissions deferred for blocks, not lanes
        self.peak_blocks = 0     # max physical blocks allocated at once
        # ---- per-step latency breakdown (totals over decode steps)
        self.host_draft_ms = 0.0     # draft retrieval/merging + tree packing
        self.device_step_ms = 0.0    # dispatch -> packed result on the host
        self.accept_commit_ms = 0.0  # accept bookkeeping, retire, tables
        self.hidden_host_ms = 0.0    # host work run while a step was in
        #                              flight on device (overlap mode only)
        self.host_syncs = 0          # every device->host pull the loop makes
        self.decode_syncs = 0        # pulls on the decode hot path only
        # ---- prefix cache (zeros when disabled)
        self.prefix_lookups = 0
        self.prefix_hits = 0          # admissions with >= 1 cached token
        self.prefix_hit_tokens = 0    # prompt tokens whose prefill was skipped
        self.prefix_prompt_tokens = 0  # prompt tokens presented to lookup
        self.prefix_cow_forks = 0
        self.prefix_evicted_blocks = 0

    @property
    def occupancy(self) -> float:
        return self.active_lane_steps / max(self.decode_steps * self.lanes, 1)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of looked-up admissions that matched a cached prefix."""
        return self.prefix_hits / max(self.prefix_lookups, 1)

    @property
    def prefill_tokens_saved(self) -> float:
        """Fraction of presented prompt tokens served from the cache."""
        return self.prefix_hit_tokens / max(self.prefix_prompt_tokens, 1)

    @property
    def syncs_per_decode_step(self) -> float:
        """Host syncs per decode step (1.0 on the fused hot path)."""
        return self.decode_syncs / max(self.decode_steps, 1)

    def breakdown(self) -> Dict[str, float]:
        """Mean per-decode-step latency split in milliseconds."""
        d = max(self.decode_steps, 1)
        return {"host_draft_ms": self.host_draft_ms / d,
                "device_step_ms": self.device_step_ms / d,
                "accept_commit_ms": self.accept_commit_ms / d,
                "hidden_host_ms": self.hidden_host_ms / d,
                "syncs_per_step": self.syncs_per_decode_step}


class ContinuousScheduler:
    """Fixed-lane continuous-batching serving loop over ``StepFns``.

    Drive it either as a batch runner (``submit`` everything, then ``run()``)
    or as an online loop (``submit`` as requests arrive, call ``step()``
    repeatedly; each call returns the requests that finished in it).
    """

    def __init__(self, fns: StepFns, config: LookaheadConfig, *,
                 lanes: int, trie: Optional[TrieTree] = None,
                 eos_id: int = -1, prefill_len: Optional[int] = None,
                 rid_start: int = 0, scrub_freed: bool = False,
                 default_params: Optional[SamplingParams] = None,
                 draft_policy: Optional[DraftPolicy] = None,
                 sources: Optional[Dict[str, DraftSource]] = None,
                 overlap_drafts: bool = False,
                 record_breakdown: bool = False,
                 prefix_cache: bool = False,
                 prefix_cache_blocks: Optional[int] = None):
        if not fns.supports_slot_serving:
            raise ValueError("StepFns lack prefill_into_slot/init_cache; "
                             "continuous batching needs per-slot admission")
        if overlap_drafts and fns.fused_step is None:
            raise ValueError("overlap_drafts needs StepFns.fused_step (the "
                             "single-dispatch step the overlap window hides "
                             "host work behind)")
        self.overlap_drafts = bool(overlap_drafts)
        self.record_breakdown = bool(record_breakdown)
        self.step_breakdown: List[Dict[str, float]] = []
        # overlap mode: requests retired at step k whose heavy bookkeeping
        # (trie elimination, block free + scrub, handle finalize) is deferred
        # into step k+1's in-flight window, and admissions whose
        # prefill_into_slot was dispatched but whose first-token pull is
        # deferred until the other lanes' drafts are built
        self._retired: List[RequestState] = []
        self._pending: Dict[int, RequestState] = {}
        self._pending_chosen: Dict[int, object] = {}
        self.fns = fns
        self.config = config
        self.eos_id = eos_id
        self.lanes = int(lanes)
        self.scrub_freed = bool(scrub_freed)
        self.prefill_len = int(prefill_len or fns.prefill_len or 0)
        if self.prefill_len <= 0:
            raise ValueError("prefill_len must be set (fixed prompt pad "
                             "length; compile-once admission)")
        # ---- draft sources (DESIGN.md §Draft sources): requests speculate
        # through the sources their resolved DraftPolicy names; the trie
        # source always exists (the default policy and the compat ``trie``
        # surface), wrapping the passed trie when one is handed over so a
        # caller-owned trie stays warm across scheduler instances.
        self.default_policy = (draft_policy if draft_policy is not None
                               else DraftPolicy()).validate()
        self.sources: Dict[str, DraftSource] = (
            sources if sources is not None else {})
        if "trie" not in self.sources:
            self.sources["trie"] = TrieSource(config, trie=trie)
        if config.strategy == "none" or config.decoding_length == 0:
            self.width = 1
        else:
            self.width = fns.slots
        if self.prefill_len + self.width > fns.max_seq_len:
            # the first tree step after admitting a full-length prompt would
            # scatter draft KV past the cache end (silently dropped rows ⇒
            # garbage logits ⇒ a losslessness violation, not an error)
            raise ValueError(
                f"prefill_len={self.prefill_len} + tree width={self.width} "
                f"exceeds max_seq_len={fns.max_seq_len}")
        self.cache = None          # allocated by the first admission batch
        self.lens = np.zeros((self.lanes,), dtype=np.int32)
        self.states: List[Optional[RequestState]] = [None] * self.lanes
        self.queue: Deque[RequestState] = deque()
        self.results: Dict[int, RequestResult] = {}
        self.handles: Dict[int, "RequestHandle"] = {}
        self._order: List[int] = []
        self.next_rid = int(rid_start)
        self.stats = SchedulerStats(self.lanes)
        # ---- per-lane sampling params (request-centric API): device-step
        # inputs, refreshed at admission; idle lanes keep the session default.
        # ``default_params`` (EngineConfig's) wins over the session-level
        # ones baked by make_session_fns (which carry no max_new_tokens)
        self._defaults = (default_params if default_params is not None
                          else fns.default_params)
        self.lane_greedy = np.full((self.lanes,), not self._defaults.sample)
        self.lane_temp = np.full((self.lanes,), self._defaults.temperature,
                                 dtype=np.float32)
        self.lane_seed = np.full((self.lanes,),
                                 np.uint32(self._defaults.seed),
                                 dtype=np.uint32)
        # ---- paged KV layout: host-side block tables + allocator
        self.kv_layout = getattr(fns, "kv_layout", "dense")
        self.allocator: Optional[BlockAllocator] = None
        if self.kv_layout == "paged":
            bpl = fns.blocks_per_lane
            nb = fns.n_blocks or 1 + self.lanes * bpl
            self.allocator = BlockAllocator(nb, fns.block_size)
            self.tables = np.zeros((self.lanes, bpl), dtype=np.int32)
            self._tables_dirty = True
        # ---- radix prefix cache (DESIGN.md §Prefix cache): lookup at
        # admission, insert at retire; shares pool blocks by refcount.
        self.prefix: Optional[PrefixCache] = None
        if prefix_cache:
            if self.allocator is None:
                raise ValueError("prefix_cache requires kv_layout='paged' "
                                 "(block sharing needs the paged pool)")
            if fns.prefill_suffix is None or fns.copy_block is None:
                raise ValueError("these StepFns lack prefill_suffix/"
                                 "copy_block; rebuild the session to enable "
                                 "the prefix cache")
            self.prefix = PrefixCache(self.allocator,
                                      max_blocks=prefix_cache_blocks)
        # transient per-admission hit info: rid -> (n_cached, cow_src,
        # cow_dst); written by _claim_blocks, consumed by the same _admit
        self._hits: Dict[int, tuple] = {}

    # ------------------------------------------------------------------ state
    @property
    def n_active(self) -> int:
        return sum(1 for s in self.states if s is not None)

    @property
    def n_queued(self) -> int:
        return len(self.queue)

    @property
    def idle(self) -> bool:
        return (self.n_active == 0 and not self.queue
                and not self._pending and not self._retired)

    def _pull(self, x, *, decode: bool = False) -> np.ndarray:
        """THE device->host transfer point: every pull the loop makes goes
        through here so tests can assert the per-step sync count (fused
        decode: exactly one packed pull per step)."""
        self.stats.host_syncs += 1
        if decode:
            self.stats.decode_syncs += 1
        return np.asarray(x)

    # ---------------------------------------------------------- draft sources
    @property
    def trie(self) -> TrieTree:
        """Default-namespace trie of the trie source (compat surface:
        engine warmup, stats printing, tests)."""
        return self.sources["trie"].trie

    def _resolve_sources(self, policy: DraftPolicy) -> List[DraftSource]:
        """The policy's source instances, instantiating registry entries on
        first use (shared across every request of this scheduler — and, when
        a ``sources`` dict was passed in, across schedulers)."""
        out = []
        for name in policy.sources:
            src = self.sources.get(name)
            if src is None:
                src = self.sources[name] = make_source(name, self.config)
            out.append(src)
        return out

    def _observe_prompt(self, rs: RequestState) -> None:
        for src in self._resolve_sources(rs.draft):
            src.observe_prompt(rs.rid, rs.prompt,
                               namespace=rs.draft.namespace)

    def _observe_output(self, rs: RequestState) -> None:
        for src in self._resolve_sources(rs.draft):
            src.observe_output(rs.rid, rs.output,
                               namespace=rs.draft.namespace)

    def _retire_sources(self, rs: RequestState) -> None:
        for src in self._resolve_sources(rs.draft):
            src.retire(rs.rid, namespace=rs.draft.namespace)

    # ------------------------------------------------------------------ paged
    def _demand_blocks(self, plen: int, max_new: int) -> int:
        """Worst-case block demand (the shared admission formula), reserved
        at admission so mid-flight ``extend`` can never fail
        (preemption-free backpressure; DESIGN.md §Paged KV cache)."""
        return demand_blocks(plen, max_new, self.width,
                             self.fns.max_seq_len, self.fns.block_size)

    def _claim_blocks(self, rs: RequestState, lane: int) -> bool:
        """Reserve + allocate initial blocks for ``rs``; False = not enough
        reservable blocks right now (request stays queued — backpressure).

        With the prefix cache enabled: look up the prompt first and PIN the
        matched nodes, so the eviction pass that makes room for this very
        admission cannot evict the blocks it is about to share; adopt
        matched full blocks into the table head by refcount, allocate a COW
        fork target for a partially-matched boundary block, and only then
        take fresh blocks for the uncached tail."""
        demand = self._demand_blocks(len(rs.prompt), rs.max_new_tokens)
        match = None
        if self.prefix is not None:
            match = self.prefix.lookup(rs.prompt,
                                       namespace=rs.draft.namespace)
            self.stats.prefix_lookups += 1
            self.stats.prefix_prompt_tokens += len(rs.prompt)
        if not self.allocator.can_admit(demand):
            # cache-only blocks are reclaimable: LRU-evict before declaring
            # backpressure (matched nodes are pinned, so a hit keeps its
            # shared blocks even under pool pressure)
            if self.prefix is not None:
                evicted = self.prefix.evict(demand)
                self.stats.prefix_evicted_blocks += len(evicted)
                self._scrub_blocks(evicted)
            if not self.allocator.can_admit(demand):
                if match is not None:
                    self.prefix.unpin(match)
                self.stats.block_waits += 1
                return False
        initial = min(self.allocator.blocks_for_tokens(
            len(rs.prompt) + self.width), demand)
        shared = match.blocks if match is not None else []
        cow_dst = None
        if match is not None and match.cow_block is not None:
            self.allocator.alloc(rs.rid, len(shared), reserve=demand,
                                 shared=shared)
            cow_dst = self.allocator.fork_cow(rs.rid, match.cow_block)
            self.allocator.extend(rs.rid, initial - len(shared) - 1)
        else:
            self.allocator.alloc(rs.rid, initial, reserve=demand,
                                 shared=shared)
        if match is not None:
            self.prefix.unpin(match)
            if match.n_tokens > 0:
                rs.stats.cached_prompt_tokens = match.n_tokens
                self.stats.prefix_hits += 1
                self.stats.prefix_hit_tokens += match.n_tokens
                self.stats.prefix_cow_forks += int(cow_dst is not None)
                self._hits[rs.rid] = (match.n_tokens, match.cow_block,
                                      cow_dst)
        table = self.allocator.table(rs.rid)
        self.tables[lane, :] = 0
        self.tables[lane, :len(table)] = table
        self._tables_dirty = True
        self.stats.peak_blocks = max(self.stats.peak_blocks,
                                     self.allocator.n_allocated)
        return True

    def _scrub_blocks(self, freed: Sequence[int]) -> None:
        """Zero freed blocks on device (hygiene) — only ids whose refcount
        actually reached zero may ever be passed here.  Chunked to the
        block-table width so one reset executable serves every call."""
        if not (self.scrub_freed and freed and self.cache is not None
                and self.fns.reset_blocks is not None):
            return
        bpl = self.fns.blocks_per_lane
        for i in range(0, len(freed), bpl):
            ids = np.zeros((bpl,), dtype=np.int32)
            chunk = freed[i:i + bpl]
            ids[:len(chunk)] = np.asarray(chunk, dtype=np.int32)
            self.cache = self.fns.reset_blocks(self.cache, ids)

    def _sync_tables(self) -> None:
        """Push host-side block-table edits into the device cache dict (the
        tables ride along as a regular input of every step fn).  Converted
        to a device array up front: a raw np array inside the donated cache
        pytree would change the donation mask and compile a second
        executable (I2)."""
        if (self.allocator is not None and self._tables_dirty
                and self.cache is not None):
            self.cache["block_tables"] = jnp.asarray(self.tables)
            self._tables_dirty = False

    # ------------------------------------------------------------ lane params
    def _set_lane_params(self, lane: int, params: SamplingParams) -> None:
        self.lane_greedy[lane] = not params.sample
        self.lane_temp[lane] = params.temperature
        self.lane_seed[lane] = np.uint32(params.seed)

    def _lane_params_all(self):
        """(lanes,) per-lane sampling vectors for a full-batch device step."""
        return {"greedy": self.lane_greedy.copy(),
                "temp": self.lane_temp.copy(),
                "seed": self.lane_seed.copy()}

    @staticmethod
    def _lane_params_one(params: SamplingParams):
        """(1,) vectors for a single-lane ``prefill_into_slot``."""
        return {"greedy": np.asarray([not params.sample]),
                "temp": np.asarray([params.temperature], dtype=np.float32),
                "seed": np.asarray([np.uint32(params.seed)],
                                   dtype=np.uint32)}

    # ----------------------------------------------------------------- submit
    def submit(self, prompt: Sequence[int], max_new_tokens: int) -> int:
        """Queue a request under the session's default params (legacy
        positional surface); returns its request id."""
        params = dataclasses.replace(self._defaults,
                                     max_new_tokens=int(max_new_tokens))
        return self.submit_request(Request(prompt=list(prompt),
                                           params=params)).rid

    def submit_request(self, request: Request) -> "RequestHandle":
        """Queue a ``Request`` and return its streaming ``RequestHandle``
        (incremental token deltas, ``.result()``, ``.cancel()``)."""
        from repro.serving.api import RequestHandle
        params = (request.params if request.params is not None
                  else self._defaults).validate()
        prompt = [int(t) for t in request.prompt]
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) > self.prefill_len:
            raise ValueError(f"prompt length {len(prompt)} exceeds "
                             f"prefill_len={self.prefill_len}")
        if params.sample and self.fns.sampling == "greedy":
            raise ValueError(
                "this session was built with sampling='greedy' (argmax-only"
                " executables); rebuild with sampling='mixed' to serve "
                "sampled requests")
        if not self.fns.per_lane_params and (
                params.sample != self._defaults.sample
                or (params.sample
                    and (params.temperature != self._defaults.temperature
                         or params.seed != self._defaults.seed))):
            raise ValueError(
                "these StepFns predate per-lane sampling params; requests "
                "must keep the session-level sample/temperature/seed")
        if self.allocator is not None:
            demand = self._demand_blocks(len(prompt), params.max_new_tokens)
            if demand > self.allocator.capacity:
                raise ValueError(
                    f"request demands {demand} KV blocks; pool capacity is "
                    f"{self.allocator.capacity} (it could never be admitted "
                    "— deadlock)")
        policy = (params.draft if params.draft is not None
                  else self.default_policy).validate()
        self._resolve_sources(policy)   # unknown names fail at submit time
        rid = self.next_rid
        self.next_rid += 1
        request.rid = rid
        rs = RequestState(rid=rid, prompt=prompt,
                          max_new_tokens=params.max_new_tokens,
                          eos_id=self.eos_id, params=params,
                          draft=policy,
                          token_limit=cache_token_limit(
                              self.fns.max_seq_len, self.width, len(prompt)))
        if policy.adaptive and self.width > 1:
            rs.budget_ctl = AdaptiveBudget.from_policy(
                policy, min(self.config.decoding_length, self.width - 1))
        rs.submit_t = time.perf_counter()
        self.queue.append(rs)
        self._order.append(rid)
        handle = RequestHandle(rs, self)
        self.handles[rid] = handle
        return handle

    # ------------------------------------------------------------------- loop
    def step(self) -> List[RequestResult]:
        """One scheduler iteration: admit into free lanes, then one masked
        decode step across all lanes.  Returns requests finished this call."""
        finished = self._admit()
        finished.extend(self._decode())
        return finished

    def run(self) -> List[RequestResult]:
        """Drain queue + lanes; results in submission order."""
        while not self.idle:
            self.step()
        return [self.results[rid] for rid in self._order
                if rid in self.results]

    # -------------------------------------------------------------- admission
    def _admit(self) -> List[RequestResult]:
        if self.cache is None and self.queue:
            return self._admit_initial_cohort()
        finished: List[RequestResult] = []
        fns = self.fns
        for lane in range(self.lanes):
            if lane in self._pending:
                continue
            while self.states[lane] is None and self.queue:
                rs = self.queue[0]
                if self.allocator is not None and \
                        not self._claim_blocks(rs, lane):
                    # not enough reservable blocks: the whole queue waits
                    # (FIFO — no overtaking, losslessness stays order-free)
                    return finished
                self.queue.popleft()
                rs.lane = lane
                rs.admit_t = time.perf_counter()
                self._set_lane_params(lane, rs.params)
                self._observe_prompt(rs)
                self._sync_tables()
                hit = self._hits.pop(rs.rid, None)
                if hit is not None:
                    # prefix-cache hit: COW-fork the boundary block if the
                    # match ends mid-block, then prefill only the uncached
                    # suffix (the shared blocks are already wired into the
                    # lane's table, so attention sees the full prefix)
                    n_cached, cow_src, cow_dst = hit
                    if cow_dst is not None:
                        self.cache = fns.copy_block(self.cache, cow_src,
                                                    cow_dst)
                    suffix = np.asarray([rs.prompt[n_cached:]],
                                        dtype=np.int32)
                    self.cache, chosen = fns.prefill_suffix(
                        self.cache, lane, suffix, n_cached,
                        lane_params=self._lane_params_one(rs.params))
                else:
                    toks = np.full((1, self.prefill_len), fns.pad_id,
                                   dtype=np.int32)
                    toks[0, :len(rs.prompt)] = np.asarray(rs.prompt,
                                                          dtype=np.int32)
                    plen = np.asarray([len(rs.prompt)], dtype=np.int32)
                    if fns.per_lane_params:
                        self.cache, chosen = fns.prefill_into_slot(
                            self.cache, lane, toks, plen,
                            lane_params=self._lane_params_one(rs.params))
                    else:
                        self.cache, chosen = fns.prefill_into_slot(
                            self.cache, lane, toks, plen)
                if self.overlap_drafts:
                    # leave the prefill in flight: its first-token pull is
                    # deferred until _decode has built the other lanes'
                    # drafts (host draft work overlaps the prefill)
                    self._pending[lane] = rs
                    self._pending_chosen[lane] = chosen
                    break
                if not self._settle(rs, int(self._pull(chosen)[0]), lane):
                    finished.append(self._finish(rs))
        return finished

    def _admit_initial_cohort(self) -> List[RequestResult]:
        """First admission: one batched (lanes, prefill_len) prefill builds
        the cache and fills as many lanes as the queue covers — the
        FLOPs-dense phase keeps its batching; per-slot prefill only pays for
        mid-flight admissions."""
        fns = self.fns
        cohort: List[RequestState] = []
        while len(cohort) < self.lanes and self.queue:
            rs = self.queue[0]
            if self.allocator is not None and \
                    not self._claim_blocks(rs, len(cohort)):
                break
            cohort.append(self.queue.popleft())
        if not cohort:
            return []
        toks = np.full((self.lanes, self.prefill_len), fns.pad_id,
                       dtype=np.int32)
        lens = np.ones((self.lanes,), dtype=np.int32)   # dummy rows: 1 pad
        now = time.perf_counter()
        for lane, rs in enumerate(cohort):
            rs.lane = lane
            rs.admit_t = now
            self._set_lane_params(lane, rs.params)
            self._observe_prompt(rs)
            toks[lane, :len(rs.prompt)] = np.asarray(rs.prompt,
                                                     dtype=np.int32)
            lens[lane] = len(rs.prompt)
        lane_kw = ({"lane_params": self._lane_params_all()}
                   if fns.per_lane_params else {})
        if self.allocator is not None:
            self.cache, chosen = fns.prefill(toks, lens, self.tables.copy(),
                                             **lane_kw)
            self._tables_dirty = False
        else:
            self.cache, chosen = fns.prefill(toks, lens, **lane_kw)
        chosen = self._pull(chosen)
        finished: List[RequestResult] = []
        for lane, rs in enumerate(cohort):
            if not self._settle(rs, int(chosen[lane]), lane):
                finished.append(self._finish(rs))
        return finished

    def _settle(self, rs: RequestState, first_token: int, lane: int) -> bool:
        """Common post-prefill bookkeeping; returns False if the request
        already finished at prefill (budget 1 / instant EOS) — its lane
        stays free for the next scheduler iteration."""
        rs.start(first_token)
        rs.first_token_t = time.perf_counter()
        rs.stats.host_syncs += 1        # the first-token pull
        self.stats.admitted += 1
        self._emit(rs, rs.output)
        if rs.done:
            self._observe_output(rs)
            return False
        self.states[lane] = rs
        self.lens[lane] = len(rs.prompt)
        return True

    # ----------------------------------------------------------------- decode
    def _build_tree(self, rs: RequestState):
        # adaptive lanes draft at their controller's current budget; the
        # remaining slots ride as padding (fixed W — no retrace)
        budget = (rs.budget_ctl.value if rs.budget_ctl is not None
                  else None)
        return build_draft_from_policy(
            self._resolve_sources(rs.draft), rs.draft, self.config, rs.rid,
            rs.context, self.fns.pad_id, self.width, budget=budget)

    def _decode(self) -> List[RequestResult]:
        fns, W = self.fns, self.width
        finished: List[RequestResult] = []
        if self.n_active == 0 and not self._pending:
            # nothing to step: flush deferred retirements so run() can end
            self._drain_retired(finished)
            return finished
        fused = fns.fused_step is not None
        t0 = time.perf_counter()
        # ---- host draft building.  In overlap mode any admission prefill
        # dispatched by _admit is still in flight here: draft retrieval /
        # merging for the established lanes runs behind that device work.
        trees: List = [None] * self.lanes
        for l in range(self.lanes):
            if self.states[l] is not None:
                trees[l] = self._build_tree(self.states[l])
        # settle deferred admissions (their first-token pull was hidden
        # behind the draft building above); a request finishing at prefill
        # leaves its lane free until the next scheduler iteration
        for lane in sorted(self._pending):
            rs = self._pending[lane]
            chosen = self._pending_chosen[lane]
            if self._settle(rs, int(self._pull(chosen)[0]), lane):
                trees[lane] = self._build_tree(rs)
            else:
                finished.append(self._finish(rs))
        self._pending.clear()
        self._pending_chosen.clear()
        active = [l for l in range(self.lanes) if self.states[l] is not None]
        if not active:
            self._drain_retired(finished)
            return finished
        for l in range(self.lanes):
            if trees[l] is None:
                trees[l] = idle_tree(W, fns.pad_id)
        tok = np.stack([t.tokens for t in trees])                     # (B,W)
        pos = (self.lens[:, None]
               + np.stack([t.depth for t in trees])).astype(np.int32)
        mask = np.stack([t.tree_mask for t in trees])                 # (B,W,W)
        self._sync_tables()
        lane_kw = ({"lane_params": self._lane_params_all()}
                   if fns.per_lane_params else {})
        t1 = time.perf_counter()
        drained = 0.0
        new_lens = self.lens.copy()
        if fused:
            # ---- single-dispatch hot path: tree forward + token choice +
            # device accept walk + commit in ONE jitted call; ONE packed
            # (B, 1+2W) pull crosses the host boundary per step.  The
            # device accepts untruncated; host-side truncation (budget /
            # EOS / stop) always retires the lane, so the extra committed
            # rows are garbage that is never attended (I3).
            parent = np.stack([t.parent for t in trees]).astype(np.int32)
            n_live = np.asarray(
                [t.n_slots if self.states[l] is not None else 0
                 for l, t in enumerate(trees)], dtype=np.int32)
            self.cache, packed = fns.fused_step(
                self.cache, self.lens, tok, pos, mask, parent, n_live,
                **lane_kw)
            if self._retired:
                # overlap window: the step is in flight — run the previous
                # step's deferred heavy retirement behind it
                td = time.perf_counter()
                self._drain_retired(finished)
                drained = time.perf_counter() - td
                self.stats.hidden_host_ms += drained * 1e3
            packed = self._pull(packed, decode=True)   # THE one sync point
            t2 = time.perf_counter()
            accepted = [packed[l, 1:1 + packed[l, 0]]
                        for l in range(self.lanes)]
            kv_slots = [packed[l, 1 + W:1 + W + packed[l, 0]]
                        for l in range(self.lanes)]
            for l in active:
                rs = self.states[l]
                n_before = len(rs.output)
                ks = rs.accept(accepted[l], kv_slots[l], trees[l].n_slots,
                               slot_sources=trees[l].slot_source)
                new_lens[l] += len(ks)
                rs.stats.host_syncs += 1
                self._emit(rs, rs.output[n_before:])
        else:
            # ---- legacy two-dispatch path (StepFns without fused_step):
            # chosen pull -> host accept walk -> commit -> new_lens pull
            if fns.per_lane_params:
                self.cache, chosen = fns.tree_step(
                    self.cache, self.lens, tok, pos, mask, **lane_kw)
            else:
                self.cache, chosen = fns.tree_step(self.cache, self.lens,
                                                   tok, pos, mask)
            chosen = self._pull(chosen, decode=True)
            t2 = time.perf_counter()
            accepted, kv_slots = verify_accept_batch(trees, chosen)
            gather = np.zeros((self.lanes, W), dtype=np.int32)
            n_acc = np.zeros((self.lanes,), dtype=np.int32)
            for l in active:
                rs = self.states[l]
                n_before = len(rs.output)
                ks = rs.accept(accepted[l], kv_slots[l], trees[l].n_slots,
                               slot_sources=trees[l].slot_source)
                gather[l, :len(ks)] = np.asarray(ks, dtype=np.int32)
                n_acc[l] = len(ks)
                rs.stats.host_syncs += 2
                self._emit(rs, rs.output[n_before:])
            self.cache, lens_dev = fns.commit(self.cache, self.lens, gather,
                                              n_acc)
            new_lens = self._pull(lens_dev, decode=True).astype(
                np.int32).copy()
        self.lens = new_lens
        self.stats.decode_steps += 1
        self.stats.active_lane_steps += len(active)

        for l in active:
            rs = self.states[l]
            self._observe_output(rs)
            # backstop: the token-granular ``token_limit`` retires a request
            # BEFORE the cache can overflow (cache_token_limit — shared with
            # the lock-step loop so both retire at the same token); this
            # device-safety check stays as a last line against a mis-set cap
            if self.lens[l] + W >= fns.max_seq_len and not rs.done:
                rs.done = True
                rs.finish_reason = rs.finish_reason or "cache"
            if rs.done:
                if self.overlap_drafts:
                    # free the lane now; the heavy bookkeeping runs in the
                    # next step's in-flight window (_drain_retired)
                    self._release_lane(rs, l)
                else:
                    finished.append(self._finish(rs))
                    self.states[l] = None
                    self.lens[l] = 0
        if self.allocator is not None:
            self._extend_tables(active)
        t3 = time.perf_counter()
        self.stats.host_draft_ms += (t1 - t0) * 1e3
        self.stats.device_step_ms += (t2 - t1 - drained) * 1e3
        self.stats.accept_commit_ms += (t3 - t2) * 1e3
        if self.record_breakdown:
            self.step_breakdown.append({
                "step": self.stats.decode_steps,
                "active": len(active),
                "host_draft_ms": (t1 - t0) * 1e3,
                "device_step_ms": (t2 - t1 - drained) * 1e3,
                "accept_commit_ms": (t3 - t2) * 1e3,
                "hidden_host_ms": drained * 1e3,
                "syncs": 1 if fused else 2})
        return finished

    def _extend_tables(self, active: List[int]) -> None:
        """Grow surviving lanes' block tables to cover the next tree step
        (lens + W rows).  Never fails: admission reserved each request's
        worst-case demand up front."""
        W = self.width
        for l in active:
            rs = self.states[l]
            if rs is None:
                continue
            needed = self.allocator.blocks_for_tokens(int(self.lens[l]) + W)
            cur = self.allocator.n_blocks_of(rs.rid)
            if needed > cur:
                new = self.allocator.extend(rs.rid, needed - cur)
                self.tables[l, cur:needed] = new
                self._tables_dirty = True
        self.stats.peak_blocks = max(self.stats.peak_blocks,
                                     self.allocator.n_allocated)

    # ------------------------------------------------------------- streaming
    def _emit(self, rs: RequestState, delta: Sequence[int]) -> None:
        """Push this step's accepted-token delta to the request's handle."""
        if not delta:
            return
        h = self.handles.get(rs.rid)
        if h is not None:
            h._push(list(delta))

    # ----------------------------------------------------------------- cancel
    def cancel(self, rid: int) -> bool:
        """Cancel a request mid-flight (or while queued).

        An active request leaves through the regular retire path — trie
        elimination, block free (+ scrub under ``scrub_freed``), lane
        release — so co-resident requests are untouched (I1 is per-request).
        Returns False if the request already finished.
        """
        for i, rs in enumerate(self.queue):      # still queued: nothing held
            if rs.rid == rid:
                del self.queue[i]
                rs.cancel()
                rs.finish_t = time.perf_counter()
                res = rs.result()
                self.results[rid] = res
                h = self.handles.pop(rid, None)
                if h is not None:
                    h._finalize(res)
                return True
        for lane in range(self.lanes):
            rs = self.states[lane]
            if rs is not None and rs.rid == rid:
                rs.cancel()
                self._finish(rs)
                self.states[lane] = None
                self.lens[lane] = 0
                return True
        for lane, rs in list(self._pending.items()):
            # overlap mode: admission prefill still in flight — drop the
            # reservation; the in-flight write lands before any re-admission
            # into the lane overwrites it (device-stream dispatch order)
            if rs.rid == rid:
                del self._pending[lane]
                del self._pending_chosen[lane]
                rs.cancel()
                self._finish(rs)
                return True
        for i, rs in enumerate(self._retired):
            # already done, heavy retirement still deferred: finalize now so
            # the caller sees a result immediately
            if rs.rid == rid:
                self._finish_retire(self._retired.pop(i))
                return False
        return False

    # ----------------------------------------------------------------- retire
    def _release_lane(self, rs: RequestState, lane: int) -> None:
        """Overlap mode: free the lane for next-iteration admission NOW;
        the heavy bookkeeping (trie elimination, block free + scrub, handle
        finalize) is deferred into the next step's in-flight window.

        The lane-keyed pieces must run here — the lane may be re-admitted
        before the deferred work drains: the table row is zeroed (the
        physical blocks stay owned by this rid until the deferred free, so
        they cannot be reallocated in between) and the dense lane scrub
        fires (a scrub after reuse would destroy the next request's KV)."""
        rs.finish_t = time.perf_counter()
        rs.lane = -1
        self.states[lane] = None
        self.lens[lane] = 0
        if self.allocator is not None:
            self.tables[lane, :] = 0
            self._tables_dirty = True
        elif (self.scrub_freed and self.fns.reset_slot is not None
                and self.cache is not None):
            self.cache = self.fns.reset_slot(self.cache, lane)
        self._retired.append(rs)

    def _drain_retired(self, finished: List[RequestResult]) -> None:
        """Run the deferred heavy retirement work (overlap mode).  Called
        while the next step is in flight on device — or, when no step is in
        flight, before run() can go idle."""
        while self._retired:
            finished.append(self._finish_retire(self._retired.pop(0)))

    def _finish(self, rs: RequestState) -> RequestResult:
        """Immediate retire (serial mode, cancel, finish-at-prefill)."""
        rs.finish_t = time.perf_counter()
        lane = rs.lane
        rs.lane = -1
        if self.allocator is not None and lane >= 0:
            self.tables[lane, :] = 0
            self._tables_dirty = True
        elif (self.scrub_freed and self.fns.reset_slot is not None
                and lane >= 0 and self.cache is not None):
            self.cache = self.fns.reset_slot(self.cache, lane)
        return self._finish_retire(rs)

    def _finish_retire(self, rs: RequestState) -> RequestResult:
        self._retire_sources(rs)
        if self.allocator is not None:
            # Promote the prompt's blocks into the prefix cache BEFORE the
            # free: the tree takes its own reference on each adopted block,
            # so the free below just drops this request's reference and the
            # cached KV stays resident.  Cancelled requests may have been
            # torn down before their prefill landed — skip them.
            if self.prefix is not None and not rs.cancelled and rs.prompt:
                nb_prompt = self.allocator.blocks_for_tokens(len(rs.prompt))
                table = self.allocator.table(rs.rid)
                self._scrub_blocks(self.prefix.insert(
                    rs.prompt, table[:nb_prompt],
                    namespace=rs.draft.namespace))
            # free-list first, scrub second — but always BEFORE the next
            # admission can reach the allocator, so a scrub can never hit a
            # block that already belongs to a newly admitted request.
            # ``free`` returns ONLY refcount-zero blocks: ids still shared
            # with the prefix cache or a co-resident request are never
            # scrubbed or re-allocated here (satellite: refcount-aware
            # deferred retirement).
            freed = self.allocator.free(rs.rid)
            self._scrub_blocks(freed)
        self._stamp_breakdown(rs)
        res = rs.result()
        self.results[rs.rid] = res
        self.stats.finished += 1
        h = self.handles.pop(rs.rid, None)   # pop: a long-running server
        if h is not None:                    # must not accrete dead handles
            h._finalize(res)
        return res

    def _stamp_breakdown(self, rs: RequestState) -> None:
        """Apportion the scheduler's batch-level per-step latency means to
        this request over the decode steps it rode in (its GenStats carry
        the breakdown into RequestResult)."""
        st, d = self.stats, max(self.stats.decode_steps, 1)
        part = max(rs.stats.steps - 1, 0)    # minus the prefill step
        rs.stats.host_draft_ms = st.host_draft_ms / d * part
        rs.stats.device_step_ms = st.device_step_ms / d * part
        rs.stats.accept_commit_ms = st.accept_commit_ms / d * part


__all__ = ["ContinuousScheduler", "SchedulerStats"]
