"""Request-centric serving API (DESIGN.md §Serving API).

The production surface over the continuous-batching stack:

  * ``SamplingParams`` / ``Request`` (repro.core.request) — per-request
    generation spec: greedy/sample, temperature, seed, stop token ids, stop
    sequences, max_new_tokens.  One co-batched scheduler run may mix them
    freely; the device step takes per-lane param vectors as traced inputs,
    so nothing retraces (I2) and every request stays bit-identical to
    ``reference_decode`` under its own params (I1).
  * ``RequestHandle`` — returned by ``submit``: incremental token stream
    (iterator or callback), ``.result()``, ``.cancel()``.
  * ``EngineConfig`` — one validated spec consolidating the kwargs that used
    to be threaded separately through ``make_session_fns``,
    ``ContinuousScheduler.__init__``, ``launch/serve.py`` argparse and
    ``benchmarks/common.py``.
  * ``build_engine(cfg, model_cfg, params)`` — the single entry point:
    jitted session + scheduler + handle plumbing as one ``ServingEngine``.

Single-threaded by design: handles *pump* the scheduler when the caller
blocks on them (``result()`` / iteration), so a plain script can stream
without an event loop; a server loop instead calls ``engine.step()`` itself
and consumes handle callbacks.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterator, List, Optional, Sequence,
                    Union)

from repro.core.draft_sources import DraftPolicy
from repro.core.request import (Request, RequestResult, RequestState,
                                SamplingParams, StepFns)
from repro.core.strategies import LookaheadConfig
from repro.serving.scheduler import ContinuousScheduler
from repro.serving.session import make_session_fns


# ---------------------------------------------------------------- EngineConfig
@dataclass(frozen=True)
class EngineConfig:
    """Validated spec of one serving engine (lanes + session + layout).

    Everything the serving stack used to take as scattered kwargs lives
    here; ``validate()`` rejects inconsistent combinations up front instead
    of at trace or admission time.
    """
    # scheduling; prefill_len None = legacy pad-to-batch-max (retraces per
    # prompt length — one-shot scripts only; the scheduler requires it set)
    lanes: int = 4
    prefill_len: Optional[int] = 128
    scrub_freed: bool = False
    # lookahead drafting
    decoding_length: int = 32
    branch_length: int = 12
    strategy: str = "hierarchical"
    # vocabulary ids
    eos_id: int = -1                    # -1 = arch defines no EOS
    pad_id: int = 0
    # attention backends (None = the model config's per-phase defaults)
    backend: Optional[str] = None
    prefill_backend: Optional[str] = None
    decode_backend: Optional[str] = None
    # KV-cache layout
    kv_layout: str = "dense"
    block_size: int = 64
    n_blocks: Optional[int] = None      # paged: None = dense-equivalent pool
    # sampling: "mixed" honors per-request params; "greedy" compiles the
    # argmax-only fast path and rejects sampled requests at submit
    sampling: str = "mixed"
    # overlap host work with the in-flight device step (DESIGN.md §Step
    # pipeline): admission first-token pulls settle after draft building,
    # and heavy retirement (trie elimination, block frees, handle finalize)
    # drains inside the next step's flight window.  Bit-identical outputs
    # to the serial path (losslessness is draft- and timing-independent).
    overlap_drafts: bool = False
    # radix-tree prefix caching over the paged pool (DESIGN.md §Prefix
    # cache): requests whose prompt prefix is already resident skip that
    # portion of prefill via refcounted copy-on-write block sharing.
    # Outputs stay bit-identical to the uncached path.  prefix_cache_blocks
    # caps the tree's resident blocks (None = bounded by pool pressure).
    prefix_cache: bool = False
    prefix_cache_blocks: Optional[int] = None
    # session defaults for requests submitted without their own params
    default_params: SamplingParams = field(default_factory=SamplingParams)
    # default speculation policy (draft sources / quotas / trie namespace /
    # adaptive budget) for requests whose params carry draft=None; purely
    # host-side, so any policy serves on the same compiled executables
    draft_policy: DraftPolicy = field(default_factory=DraftPolicy)
    # ---- multi-tenant SLO controls (DESIGN.md §Multi-tenant SLOs).  All
    # host-side admission/draft policy: outputs stay bit-identical (I1) and
    # nothing retraces (I2).
    # lane_shares: namespace -> fraction of the lane pool in (0, 1] it may
    # hold at once (weighted-fair admission; unlisted namespaces weigh like
    # the smallest listed share and are uncapped).  None/{} = global FIFO.
    lane_shares: Optional[Dict[str, float]] = None
    # draft_budget_caps: namespace -> max draft tokens per tree (bounds a
    # hot tenant's host-side draft cost; the compiled width is untouched)
    draft_budget_caps: Optional[Dict[str, int]] = None
    # autotune: per-namespace EMA bandit over draft-source quotas — sources
    # that never verify on a namespace get their quota driven to zero and
    # their retrieve cost skipped (core/autotune.py)
    autotune: bool = False
    # sanitize: opt-in runtime sanitizer (repro.analysis.sanitizer) —
    # per-request lifecycle state machine, shadow block-ownership ledger,
    # retrace monitor.  Debug/CI tool: adds host work and device probes
    # but never changes outputs; default-off costs nothing.
    sanitize: bool = False

    @property
    def slots(self) -> int:
        """Device tree width T = 1 + decoding_length (1 in plain mode)."""
        if self.strategy == "none" or self.decoding_length == 0:
            return 1
        return 1 + self.decoding_length

    def lookahead(self) -> LookaheadConfig:
        return LookaheadConfig(
            decoding_length=self.decoding_length,
            branch_length=self.branch_length, strategy=self.strategy,
            sample=self.default_params.sample,
            temperature=self.default_params.temperature)

    def validate(self) -> "EngineConfig":
        if self.lanes < 1:
            raise ValueError(f"lanes={self.lanes}: need >= 1")
        if self.prefill_len is not None and self.prefill_len < 1:
            raise ValueError(f"prefill_len={self.prefill_len}: need >= 1 "
                             "(fixed prompt pad length, compile-once)")
        if self.decoding_length < 0 or self.branch_length < 1:
            raise ValueError(
                f"decoding_length={self.decoding_length} / "
                f"branch_length={self.branch_length} out of range")
        if self.kv_layout not in ("dense", "paged"):
            raise ValueError(f"kv_layout={self.kv_layout!r}: expected "
                             "'dense' or 'paged'")
        if self.kv_layout == "paged" and self.block_size < 1:
            raise ValueError(f"block_size={self.block_size}: need >= 1")
        if self.prefix_cache and self.kv_layout != "paged":
            raise ValueError("prefix_cache=True requires kv_layout='paged' "
                             "(block sharing needs the paged pool)")
        if self.prefix_cache_blocks is not None \
                and self.prefix_cache_blocks < 0:
            raise ValueError(
                f"prefix_cache_blocks={self.prefix_cache_blocks}")
        if self.sampling not in ("mixed", "greedy"):
            raise ValueError(f"sampling={self.sampling!r}: expected 'mixed' "
                             "or 'greedy'")
        if self.sampling == "greedy" and self.default_params.sample:
            raise ValueError("sampling='greedy' (argmax-only executables) "
                             "conflicts with default_params.sample=True")
        from repro.models.attention import available_backends
        names = available_backends()
        for b in (self.backend, self.prefill_backend, self.decode_backend):
            if b is not None and b not in names:
                raise ValueError(f"unknown attention backend {b!r} "
                                 f"(registry: {', '.join(names)})")
        for nsn, share in (self.lane_shares or {}).items():
            if not 0.0 < float(share) <= 1.0:
                raise ValueError(f"lane_shares[{nsn!r}]={share}: need a "
                                 "pool fraction in (0, 1]")
        for nsn, cap in (self.draft_budget_caps or {}).items():
            if int(cap) < 0:
                raise ValueError(f"draft_budget_caps[{nsn!r}]={cap}: "
                                 "need >= 0")
        self.default_params.validate()
        self.draft_policy.validate()
        return self


def build_session_fns(cfg: EngineConfig, model_cfg, params, *,
                      logits_transform: Optional[Callable] = None
                      ) -> StepFns:
    """Compile the jitted ``StepFns`` an ``EngineConfig`` describes."""
    cfg.validate()
    if cfg.prefill_len is not None \
            and cfg.prefill_len + cfg.slots > model_cfg.max_seq_len:
        raise ValueError(
            f"prefill_len={cfg.prefill_len} + tree width {cfg.slots} "
            f"exceeds the model's max_seq_len={model_cfg.max_seq_len}; "
            "shorten prefill_len, shrink decoding_length, or raise "
            "max_seq_len")
    dp = cfg.default_params
    return make_session_fns(
        model_cfg, params, sample=dp.sample, temperature=dp.temperature,
        seed=dp.seed, sampling=cfg.sampling, slots=cfg.slots,
        pad_id=cfg.pad_id, prefill_len=cfg.prefill_len,
        logits_transform=logits_transform, backend=cfg.backend,
        prefill_backend=cfg.prefill_backend,
        decode_backend=cfg.decode_backend, kv_layout=cfg.kv_layout,
        block_size=cfg.block_size if cfg.kv_layout == "paged" else None,
        n_blocks=cfg.n_blocks)


# --------------------------------------------------------------- RequestHandle
class RequestHandle:
    """Streaming handle of one submitted request.

    Tokens arrive as per-step accepted deltas (a lookahead step may emit
    several at once).  Three consumption styles:

      * iterate: ``for tok in handle: ...`` — pumps the scheduler while the
        request is unfinished, yields tokens in order;
      * callback: ``handle.on_token(fn)`` — ``fn(delta_tokens)`` fires on
        every accepted delta (the backlog is replayed at registration);
      * block: ``handle.result()`` — pumps to completion, returns the
        ``RequestResult``.

    ``cancel()`` retires the request immediately through the scheduler's
    regular retire path (lane + KV blocks released, co-resident requests
    untouched); the result carries ``cancelled=True`` and the tokens
    streamed so far.
    """

    def __init__(self, state: RequestState, scheduler: ContinuousScheduler):
        self._state = state
        self._scheduler = scheduler
        self.rid = state.rid
        self._tokens: List[int] = []
        self._result: Optional[RequestResult] = None
        self._callbacks: List[Callable[[List[int]], None]] = []

    # ---- scheduler-side plumbing
    def _push(self, delta: List[int]) -> None:
        self._tokens.extend(delta)
        for cb in self._callbacks:
            cb(list(delta))

    def _finalize(self, result: RequestResult) -> None:
        self._result = result

    # ---- caller surface
    @property
    def done(self) -> bool:
        return self._result is not None

    @property
    def cancelled(self) -> bool:
        return self._result is not None and self._result.cancelled

    @property
    def tokens(self) -> List[int]:
        """Tokens streamed so far (a copy; grows until ``done``)."""
        return list(self._tokens)

    def on_token(self, callback: Callable[[List[int]], None]) -> None:
        """Register a per-delta callback; already-streamed tokens are
        replayed immediately so late registration never drops output."""
        if self._tokens:
            callback(list(self._tokens))
        self._callbacks.append(callback)

    def _pump(self) -> None:
        if self._scheduler.idle:
            raise RuntimeError(
                f"request {self.rid} never finished but the scheduler is "
                "idle (internal error)")
        self._scheduler.step()

    def result(self) -> RequestResult:
        """Drive the scheduler until this request finishes; returns its
        ``RequestResult`` (co-batched requests keep progressing too)."""
        while self._result is None:
            self._pump()
        return self._result

    def cancel(self) -> RequestResult:
        """Stop generating, release the lane and KV blocks; returns the
        partial result.  No-op if already finished."""
        if self._result is None:
            self._scheduler.cancel(self.rid)
        return self._result

    def __iter__(self) -> Iterator[int]:
        """Yield output tokens incrementally, pumping the scheduler as
        needed.  Ends when the request finishes (or is cancelled)."""
        i = 0
        while True:
            while i < len(self._tokens):
                yield self._tokens[i]
                i += 1
            if self._result is not None:
                return
            self._pump()


# ---------------------------------------------------------------- ServingEngine
class ServingEngine:
    """One serving engine: jitted session + continuous scheduler + handles.

    Drive it blocking (``submit`` everything, ``run()`` or
    ``handle.result()``) or as an online loop (``submit`` as requests
    arrive, call ``step()`` repeatedly).
    """

    def __init__(self, fns: StepFns, config: EngineConfig, *, trie=None):
        self.fns = fns
        self.config = config.validate()
        self.scheduler = ContinuousScheduler(
            fns, config.lookahead(), lanes=config.lanes,
            eos_id=config.eos_id, prefill_len=config.prefill_len,
            scrub_freed=config.scrub_freed, trie=trie,
            default_params=config.default_params,
            draft_policy=config.draft_policy,
            overlap_drafts=config.overlap_drafts,
            prefix_cache=config.prefix_cache,
            prefix_cache_blocks=config.prefix_cache_blocks,
            lane_shares=config.lane_shares,
            draft_budget_caps=config.draft_budget_caps,
            autotune=config.autotune, sanitize=config.sanitize)

    # ---- request surface
    def submit(self, request: Union[Request, Sequence[int]],
               params: Optional[SamplingParams] = None,
               **param_overrides: Any) -> RequestHandle:
        """Submit a ``Request`` — or a raw token prompt plus
        ``SamplingParams`` / keyword overrides of the engine defaults
        (e.g. ``submit(prompt, max_new_tokens=64, temperature=0.7,
        sample=True)``)."""
        if not isinstance(request, Request):
            if params is None:
                params = dataclasses.replace(self.config.default_params,
                                             **param_overrides)
            elif param_overrides:
                raise ValueError("pass params= or keyword overrides, "
                                 "not both")
            request = Request(prompt=list(request), params=params)
        elif params is not None or param_overrides:
            raise ValueError("a Request already carries its params")
        return self.scheduler.submit_request(request)

    def step(self) -> List[RequestResult]:
        """One scheduler iteration (admission + one masked decode step)."""
        return self.scheduler.step()

    def run(self) -> List[RequestResult]:
        """Drain queue + lanes; results in submission order."""
        return self.scheduler.run()

    def warmup(self, corpora: Sequence[Sequence[int]]) -> None:
        """Pre-load responses into the trie (paper Appendix D)."""
        la = self.scheduler.config
        if not la.insert_output:
            return
        for toks in corpora:
            self.scheduler.trie.insert_ngrams(toks, la.branch_length)

    # ---- warm draft-state persistence (repro.fleet; lazy imports keep the
    # fleet package out of the engine's import graph until first use)
    def draft_state(self, *, max_prefix_keys: Optional[int] = 64
                    ) -> Dict[str, Any]:
        """Snapshot the shared draft statistics (trie forests, n-gram
        tables, hot prefix keys) as a plain-data payload."""
        from repro.fleet.persist import collect_draft_state
        return collect_draft_state(self.scheduler,
                                   max_prefix_keys=max_prefix_keys)

    def merge_draft_state(self, payload: Dict[str, Any]) -> None:
        """Gossip: freq-sum another replica's payload into this engine's
        draft sources (capacity budgets re-enforced after the merge)."""
        from repro.fleet.persist import install_draft_state
        install_draft_state(self.scheduler, payload, merge=True)

    def save_draft_state(self, path: str, *,
                         max_prefix_keys: Optional[int] = 64
                         ) -> Dict[str, Any]:
        """Persist the warm draft state to ``path`` (atomic, versioned,
        checksummed); returns the payload written."""
        from repro.fleet.persist import save_draft_state
        payload = self.draft_state(max_prefix_keys=max_prefix_keys)
        save_draft_state(path, payload)
        return payload

    def load_draft_state(self, path: str, *,
                         prime_prefix: bool = True) -> Dict[str, Any]:
        """Resume with a donor's branch statistics (the continuous version
        of the paper's Appendix D warmup).

        Replaces the shared state of every source the file names, then —
        when this engine runs a prefix cache and ``prime_prefix`` is set —
        re-prefills each persisted hot prefix key as a 1-token priming
        request so the retire-time insert repopulates the radix tree
        through the regular machinery (KV blocks are device-resident and
        never travel in the file).  Priming requests run through the
        normal scheduler and show up in its stats.  Must be called on an
        idle engine, before serving traffic.
        """
        from repro.fleet.persist import install_draft_state, load_draft_state
        if not self.idle:
            raise RuntimeError("load_draft_state needs an idle engine "
                               "(warm state must precede traffic)")
        payload = load_draft_state(path)
        install_draft_state(self.scheduler, payload)
        prefix_keys = payload.get("prefix", {})
        if prime_prefix and self.scheduler.prefix is not None and prefix_keys:
            plen = self.scheduler.prefill_len
            for ns, keys in prefix_keys.items():
                policy = dataclasses.replace(self.config.draft_policy,
                                             namespace=str(ns))
                params = dataclasses.replace(self.config.default_params,
                                             max_new_tokens=1, draft=policy)
                for toks in keys:
                    toks = [int(t) for t in toks][:plen]
                    if toks:
                        self.submit(Request(prompt=toks, params=params))
            self.run()
        return payload

    # ---- state passthrough
    @property
    def idle(self) -> bool:
        return self.scheduler.idle

    @property
    def stats(self):
        return self.scheduler.stats

    @property
    def trie(self):
        return self.scheduler.trie


def build_engine(cfg: EngineConfig, model_cfg, params, *,
                 logits_transform: Optional[Callable] = None,
                 trie=None) -> ServingEngine:
    """THE entry point: compile a session for ``(model_cfg, params)`` under
    ``cfg`` and wrap it in a ``ServingEngine``."""
    fns = build_session_fns(cfg, model_cfg, params,
                            logits_transform=logits_transform)
    return ServingEngine(fns, cfg, trie=trie)


__all__ = ["EngineConfig", "RequestHandle", "ServingEngine",
           "build_session_fns", "build_engine", "Request", "SamplingParams",
           "DraftPolicy"]
