"""Token choice: greedy argmax or *position-keyed* sampling, per lane.

Lossless sampling for tree verification requires the sampled token at output
position ``p`` to be a deterministic function of (seed, p, logits) —
independent of how many tokens were accepted per step.  We use Gumbel-argmax
with a per-request key folded on the position:
``argmax(logits/τ_b + gumbel(fold_in(key(seed_b), p)))``.
Step-by-step decoding with the same rule produces bit-identical streams, which
is what the lossless property tests assert.

``choose_tokens_lanes`` is the request-centric entry point: the greedy flag,
temperature and seed are (B,) device vectors — traced *inputs*, not trace
constants — so one compiled step serves a lane pool mixing greedy and sampled
requests at distinct temperatures without retracing (I2).  ``choose_tokens``
keeps the legacy session-constant surface (dry-run cells, ad-hoc callers).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.distributed.sharding import active_mesh


def _sharded_argmax(logits: jax.Array) -> jax.Array:
    """§Perf: argmax over vocab-SHARDED logits without XLA's fallback of
    all-gathering (batch, T, V) — local argmax per model shard, then a tiny
    (tp, B, T) cross-shard reduction."""
    mesh = active_mesh()
    B, T, V = logits.shape
    if mesh is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    tp = mesh.shape.get("model", 1)
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    if tp <= 1 or V % tp:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    ba = dp_axes if (dp > 1 and B % dp == 0) else None

    def local(lg):                           # (B_loc, T, V/tp)
        v_loc = lg.shape[-1]
        li = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        lv = jnp.take_along_axis(lg, li[..., None], axis=-1)[..., 0]
        gi = li + jax.lax.axis_index("model") * v_loc
        vs = jax.lax.all_gather(lv, "model")         # (tp, B_loc, T)
        gs = jax.lax.all_gather(gi, "model")
        w = jnp.argmax(vs, axis=0)
        return jnp.take_along_axis(gs, w[None], axis=0)[0]

    return shard_map(local, mesh=mesh,
                     in_specs=P(ba, None, "model"),
                     out_specs=P(ba, None), check_rep=False)(logits)


def choose_tokens(logits: jax.Array, pred_positions: jax.Array,
                  sample: bool = False, temperature: float = 1.0,
                  base_key: Optional[jax.Array] = None) -> jax.Array:
    """logits (B, T, V); pred_positions (B, T) — the *output* position each
    slot's logits predict.  Returns (B, T) int32 chosen ids."""
    if not sample:
        return _sharded_argmax(logits)
    assert base_key is not None
    B, T, V = logits.shape
    flat_pos = pred_positions.reshape(-1)
    keys = jax.vmap(lambda p: jax.random.fold_in(base_key, p))(flat_pos)
    gum = jax.vmap(lambda k: jax.random.gumbel(k, (V,), jnp.float32))(keys)
    z = logits.astype(jnp.float32).reshape(-1, V) / max(temperature, 1e-6)
    return jnp.argmax(z + gum, axis=-1).astype(jnp.int32).reshape(B, T)


# ------------------------------------------------------------- per-lane choice
LaneParams = Dict[str, jax.Array]   # {"greedy": (B,) bool, "temp": (B,) f32,
                                    #  "seed": (B,) u32}


def choose_tokens_lanes(logits: jax.Array, pred_positions: jax.Array,
                        lane_params: LaneParams) -> jax.Array:
    """Per-lane token choice: lane b argmaxes when ``greedy[b]`` else
    Gumbel-argmax samples at ``temp[b]`` with key fold_in(key(seed[b]), p).

    logits (B, T, V); pred_positions (B, T) absolute output positions.
    Returns (B, T) int32.  All lane params are traced device vectors —
    values never retrace.  Both branches are evaluated and selected with
    ``where`` (per-lane mixing forbids lax.cond); build the session with
    ``sampling="greedy"`` to skip the Gumbel lane entirely.
    """
    arg = _sharded_argmax(logits)
    B, T, V = logits.shape
    seeds = lane_params["seed"]

    def _lane_keys(seed, ps):                       # ps (T,)
        base = jax.random.key(seed)
        return jax.vmap(lambda p: jax.random.fold_in(base, p))(ps)

    keys = jax.vmap(_lane_keys)(seeds, pred_positions)          # (B, T) keys
    gum = jax.vmap(jax.vmap(
        lambda k: jax.random.gumbel(k, (V,), jnp.float32)))(keys)
    tau = jnp.maximum(lane_params["temp"].astype(jnp.float32), 1e-6)
    z = logits.astype(jnp.float32) / tau[:, None, None]
    samp = jnp.argmax(z + gum, axis=-1).astype(jnp.int32)
    return jnp.where(lane_params["greedy"][:, None], arg, samp)


__all__ = ["choose_tokens", "choose_tokens_lanes", "LaneParams"]
