"""Token choice: greedy argmax or *position-keyed* sampling.

Lossless sampling for tree verification requires the sampled token at output
position ``p`` to be a deterministic function of (rng_key, p, logits) —
independent of how many tokens were accepted per step.  We use Gumbel-argmax
with a key folded on the position: ``argmax(logits/τ + gumbel(fold_in(key, p)))``.
Step-by-step decoding with the same rule produces bit-identical streams, which
is what the lossless property tests assert.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.distributed.sharding import active_mesh


def _sharded_argmax(logits: jax.Array) -> jax.Array:
    """§Perf: argmax over vocab-SHARDED logits without XLA's fallback of
    all-gathering (batch, T, V) — local argmax per model shard, then a tiny
    (tp, B, T) cross-shard reduction."""
    mesh = active_mesh()
    B, T, V = logits.shape
    if mesh is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    tp = mesh.shape.get("model", 1)
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    if tp <= 1 or V % tp:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    ba = dp_axes if (dp > 1 and B % dp == 0) else None

    def local(lg):                           # (B_loc, T, V/tp)
        v_loc = lg.shape[-1]
        li = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        lv = jnp.take_along_axis(lg, li[..., None], axis=-1)[..., 0]
        gi = li + jax.lax.axis_index("model") * v_loc
        vs = jax.lax.all_gather(lv, "model")         # (tp, B_loc, T)
        gs = jax.lax.all_gather(gi, "model")
        w = jnp.argmax(vs, axis=0)
        return jnp.take_along_axis(gs, w[None], axis=0)[0]

    return shard_map(local, mesh=mesh,
                     in_specs=P(ba, None, "model"),
                     out_specs=P(ba, None), check_rep=False)(logits)


def choose_tokens(logits: jax.Array, pred_positions: jax.Array,
                  sample: bool = False, temperature: float = 1.0,
                  base_key: Optional[jax.Array] = None) -> jax.Array:
    """logits (B, T, V); pred_positions (B, T) — the *output* position each
    slot's logits predict.  Returns (B, T) int32 chosen ids."""
    if not sample:
        return _sharded_argmax(logits)
    assert base_key is not None
    B, T, V = logits.shape
    flat_pos = pred_positions.reshape(-1)
    keys = jax.vmap(lambda p: jax.random.fold_in(base_key, p))(flat_pos)
    gum = jax.vmap(lambda k: jax.random.gumbel(k, (V,), jnp.float32))(keys)
    z = logits.astype(jnp.float32).reshape(-1, V) / max(temperature, 1e-6)
    return jnp.argmax(z + gum, axis=-1).astype(jnp.int32).reshape(B, T)


__all__ = ["choose_tokens"]
