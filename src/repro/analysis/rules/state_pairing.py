"""R6 — state_dict/load_state_dict pairing (DESIGN.md §Fleet serving).

Warm-state persistence (repro.fleet) round-trips every stateful component
through ``state_dict()`` / ``load_state_dict()``.  A class that grows one
half of the pair silently breaks the fleet contract:

* ``state_dict`` without ``load_state_dict`` — the component's warmth can
  be saved but a restarted replica can never take it back: the donor's
  statistics rot in the file.
* ``load_state_dict`` without ``state_dict`` — the component can consume
  foreign state but never donate its own, so gossip and warm restarts
  walk past it and a "fully saved" file quietly omits it.

Both methods must be defined on the SAME class (inheriting one half does
not pair it — the serialized shape is the defining class's business).
Suppress a justified exception with ``# repro-lint: disable=R6``.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.rules import Rule

PAIR = ("state_dict", "load_state_dict")


class StatePairingRule(Rule):
    rule_id = "R6"
    title = ("every state_dict() pairs with a load_state_dict() on the "
             "same class (warm-state round-trip contract)")

    def check(self, tree: ast.AST, path: str) -> List:
        findings: List = []
        for cls in (n for n in ast.walk(tree)
                    if isinstance(n, ast.ClassDef)):
            defs = {m.name: m for m in cls.body
                    if isinstance(m, (ast.FunctionDef,
                                      ast.AsyncFunctionDef))}
            save, load = PAIR
            if save in defs and load not in defs:
                findings.append(self.finding(
                    path, defs[save],
                    f"class {cls.name!r} defines {save}() without "
                    f"{load}(); persisted state could never be restored"))
            elif load in defs and save not in defs:
                findings.append(self.finding(
                    path, defs[load],
                    f"class {cls.name!r} defines {load}() without "
                    f"{save}(); the component consumes warm state but "
                    "never donates its own"))
        return findings


__all__ = ["StatePairingRule"]
