"""R5 — donated-cache pytree hygiene (the PR 3 footgun).

The KV cache dict is donated into the fused step (``donate_argnums``
covers it), so jax derives the donation mask from the pytree's *leaf
types and structure*.  Two mutations silently invalidate that mask:

* storing a **raw numpy array** under a cache key — the leaf type flips
  from ``jax.Array`` to ``np.ndarray``, the donation mask changes, and
  the next call recompiles (and stops donating, doubling peak memory).
  Device-put the value first;
* **adding/removing keys** (``del cache[...]`` / ``cache.pop(...)``) —
  the pytree structure changes, which is a guaranteed retrace.

The rule matches subscript stores / deletes whose base is named
``cache`` or ends in ``.cache`` (the repo's convention for the donated
pytree), with the value being an ``np.*`` constructor call.

Suppress a justified exception with ``# repro-lint: disable=R5``.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.rules import Rule, call_name, dotted_name

NP_CONSTRUCTORS = frozenset({
    "np.asarray", "np.array", "np.zeros", "np.ones", "np.full",
    "np.empty", "np.arange", "numpy.asarray", "numpy.array",
    "numpy.zeros", "numpy.ones", "numpy.full", "numpy.empty",
})


def _cache_base(node: ast.AST) -> Optional[str]:
    """Name of the subscript base if it looks like the donated cache."""
    if not isinstance(node, ast.Subscript):
        return None
    base = dotted_name(node.value)
    if base and (base == "cache" or base.endswith(".cache") or
                 base.endswith("_cache")):
        return base
    return None


class DonationMaskRule(Rule):
    rule_id = "R5"
    title = ("cache-dict mutations must not change the donation mask "
             "(no raw np leaves, no key add/remove)")

    def check(self, tree: ast.AST, path: str) -> List:
        findings: List = []
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    base = _cache_base(t)
                    if base and isinstance(node.value, ast.Call) and \
                            call_name(node.value) in NP_CONSTRUCTORS:
                        findings.append(self.finding(
                            path, node,
                            f"storing a raw numpy array into donated "
                            f"pytree {base!r} flips the leaf type and "
                            "invalidates the donation mask (recompile + "
                            "no donation); jax.device_put it first"))
            elif isinstance(node, ast.Delete):
                for t in node.targets:
                    base = _cache_base(t)
                    if base:
                        findings.append(self.finding(
                            path, node,
                            f"deleting a key from donated pytree "
                            f"{base!r} changes the pytree structure — "
                            "guaranteed retrace of every consumer"))
            elif isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "pop":
                base = dotted_name(node.func.value)
                if base and (base == "cache" or base.endswith(".cache")
                             or base.endswith("_cache")):
                    findings.append(self.finding(
                        path, node,
                        f"{base}.pop() changes the donated pytree "
                        "structure — guaranteed retrace of every "
                        "consumer"))
        return findings


__all__ = ["DonationMaskRule"]
