"""R4 — retrace hazards (DESIGN.md §Compile-once contract).

A jitted function recompiles for every distinct input *shape*.  The repo's
defence is the bucket ladder: variable-length host data is padded into one
of a fixed set of buckets *before* it reaches a jitted callable, so the
shape set is closed and compile counts stay flat.

This rule tracks names bound to jit applications in the module
(``step = jax.jit(fn, ...)`` or ``@jax.jit``-style decorated defs) and
flags call sites where an argument's shape depends on a Python value:

* an array constructor (``np.asarray``/``np.array``/``np.zeros``/...)
  whose payload contains ``len(...)`` or a variable-bound slice
  (``toks[n_cached:]``), fed straight into the jitted callable;
* a variable-bound slice passed directly as an argument.

The fix is always the same: pad into a preallocated fixed-size buffer
(see ``session.prefill_suffix``'s bucket ladder) so every call presents
a bucket shape.  Constant slices (``x[:, :4]``) are fine — the extent is
static.  Wrapper methods like ``prefill_suffix`` are deliberately *not*
treated as jitted callables: they ARE the padding layer.

Suppress a justified exception with ``# repro-lint: disable=R4``.
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.rules import Rule, call_name, dotted_name

ARRAY_CONSTRUCTORS = frozenset({
    "np.asarray", "np.array", "np.zeros", "np.ones", "np.full",
    "np.empty", "numpy.asarray", "numpy.array", "numpy.zeros",
    "numpy.ones", "numpy.full", "numpy.empty",
    "jnp.asarray", "jnp.array", "jnp.zeros", "jnp.ones", "jnp.full",
})
JIT_NAMES = frozenset({"jax.jit", "jit", "pjit", "jax.pjit"})
PARTIAL_NAMES = frozenset({"functools.partial", "partial"})


def _is_jit_application(node: ast.AST) -> bool:
    name = call_name(node)
    if name in JIT_NAMES:
        return True
    if name in PARTIAL_NAMES and isinstance(node, ast.Call) and \
            node.args and dotted_name(node.args[0]) in JIT_NAMES:
        return True
    return False


def _dynamic_slice(node: ast.Slice) -> bool:
    """Slice whose bound is a runtime Python value (not None/constant)."""
    for bound in (node.lower, node.upper):
        if bound is None or isinstance(bound, ast.Constant):
            continue
        if isinstance(bound, ast.UnaryOp) and \
                isinstance(bound.operand, ast.Constant):
            continue               # x[:-1] — static extent
        return True
    return False


def _dynamic_extent(node: ast.AST) -> bool:
    """Expression whose resulting array extent depends on a Python value:
    contains ``len(...)`` or a variable-bound slice."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and call_name(sub) == "len":
            return True
        if isinstance(sub, ast.Slice) and _dynamic_slice(sub):
            return True
    return False


def _collect_jitted_names(tree: ast.AST) -> Set[str]:
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and \
                _is_jit_application(node.value):
            for t in node.targets:
                n = dotted_name(t)
                if n:
                    names.add(n)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for deco in node.decorator_list:
                if dotted_name(deco) in JIT_NAMES or \
                        _is_jit_application(deco):
                    names.add(node.name)
    return names


class RetraceHazardRule(Rule):
    rule_id = "R4"
    title = ("no Python-value-dependent shapes into jitted callables — "
             "pad into a fixed bucket first")

    def check(self, tree: ast.AST, path: str) -> List:
        jitted = _collect_jitted_names(tree)
        if not jitted:
            return []
        findings: List = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            callee = call_name(node)
            if callee not in jitted:
                continue
            for arg in list(node.args) + [kw.value for kw in
                                          node.keywords]:
                hazard = False
                for sub in ast.walk(arg):
                    if isinstance(sub, ast.Call) and \
                            call_name(sub) in ARRAY_CONSTRUCTORS and \
                            any(_dynamic_extent(a) for a in sub.args):
                        hazard = True
                        break
                    if isinstance(sub, ast.Subscript) and \
                            isinstance(sub.slice, ast.Slice) and \
                            _dynamic_slice(sub.slice):
                        hazard = True
                        break
                if hazard:
                    findings.append(self.finding(
                        path, arg,
                        f"argument to jitted {callee!r} has a "
                        "Python-value-dependent shape (retrace per "
                        "distinct length); pad into a fixed bucket "
                        "before the call"))
        return findings


__all__ = ["RetraceHazardRule"]
