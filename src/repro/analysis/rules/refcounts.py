"""R3 — refcount API pairing (DESIGN.md §Paged KV ownership).

The ``BlockAllocator`` hands out *shared* ownership: ``share()`` and
``cache_ref()`` bump per-block refcounts, and the matching ``free()`` /
``cache_unref()`` drop them.  Two classes of rot this rule catches:

* **Unpaired acquire** — a class that calls ``share``/``cache_ref`` but
  has no reachable ``free``/``cache_unref`` anywhere in the same class
  leaks blocks by construction (refcounts only ever go up).

* **Dropped release result** — ``free()`` and ``cache_unref()`` return
  the ids whose refcount actually hit zero; only *those* may be scrubbed
  or handed back to the pool.  A bare ``self.alloc.free(ids)`` statement
  throws that list away, which is exactly the shape of PR 8's
  cancel-of-pending use-after-free (blocks freed and re-allocated while
  a dispatch was still in flight, because nobody tracked which ids had
  truly quiesced).

Suppress a justified exception with ``# repro-lint: disable=R3``.
"""
from __future__ import annotations

import ast
from typing import Dict, List

from repro.analysis.rules import Rule

ACQUIRE_TO_RELEASE = {"share": "free", "cache_ref": "cache_unref"}
RELEASE_METHODS = frozenset(ACQUIRE_TO_RELEASE.values())


def _attr_calls(node: ast.AST):
    """Yield (method_name, Call) for every attribute call in ``node``."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and \
                isinstance(sub.func, ast.Attribute):
            yield sub.func.attr, sub


class RefcountPairingRule(Rule):
    rule_id = "R3"
    title = ("share/cache_ref acquires pair with free/cache_unref in the "
             "same class; release results are never dropped")

    def check(self, tree: ast.AST, path: str) -> List:
        findings: List = []
        for cls in (n for n in ast.walk(tree)
                    if isinstance(n, ast.ClassDef)):
            # skip the allocator itself: it *defines* these methods
            defined = {m.name for m in cls.body
                       if isinstance(m, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))}
            if RELEASE_METHODS & defined:
                continue

            called: Dict[str, ast.Call] = {}
            for name, call in _attr_calls(cls):
                called.setdefault(name, call)

            for acquire, release in ACQUIRE_TO_RELEASE.items():
                if acquire in called and release not in called:
                    findings.append(self.finding(
                        path, called[acquire],
                        f"{acquire}() acquires block refs but class "
                        f"{cls.name!r} has no reachable {release}(); "
                        "refcounts can only ever go up"))

            # dropped release results: a bare-expression statement whose
            # value is free()/cache_unref() discards the refcount-zero ids
            for sub in ast.walk(cls):
                if isinstance(sub, ast.Expr) and \
                        isinstance(sub.value, ast.Call) and \
                        isinstance(sub.value.func, ast.Attribute) and \
                        sub.value.func.attr in RELEASE_METHODS:
                    meth = sub.value.func.attr
                    findings.append(self.finding(
                        path, sub.value,
                        f"result of {meth}() dropped on the floor; it "
                        "returns the refcount-zero ids that must be "
                        "scrubbed before re-allocation"))
        return findings


__all__ = ["RefcountPairingRule"]
