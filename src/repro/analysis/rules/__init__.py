"""Repo-specific lint rules over the serving engine's invariants.

Each rule is a class with a ``rule_id`` (``R1``..``R5``), a one-line
``title``, and ``check(tree, path) -> List[Finding]``.  Rules are pure AST
walks — no imports of the linted code, no execution — so the linter runs on
a bare stdlib interpreter.

    R1  device-pull discipline: inside classes that define a ``_pull``
        choke point, every device->host transfer must go through it
    R2  jit call sites declare donate_argnums/static_argnums explicitly
        and never close over mutable object state
    R3  refcount API pairing: share/cache_ref acquires need a reachable
        free/cache_unref in the same class, and free()/cache_unref()
        results must never be dropped (only refcount-zero ids may be
        scrubbed or re-allocated)
    R4  no Python-value-dependent shapes flowing into jitted functions
        (retrace hazards: pad to a fixed bucket first)
    R5  donated-cache-dict hygiene: key stores must be device arrays
        (a raw np array changes the donation mask and recompiles), key
        deletion changes the pytree structure
    R6  warm-state pairing: every ``state_dict`` has a matching
        ``load_state_dict`` on the same class (and vice versa) — the
        fleet persistence round-trip contract
"""
from __future__ import annotations

import ast
import dataclasses
from typing import List, Optional


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint violation, formatted ``path:line:col: Rn message``."""
    path: str
    line: int
    col: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} " \
               f"{self.message}"


class Rule:
    """Base class: subclasses set ``rule_id``/``title`` and implement
    ``check``."""

    rule_id: str = ""
    title: str = ""

    def check(self, tree: ast.AST, path: str) -> List[Finding]:
        raise NotImplementedError

    def finding(self, path: str, node: ast.AST, message: str) -> Finding:
        return Finding(path=path, line=getattr(node, "lineno", 0),
                       col=getattr(node, "col_offset", 0),
                       rule=self.rule_id, message=message)


# ------------------------------------------------------------- AST helpers
def dotted_name(node: ast.AST) -> Optional[str]:
    """``np.asarray`` for a Name/Attribute chain, None for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.AST) -> Optional[str]:
    """Dotted callee name of a Call node (None for computed callees)."""
    if isinstance(node, ast.Call):
        return dotted_name(node.func)
    return None


def contains_len_or_slice(node: ast.AST) -> bool:
    """True if the expression contains a ``len(...)`` call or a slice —
    the two spellings of a Python-value-dependent array extent."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and call_name(sub) == "len":
            return True
        if isinstance(sub, ast.Slice):
            return True
    return False


def function_defs(node: ast.AST):
    """Immediate FunctionDef/AsyncFunctionDef children of a body-carrier."""
    for child in getattr(node, "body", []):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield child


def all_rules() -> List[Rule]:
    """Instantiate the full registry in rule-id order."""
    from repro.analysis.rules.device_pulls import DevicePullRule
    from repro.analysis.rules.donation import DonationMaskRule
    from repro.analysis.rules.jit_discipline import JitDisciplineRule
    from repro.analysis.rules.refcounts import RefcountPairingRule
    from repro.analysis.rules.retrace import RetraceHazardRule
    from repro.analysis.rules.state_pairing import StatePairingRule
    return [DevicePullRule(), JitDisciplineRule(), RefcountPairingRule(),
            RetraceHazardRule(), DonationMaskRule(), StatePairingRule()]


__all__ = ["Finding", "Rule", "all_rules", "dotted_name", "call_name",
           "contains_len_or_slice", "function_defs"]
