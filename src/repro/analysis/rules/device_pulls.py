"""R1 — device-pull discipline (DESIGN.md §Step pipeline).

The fused decode path makes exactly ONE device->host transfer per step, and
it goes through the scheduler's ``_pull()`` choke point so tests can count
it.  A raw ``np.asarray``/``.item()``/``int()`` on a traced value anywhere
else in the loop silently adds a hidden sync — the exact perf rot PR 6
removed.

The rule therefore activates inside any class that defines a ``_pull``
method (the choke-point contract) and, per method, tracks which local names
hold *device values*: results of calls to the jitted ``StepFns`` surface
(``prefill``, ``tree_step``, ``fused_step``, ...), and anything derived
from them.  A name laundered through ``self._pull(...)`` becomes a host
value again.  Flagged on device values outside ``_pull`` itself:

  * ``np.asarray(x)`` / ``np.array(x)`` / ``jax.device_get(x)``
  * ``int(x)`` / ``float(x)`` / ``bool(x)``
  * ``x.item()`` / ``x.tolist()``
  * ``x.block_until_ready()`` (flagged unconditionally — it is always a
    sync, whatever ``x`` is)

Suppress a justified exception with ``# repro-lint: disable=R1``.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from repro.analysis.rules import Rule, call_name, dotted_name

# the jitted StepFns members whose results live on device
DEVICE_PRODUCERS = frozenset({
    "prefill", "prefill_into_slot", "prefill_suffix", "tree_step",
    "fused_step", "commit", "copy_block", "reset_blocks", "reset_slot",
    "init_cache",
})
PULL_CALLS = frozenset({"np.asarray", "np.array", "numpy.asarray",
                        "numpy.array", "jax.device_get"})
SCALAR_CASTS = frozenset({"int", "float", "bool"})
SYNC_METHODS = frozenset({"item", "tolist"})


def _is_device_call(node: ast.AST) -> bool:
    """Call whose callee is a StepFns member (``fns.fused_step(...)``,
    ``self.fns.prefill(...)``)."""
    if not isinstance(node, ast.Call):
        return False
    if isinstance(node.func, ast.Attribute) and \
            node.func.attr in DEVICE_PRODUCERS:
        return True
    return False


def _is_pull_call(node: ast.AST) -> bool:
    """A call through the ``_pull`` choke point."""
    name = call_name(node)
    return bool(name) and (name == "_pull" or name.endswith("._pull"))


def _root(node: ast.AST) -> Optional[str]:
    """Dotted root a value expression reads from: ``packed[l, 0]`` ->
    ``packed``; ``self.cache["k"]`` -> ``self.cache``."""
    while isinstance(node, ast.Subscript):
        node = node.value
    return dotted_name(node)


class _MethodScanner:
    """Order-sensitive scan of one method body, tracking device names."""

    def __init__(self, rule: "DevicePullRule", path: str):
        self.rule = rule
        self.path = path
        self.device: Set[str] = set()
        self.findings: List = []

    # -------------------------------------------------------------- taint
    def _tainted(self, node: ast.AST) -> bool:
        """Expression reads a device value (or IS a device call)."""
        for sub in ast.walk(node):
            if _is_device_call(sub):
                return True
            if _is_pull_call(sub):
                # a pull result is host data; don't descend further —
                # handled by the coarse walk being permissive here
                continue
            name = dotted_name(sub) if isinstance(
                sub, (ast.Name, ast.Attribute)) else None
            if name in self.device:
                return True
        return False

    def _bind(self, targets, value: ast.AST) -> None:
        names = []
        for t in targets:
            if isinstance(t, (ast.Tuple, ast.List)):
                names.extend(n for n in map(dotted_name, t.elts) if n)
            else:
                n = dotted_name(t)
                if n:
                    names.append(n)
        if _is_pull_call(value):
            for n in names:
                self.device.discard(n)
        elif _is_device_call(value) or self._tainted(value):
            for n in names:
                self.device.add(n)
        else:
            for n in names:
                self.device.discard(n)

    # --------------------------------------------------------- violations
    def _check_expr(self, node: ast.AST) -> None:
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            if _is_pull_call(sub):
                continue                      # the blessed choke point
            name = call_name(sub)
            if isinstance(sub.func, ast.Attribute) and \
                    sub.func.attr == "block_until_ready":
                self.findings.append(self.rule.finding(
                    self.path, sub,
                    "block_until_ready() is a device sync; route the "
                    "transfer through the _pull() choke point"))
                continue
            args_tainted = any(
                _root(a) in self.device or _is_device_call(a)
                for a in sub.args)
            if name in PULL_CALLS and args_tainted:
                self.findings.append(self.rule.finding(
                    self.path, sub,
                    f"raw device pull {name}() on a traced value outside "
                    "_pull(); route it through the choke point (or "
                    "# repro-lint: disable=R1 with a justification)"))
            elif name in SCALAR_CASTS and args_tainted:
                self.findings.append(self.rule.finding(
                    self.path, sub,
                    f"{name}() on a traced value forces a hidden device "
                    "sync; pull through _pull() first"))
            elif isinstance(sub.func, ast.Attribute) and \
                    sub.func.attr in SYNC_METHODS and \
                    _root(sub.func.value) in self.device:
                self.findings.append(self.rule.finding(
                    self.path, sub,
                    f".{sub.func.attr}() on a traced value is a hidden "
                    "device sync; pull through _pull() first"))

    # -------------------------------------------------------------- drive
    def scan(self, body) -> None:
        for st in body:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                continue                       # nested scopes: out of scope
            if isinstance(st, ast.Assign):
                self._check_expr(st.value)
                self._bind(st.targets, st.value)
            elif isinstance(st, (ast.AnnAssign, ast.AugAssign)):
                if st.value is not None:
                    self._check_expr(st.value)
                    self._bind([st.target], st.value)
            elif isinstance(st, ast.For):
                self._check_expr(st.iter)
                if self._tainted(st.iter):
                    self._bind([st.target], st.iter)
                self.scan(st.body)
                self.scan(st.orelse)
            elif isinstance(st, ast.While):
                self._check_expr(st.test)
                self.scan(st.body)
                self.scan(st.orelse)
            elif isinstance(st, ast.If):
                self._check_expr(st.test)
                self.scan(st.body)
                self.scan(st.orelse)
            elif isinstance(st, ast.With):
                for item in st.items:
                    self._check_expr(item.context_expr)
                self.scan(st.body)
            elif isinstance(st, ast.Try):
                self.scan(st.body)
                for h in st.handlers:
                    self.scan(h.body)
                self.scan(st.orelse)
                self.scan(st.finalbody)
            else:
                self._check_expr(st)


class DevicePullRule(Rule):
    rule_id = "R1"
    title = ("device->host transfers go through the _pull() choke point "
             "(one sync per decode step)")

    def check(self, tree: ast.AST, path: str) -> List:
        findings: List = []
        for cls in (n for n in ast.walk(tree)
                    if isinstance(n, ast.ClassDef)):
            methods = [m for m in cls.body
                       if isinstance(m, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))]
            if not any(m.name == "_pull" for m in methods):
                continue                       # no choke-point contract
            for m in methods:
                if m.name == "_pull":
                    continue                   # the choke point itself
                scanner = _MethodScanner(self, path)
                scanner.scan(m.body)
                findings.extend(scanner.findings)
        return findings


__all__ = ["DevicePullRule", "DEVICE_PRODUCERS"]
