"""R2 — jit call-site discipline (DESIGN.md §Compile-once contract).

Every ``jax.jit`` in this repo exists to be compiled exactly once per
shape bucket, with buffer donation spelled out.  Two ways that rots:

* An **implicit argnums** site — ``@jax.jit`` with no
  ``donate_argnums``/``static_argnums`` (or the ``*_argnames`` forms).
  Donation then defaults to "nothing", silently doubling peak KV memory
  on the fused step, and the reader cannot tell whether that was chosen
  or forgotten.  The empty tuple is fine; it just has to be *written*.

* A jitted function that **closes over ``self``** — scheduler state read
  at trace time gets baked into the compiled executable, so later
  mutation either desyncs silently or forces a retrace.  Everything the
  function needs must arrive as an argument.

Accepted spellings::

    @functools.partial(jax.jit, donate_argnums=(0,), static_argnums=(2,))
    step = jax.jit(fn, donate_argnums=())

Suppress a justified exception with ``# repro-lint: disable=R2``.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.rules import Rule, call_name, dotted_name

JIT_NAMES = frozenset({"jax.jit", "jit", "pjit", "jax.pjit"})
ARGNUM_KWARGS = frozenset({"donate_argnums", "static_argnums",
                           "donate_argnames", "static_argnames"})
PARTIAL_NAMES = frozenset({"functools.partial", "partial"})


def _is_jit_ref(node: ast.AST) -> bool:
    name = dotted_name(node)
    return name in JIT_NAMES


def _jit_call_kwargs(node: ast.Call) -> Optional[List[str]]:
    """If ``node`` is a jit application (``jax.jit(...)`` or
    ``functools.partial(jax.jit, ...)``), return its keyword names,
    else None."""
    name = call_name(node)
    if name in JIT_NAMES:
        return [kw.arg for kw in node.keywords if kw.arg]
    if name in PARTIAL_NAMES and node.args and _is_jit_ref(node.args[0]):
        return [kw.arg for kw in node.keywords if kw.arg]
    return None


class JitDisciplineRule(Rule):
    rule_id = "R2"
    title = ("jax.jit sites declare donate_argnums/static_argnums "
             "explicitly and never close over mutable object state")

    def check(self, tree: ast.AST, path: str) -> List:
        findings: List = []
        jitted_fn_names = set()

        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for deco in node.decorator_list:
                    if _is_jit_ref(deco):
                        findings.append(self.finding(
                            path, deco,
                            "bare @jax.jit: spell out donate_argnums=() "
                            "and static_argnums=() (use functools.partial)"
                        ))
                        jitted_fn_names.add(node.name)
                    elif isinstance(deco, ast.Call):
                        kwargs = _jit_call_kwargs(deco)
                        if kwargs is None:
                            continue
                        jitted_fn_names.add(node.name)
                        if not any(k in ARGNUM_KWARGS for k in kwargs):
                            findings.append(self.finding(
                                path, deco,
                                "jit application without explicit "
                                "donate_argnums/static_argnums"))
            elif isinstance(node, ast.Call):
                kwargs = _jit_call_kwargs(node)
                if kwargs is not None and \
                        not any(k in ARGNUM_KWARGS for k in kwargs):
                    findings.append(self.finding(
                        path, node,
                        "jit application without explicit "
                        "donate_argnums/static_argnums"))

        # closure check: jitted defs must not read the enclosing ``self``
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            is_jitted = node.name in jitted_fn_names or any(
                _is_jit_ref(d) or (isinstance(d, ast.Call) and
                                   _jit_call_kwargs(d) is not None)
                for d in node.decorator_list)
            if not is_jitted:
                continue
            params = {a.arg for a in node.args.args +
                      node.args.posonlyargs + node.args.kwonlyargs}
            if "self" in params:
                continue            # a bound method: self is an argument
            for sub in ast.walk(node):
                if isinstance(sub, ast.Name) and sub.id == "self" and \
                        isinstance(sub.ctx, ast.Load):
                    findings.append(self.finding(
                        path, sub,
                        f"jitted function {node.name!r} closes over "
                        "mutable object state via `self`; pass the value "
                        "as an argument instead"))
                    break
        return findings


__all__ = ["JitDisciplineRule"]
