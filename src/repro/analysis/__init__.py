"""Invariant analysis for the serving engine (DESIGN.md §Invariants &
analysis).

Two halves, one purpose: the engine's losslessness guarantee rests on a
stack of hand-enforced invariants (one device sync per decode step through
``_pull``, compile-once fixed shapes, refcounted block ownership with
scrub-before-realloc, deferred frees under draft/device overlap).  Reviewer
vigilance does not scale with the scheduler; mechanical checking does.

* **Static pass** — ``repro.analysis.lint`` walks the AST of ``src/`` with
  repo-specific rules R1-R5 (``repro.analysis.rules``).  Run it as

      python -m repro.analysis.lint src/

  Findings suppress per line with ``# repro-lint: disable=Rn``.

* **Runtime sanitizer** — ``repro.analysis.sanitizer`` is the opt-in
  (``EngineConfig.sanitize=True`` / ``serve.py --sanitize``) shadow layer:
  a block-ownership ledger mirroring the ``BlockAllocator``, a per-request
  lifecycle state machine on the scheduler, and a retrace monitor asserting
  observed jit compile counts against a declared manifest.

This module deliberately imports nothing heavyweight: the linter runs on a
bare stdlib interpreter (CI's lint job), and the sanitizer needs only
numpy.  Import the submodules directly.
"""

__all__ = ["lint", "rules", "sanitizer"]
