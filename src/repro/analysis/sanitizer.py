"""Runtime sanitizer: shadow checks for the serving engine (opt-in).

Enabled with ``EngineConfig(sanitize=True)`` / ``serve.py --sanitize`` (or
``ContinuousScheduler(..., sanitize=True)`` directly).  Three monitors:

* :class:`LifecycleMonitor` — a per-request state machine
  (queued → admitted → active → retiring → drained).  Every scheduler
  transition is recorded; an out-of-order transition raises
  :class:`InvariantViolation` carrying the request's full history.  This
  is the check that pins PR 8's cancel-of-pending shape: a cancelled
  overlap admission must sit in ``retiring`` (blocks still owned) until
  the deferred drain moves it to ``drained``.

* :class:`ShadowLedger` — an independent replica of the
  ``BlockAllocator``'s per-block refcounts, built purely from the
  allocator's observer events.  Catches double frees (a block's shadow
  refcount going negative), frees of requests that are not retiring
  (the use-after-free window: blocks re-enter the free list while a
  dispatch may still write into them), refcount desyncs, and — under
  ``scrub_freed`` — poison-on-free: scrubbed free blocks are probed
  against the actual device KV rows and must still be all-zero when the
  pool hands them out again.

* :class:`RetraceMonitor` — snapshots each StepFns member's jit cache
  size at attach and asserts the *delta* stays within a declared
  manifest (one compile per member per scheduler shape; one per suffix
  bucket for ``prefill_suffix``).  Deltas, not absolutes: sessions are
  shared across schedulers in tests, and each distinct lane count
  legitimately compiles once.

All checks raise :class:`InvariantViolation` the moment they trip — a
sanitized fuzz run passing means zero ledger violations, not a report to
read.  Everything here is observation: with ``sanitize=False`` none of
this module is even imported, and outputs are bit-identical either way.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Set, Tuple

import numpy as np


class InvariantViolation(AssertionError):
    """A runtime invariant of the serving engine was broken."""


# --------------------------------------------------------------- lifecycle
QUEUED = "queued"
ADMITTED = "admitted"
ACTIVE = "active"
RETIRING = "retiring"
DRAINED = "drained"

# queued can retire directly (cancel while waiting); admitted can retire
# without ever going active (finish-at-prefill, cancel-of-pending)
ALLOWED_TRANSITIONS: Set[Tuple[Optional[str], str]] = {
    (None, QUEUED),
    (QUEUED, ADMITTED),
    (QUEUED, RETIRING),
    (ADMITTED, ACTIVE),
    (ADMITTED, RETIRING),
    (ACTIVE, RETIRING),
    (RETIRING, DRAINED),
}


class LifecycleMonitor:
    """Per-request lifecycle state machine with full history retention."""

    def __init__(self):
        self._state: Dict[int, str] = {}
        self._history: Dict[int, List[str]] = {}

    def state(self, rid: int) -> Optional[str]:
        return self._state.get(rid)

    def history(self, rid: int) -> List[str]:
        return list(self._history.get(rid, []))

    def transition(self, rid: int, new: str) -> None:
        cur = self._state.get(rid)
        if (cur, new) not in ALLOWED_TRANSITIONS:
            hist = " -> ".join(self._history.get(rid, ["<never seen>"]))
            raise InvariantViolation(
                f"request {rid}: illegal lifecycle transition "
                f"{cur!r} -> {new!r} (history: {hist})")
        self._state[rid] = new
        self._history.setdefault(rid, []).append(new)

    def assert_all_drained(self) -> None:
        stuck = {rid: st for rid, st in self._state.items()
                 if st != DRAINED}
        if stuck:
            detail = ", ".join(
                f"rid {rid} in {st!r} (history: "
                f"{' -> '.join(self._history[rid])})"
                for rid, st in sorted(stuck.items()))
            raise InvariantViolation(
                f"{len(stuck)} request(s) not drained at idle: {detail}")


# ------------------------------------------------------------ shadow ledger
class ShadowLedger:
    """Independent replica of the allocator's block ownership, fed by its
    observer events (``BlockAllocator.observer``).  The ledger never
    consults the allocator's own refcounts while running — desyncs are
    caught by :meth:`assert_matches` at idle."""

    def __init__(self, lifecycle: Optional[LifecycleMonitor] = None):
        self.lifecycle = lifecycle
        self._ref: Dict[int, int] = {}       # block -> shadow refcount
        self._live_rids: Set[int] = set()
        self._cache_held: Set[int] = set()
        self.poisoned: Set[int] = set()      # scrubbed-while-free blocks
        self._free_zeroed: List[int] = []    # transient, free_enter->free

    # --------------------------------------------------------------- events
    def on_event(self, event: str, **kw) -> None:
        handler = getattr(self, f"_on_{event}", None)
        if handler is None:
            raise InvariantViolation(f"unknown allocator event {event!r}")
        handler(**kw)

    def _on_alloc(self, rid: int, reserve: int) -> None:
        if rid in self._live_rids:
            raise InvariantViolation(
                f"request {rid} allocated twice (already live)")
        self._live_rids.add(rid)

    def _on_extend(self, rid: int, blocks: List[int]) -> None:
        if rid not in self._live_rids:
            raise InvariantViolation(
                f"extend for request {rid} which holds no allocation")
        for b in blocks:
            if self._ref.get(b, 0) != 0:
                raise InvariantViolation(
                    f"block {b} handed out while shadow refcount is "
                    f"{self._ref[b]} (allocating a live block)")
            self._ref[b] = 1
            self.poisoned.discard(b)

    def _on_share(self, rid: int, blocks: List[int]) -> None:
        for b in blocks:
            if self._ref.get(b, 0) <= 0:
                raise InvariantViolation(
                    f"block {b} shared while free (shadow refcount 0)")
            self._ref[b] += 1

    def _on_free_enter(self, rid: int, table: List[int]) -> None:
        if rid not in self._live_rids:
            raise InvariantViolation(
                f"double free: request {rid} holds no allocation")
        if self.lifecycle is not None and \
                self.lifecycle.state(rid) not in (None, RETIRING):
            raise InvariantViolation(
                f"use-after-free window: request {rid} freed while "
                f"{self.lifecycle.state(rid)!r} (history: "
                f"{' -> '.join(self.lifecycle.history(rid))}); a dispatch "
                "may still write into its blocks — frees belong in the "
                "retire/drain path")
        self._free_zeroed = []
        for b in table:
            n = self._ref.get(b, 0) - 1
            if n < 0:
                raise InvariantViolation(
                    f"double free of block {b} (shadow refcount went "
                    "negative)")
            if n == 0:
                del self._ref[b]
                self._free_zeroed.append(b)
            else:
                self._ref[b] = n

    def _on_free(self, rid: int, freed: List[int]) -> None:
        self._live_rids.discard(rid)
        if sorted(freed) != sorted(self._free_zeroed):
            raise InvariantViolation(
                f"request {rid}: allocator freed blocks {sorted(freed)} "
                f"but the shadow ledger expected "
                f"{sorted(self._free_zeroed)} to reach refcount zero")
        self._free_zeroed = []

    def _on_cache_ref(self, blocks: List[int]) -> None:
        for b in blocks:
            if b in self._cache_held:
                raise InvariantViolation(
                    f"block {b} cache-referenced twice")
            if self._ref.get(b, 0) <= 0:
                raise InvariantViolation(
                    f"free block {b} pinned by the prefix cache")
            self._ref[b] += 1
            self._cache_held.add(b)

    def _on_cache_unref(self, blocks: List[int],
                        freed: List[int]) -> None:
        zeroed = []
        for b in blocks:
            if b not in self._cache_held:
                raise InvariantViolation(
                    f"cache_unref of block {b} the cache never held")
            self._cache_held.discard(b)
            n = self._ref.get(b, 0) - 1
            if n < 0:
                raise InvariantViolation(
                    f"double free of cache block {b}")
            if n == 0:
                del self._ref[b]
                zeroed.append(b)
            else:
                self._ref[b] = n
        if sorted(freed) != sorted(zeroed):
            raise InvariantViolation(
                f"cache_unref freed {sorted(freed)} but the shadow "
                f"ledger expected {sorted(zeroed)}")

    # ------------------------------------------------------ poison-on-free
    def on_scrubbed(self, blocks: Iterable[int]) -> None:
        """Freed blocks were zeroed on device: arm the poison check."""
        for b in blocks:
            if self._ref.get(int(b), 0) == 0:
                self.poisoned.add(int(b))

    def check_poison(self, cache) -> None:
        """Probe every armed block's actual KV rows: a scrubbed free block
        must still be all-zero when it can next be handed out — a nonzero
        row means something wrote into memory it no longer owns."""
        if cache is None or not self.poisoned:
            return
        for b in sorted(self.poisoned):
            for leaf in ("k", "v"):
                rows = np.asarray(cache[leaf][:, b])
                if np.any(rows):
                    raise InvariantViolation(
                        f"use-after-free write detected: freed+scrubbed "
                        f"block {b} has nonzero {leaf!r} rows — some "
                        "dispatch wrote into memory it no longer owns")

    # ------------------------------------------------------------ idle gate
    def assert_matches(self, allocator) -> None:
        """Shadow-vs-real refcount comparison (ledger desync check)."""
        real = dict(getattr(allocator, "_ref"))
        if self._ref != real:
            raise InvariantViolation(
                f"shadow ledger desync: shadow refcounts {self._ref} != "
                f"allocator refcounts {real}")
        if self._cache_held != set(getattr(allocator, "_cache_held")):
            raise InvariantViolation("shadow ledger desync on cache-held "
                                     "block set")

    def assert_idle(self, allocator) -> None:
        """At scheduler idle every live block must be explained by the
        prefix cache; anything else leaked."""
        self.assert_matches(allocator)
        leaked = {b: n for b, n in self._ref.items()
                  if b not in self._cache_held}
        if leaked:
            raise InvariantViolation(
                f"block leak at idle: {len(leaked)} block(s) still "
                f"referenced by no live request or cache: {leaked}")
        if self._live_rids:
            raise InvariantViolation(
                f"requests still hold allocations at idle: "
                f"{sorted(self._live_rids)}")


# ---------------------------------------------------------------- retraces
# StepFns members whose jit compile counters (``_cache_size``) we watch
_COUNTED_MEMBERS = ("prefill", "prefill_into_slot", "prefill_suffix",
                    "tree_step", "fused_step", "commit", "copy_block",
                    "reset_blocks", "reset_slot")


class RetraceMonitor:
    """Asserts observed jit compile-count *deltas* against a manifest."""

    def __init__(self, fns, manifest: Optional[Dict[str, int]] = None):
        self.fns = fns
        self.manifest = (dict(manifest) if manifest is not None
                         else self.default_manifest(fns))
        self._base = self._counts()

    @staticmethod
    def default_manifest(fns) -> Dict[str, int]:
        """The compile-once contract (I2): one executable per member per
        scheduler shape; the suffix-prefill bucket ladder compiles once
        per bucket."""
        manifest = {name: 1 for name in _COUNTED_MEMBERS}
        buckets = getattr(fns, "suffix_buckets", ()) or ()
        manifest["prefill_suffix"] = max(len(buckets), 1)
        return manifest

    def _counts(self) -> Dict[str, int]:
        out = {}
        for name in _COUNTED_MEMBERS:
            member = getattr(self.fns, name, None)
            counter = getattr(member, "_cache_size", None)
            if counter is not None:
                out[name] = int(counter())
        return out

    def check(self) -> None:
        for name, now in self._counts().items():
            delta = now - self._base[name]
            budget = self.manifest.get(name, 1)
            if delta > budget:
                raise InvariantViolation(
                    f"retrace: StepFns.{name} compiled {delta} time(s) "
                    f"under this scheduler; the manifest allows "
                    f"{budget} (a shape or donation mask is drifting "
                    "call-to-call)")


# ------------------------------------------------------------------ facade
class Sanitizer:
    """The bundle a sanitized scheduler owns: lifecycle machine, shadow
    ledger (paged layouts only), retrace monitor."""

    def __init__(self, lifecycle: LifecycleMonitor,
                 ledger: Optional[ShadowLedger],
                 retrace: RetraceMonitor):
        self.lifecycle = lifecycle
        self.ledger = ledger
        self.retrace = retrace

    @classmethod
    def attach(cls, scheduler) -> "Sanitizer":
        """Wire a sanitizer onto a scheduler under construction: installs
        the shadow ledger as the allocator's observer."""
        lifecycle = LifecycleMonitor()
        ledger = None
        if scheduler.allocator is not None:
            ledger = ShadowLedger(lifecycle)
            scheduler.allocator.observer = ledger
        return cls(lifecycle, ledger, RetraceMonitor(scheduler.fns))

    def transition(self, rid: int, state: str) -> None:
        self.lifecycle.transition(rid, state)

    def on_scrubbed(self, blocks: Iterable[int]) -> None:
        if self.ledger is not None:
            self.ledger.on_scrubbed(blocks)

    def check_poison(self, cache) -> None:
        if self.ledger is not None:
            self.ledger.check_poison(cache)

    def verify_idle(self, scheduler) -> None:
        """The full idle-state audit; run() calls this after draining."""
        self.lifecycle.assert_all_drained()
        if scheduler._retired or scheduler._pending:
            raise InvariantViolation(
                "scheduler idle with deferred retirements or pending "
                f"admissions: retired={len(scheduler._retired)} "
                f"pending={sorted(scheduler._pending)}")
        if self.ledger is not None and scheduler.allocator is not None:
            self.ledger.assert_idle(scheduler.allocator)
            self.ledger.check_poison(scheduler.cache)
        self.retrace.check()


__all__ = ["InvariantViolation", "LifecycleMonitor", "ShadowLedger",
           "RetraceMonitor", "Sanitizer", "QUEUED", "ADMITTED", "ACTIVE",
           "RETIRING", "DRAINED", "ALLOWED_TRANSITIONS"]
