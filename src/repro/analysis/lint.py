"""Invariant linter driver: ``python -m repro.analysis.lint src/``.

Walks every ``.py`` file under the given paths, parses it with ``ast``,
runs the rule registry (``repro.analysis.rules.all_rules``), and prints
findings as ``path:line:col: Rn message``.  Exit code 0 when clean, 1 when
any finding survives suppression, 2 on usage / syntax errors.

Per-line suppression::

    chosen = int(packed[0])  # repro-lint: disable=R1  (startup, pre-loop)
    # repro-lint: disable   — suppresses every rule on that line

Options::

    --select R1,R3    run only these rules
    --list-rules      print the registry and exit

The linter imports nothing from the linted code — pure stdlib AST walks —
so it runs in CI's lint job without jax installed.
"""
from __future__ import annotations

import argparse
import ast
import re
import sys
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set

from repro.analysis.rules import Finding, Rule, all_rules

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*disable(?:=(?P<rules>[A-Za-z0-9,\s]+))?")


def _suppressions(source: str) -> Dict[int, Optional[Set[str]]]:
    """Map line number -> suppressed rule ids (None = all rules)."""
    out: Dict[int, Optional[Set[str]]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(line)
        if not m:
            continue
        rules = m.group("rules")
        if rules is None:
            out[i] = None
        else:
            out[i] = {r.strip() for r in rules.split(",") if r.strip()}
    return out


def _suppressed(finding: Finding,
                supp: Dict[int, Optional[Set[str]]]) -> bool:
    rules = supp.get(finding.line, "absent")
    if rules == "absent":
        return False
    return rules is None or finding.rule in rules


def lint_source(source: str, path: str = "<string>",
                rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    """Lint one source string; the unit the tests drive directly."""
    tree = ast.parse(source, filename=path)
    supp = _suppressions(source)
    findings: List[Finding] = []
    for rule in (rules if rules is not None else all_rules()):
        findings.extend(f for f in rule.check(tree, path)
                        if not _suppressed(f, supp))
    findings.sort(key=lambda f: (f.line, f.col, f.rule))
    return findings


def lint_file(path: Path,
              rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    source = path.read_text(encoding="utf-8")
    return lint_source(source, str(path), rules)


def iter_py_files(targets: Iterable[str]) -> Iterable[Path]:
    for target in targets:
        p = Path(target)
        if p.is_dir():
            yield from sorted(p.rglob("*.py"))
        elif p.suffix == ".py":
            yield p


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Invariant linter for the serving engine (R1-R6).")
    parser.add_argument("paths", nargs="*", default=["src/"],
                        help="files or directories to lint")
    parser.add_argument("--select", default=None,
                        help="comma-separated rule ids to run (e.g. R1,R3)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule registry and exit")
    args = parser.parse_args(argv)

    registry = all_rules()
    if args.list_rules:
        for rule in registry:
            print(f"{rule.rule_id}  {rule.title}")
        return 0

    if args.select:
        wanted = {r.strip() for r in args.select.split(",") if r.strip()}
        unknown = wanted - {r.rule_id for r in registry}
        if unknown:
            print(f"unknown rule id(s): {', '.join(sorted(unknown))}",
                  file=sys.stderr)
            return 2
        registry = [r for r in registry if r.rule_id in wanted]

    paths = list(iter_py_files(args.paths or ["src/"]))
    if not paths:
        print("no .py files found under: " + " ".join(args.paths),
              file=sys.stderr)
        return 2

    n_findings = 0
    for path in paths:
        try:
            findings = lint_file(path, registry)
        except SyntaxError as exc:
            print(f"{path}:{exc.lineno}:{exc.offset}: syntax error: "
                  f"{exc.msg}", file=sys.stderr)
            return 2
        for f in findings:
            print(f)
        n_findings += len(findings)

    if n_findings:
        print(f"\n{n_findings} finding(s) in {len(paths)} file(s)",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
