"""phi3-mini-3.8b [arXiv:2404.14219]: 32L d_model=3072 32H (kv=32) d_ff=8192
vocab=32064 — RoPE SwiGLU, MHA (GQA with kv=heads)."""
from repro.configs import lm_common
from repro.models.transformer import TransformerConfig

ARCH = "phi3-mini-3.8b"
SHAPES = lm_common.SHAPES


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH, n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=32064, head_dim=96, rope_theta=10000.0,
        act="silu", tie_embeddings=False)


def smoke_config() -> TransformerConfig:
    return lm_common.smoke_config(full_config())


def build_cell(shape: str, mesh=None, fast: bool = False, **backends):
    # **backends: prefill_backend= / decode_backend= attention overrides
    # (repro.models.attention registry), threaded to lm_common.build_cell.
    return lm_common.build_cell(ARCH, full_config(), shape, mesh, fast=fast,
                                **backends)
