"""Architecture registry: one module per assigned arch (+ the paper's own
AntGLM-10B).  Each module exposes

  ARCH            — id string
  full_config()   — the exact published configuration
  smoke_config()  — reduced same-family config for CPU smoke tests
  SHAPES          — list of shape-cell names
  build_cell(shape, mesh=None)  — (fn, args_abstract, args_logical_axes, meta)
                    ready for jit(...).lower(*args) under the mesh.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

ARCHS: List[str] = [
    "phi3_mini_3_8b",
    "qwen2_1_5b",
    "phi3_medium_14b",
    "qwen3_moe_30b_a3b",
    "moonshot_v1_16b_a3b",
    "equiformer_v2",
    "wide_deep",
    "bert4rec",
    "two_tower_retrieval",
    "sasrec",
    "antglm_10b",       # paper's own model (extra, not an assigned cell)
]

ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def get_arch(name: str):
    mod = name.replace("-", "_").replace(".", "_")
    if mod not in ARCHS:
        raise KeyError(f"unknown arch {name}; have {sorted(ALIASES)}")
    return importlib.import_module(f"repro.configs.{mod}")


def assigned_cells() -> List[tuple]:
    """All 40 assigned (arch, shape) cells."""
    cells = []
    for a in ARCHS:
        if a == "antglm_10b":
            continue
        m = get_arch(a)
        for s in m.SHAPES:
            cells.append((a, s))
    return cells
