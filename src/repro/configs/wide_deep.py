"""wide-deep [arXiv:1606.07792]: 40 sparse fields, embed_dim=32,
MLP 1024-512-256, concat interaction.  Tables: 1M rows/field."""
import dataclasses

import jax.numpy as jnp

from repro.configs import recsys_common as rc
from repro.configs.common import Cell, sds
from repro.models.recsys import wide_deep as model

ARCH = "wide-deep"
SHAPES = rc.SHAPES


def full_config() -> model.WideDeepConfig:
    return model.WideDeepConfig(n_sparse=40, embed_dim=32,
                                rows_per_table=1_000_000, multi_hot=4,
                                mlp_dims=(1024, 512, 256), n_dense=13)


def smoke_config() -> model.WideDeepConfig:
    return model.WideDeepConfig(n_sparse=6, embed_dim=8, rows_per_table=512,
                                multi_hot=3, mlp_dims=(32, 16), n_dense=5)


def _batch_abs(cfg, B):
    return {"sparse_ids": sds((B, cfg.n_sparse, cfg.multi_hot), jnp.int32),
            "sparse_mask": sds((B, cfg.n_sparse, cfg.multi_hot), jnp.bool_),
            "dense": sds((B, cfg.n_dense), jnp.float32),
            "labels": sds((B,), jnp.float32)}


def _batch_axes():
    return {"sparse_ids": ("batch", None, None),
            "sparse_mask": ("batch", None, None), "dense": ("batch", None),
            "labels": ("batch",)}


def build_cell(shape: str, mesh=None, fast: bool = False) -> Cell:
    cfg = full_config()
    B = rc.BATCHES[shape]
    if shape == "retrieval_cand":
        B = 1_000_000       # scoring 1M candidate contexts for one user
    mult = 3 if shape == "train_batch" else 1
    meta = {"n_params": cfg.n_params(), "n_active_params": cfg.n_params(),
            "model_flops": _flops(cfg, B, train=(shape == "train_batch")),
            "tokens_per_step": B, "batch": B,
            "weight_bytes": cfg.n_params() * 4,
            "bytes_floor": float(
                B * cfg.n_sparse * cfg.multi_hot * cfg.embed_dim * 4 * mult
                + B * sum(cfg.mlp_dims) * 4 * mult
                + (cfg.n_params() * 16 if mult == 3 else 0))}
    if shape == "train_batch":
        return rc.train_cell(ARCH, cfg, model.init_params, model.loss,
                             _batch_abs(cfg, B), _batch_axes(),
                             model.param_logical_axes(cfg), meta)
    serve = lambda c, p, ids, m, d: model.forward(c, p, ids, m, d)
    return rc.serve_cell(
        ARCH, shape, cfg, model.init_params, serve,
        (sds((B, cfg.n_sparse, cfg.multi_hot), jnp.int32),
         sds((B, cfg.n_sparse, cfg.multi_hot), jnp.bool_),
         sds((B, cfg.n_dense), jnp.float32)),
        (("batch", None, None), ("batch", None, None), ("batch", None)),
        model.param_logical_axes(cfg), meta)


def _flops(cfg, B, train):
    dims = (cfg.n_sparse * cfg.embed_dim + cfg.n_dense,) + cfg.mlp_dims
    mlp = sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))
    per = mlp + cfg.n_sparse * cfg.multi_hot * cfg.embed_dim * 2
    return B * per * (3 if train else 1)
