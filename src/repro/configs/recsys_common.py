"""Shared cell builders for the recsys archs.

Shapes (assignment): train_batch 65,536 · serve_p99 512 · serve_bulk 262,144
· retrieval_cand (batch=1 vs 1,000,000 candidates).
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.common import Cell, opt_state_axes, sds
from repro.training.optimizer import adamw_init
from repro.training.train_step import make_train_step

SHAPES = ["train_batch", "serve_p99", "serve_bulk", "retrieval_cand"]
BATCHES = {"train_batch": 65536, "serve_p99": 512, "serve_bulk": 262144,
           "retrieval_cand": 1}


def train_cell(arch: str, cfg, init_fn: Callable, loss_fn: Callable,
               batch_abs: Dict, batch_axes: Dict, p_axes, meta: Dict) -> Cell:
    params = jax.eval_shape(lambda k: init_fn(cfg, k), jax.random.key(0))
    opt = jax.eval_shape(adamw_init, params)
    step = make_train_step(lambda p, b: loss_fn(cfg, p, b), lr=1e-3,
                           grad_dtype="bfloat16")
    axes = (p_axes, opt_state_axes(p_axes), batch_axes)
    return Cell(arch, "train_batch", "train", step, (params, opt, batch_abs),
                axes, meta, donate=(0, 1))


def serve_cell(arch: str, shape: str, cfg, init_fn: Callable,
               serve_fn: Callable, in_abs: tuple, in_axes: tuple, p_axes,
               meta: Dict) -> Cell:
    params = jax.eval_shape(lambda k: init_fn(cfg, k), jax.random.key(0))
    fn = lambda p, *a: serve_fn(cfg, p, *a)
    return Cell(arch, shape, "score", fn, (params,) + in_abs,
                (p_axes,) + in_axes, meta)
