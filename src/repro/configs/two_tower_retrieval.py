"""two-tower-retrieval [RecSys'19 YouTube]: embed_dim=256, towers
1024-512-256, dot interaction, sampled softmax; retrieval scores 1M
candidates via sharded batched-dot + top-k."""
import jax.numpy as jnp

from repro.configs import recsys_common as rc
from repro.configs.common import Cell, sds
from repro.models.recsys import two_tower as model

ARCH = "two-tower-retrieval"
SHAPES = rc.SHAPES
N_CAND = 1_000_000


def full_config() -> model.TwoTowerConfig:
    return model.TwoTowerConfig(embed_dim=256, feat_dim=64,
                                n_user_fields=8, n_item_fields=4,
                                rows_per_table=1_000_000,
                                tower_dims=(1024, 512, 256))


def smoke_config() -> model.TwoTowerConfig:
    return model.TwoTowerConfig(embed_dim=16, feat_dim=8, n_user_fields=3,
                                n_item_fields=2, rows_per_table=256,
                                tower_dims=(32, 16))


def build_cell(shape: str, mesh=None, fast: bool = False) -> Cell:
    cfg = full_config()
    B = rc.BATCHES[shape]
    meta = {"n_params": cfg.n_params(), "n_active_params": cfg.n_params(),
            "model_flops": _flops(cfg, B, shape), "tokens_per_step": B,
            "batch": B, "weight_bytes": cfg.n_params() * 4}
    if shape == "train_batch":
        batch = {"user_ids": sds((B, cfg.n_user_fields), jnp.int32),
                 "item_ids": sds((B, cfg.n_item_fields), jnp.int32)}
        axes = {"user_ids": ("batch", None), "item_ids": ("batch", None)}
        return rc.train_cell(ARCH, cfg, model.init_params, model.loss,
                             batch, axes, model.param_logical_axes(cfg), meta)
    if shape == "retrieval_cand":
        serve = lambda c, p, u, cand: model.score_candidates(c, p, u, cand,
                                                             k=128)
        return rc.serve_cell(
            ARCH, shape, cfg, model.init_params, serve,
            (sds((1, cfg.n_user_fields), jnp.int32),
             sds((N_CAND, cfg.tower_dims[-1]), jnp.float32)),
            ((None, None), ("candidates", None)),
            model.param_logical_axes(cfg), meta)
    # serve_p99 / serve_bulk: paired user·item scoring
    def serve(c, p, u, it):
        q = model.user_embed(c, p, u)
        e = model.item_embed(c, p, it)
        return jnp.sum(q * e, axis=-1)
    return rc.serve_cell(
        ARCH, shape, cfg, model.init_params, serve,
        (sds((B, cfg.n_user_fields), jnp.int32),
         sds((B, cfg.n_item_fields), jnp.int32)),
        (("batch", None), ("batch", None)),
        model.param_logical_axes(cfg), meta)


def _flops(cfg, B, shape):
    ud = (cfg.n_user_fields * cfg.feat_dim,) + cfg.tower_dims
    it = (cfg.n_item_fields * cfg.feat_dim,) + cfg.tower_dims
    t = sum(2 * a * b for a, b in zip(ud[:-1], ud[1:])) \
        + sum(2 * a * b for a, b in zip(it[:-1], it[1:]))
    if shape == "train_batch":
        return B * (t * 3 + 2 * B * cfg.tower_dims[-1])
    if shape == "retrieval_cand":
        return t + 2 * N_CAND * cfg.tower_dims[-1]
    return B * t
