"""Shared cell builders for the LM-family transformers.

Shapes (assignment):
  train_4k     seq 4096,   global_batch 256   → train_step
  prefill_32k  seq 32768,  global_batch 32    → prefill (KV-cache fill)
  decode_32k   seq 32768,  global_batch 128   → serve_step (lookahead tree,
                                                1+64 slots, KV cache 32k)
  long_500k    seq 524288, global_batch 1     → serve_step, sequence-parallel
                                                flash-decode KV sharding

Decode cells lower the *lookahead* serve step (the paper's technique is the
first-class serving path); T=65 slots = 1 root + decoding_length 64.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.common import Cell, opt_state_axes, replicate_axes, sds
from repro.models import transformer as tx
from repro.serving.sampler import choose_tokens
from repro.training.optimizer import adamw_init
from repro.training.train_step import make_train_step

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
LA_SLOTS = 65              # 1 + decoding_length(64); ≤ CDL (paper Fig. 1)


def smoke_config(base: tx.TransformerConfig) -> tx.TransformerConfig:
    """Reduced same-family config: keeps GQA ratio / bias / MoE topology."""
    kv = max(1, base.n_kv_heads * 4 // base.n_heads)
    return dataclasses.replace(
        base, n_layers=2, d_model=64, n_heads=4, n_kv_heads=kv,
        d_ff=128 if not base.moe else 0, vocab_size=512, head_dim=16,
        max_seq_len=128, q_chunk=0, remat=False, dtype="float32",
        param_dtype="float32",
        n_experts=8 if base.moe else 0, top_k=min(base.top_k, 2),
        moe_d_ff=32 if base.moe else 0,
        n_shared_experts=min(base.n_shared_experts, 1), moe_impl="ref")


def _serve_fn(cfg: tx.TransformerConfig):
    # The serving hot path is the FUSED step (one dispatch per decode step,
    # DESIGN.md §Step pipeline): forward + token choice + device accept walk
    # + KV commit, returning only the packed (B, 1+2T) accept array — the
    # (B,T,V) logits never leave the chip, so the cell's lowered module and
    # its roofline accounting match what serving actually dispatches.
    def serve_step(params, cache, cache_lens, tokens, pos, mask,
                   parent, n_live):
        cache, logits = tx.tree_step(cfg, params, cache, cache_lens, tokens,
                                     pos, mask)
        chosen = choose_tokens(logits, pos + 1)
        n_acc, acc_tok, kv_slots = tx.verify_accept_device(tokens, parent,
                                                           n_live, chosen)
        cache, _ = tx.commit_cache(cache, cache_lens, kv_slots, n_acc)
        return cache, tx.pack_step_result(n_acc, acc_tok, kv_slots)
    return serve_step


def _prefill_fn(cfg: tx.TransformerConfig):
    def prefill_step(params, tokens, lens, cache):
        return tx.prefill(cfg, params, tokens, lens, cache)
    return prefill_step


def _attn_scan_correction(cfg, B, S, kind) -> Dict[str, float]:
    """The q-chunked attention is a lax.scan; XLA cost_analysis counts while
    bodies ONCE, so add the missing (n_chunks-1)/n_chunks share analytically
    (documented in EXPERIMENTS.md §Dry-run).  Returns TOTAL (all-chip) flops
    and bytes to add."""
    if not cfg.q_chunk or S <= cfg.q_chunk:
        return {"flops_correction": 0.0, "bytes_correction": 0.0}
    nc = S // cfg.q_chunk
    H, dh, K = cfg.n_heads, cfg.dh, cfg.n_kv_heads
    attn_flops = 4.0 * B * H * S * S * dh          # scores + weighted sum
    score_bytes = 2.0 * B * H * S * S * 2 * 2      # write+read scores (f32→2B bf16 eff.)
    kv_bytes = 2.0 * B * S * K * dh * 2 * nc       # K,V re-read per chunk
    mult = 4.0 if kind == "train" else 1.0         # remat fwd+recompute+bwd
    frac = (nc - 1) / nc
    return {"flops_correction": cfg.n_layers * mult * frac * attn_flops,
            "bytes_correction": cfg.n_layers * mult * frac
            * (score_bytes + kv_bytes)}


def _perf_overrides(cfg: tx.TransformerConfig) -> tx.TransformerConfig:
    """§Perf hillclimb hook: REPRO_PERF_OVERRIDES="k=v,k=v" patches the
    dry-run config (e.g. attn_score_f32=0, q_chunk=2048)."""
    import os
    ov = os.environ.get("REPRO_PERF_OVERRIDES", "")
    if not ov:
        return cfg
    kw = {}
    for item in ov.split(","):
        k, v = item.split("=")
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            kw[k] = v not in ("0", "false", "False")
        elif isinstance(cur, int):
            kw[k] = int(v)
        elif isinstance(cur, float):
            kw[k] = float(v)
        else:
            kw[k] = v
    return dataclasses.replace(cfg, **kw)


def build_cell(arch: str, base: tx.TransformerConfig, shape: str,
               mesh=None, fast: bool = False,
               prefill_backend: str = None,
               decode_backend: str = None) -> Cell:
    # fast=True keeps lax.scan over layers (quick compile; multi-pod leg);
    # fast=False unrolls for accurate cost_analysis (roofline leg).
    # prefill_backend/decode_backend override the per-phase attention
    # backends (repro.models.attention registry); decode cells default to
    # the sharded flash_decode path, everything else to dense.
    key = jax.random.key(0)
    if shape == "train_4k":
        cfg = dataclasses.replace(base, dtype="bfloat16", remat=True,
                                  q_chunk=512, max_seq_len=4096,
                                  prefill_backend=prefill_backend or "dense",
                                  moe_impl="auto", scan_layers=fast)
        cfg = _perf_overrides(cfg)
        B, S = 256, 4096
        params = jax.eval_shape(lambda k: tx.init_params(cfg, k), key)
        opt = jax.eval_shape(adamw_init, params)
        batch = {"tokens": sds((B, S), jnp.int32),
                 "labels": sds((B, S), jnp.int32)}
        loss = lambda p, b: tx.lm_loss(cfg, p, b["tokens"], b["labels"])
        # memory-truth (fast/scan) build runs 2 microbatches — halves the
        # activation temp; cost-truth (unrolled) build keeps accum=1 so the
        # per-step cost analysis covers the full global batch exactly.
        step = make_train_step(loss, lr=3e-4, grad_dtype="bfloat16",
                               accum_steps=4 if fast else 1)
        p_axes = tx.param_logical_axes(cfg)
        axes = (p_axes, opt_state_axes(p_axes),
                {"tokens": ("batch", None), "labels": ("batch", None)})
        meta = _meta(cfg, tokens_per_step=B * S, kind="train", seq=S, batch=B)
        meta.update(_attn_scan_correction(cfg, B, S, "train"))
        from repro.distributed.sharding import DEFAULT_RULES
        # Megatron-SP-style: remat-saved residual stream sharded over model
        rules = DEFAULT_RULES.override(residual_seq=("model",))
        return Cell(arch, shape, "train", step, (params, opt, batch), axes,
                    meta, donate=(0, 1), rules=rules)

    if shape == "prefill_32k":
        cfg = dataclasses.replace(base, dtype="bfloat16",
                                  param_dtype="bfloat16", q_chunk=1024,
                                  max_seq_len=32768, moe_impl="auto",
                                  prefill_backend=prefill_backend or "dense",
                                  scan_layers=fast)
        cfg = _perf_overrides(cfg)
        B, S = 32, 32768
        params = jax.eval_shape(lambda k: tx.init_params(cfg, k), key)
        # cache=None: the stacked per-layer KV IS the returned cache —
        # no second cache-sized buffer.
        args = (params, {"tokens": sds((B, S), jnp.int32)},
                sds((B,), jnp.int32))
        fn = lambda p, b, l: _prefill_fn(cfg)(p, b["tokens"], l, None)
        axes = (tx.param_logical_axes(cfg), {"tokens": ("batch", None)},
                ("batch",))
        meta = _meta(cfg, tokens_per_step=B * S, kind="prefill", seq=S,
                     batch=B)
        meta.update(_attn_scan_correction(cfg, B, S, "prefill"))
        return Cell(arch, shape, "prefill", fn, args, axes, meta)

    if shape in ("decode_32k", "long_500k"):
        long = shape == "long_500k"
        # flash_decode for BOTH decode cells: shards the KV sequence over
        # whatever mesh axes batch/heads cannot absorb (see
        # distributed/flash_decode._derive_axes).
        cfg = dataclasses.replace(
            base, dtype="bfloat16", param_dtype="bfloat16",
            max_seq_len=524288 if long else 32768,
            prefill_backend=prefill_backend or "dense",
            decode_backend=decode_backend or "flash_decode",
            moe_impl="auto", scan_layers=fast)
        cfg = _perf_overrides(cfg)
        B = 1 if long else 128
        T = LA_SLOTS
        params = jax.eval_shape(lambda k: tx.init_params(cfg, k), key)
        cache = jax.eval_shape(lambda: tx.init_cache(cfg, B, jnp.bfloat16))
        if mesh is not None:
            from repro.distributed.flash_decode import cache_partition_spec
            cspec = cache_partition_spec(mesh, B, cfg.max_seq_len,
                                         cfg.n_kv_heads, cfg.n_heads)
            cache_axes = {"k": cspec, "v": cspec}
        else:
            cache_axes = tx.cache_logical_axes(cfg)
        args = (params, cache, sds((B,), jnp.int32), sds((B, T), jnp.int32),
                sds((B, T), jnp.int32), sds((B, T, T), jnp.bool_),
                sds((B, T), jnp.int32), sds((B,), jnp.int32))
        axes = (tx.param_logical_axes(cfg), cache_axes,
                ("batch",), ("batch", None), ("batch", None),
                ("batch", None, None),
                ("batch", None), ("batch",))       # draft parents, n_live
        meta = _meta(cfg, tokens_per_step=B * T, kind="decode",
                     seq=cfg.max_seq_len, batch=B)
        # §Perf iteration 1 (decode): serve weights are bf16 and fit at
        # TP=16, so fsdp-sharding them only buys per-layer weight
        # all-gathers AND forces the unembed to contract over a sharded d —
        # a (B,T,V) f32 all-reduce over `data` (~0.6 GiB/chip/step measured).
        # Dropping fsdp for serve cells removes both.
        # REPRO_SERVE_FSDP=1 restores the iteration-0 baseline.
        import os
        from repro.distributed.sharding import DEFAULT_RULES
        rules = DEFAULT_RULES if os.environ.get("REPRO_SERVE_FSDP") \
            else DEFAULT_RULES.override(fsdp=())
        return Cell(arch, shape, "decode", _serve_fn(cfg), args, axes, meta,
                    donate=(1,), rules=rules)

    raise KeyError(shape)


def _meta(cfg: tx.TransformerConfig, tokens_per_step: int, kind: str,
          seq: int, batch: int) -> Dict:
    n = cfg.n_params()
    na = cfg.n_active_params()
    L, d, V = cfg.n_layers, cfg.d_model, cfg.vocab_size
    K, dh = cfg.n_kv_heads, cfg.dh
    T = tokens_per_step // batch
    # analytic TPU-facing HBM floor (XLA CPU legalizes bf16->f32 and inflates
    # cost_analysis bytes ~3-5x — measured; see EXPERIMENTS.md §Dry-run):
    if kind == "decode":
        # fused step: the dispatch's only output besides the (donated) cache
        # is the packed (B, 1+2T) i32 accept array — the (B,T,V) logits are
        # consumed on-chip by the fused choose+accept epilogue (a reduction
        # over V the compiler can stream out of the unembed matmul), so the
        # floor charges the packed output where it used to charge logits.
        step_out = batch * (1 + 2 * T) * 4
        floor = (n * 2                                  # weight stream (bf16)
                 + L * 2 * K * dh * seq * batch * 2     # KV cache read
                 + step_out                             # packed accept out
                 + L * batch * T * d * 2 * 10)          # residual stream
    elif kind == "prefill":
        floor = (n * 2
                 + L * 2 * K * dh * batch * seq * 2 * 2  # KV write+read
                 + L * batch * seq * d * 2 * 12
                 + batch * V * 4)
    else:  # train
        floor = (na * 16                                 # p/g/m/v f32 streams
                 + L * batch * seq * d * 2 * 30          # fwd+bwd activations
                 + batch * seq * V * 4 * 3)              # logits + bwd
    return {
        "bytes_floor": float(floor),
        "n_params": n,
        "n_active_params": na,
        # MODEL_FLOPS: 6·N_active·D tokens (train fwd+bwd);
        # decode/prefill fwd-only → 2·N_active·D (+ attention term separately)
        "model_flops": (6 if kind == "train" else 2) * na * tokens_per_step,
        "tokens_per_step": tokens_per_step,
        "seq": seq,
        "batch": batch,
        "weight_bytes": (n if kind != "train" else na) * (4 if kind == "train" else 2),
        "kv_bytes_per_step": (cfg.n_layers * 2 * cfg.n_kv_heads * cfg.dh
                              * seq * batch * 2 if kind == "decode" else 0),
        # bytes the step actually hands back across the dispatch boundary:
        # decode = the packed accept array (fused step), prefill = the
        # chosen roots; the old unfused decode figure was B*T*V*4 logits
        "step_output_bytes": (batch * (1 + 2 * T) * 4 if kind == "decode"
                              else batch * 4 if kind == "prefill" else 0),
    }
