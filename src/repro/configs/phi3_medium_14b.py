"""phi3-medium-14b [arXiv:2404.14219]: 40L d_model=5120 40H (GQA kv=10)
d_ff=17920 vocab=100352 — RoPE SwiGLU GQA."""
from repro.configs import lm_common
from repro.models.transformer import TransformerConfig

ARCH = "phi3-medium-14b"
SHAPES = lm_common.SHAPES


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH, n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10,
        d_ff=17920, vocab_size=100352, head_dim=128, rope_theta=10000.0,
        act="silu", tie_embeddings=False)


def smoke_config() -> TransformerConfig:
    return lm_common.smoke_config(full_config())


def build_cell(shape: str, mesh=None, fast: bool = False, **backends):
    # **backends: prefill_backend= / decode_backend= attention overrides
    # (repro.models.attention registry), threaded to lm_common.build_cell.
    return lm_common.build_cell(ARCH, full_config(), shape, mesh, fast=fast,
                                **backends)
