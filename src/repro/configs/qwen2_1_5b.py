"""qwen2-1.5b [arXiv:2407.10671; hf:Qwen/Qwen2-1.5B]: 28L d_model=1536 12H
(GQA kv=2) d_ff=8960 vocab=151936 — QKV bias, tied embeddings."""
from repro.configs import lm_common
from repro.models.transformer import TransformerConfig

ARCH = "qwen2-1.5b"
SHAPES = lm_common.SHAPES


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH, n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
        d_ff=8960, vocab_size=151936, head_dim=128, qkv_bias=True,
        rope_theta=1_000_000.0, act="silu", tie_embeddings=True)


def smoke_config() -> TransformerConfig:
    return lm_common.smoke_config(full_config())


def build_cell(shape: str, mesh=None, fast: bool = False, **backends):
    # **backends: prefill_backend= / decode_backend= attention overrides
    # (repro.models.attention registry), threaded to lm_common.build_cell.
    return lm_common.build_cell(ARCH, full_config(), shape, mesh, fast=fast,
                                **backends)
