"""AntGLM-10B — the paper's own deployment model (GLM structure [arXiv:
2103.10360], trained from scratch at Ant).  Paper Table 9: 48L, hidden 4096,
32 heads, MLP 16384, vocab 115328.  Modeled as a decoder-only with GeGLU
(GLM's blank-infilling objective is irrelevant for serving-path perf)."""
from repro.configs import lm_common
from repro.models.transformer import TransformerConfig

ARCH = "antglm-10b"
SHAPES = lm_common.SHAPES


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH, n_layers=48, d_model=4096, n_heads=32, n_kv_heads=32,
        d_ff=16384, vocab_size=115328, head_dim=128, rope_theta=10000.0,
        act="gelu", tie_embeddings=True)


def smoke_config() -> TransformerConfig:
    return lm_common.smoke_config(full_config())


def build_cell(shape: str, mesh=None, fast: bool = False, **backends):
    # **backends: prefill_backend= / decode_backend= attention overrides
    # (repro.models.attention registry), threaded to lm_common.build_cell.
    return lm_common.build_cell(ARCH, full_config(), shape, mesh, fast=fast,
                                **backends)
