"""equiformer-v2 [arXiv:2306.12059]: 12 layers, 128 sphere channels,
l_max=6, m_max=2, 8 heads, SO(2)-eSCN convolutions.

Cells: full_graph_sm (Cora-like 2,708/10,556 d=1433), minibatch_lg
(Reddit-like sampled subgraph, fanout 15-10 from batch_nodes=1024),
ogb_products (2,449,029/61,859,140 d=100), molecule (128×30-node graphs).
Positions for the non-geometric graphs are synthetic (see DESIGN.md)."""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.common import Cell, opt_state_axes, pad_to_multiple, sds
from repro.models.gnn import equiformer as model
from repro.training.optimizer import adamw_init
from repro.training.train_step import make_train_step

ARCH = "equiformer-v2"
SHAPES = ["full_graph_sm", "minibatch_lg", "ogb_products", "molecule"]

# (n_nodes, n_edges_padded, d_feat, n_out, node_level, edge_chunk)
CELLS = {
    "full_graph_sm": dict(n_nodes=2708, n_edges=pad_to_multiple(10556, 512),
                          d_feat=1433, n_out=7, node_level=True,
                          edge_chunk=0),
    # sampled subgraph caps for batch_nodes=1024, fanout 15-10
    # dense edge path: 169k edges sharded over 512 chips = 330/chip; the
    # chunked path's scan CARRY (the (N,lsq,C) accumulator) would be saved
    # per chunk by backward — 32×4.3GiB — so chunking is strictly worse
    # under edge sharding (measured; see EXPERIMENTS.md §Perf).
    "minibatch_lg": dict(n_nodes=1024 + 1024 * 15 + 1024 * 150,
                         n_edges=1024 * 15 + 1024 * 15 * 10,
                         d_feat=602, n_out=41, node_level=True,
                         edge_chunk=0),
    "ogb_products": dict(n_nodes=pad_to_multiple(2_449_029, 512),
                         n_edges=pad_to_multiple(61_859_140, 512),
                         d_feat=100, n_out=47, node_level=True,
                         edge_chunk=120832),
    "molecule": dict(n_nodes=128 * 30, n_edges=128 * 64, d_feat=64,
                     n_out=1, node_level=False, edge_chunk=0,
                     n_graphs=128),
}


def full_config(shape: str = "molecule", fast: bool = False) -> model.EquiformerConfig:
    c = CELLS[shape]
    return model.EquiformerConfig(
        n_layers=12, channels=128, l_max=6, m_max=2, n_heads=8,
        d_feat_in=c["d_feat"], n_rbf=32, n_out=c["n_out"],
        node_level=c["node_level"], edge_chunk=c["edge_chunk"],
        scan_layers=fast, remat=True, dtype="bfloat16")


def smoke_config() -> model.EquiformerConfig:
    return model.EquiformerConfig(n_layers=2, channels=16, l_max=2, m_max=1,
                                  n_heads=4, d_feat_in=8, n_rbf=8, n_out=3)


def build_cell(shape: str, mesh=None, fast: bool = False) -> Cell:
    c = CELLS[shape]
    cfg = full_config(shape, fast=fast)
    N, E = c["n_nodes"], c["n_edges"]
    batch = {"node_feat": sds((N, c["d_feat"]), jnp.float32),
             "positions": sds((N, 3), jnp.float32),
             "edges": sds((E, 2), jnp.int32),
             "edge_mask": sds((E,), jnp.bool_)}
    axes = {"node_feat": (None, None), "positions": (None, None),
            "edges": ("edges", None), "edge_mask": ("edges",)}
    if shape == "molecule":
        batch["graph_ids"] = sds((N,), jnp.int32)
        batch["energies"] = sds((c["n_graphs"],), jnp.float32)
        axes["graph_ids"] = (None,)
        axes["energies"] = (None,)
        loss = model.energy_loss
    else:
        batch["labels"] = sds((N,), jnp.int32)
        axes["labels"] = (None,)
        loss = model.node_class_loss
    params = jax.eval_shape(lambda k: model.init_params(cfg, k), jax.random.key(0))
    opt = jax.eval_shape(adamw_init, params)
    step = make_train_step(lambda p, b: loss(cfg, p, b), lr=3e-4,
                           grad_dtype="bfloat16")
    p_axes = model.param_logical_axes(cfg)
    meta = {"n_params": cfg.n_params(), "n_active_params": cfg.n_params(),
            "model_flops": _flops(cfg, E), "tokens_per_step": N,
            "batch": N, "weight_bytes": cfg.n_params() * 4,
            "n_edges": E,
            # train floor: param streams + per-edge message traffic (rotate
            # in/out + SO(2) in/out, fwd + remat + bwd ≈ x4)
            "bytes_floor": float(cfg.n_params() * 16
                                 + cfg.n_layers * E * cfg.lsq * cfg.channels
                                 * 2 * 6 * 4
                                 + cfg.n_layers * N * cfg.lsq * cfg.channels
                                 * 2 * 8)}
    if cfg.edge_chunk and E > cfg.edge_chunk:
        # edge-chunk lax.scan body counted once by cost_analysis: add the
        # missing (nc-1)/nc of the per-edge message work (×4/3 converts the
        # fwd-only per-edge estimate to remat'd fwd+bwd)
        nc = E // cfg.edge_chunk
        fwd = _flops(cfg, E) / 3
        meta["flops_correction"] = (nc - 1) / nc * fwd * 4
        meta["bytes_correction"] = (nc - 1) / nc * (
            4.0 * E * cfg.lsq * cfg.channels * 2 * 6)
    return Cell(ARCH, shape, "train", step,
                (params, opt, batch),
                (p_axes, opt_state_axes(p_axes), axes), meta, donate=(0, 1))



def _flops(cfg, E):
    # dominant: per-edge Wigner rotate (2×lsq²·C) + SO(2) conv
    C, L = cfg.channels, cfg.l_max
    rot = 2 * 2 * cfg.lsq * cfg.lsq * C
    so2 = 2 * (2 * (L + 1) * C) * ((L + 1) * C)
    for m in range(1, cfg.m_max + 1):
        nl = L + 1 - m
        so2 += 2 * 2 * (2 * nl * C) * (nl * C)
    return cfg.n_layers * E * (rot + so2) * 3   # ×3 for fwd+bwd
