"""bert4rec [arXiv:1904.06690]: embed_dim=64, 2 blocks, 2 heads, seq 200,
bidirectional cloze.  Encoder-only: serve = full-sequence scoring."""
import dataclasses

import jax.numpy as jnp

from repro.configs import recsys_common as rc
from repro.configs.common import Cell, sds
from repro.models.recsys import bert4rec as model

ARCH = "bert4rec"
SHAPES = rc.SHAPES
N_ITEMS = 1_000_000


def full_config() -> model.Bert4RecConfig:
    return model.Bert4RecConfig(n_items=N_ITEMS, embed_dim=64, n_blocks=2,
                                n_heads=2, seq_len=200)


def smoke_config() -> model.Bert4RecConfig:
    return model.Bert4RecConfig(n_items=500, embed_dim=16, n_blocks=2,
                                n_heads=2, seq_len=24)


def build_cell(shape: str, mesh=None, fast: bool = False) -> Cell:
    cfg = full_config()
    B = rc.BATCHES[shape]
    meta = {"n_params": cfg.n_params(), "n_active_params": cfg.n_params(),
            "model_flops": _flops(cfg, B, shape), "tokens_per_step":
            B * cfg.seq_len, "batch": B, "weight_bytes": cfg.n_params() * 4,
            "bytes_floor": float(B * (cfg.embed_dim * cfg.seq_len * 8) * 4
                                 * (3 if shape == "train_batch" else 1)
                                 + (cfg.n_params() * 16
                                    if shape == "train_batch" else 0))}
    M, NS = cfg.seq_len // 5, 8192      # cloze slots, shared negatives
    if shape == "train_batch":
        batch = {"ids": sds((B, cfg.seq_len), jnp.int32),
                 "masked_pos": sds((B, M), jnp.int32),
                 "masked_labels": sds((B, M), jnp.int32),
                 "negatives": sds((NS,), jnp.int32),
                 "pad_mask": sds((B, cfg.seq_len), jnp.bool_)}
        axes = {"ids": ("batch", None), "masked_pos": ("batch", None),
                "masked_labels": ("batch", None), "negatives": (None,),
                "pad_mask": ("batch", None)}
        return rc.train_cell(ARCH, cfg, model.init_params, model.loss,
                             batch, axes, model.param_logical_axes(cfg), meta)
    if shape == "retrieval_cand":
        # B=1 full-catalog (10⁶ candidates) scoring — retrieval stage
        return rc.serve_cell(
            ARCH, shape, cfg, model.init_params, model.serve,
            (sds((B, cfg.seq_len), jnp.int32),
             sds((B, cfg.seq_len), jnp.bool_)),
            (("batch", None), ("batch", None)),
            model.param_logical_axes(cfg), meta)
    # serve_p99 / serve_bulk: ranking stage — 512 candidates per user
    C = 512
    return rc.serve_cell(
        ARCH, shape, cfg, model.init_params, model.serve,
        (sds((B, cfg.seq_len), jnp.int32), sds((B, cfg.seq_len), jnp.bool_),
         sds((B, C), jnp.int32)),
        (("batch", None), ("batch", None), ("batch", None)),
        model.param_logical_axes(cfg), meta)


def _flops(cfg, B, shape):
    d, S = cfg.embed_dim, cfg.seq_len
    blocks = cfg.n_blocks * (8 * d * d * S + 4 * S * S * d + 16 * d * d * S)
    head = 2 * S * d * cfg.n_items if shape != "train_batch" else \
        2 * S * d * cfg.n_items
    f = B * (blocks + head)
    return f * (3 if shape == "train_batch" else 1)
