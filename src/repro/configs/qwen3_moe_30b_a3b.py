"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B]: 48L d_model=2048 32H (GQA kv=4)
per-expert d_ff=768 vocab=151936, MoE 128 experts top-8."""
from repro.configs import lm_common
from repro.models.transformer import TransformerConfig

ARCH = "qwen3-moe-30b-a3b"
SHAPES = lm_common.SHAPES


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH, n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
        d_ff=0, vocab_size=151936, head_dim=128, rope_theta=1_000_000.0,
        act="silu", tie_embeddings=False,
        moe=True, n_experts=128, top_k=8, moe_d_ff=768, n_shared_experts=0,
        capacity_factor=1.25)


def smoke_config() -> TransformerConfig:
    return lm_common.smoke_config(full_config())


def build_cell(shape: str, mesh=None, fast: bool = False, **backends):
    # **backends: prefill_backend= / decode_backend= attention overrides
    # (repro.models.attention registry), threaded to lm_common.build_cell.
    return lm_common.build_cell(ARCH, full_config(), shape, mesh, fast=fast,
                                **backends)
