"""Shared cell-building helpers for the dry-run."""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import NamedSharding, PartitionSpec

from repro.distributed.sharding import DEFAULT_RULES, named_sharding


def sds(shape: Sequence[int], dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def is_abstract_leaf(x) -> bool:
    return isinstance(x, (jax.ShapeDtypeStruct,)) or hasattr(x, "shape") \
        and hasattr(x, "dtype") and not isinstance(x, (dict, list, tuple))


def tree_shardings(mesh, abs_tree: Any, axes_tree: Any, rules=DEFAULT_RULES):
    """Map a logical-axes tree (parallel to abs_tree; leaves are tuples of
    logical names or None) to NamedShardings."""

    def rec(a, ax):
        if isinstance(a, dict):
            return {k: rec(a[k], ax[k] if ax is not None else None)
                    for k in a}
        if isinstance(a, (list, tuple)) and not hasattr(a, "shape"):
            vals = [rec(v, ax[i] if ax is not None else None)
                    for i, v in enumerate(a)]
            if hasattr(a, "_fields"):
                return type(a)(*vals)
            return type(a)(vals)
        if a is None:
            return None
        if isinstance(ax, PartitionSpec):          # raw spec leaf
            return NamedSharding(mesh, ax)
        logical = ax if ax is not None else (None,) * len(a.shape)
        if logical == ():  # scalar
            logical = (None,) * len(a.shape)
        return named_sharding(mesh, logical, a.shape, rules)

    return rec(abs_tree, axes_tree)


def replicate_axes(abs_tree: Any) -> Any:
    """All-None logical axes tree matching abs_tree."""

    def rec(a):
        if isinstance(a, dict):
            return {k: rec(v) for k, v in a.items()}
        if isinstance(a, (list, tuple)) and not hasattr(a, "shape"):
            vals = [rec(v) for v in a]
            if hasattr(a, "_fields"):
                return type(a)(*vals)
            return type(a)(vals)
        if a is None:
            return None
        return (None,) * len(a.shape)

    return rec(abs_tree)


def pad_to_multiple(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def tree_tangent_axes(axes: Any) -> Any:
    return axes  # gradients/moments share parameter logical axes


def opt_state_axes(param_axes: Any):
    """Logical axes for AdamWState(step, mu, nu)."""
    from repro.training.optimizer import AdamWState
    return AdamWState(step=(), mu=param_axes, nu=param_axes)


class Cell:
    """One (arch × shape) dry-run cell."""

    def __init__(self, arch: str, shape: str, kind: str, fn: Callable,
                 args: Tuple, axes: Tuple, meta: Optional[Dict] = None,
                 donate: Tuple[int, ...] = (), rules=None):
        self.arch = arch
        self.shape = shape
        self.kind = kind          # train | decode | prefill | score
        self.fn = fn
        self.args = args
        self.axes = axes
        self.meta = meta or {}
        self.donate = donate
        self.rules = rules or DEFAULT_RULES

    def donatable_bytes(self) -> int:
        """Bytes of donated args (aliased in/out on TPU; XLA CPU ignores
        donation, so memory_analysis double-counts them — subtracted in the
        dry-run 'fits' accounting)."""
        tot = 0
        for i in self.donate:
            for leaf in jax.tree.leaves(self.args[i]):
                if hasattr(leaf, "shape"):
                    tot += int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        return tot

    def shardings(self, mesh, rules=DEFAULT_RULES):
        return tuple(tree_shardings(mesh, a, x, rules)
                     for a, x in zip(self.args, self.axes))
