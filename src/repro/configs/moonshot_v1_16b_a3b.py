"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B]: 48L d_model=2048
16H (kv=16) per-expert d_ff=1408 vocab=163840, MoE 64e top-6 (+2 shared
experts per the HF config)."""
from repro.configs import lm_common
from repro.models.transformer import TransformerConfig

ARCH = "moonshot-v1-16b-a3b"
SHAPES = lm_common.SHAPES


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH, n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=0, vocab_size=163840, head_dim=128, rope_theta=50000.0,
        act="silu", tie_embeddings=False,
        moe=True, n_experts=64, top_k=6, moe_d_ff=1408, n_shared_experts=2,
        capacity_factor=1.25)


def smoke_config() -> TransformerConfig:
    return lm_common.smoke_config(full_config())


def build_cell(shape: str, mesh=None, fast: bool = False, **backends):
    # **backends: prefill_backend= / decode_backend= attention overrides
    # (repro.models.attention registry), threaded to lm_common.build_cell.
    return lm_common.build_cell(ARCH, full_config(), shape, mesh, fast=fast,
                                **backends)
