"""sasrec [arXiv:1808.09781]: embed_dim=50, 2 blocks, 1 head, seq 50,
causal self-attention, next-item objective."""
import jax.numpy as jnp

from repro.configs import recsys_common as rc
from repro.configs.common import Cell, sds
from repro.models.recsys import sasrec as model

ARCH = "sasrec"
SHAPES = rc.SHAPES
N_ITEMS = 1_000_000


def full_config() -> model.SasRecConfig:
    # embed_dim 50 padded to 52 (heads=1; keep d%4==0 for TPU lanes)
    return model.SasRecConfig(n_items=N_ITEMS, embed_dim=52, n_blocks=2,
                              n_heads=1, seq_len=50)


def smoke_config() -> model.SasRecConfig:
    return model.SasRecConfig(n_items=300, embed_dim=16, n_blocks=2,
                              n_heads=1, seq_len=12)


def build_cell(shape: str, mesh=None, fast: bool = False) -> Cell:
    cfg = full_config()
    B = rc.BATCHES[shape]
    meta = {"n_params": cfg.n_params(), "n_active_params": cfg.n_params(),
            "model_flops": _flops(cfg, B, shape),
            "tokens_per_step": B * cfg.seq_len, "batch": B,
            "weight_bytes": cfg.n_params() * 4,
            "bytes_floor": float(B * (cfg.embed_dim * cfg.seq_len * 8) * 4
                                 * (3 if shape == "train_batch" else 1)
                                 + (cfg.n_params() * 16
                                    if shape == "train_batch" else 0))}
    NS = 8192                            # shared negatives
    if shape == "train_batch":
        batch = {"ids": sds((B, cfg.seq_len), jnp.int32),
                 "labels": sds((B, cfg.seq_len), jnp.int32),
                 "negatives": sds((NS,), jnp.int32),
                 "pad_mask": sds((B, cfg.seq_len), jnp.bool_)}
        axes = {"ids": ("batch", None), "labels": ("batch", None),
                "negatives": (None,), "pad_mask": ("batch", None)}
        return rc.train_cell(ARCH, cfg, model.init_params, model.loss,
                             batch, axes, model.param_logical_axes(cfg), meta)
    if shape == "retrieval_cand":
        return rc.serve_cell(
            ARCH, shape, cfg, model.init_params, model.serve,
            (sds((B, cfg.seq_len), jnp.int32),
             sds((B, cfg.seq_len), jnp.bool_)),
            (("batch", None), ("batch", None)),
            model.param_logical_axes(cfg), meta)
    C = 512
    return rc.serve_cell(
        ARCH, shape, cfg, model.init_params, model.serve,
        (sds((B, cfg.seq_len), jnp.int32), sds((B, cfg.seq_len), jnp.bool_),
         sds((B, C), jnp.int32)),
        (("batch", None), ("batch", None), ("batch", None)),
        model.param_logical_axes(cfg), meta)


def _flops(cfg, B, shape):
    d, S = cfg.embed_dim, cfg.seq_len
    blocks = cfg.n_blocks * (8 * d * d * S + 4 * S * S * d + 16 * d * d * S)
    head = 2 * S * d * cfg.n_items
    return B * (blocks + head) * (3 if shape == "train_batch" else 1)
