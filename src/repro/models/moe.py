"""Mixture-of-Experts FFN: reference path + expert-parallel shard_map path.

* ``moe_ref``  — dense all-experts einsum; exact, O(E·N·D·F); used for smoke
  tests, lossless tests and small benches.
* ``moe_ep``   — production path: tokens sharded over (pod, data, model),
  local top-k routing, sort-based dispatch into per-expert capacity blocks,
  all-to-all over the ``model`` (expert-parallel) axis, per-expert GEMMs,
  all-to-all back, weighted combine.  With a high enough capacity factor it
  is numerically identical to ``moe_ref`` (property-tested).

Routing: softmax → top-k → renormalized top-k weights (Qwen/Mixtral style).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.distributed.sharding import axis_size


def router_topk(x: jax.Array, w_router: jax.Array, top_k: int
                ) -> Tuple[jax.Array, jax.Array]:
    """x (N, D) -> (weights (N,k) f32 normalized, idx (N,k) i32)."""
    logits = (x.astype(jnp.float32) @ w_router.astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(gates, top_k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return w, idx


def moe_ref(x: jax.Array, w_router: jax.Array, w_gate: jax.Array,
            w_up: jax.Array, w_down: jax.Array, top_k: int,
            act=jax.nn.silu) -> jax.Array:
    """Exact reference: every expert computes every token. x (N, D)."""
    N, D = x.shape
    E = w_router.shape[-1]
    w, idx = router_topk(x, w_router, top_k)
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)       # (N,k,E)
    comb = jnp.einsum("nke,nk->ne", onehot, w)               # (N,E)
    g = jnp.einsum("nd,edf->enf", x, w_gate)
    u = jnp.einsum("nd,edf->enf", x, w_up)
    h = act(g) * u
    y = jnp.einsum("enf,efd->end", h, w_down)
    return jnp.einsum("ne,end->nd", comb.astype(x.dtype), y)


def _dispatch_local(x: jax.Array, w: jax.Array, idx: jax.Array, E: int,
                    capacity: int) -> Tuple[jax.Array, jax.Array, jax.Array,
                                            jax.Array]:
    """Sort-based local dispatch.

    x (n, D); idx/w (n, k).  Returns
      buf (E, C, D)      — tokens grouped per expert (zero-padded / dropped),
      src (n*k,) i32     — source token per sorted element,
      dest (n*k,) i32    — flat destination slot (E*C = dropped),
      wflat (n*k,) f32   — combine weight per sorted element (0 if dropped).
    """
    n, k = idx.shape
    D = x.shape[-1]
    flat_e = idx.reshape(-1)
    flat_w = w.reshape(-1)
    order = jnp.argsort(flat_e)                      # stable
    sorted_e = flat_e[order]
    counts = jax.ops.segment_sum(jnp.ones_like(sorted_e), sorted_e,
                                 num_segments=E)     # (E,)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(n * k) - starts[sorted_e]
    keep = pos < capacity
    dest = jnp.where(keep, sorted_e * capacity + jnp.clip(pos, 0, capacity - 1),
                     E * capacity)
    src = order // k
    buf = jnp.zeros((E * capacity + 1, D), dtype=x.dtype)
    buf = buf.at[dest].set(x[src])                   # unique dests (except drop row)
    buf = buf[:-1].reshape(E, capacity, D)
    wflat = jnp.where(keep, flat_w[order], 0.0)
    return buf, src, dest, wflat


def _expert_ffn(buf: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                w_down: jax.Array, act) -> jax.Array:
    """buf (E, C, D) × per-expert weights (E, D, F) -> (E, C, D)."""
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    return jnp.einsum("ecf,efd->ecd", act(g) * u, w_down)


def moe_local(x: jax.Array, w_router: jax.Array, w_gate: jax.Array,
              w_up: jax.Array, w_down: jax.Array, top_k: int,
              capacity_factor: float, act=jax.nn.silu,
              ep_axis: Optional[str] = None) -> jax.Array:
    """Single-device (or per-shard, when called inside shard_map) MoE.

    When ``ep_axis`` is given the expert dimension of the weights is assumed
    already sharded over that mesh axis and two all-to-alls move the capacity
    blocks to/from the owning devices.
    """
    n, D = x.shape
    if ep_axis is not None:
        ep = axis_size(ep_axis)
        E = w_gate.shape[0] * ep      # global expert count
    else:
        ep = 1
        E = w_gate.shape[0]
    # static per-expert capacity (shapes must be static under trace)
    C = max(4, math.ceil(top_k * n / E * capacity_factor))
    C = -(-C // 4) * 4

    rw, ridx = router_topk(x, w_router, top_k)
    buf, src, dest, wflat = _dispatch_local(x, rw, ridx, E, C)
    if ep_axis is not None:
        # (E, C, D) -> (E/ep, C*ep, D): each rank keeps its expert slice,
        # receiving that slice's rows from every peer.
        buf = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=1,
                                 tiled=True)
    y = _expert_ffn(buf, w_gate, w_up, w_down, act)
    if ep_axis is not None:
        y = jax.lax.all_to_all(y, ep_axis, split_axis=1, concat_axis=0,
                               tiled=True)
    yflat = jnp.concatenate(
        [y.reshape(E * C, D), jnp.zeros((1, D), y.dtype)], axis=0)
    contrib = yflat[dest] * wflat[:, None].astype(y.dtype)
    out = jnp.zeros_like(x).at[src].add(contrib)
    return out


def moe_ep(x: jax.Array, w_router: jax.Array, w_gate: jax.Array,
           w_up: jax.Array, w_down: jax.Array, top_k: int,
           capacity_factor: float, mesh: Mesh, act=jax.nn.silu) -> jax.Array:
    """Expert-parallel MoE over a (pod?, data, model) mesh. x (N, D) global.

    Tokens are sharded over every mesh axis; experts live on ``model``.
    N is padded to a multiple of the device count.
    """
    N, D = x.shape
    ndev = mesh.size
    pad = (-N) % ndev
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, D), x.dtype)], axis=0)
    dp_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)

    fn = functools.partial(moe_local, top_k=top_k,
                           capacity_factor=capacity_factor, act=act,
                           ep_axis="model")
    out = shard_map(
        fn, mesh=mesh,
        in_specs=(P(dp_axes, None), P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=P(dp_axes, None),
        check_rep=False,
    )(x, w_router, w_gate, w_up, w_down)
    return out[:N] if pad else out


__all__ = ["router_topk", "moe_ref", "moe_local", "moe_ep"]
