"""Shared neural building blocks: RMSNorm, RoPE, GQA attention, SwiGLU."""
from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain

NEG_INF = -1e30


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * scale.astype(dt)


def rope_angles(positions: jax.Array, head_dim: int, theta: float
                ) -> Tuple[jax.Array, jax.Array]:
    """positions (..., T) -> cos/sin (..., T, head_dim//2), f32."""
    half = head_dim // 2
    inv = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B,T,H,dh); cos/sin (B,T,dh/2). LLaMA-style rotate-half."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dt)


ACTS: dict = {
    "silu": jax.nn.silu,
    "gelu": functools.partial(jax.nn.gelu, approximate=True),
    "relu": jax.nn.relu,
}


def causal_prefill_mask(positions: jax.Array, len_mask: jax.Array
                        ) -> jax.Array:
    """(B, T) positions + (B, S) valid-key mask → (B, T, S) causal mask.

    Shared by the dense attention backend (repro.models.attention); the
    Pallas flash-prefill kernel derives the same mask from block indices
    in-kernel and never materializes it.
    """
    causal = positions[:, :, None] >= positions[:, None, :]
    return causal & len_mask[:, None, :]


def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  mask: jax.Array, *, softmax_in_f32: bool = True
                  ) -> jax.Array:
    """Grouped-query attention.

    q: (B, T, H, dh); k, v: (B, S, K, dh); mask: (B, T, S) bool (True=attend).
    H must be a multiple of K.  Returns (B, T, H, dh).
    """
    B, T, H, dh = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    scale = dh ** -0.5
    qg = q.reshape(B, T, K, G, dh)
    scores = jnp.einsum("btkgh,bskh->bkgts", qg, k,
                        preferred_element_type=jnp.float32 if softmax_in_f32
                        else q.dtype)
    scores = scores * scale
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgts,bskh->btkgh", p, v)
    return out.reshape(B, T, H, dh)


def gqa_attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                          q_positions: jax.Array, kv_len_mask: jax.Array,
                          q_chunk: int) -> jax.Array:
    """Memory-bounded causal attention: scan over query chunks so the score
    tensor is (B, K, G, q_chunk, S) instead of (B, K, G, T, S).

    q_positions: (B, T) absolute position of each query token.
    kv_len_mask: (B, S) bool — valid (non-pad) key positions.
    Causality: q attends to keys with position <= its own position; key
    position here equals the buffer index (self-attention over the same seq).
    """
    B, T, H, dh = q.shape
    S = k.shape[1]
    assert T % q_chunk == 0, (T, q_chunk)
    n_chunks = T // q_chunk
    kpos = jnp.arange(S)[None, :]

    def body(carry, xs):
        qc, pc = xs  # (B, q_chunk, H, dh), (B, q_chunk)
        m = (kpos[:, None, :] <= pc[:, :, None]) & kv_len_mask[:, None, :]
        oc = gqa_attention(qc, k, v, m)
        return carry, oc

    qs = q.reshape(B, n_chunks, q_chunk, H, dh).transpose(1, 0, 2, 3, 4)
    ps = q_positions.reshape(B, n_chunks, q_chunk).transpose(1, 0, 2)
    _, outs = jax.lax.scan(body, None, (qs, ps))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, T, H, dh)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array, act: Callable = jax.nn.silu) -> jax.Array:
    g = x @ w_gate
    u = x @ w_up
    h = act(g) * u
    h = constrain(h, "batch", "seq", "ffn_act") if h.ndim == 3 else h
    return h @ w_down


__all__ = ["rms_norm", "rope_angles", "apply_rope", "causal_prefill_mask",
           "gqa_attention", "gqa_attention_chunked", "swiglu", "ACTS",
           "NEG_INF"]
