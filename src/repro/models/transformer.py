"""Config-driven decoder-only transformer (dense + MoE) with three lowerable
entry points:

  * ``train_logits``  — full-sequence causal forward (training).
  * ``prefill``       — causal forward that fills a KV cache and returns the
                        logits of each sequence's last real token.
  * ``tree_step``     — the Lookahead step: T = 1+decoding_length slots with a
                        tree-structured attention mask attend to the cache,
                        new KV entries are scattered at cache_len + slot.

Layers are stacked and iterated with ``lax.scan`` (HLO size O(1) in depth);
``remat=True`` wraps the scanned body in ``jax.checkpoint`` for training.
All tensors carry logical-axis sharding hints (repro.distributed.sharding).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import active_mesh, constrain
from repro.models import attention as attn_backends
from repro.models import moe as moe_lib
from repro.models.layers import (ACTS, apply_rope, rms_norm, rope_angles,
                                 swiglu)

Params = Dict[str, Any]


@dataclass(frozen=True)
class TransformerConfig:
    name: str = "tiny"
    n_layers: int = 2
    d_model: int = 64
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 128
    vocab_size: int = 256
    head_dim: Optional[int] = None          # default: d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    act: str = "silu"
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.5
    moe_impl: str = "auto"                  # "ref" | "ep" | "auto"
    # execution
    dtype: str = "float32"                  # activation dtype
    param_dtype: str = "float32"
    remat: bool = False
    scan_layers: bool = True                # False: unroll (dry-run accuracy —
                                            # XLA cost_analysis counts while
                                            # bodies once; see EXPERIMENTS.md)
    q_chunk: int = 0                        # >0: chunked prefill attention
    max_seq_len: int = 512                  # KV cache allocation length
    # per-phase attention backends, resolved from the registry in
    # repro.models.attention: "dense" | "pallas" | "flash_decode"
    prefill_backend: str = "dense"
    decode_backend: str = "dense"
    attn_score_f32: bool = True             # False: bf16 score temps (perf)
    # KV-cache layout: "dense" = (lanes, max_seq_len) rows per lane;
    # "paged" = a shared pool of (n_blocks, kv_block_size) rows indexed by
    # per-lane block tables (vLLM-style; block 0 reserved as NULL/trash)
    kv_layout: str = "dense"
    kv_block_size: int = 64

    @property
    def dh(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def adtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def n_params(self) -> int:
        """Total parameter count (for 6ND model-flops accounting)."""
        d, dh, V = self.d_model, self.dh, self.vocab_size
        qkvo = d * (self.n_heads * dh) * 2 + d * (self.n_kv_heads * dh) * 2
        if self.moe:
            ffn = 3 * d * self.moe_d_ff * self.n_experts + d * self.n_experts
            ffn += 3 * d * self.moe_d_ff * self.n_shared_experts
        else:
            ffn = 3 * d * self.d_ff
        per_layer = qkvo + ffn + 2 * d
        emb = V * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only routed experts)."""
        if not self.moe:
            return self.n_params()
        d, dh, V = self.d_model, self.dh, self.vocab_size
        qkvo = d * (self.n_heads * dh) * 2 + d * (self.n_kv_heads * dh) * 2
        ffn = 3 * d * self.moe_d_ff * (self.top_k + self.n_shared_experts)
        ffn += d * self.n_experts
        per_layer = qkvo + ffn + 2 * d
        emb = V * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d


# ------------------------------------------------------------------ parameters
def init_params(cfg: TransformerConfig, key: jax.Array) -> Params:
    k = jax.random.split(key, 16)
    d, dh, H, K = cfg.d_model, cfg.dh, cfg.n_heads, cfg.n_kv_heads
    L, V = cfg.n_layers, cfg.vocab_size
    pd = cfg.pdtype
    init = lambda kk, shape, scale=0.02: (
        jax.random.normal(kk, shape, dtype=jnp.float32) * scale).astype(pd)

    layers: Params = {
        "ln1": jnp.ones((L, d), pd),
        "ln2": jnp.ones((L, d), pd),
        "wq": init(k[0], (L, d, H * dh)),
        "wk": init(k[1], (L, d, K * dh)),
        "wv": init(k[2], (L, d, K * dh)),
        "wo": init(k[3], (L, H * dh, d)),
    }
    if cfg.qkv_bias:
        layers["bq"] = jnp.zeros((L, H * dh), pd)
        layers["bk"] = jnp.zeros((L, K * dh), pd)
        layers["bv"] = jnp.zeros((L, K * dh), pd)
    if cfg.moe:
        E, F = cfg.n_experts, cfg.moe_d_ff
        layers["router"] = init(k[4], (L, d, E))
        layers["we_gate"] = init(k[5], (L, E, d, F))
        layers["we_up"] = init(k[6], (L, E, d, F))
        layers["we_down"] = init(k[7], (L, E, F, d))
        if cfg.n_shared_experts:
            Fs = F * cfg.n_shared_experts
            layers["ws_gate"] = init(k[8], (L, d, Fs))
            layers["ws_up"] = init(k[9], (L, d, Fs))
            layers["ws_down"] = init(k[10], (L, Fs, d))
    else:
        layers["w_gate"] = init(k[4], (L, d, cfg.d_ff))
        layers["w_up"] = init(k[5], (L, d, cfg.d_ff))
        layers["w_down"] = init(k[6], (L, cfg.d_ff, d))

    params: Params = {
        "embed": init(k[11], (V, d)),
        "ln_f": jnp.ones((d,), pd),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init(k[12], (d, V))
    return params


def param_logical_axes(cfg: TransformerConfig) -> Params:
    """Logical-axis names per param (for dry-run in_shardings)."""
    layers = {
        "ln1": (None, None), "ln2": (None, None),
        "wq": (None, "fsdp", "tensor"), "wk": (None, "fsdp", "tensor"),
        "wv": (None, "fsdp", "tensor"), "wo": (None, "tensor", "fsdp"),
    }
    if cfg.qkv_bias:
        layers.update({"bq": (None, "tensor"), "bk": (None, "tensor"),
                       "bv": (None, "tensor")})
    if cfg.moe:
        layers.update({
            "router": (None, "fsdp", None),
            "we_gate": (None, "expert", "fsdp", None),
            "we_up": (None, "expert", "fsdp", None),
            "we_down": (None, "expert", None, "fsdp"),
        })
        if cfg.n_shared_experts:
            layers.update({"ws_gate": (None, "fsdp", "tensor"),
                           "ws_up": (None, "fsdp", "tensor"),
                           "ws_down": (None, "tensor", "fsdp")})
    else:
        layers.update({"w_gate": (None, "fsdp", "tensor"),
                       "w_up": (None, "fsdp", "tensor"),
                       "w_down": (None, "tensor", "fsdp")})
    out = {"embed": ("tensor", "fsdp"), "ln_f": (None,), "layers": layers}
    if not cfg.tie_embeddings:
        out["lm_head"] = ("fsdp", "tensor")
    return out


# ------------------------------------------------------------------- layer fwd
def _qkv(cfg: TransformerConfig, lp: Params, h: jax.Array
         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, T, _ = h.shape
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    q = h @ lp["wq"]
    k = h @ lp["wk"]
    v = h @ lp["wv"]
    if cfg.qkv_bias:
        q = q + lp["bq"]
        k = k + lp["bk"]
        v = v + lp["bv"]
    return (q.reshape(B, T, H, dh), k.reshape(B, T, K, dh),
            v.reshape(B, T, K, dh))


def _ffn(cfg: TransformerConfig, lp: Params, h: jax.Array) -> jax.Array:
    B, T, d = h.shape
    act = ACTS[cfg.act]
    if not cfg.moe:
        return swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"], act)
    x = h.reshape(B * T, d)
    mesh = active_mesh()
    impl = cfg.moe_impl
    if impl == "auto":
        impl = "ep" if (mesh is not None and "model" in mesh.shape) else "ref"
    if impl == "ep":
        y = moe_lib.moe_ep(x, lp["router"], lp["we_gate"], lp["we_up"],
                           lp["we_down"], cfg.top_k, cfg.capacity_factor,
                           mesh, act)
    elif impl == "local":
        y = moe_lib.moe_local(x, lp["router"], lp["we_gate"], lp["we_up"],
                              lp["we_down"], cfg.top_k, cfg.capacity_factor,
                              act)
    else:
        y = moe_lib.moe_ref(x, lp["router"], lp["we_gate"], lp["we_up"],
                            lp["we_down"], cfg.top_k, act)
    y = y.reshape(B, T, d)
    if cfg.n_shared_experts:
        y = y + swiglu(h, lp["ws_gate"], lp["ws_up"], lp["ws_down"], act)
    return y


def _layer_self(cfg: TransformerConfig, lp: Params, h: jax.Array,
                positions: jax.Array, len_mask: jax.Array,
                want_kv: bool = True):
    """Self-attention layer over the full sequence (train / prefill).
    Returns new hidden states and the (k, v) tensors for cache filling."""
    hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
    q, k, v = _qkv(cfg, lp, hn)
    cos, sin = rope_angles(positions, cfg.dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv_heads", None)
    v = constrain(v, "batch", "seq", "kv_heads", None)
    backend = attn_backends.get_backend(cfg.prefill_backend)
    attn = backend.prefill_attention(cfg, q, k, v, positions, len_mask)
    B, T, H, dh = attn.shape
    h = h + attn.reshape(B, T, H * dh) @ lp["wo"]
    h = constrain(h, "batch", "residual_seq", None)
    h = h + _ffn(cfg, lp, rms_norm(h, lp["ln2"], cfg.norm_eps))
    # residual_seq: () by default; train cells map it to ("model",) so the
    # remat-saved residual stream is sequence-sharded (Megatron-SP style)
    h = constrain(h, "batch", "residual_seq", None)
    return h, ((k, v) if want_kv else None)


def _layer_tree(cfg: TransformerConfig, lp: Params, h: jax.Array,
                positions: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                attend: Any
                ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Tree-decode layer: T slots attend to cache + tree siblings.

    k_cache/v_cache: (B, S_max, K, dh).  ``attend`` is the backend closure
    built by ``AttentionBackend.make_tree_attend`` — it scatters the new KV
    at cache_len + slot, then attends the slots against the cache.
    """
    B, T, _ = h.shape
    hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
    q, k, v = _qkv(cfg, lp, hn)
    cos, sin = rope_angles(positions, cfg.dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    attn, k_cache, v_cache = attend(q, k, v, k_cache, v_cache)
    H, dh = cfg.n_heads, cfg.dh
    h = h + attn.reshape(B, T, H * dh) @ lp["wo"]
    h = h + _ffn(cfg, lp, rms_norm(h, lp["ln2"], cfg.norm_eps))
    return h, (k_cache, v_cache)


# ----------------------------------------------------------------- full models
def _embed(cfg: TransformerConfig, params: Params, tokens: jax.Array
           ) -> jax.Array:
    h = params["embed"].astype(cfg.adtype)[tokens]
    return h * jnp.asarray(1.0, cfg.adtype)


def _unembed(cfg: TransformerConfig, params: Params, h: jax.Array
             ) -> jax.Array:
    h = rms_norm(h, params["ln_f"], cfg.norm_eps)
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = h @ w.astype(h.dtype)
    if logits.ndim == 3:
        logits = constrain(logits, "batch", "seq", "vocab_act")
    return logits


def _scan_layers(cfg: TransformerConfig, params: Params, h: jax.Array,
                 layer_fn, extra_xs: Tuple = (), extra_args: Tuple = (),
                 alias_ys_to_xs: bool = False) -> Tuple[jax.Array, Tuple]:
    """Run layer_fn over stacked layer params with lax.scan (or unrolled
    when cfg.scan_layers=False — dry-run cost accuracy).

    layer_fn(cfg, lp, h, *extra_args, *per_layer_xs) -> (h, per_layer_ys)

    alias_ys_to_xs: per-layer ys have the same structure/shape as per-layer
    xs (tree-decode cache update): in unrolled mode write y back into the
    stacked xs buffer with .at[i].set — XLA aliases this in place, so the
    unrolled path does NOT hold n_layers live cache copies.
    """
    # §Perf (train cells): cast the stacked weights to the activation dtype
    # BEFORE the layer loop — XLA hoists the loop-invariant fsdp all-gather
    # out of the scan, and gathering the f32 master copy moves (and holds)
    # 2x the bytes of the bf16 compute copy.
    lps = jax.tree.map(lambda a: a.astype(cfg.adtype)
                       if jnp.issubdtype(a.dtype, jnp.floating) else a,
                       params["layers"])

    def body(h, xs):
        lp, xtra = xs
        h, ys = layer_fn(cfg, lp, h, *extra_args, *xtra)
        return h, ys

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        h, ys = jax.lax.scan(body, h, (lps, extra_xs))
        return h, ys
    # unrolled
    buf = extra_xs
    ys_buf = None
    for i in range(cfg.n_layers):
        lp_i = jax.tree.map(lambda a: a[i], lps)
        xs_i = jax.tree.map(lambda a: a[i], buf)
        h, y = body(h, (lp_i, xs_i))
        if y is None:
            continue
        if alias_ys_to_xs:
            buf = jax.tree.map(lambda acc, yy: acc.at[i].set(yy), buf, y)
        else:
            if ys_buf is None:
                ys_buf = jax.tree.map(
                    lambda yy: jnp.zeros((cfg.n_layers,) + yy.shape,
                                         yy.dtype), y)
            ys_buf = jax.tree.map(lambda acc, yy: acc.at[i].set(yy),
                                  ys_buf, y)
    return h, (buf if alias_ys_to_xs else ys_buf)


def train_logits(cfg: TransformerConfig, params: Params, tokens: jax.Array,
                 ) -> jax.Array:
    """tokens (B, S) -> logits (B, S, V); plain causal, no padding mask."""
    B, S = tokens.shape
    h = _embed(cfg, params, tokens)
    h = constrain(h, "batch", "residual_seq", None)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    len_mask = jnp.ones((B, S), dtype=bool)
    h, _ = _scan_layers(cfg, params, h,
                        lambda c, lp, hh, pos, lm: _layer_self(
                            c, lp, hh, pos, lm, want_kv=False),
                        extra_xs=(), extra_args=(positions, len_mask))
    return _unembed(cfg, params, h)


def lm_loss(cfg: TransformerConfig, params: Params, tokens: jax.Array,
            labels: jax.Array, loss_mask: Optional[jax.Array] = None
            ) -> jax.Array:
    B, S = tokens.shape
    h = _embed(cfg, params, tokens)
    h = constrain(h, "batch", "residual_seq", None)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    len_mask = jnp.ones((B, S), dtype=bool)
    h, _ = _scan_layers(cfg, params, h,
                        lambda c, lp, hh, pos, lm: _layer_self(
                            c, lp, hh, pos, lm, want_kv=False),
                        extra_xs=(), extra_args=(positions, len_mask))

    # checkpointed loss head: the (B, S, V) f32 logits are NOT saved for the
    # backward pass — only h (bf16, V/vocab-factor smaller) is; logits are
    # recomputed during bwd.  Cuts several GiB/chip at 100k+ vocabularies.
    def head(h_, labels_, mask_):
        logits = _unembed(cfg, params, h_)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels_[..., None], axis=-1)[..., 0]
        if mask_ is None:
            return jnp.mean(nll)
        return jnp.sum(nll * mask_) / jnp.maximum(jnp.sum(mask_), 1.0)

    return jax.checkpoint(head, static_argnums=())(h, labels, loss_mask) \
        if cfg.remat else head(h, labels, loss_mask)


def init_cache(cfg: TransformerConfig, batch: int,
               dtype: Optional[jnp.dtype] = None) -> Dict[str, jax.Array]:
    L, S, K, dh = cfg.n_layers, cfg.max_seq_len, cfg.n_kv_heads, cfg.dh
    dt = dtype or cfg.adtype
    return {"k": jnp.zeros((L, batch, S, K, dh), dt),
            "v": jnp.zeros((L, batch, S, K, dh), dt)}


def cache_logical_axes(cfg: TransformerConfig) -> Dict[str, Tuple]:
    if cfg.kv_layout == "paged":
        # block pool is lane-agnostic: only the head axis is shardable
        return {"k": (None, None, None, "kv_heads", None),
                "v": (None, None, None, "kv_heads", None),
                "block_tables": (None, None)}
    if cfg.decode_backend == "flash_decode":
        return {"k": (None, None, "kv_seq", "kv_heads", None),
                "v": (None, None, "kv_seq", "kv_heads", None)}
    return {"k": (None, "batch", None, "kv_heads", None),
            "v": (None, "batch", None, "kv_heads", None)}


# ------------------------------------------------------------ paged KV cache
def blocks_per_lane(cfg: TransformerConfig) -> int:
    """Block-table width: blocks covering max_seq_len logical positions."""
    return -(-cfg.max_seq_len // cfg.kv_block_size)


def init_paged_cache(cfg: TransformerConfig, lanes: int,
                     n_blocks: Optional[int] = None,
                     dtype: Optional[jnp.dtype] = None
                     ) -> Dict[str, jax.Array]:
    """Block-pool KV cache: k/v (L, n_blocks, block_size, K, dh) plus the
    per-lane block tables (lanes, blocks_per_lane) int32.

    ``n_blocks`` defaults to the dense-equivalent worst case (every lane can
    hold max_seq_len rows) plus the reserved NULL block 0; serving stacks
    pass a smaller pool sized to the actual workload — that is the paged
    layout's memory win.  Table entries start at 0 (the NULL block), where
    never-attended scatters land harmlessly.
    """
    L, K, dh = cfg.n_layers, cfg.n_kv_heads, cfg.dh
    bs, bpl = cfg.kv_block_size, blocks_per_lane(cfg)
    nb = int(n_blocks) if n_blocks else 1 + lanes * bpl
    dt = dtype or cfg.adtype
    return {"k": jnp.zeros((L, nb, bs, K, dh), dt),
            "v": jnp.zeros((L, nb, bs, K, dh), dt),
            "block_tables": jnp.zeros((lanes, bpl), jnp.int32)}


def paged_row_index(block_tables: jax.Array, positions: jax.Array,
                    block_size: int) -> jax.Array:
    """Logical positions -> physical flat cache rows through block tables.

    block_tables (B, blocks_per_lane) int32; positions (B, N) logical token
    positions.  Returns (B, N) rows into the (n_blocks*block_size, ...) flat
    view.  Positions past a lane's allocated coverage resolve through table
    entry 0 to the NULL block (garbage rows, never attended)."""
    blk = jnp.clip(positions // block_size, 0, block_tables.shape[-1] - 1)
    phys = jnp.take_along_axis(block_tables, blk.astype(jnp.int32), axis=-1)
    return phys * block_size + positions % block_size


def prefill(cfg: TransformerConfig, params: Params, tokens: jax.Array,
            lens: jax.Array, cache: Optional[Dict[str, jax.Array]] = None
            ) -> Tuple[Dict[str, jax.Array], jax.Array]:
    """Causal forward over padded prompts; fills cache[:, :, :S].

    cache=None: the per-layer KV stack itself becomes the cache (S must be
    max_seq_len) — avoids a second cache-sized buffer for big prefills.
    Returns (cache, last_logits (B, V)) at position lens-1 of each row.
    """
    B, S = tokens.shape
    h = _embed(cfg, params, tokens)
    h = constrain(h, "batch", "seq", None)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    len_mask = positions < lens[:, None]
    h, kv = _scan_layers(cfg, params, h, _layer_self, extra_xs=(),
                         extra_args=(positions, len_mask))
    k_new, v_new = kv     # (L, B, S, K, dh)
    if cache is None:
        assert S == cfg.max_seq_len, (S, cfg.max_seq_len)
        k_new = k_new.astype(cfg.adtype)
        v_new = v_new.astype(cfg.adtype)
        mesh = active_mesh()
        if mesh is not None:
            from jax.sharding import NamedSharding
            from repro.distributed.flash_decode import cache_partition_spec
            spec = NamedSharding(mesh, cache_partition_spec(
                mesh, B, S, cfg.n_kv_heads, cfg.n_heads))
            k_new = jax.lax.with_sharding_constraint(k_new, spec)
            v_new = jax.lax.with_sharding_constraint(v_new, spec)
        cache = {"k": k_new, "v": v_new}
    else:
        cache = {
            "k": cache["k"].at[:, :, :S].set(k_new.astype(cache["k"].dtype)),
            "v": cache["v"].at[:, :, :S].set(v_new.astype(cache["v"].dtype))}
    h_last = jnp.take_along_axis(
        h, (lens - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    return cache, _unembed(cfg, params, h_last)


def prefill_into_slot(cfg: TransformerConfig, params: Params,
                      cache: Dict[str, jax.Array], slot: jax.Array,
                      tokens: jax.Array, lens: jax.Array
                      ) -> Tuple[Dict[str, jax.Array], jax.Array]:
    """Prefill ONE request into batch lane ``slot`` of an existing cache.

    tokens (1, S) padded prompt; lens (1,).  Writes KV for positions [0, S)
    of that lane only (other lanes untouched — mid-flight admission in the
    continuous-batching scheduler).  ``slot`` may be a traced scalar, so one
    compilation serves every lane.  Returns (cache, last_logits (1, V)).
    """
    B, S = tokens.shape
    assert B == 1, "prefill_into_slot admits one request at a time"
    h = _embed(cfg, params, tokens)
    h = constrain(h, "batch", "seq", None)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    len_mask = positions < lens[:, None]
    h, kv = _scan_layers(cfg, params, h, _layer_self, extra_xs=(),
                         extra_args=(positions, len_mask))
    k_new, v_new = kv     # (L, 1, S, K, dh)
    slot = jnp.asarray(slot, jnp.int32)
    zero = jnp.zeros((), jnp.int32)
    start = (zero, slot, zero, zero, zero)
    cache = {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), start),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), start)}
    h_last = jnp.take_along_axis(
        h, (lens - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    return cache, _unembed(cfg, params, h_last)


def _scatter_paged_rows(cache: Dict[str, jax.Array], rows: jax.Array,
                        k_new: jax.Array, v_new: jax.Array
                        ) -> Dict[str, jax.Array]:
    """Write per-layer KV (L, B, N, K, dh) at flat physical ``rows`` (B, N)
    of the paged pool.  Duplicate rows only ever arise on NULL-block
    garbage, where any write order is fine."""
    k, v = cache["k"], cache["v"]
    L, nb, bs, K, dh = k.shape
    flat = rows.reshape(-1)
    kf = k.reshape(L, nb * bs, K, dh)
    vf = v.reshape(L, nb * bs, K, dh)
    kf = kf.at[:, flat].set(k_new.reshape(L, -1, K, dh).astype(k.dtype))
    vf = vf.at[:, flat].set(v_new.reshape(L, -1, K, dh).astype(v.dtype))
    return {"k": kf.reshape(k.shape), "v": vf.reshape(v.shape),
            "block_tables": cache["block_tables"]}


def prefill_paged(cfg: TransformerConfig, params: Params, tokens: jax.Array,
                  lens: jax.Array, cache: Dict[str, jax.Array]
                  ) -> Tuple[Dict[str, jax.Array], jax.Array]:
    """Batched causal prefill into a paged cache: row p of lane b lands at
    the physical row its block table maps p to.  Rows past a lane's
    allocated coverage (prompt padding, lanes without a request) resolve to
    the NULL block — garbage, never attended (I3)."""
    B, S = tokens.shape
    h = _embed(cfg, params, tokens)
    h = constrain(h, "batch", "seq", None)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    len_mask = positions < lens[:, None]
    h, kv = _scan_layers(cfg, params, h, _layer_self, extra_xs=(),
                         extra_args=(positions, len_mask))
    rows = paged_row_index(cache["block_tables"], positions,
                           cfg.kv_block_size)
    cache = _scatter_paged_rows(cache, rows, kv[0], kv[1])
    h_last = jnp.take_along_axis(
        h, (lens - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    return cache, _unembed(cfg, params, h_last)


def prefill_into_slot_paged(cfg: TransformerConfig, params: Params,
                            cache: Dict[str, jax.Array], slot: jax.Array,
                            tokens: jax.Array, lens: jax.Array
                            ) -> Tuple[Dict[str, jax.Array], jax.Array]:
    """Paged twin of ``prefill_into_slot``: one request's KV scatters through
    lane ``slot``'s block table; every other lane's blocks are untouched
    (block ownership is exclusive, so no start-index arithmetic needed)."""
    B, S = tokens.shape
    assert B == 1, "prefill_into_slot admits one request at a time"
    h = _embed(cfg, params, tokens)
    h = constrain(h, "batch", "seq", None)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    len_mask = positions < lens[:, None]
    h, kv = _scan_layers(cfg, params, h, _layer_self, extra_xs=(),
                         extra_args=(positions, len_mask))
    bt_row = jax.lax.dynamic_index_in_dim(
        cache["block_tables"], jnp.asarray(slot, jnp.int32), axis=0)  # (1,bpl)
    rows = paged_row_index(bt_row, positions, cfg.kv_block_size)
    cache = _scatter_paged_rows(cache, rows, kv[0], kv[1])
    h_last = jnp.take_along_axis(
        h, (lens - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    return cache, _unembed(cfg, params, h_last)


def prefill_from_offset_paged(cfg: TransformerConfig, params: Params,
                              cache: Dict[str, jax.Array], slot: jax.Array,
                              tokens: jax.Array, offset: jax.Array,
                              lens: jax.Array
                              ) -> Tuple[Dict[str, jax.Array], jax.Array]:
    """Suffix prefill for prefix-cache hits: prefill only the uncached tail
    of one request's prompt, attending the shared prefix blocks through
    lane ``slot``'s block table.

    tokens (1, Sb): the prompt suffix padded to a fixed bucket length;
    offset (1,): cached prefix length (absolute position of tokens[0]);
    lens (1,): real (un-padded) suffix length.

    Implemented as a causally-masked tree step at cache_lens=offset: the
    decode backend scatters the suffix KV at rows offset+i through the
    block-table indirection and masks attention to past ∨ causal-within-
    suffix — exactly what full prefill computes for those positions, so the
    resulting KV (and logits) match the uncached path.  Pad slots scatter
    to the NULL block (``slot_valid``) and are causally invisible to real
    queries.  One executable per (bucket, lane-count) — lanes and offsets
    are traced, so compile-once survives arbitrary hit patterns."""
    B, Sb = tokens.shape
    assert B == 1, "prefill_from_offset admits one request at a time"
    bt_row = jax.lax.dynamic_index_in_dim(
        cache["block_tables"], jnp.asarray(slot, jnp.int32), axis=0)  # (1,bpl)
    positions = offset[:, None] + jnp.arange(Sb)[None, :]             # (1,Sb)
    causal = jnp.broadcast_to(
        jnp.tril(jnp.ones((Sb, Sb), bool)), (B, Sb, Sb))
    valid = jnp.arange(Sb)[None, :] < lens[:, None]
    backend = attn_backends.get_backend(cfg.decode_backend)
    attend = backend.make_paged_tree_attend(
        cfg, bt_row, jnp.asarray(offset, jnp.int32), causal, valid)

    h = _embed(cfg, params, tokens)

    def layer(cfg_, lp, h_, k_c, v_c):
        return _layer_tree(cfg_, lp, h_, positions, k_c, v_c, attend)

    h, kv = _scan_layers(cfg, params, h, layer,
                         extra_xs=(cache["k"], cache["v"]), extra_args=(),
                         alias_ys_to_xs=True)
    new_cache = {"k": kv[0], "v": kv[1],
                 "block_tables": cache["block_tables"]}
    h_last = jnp.take_along_axis(
        h, (lens - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    return new_cache, _unembed(cfg, params, h_last)


def copy_paged_block(cache: Dict[str, jax.Array], src: jax.Array,
                     dst: jax.Array) -> Dict[str, jax.Array]:
    """Device copy of one physical block (all layers, K and V) — the
    copy-on-write fork of a partially-filled boundary block a prefix-cache
    hit must extend.  Rows past the valid prefix are garbage in ``src`` and
    stay garbage in ``dst`` until the suffix prefill overwrites them."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    out = dict(cache)
    for name in ("k", "v"):
        buf = cache[name]                                 # (L, nb, bs, K, dh)
        blk = jax.lax.dynamic_slice_in_dim(buf, src, 1, axis=1)
        out[name] = jax.lax.dynamic_update_slice_in_dim(buf, blk, dst, axis=1)
    return out


def tree_step_paged(cfg: TransformerConfig, params: Params,
                    cache: Dict[str, jax.Array], cache_lens: jax.Array,
                    tokens: jax.Array, positions: jax.Array,
                    tree_mask: jax.Array
                    ) -> Tuple[Dict[str, jax.Array], jax.Array]:
    """Lookahead VA forward over the paged cache: the decode backend's
    ``make_paged_tree_attend`` scatters draft-slot KV through the block
    tables and attends against the blocks (dense: gather via jnp.take;
    pallas: the block-table streaming kernel)."""
    bt = cache["block_tables"]
    backend = attn_backends.get_backend(cfg.decode_backend)
    attend = backend.make_paged_tree_attend(cfg, bt, cache_lens, tree_mask)

    h = _embed(cfg, params, tokens)

    def layer(cfg_, lp, h_, k_c, v_c):
        return _layer_tree(cfg_, lp, h_, positions, k_c, v_c, attend)

    h, kv = _scan_layers(cfg, params, h, layer,
                         extra_xs=(cache["k"], cache["v"]), extra_args=(),
                         alias_ys_to_xs=True)
    new_cache = {"k": kv[0], "v": kv[1], "block_tables": bt}
    return new_cache, _unembed(cfg, params, h)


def commit_paged_cache(cfg: TransformerConfig, cache: Dict[str, jax.Array],
                       cache_lens: jax.Array, gather_idx: jax.Array,
                       n_accept: jax.Array
                       ) -> Tuple[Dict[str, jax.Array], jax.Array]:
    """Paged twin of ``commit_cache``: logical src/dst positions resolve
    through the block tables before the gather/scatter."""
    k, v, bt = cache["k"], cache["v"], cache["block_tables"]
    L, nb, bs, K, dh = k.shape
    B, T = gather_idx.shape
    src = cache_lens[:, None] + gather_idx                         # (B, T)
    dst = cache_lens[:, None] + jnp.arange(T)[None, :]
    src_rows = paged_row_index(bt, src, cfg.kv_block_size).reshape(-1)
    dst_rows = paged_row_index(bt, dst, cfg.kv_block_size).reshape(-1)
    kf = k.reshape(L, nb * bs, K, dh)
    vf = v.reshape(L, nb * bs, K, dh)
    kg = kf[:, src_rows]                                    # (L, B*T, K, dh)
    vg = vf[:, src_rows]
    kf = kf.at[:, dst_rows].set(kg)
    vf = vf.at[:, dst_rows].set(vg)
    return {"k": kf.reshape(k.shape), "v": vf.reshape(v.shape),
            "block_tables": bt}, cache_lens + n_accept


def reset_blocks(cache: Dict[str, jax.Array], block_ids: jax.Array
                 ) -> Dict[str, jax.Array]:
    """Zero the given physical blocks of a paged cache (hygiene scrub).

    ``block_ids`` (N,) int32 — pad with 0: scrubbing the NULL block is
    harmless.  MUST be called on blocks at free time, BEFORE the allocator
    can hand them to a newly admitted request (a lane- or table-keyed scrub
    after re-allocation would destroy the new request's KV)."""
    block_ids = jnp.asarray(block_ids, jnp.int32)
    out = dict(cache)
    for name in ("k", "v"):
        buf = cache[name]
        zero = jnp.zeros((buf.shape[0], block_ids.shape[0]) + buf.shape[2:],
                         buf.dtype)
        out[name] = buf.at[:, block_ids].set(zero)
    return out


def reset_slot(cache: Dict[str, jax.Array], slot: jax.Array
               ) -> Dict[str, jax.Array]:
    """Zero one batch lane of the KV cache.  Hygiene only: correctness never
    depends on it (rows ≥ cache_len are masked out of every attention)."""
    slot = jnp.asarray(slot, jnp.int32)
    zero = jnp.zeros((), jnp.int32)
    start = (zero, slot, zero, zero, zero)
    out = {}
    for name, buf in cache.items():
        lane = jax.lax.dynamic_slice_in_dim(buf, 0, 1, axis=1)
        out[name] = jax.lax.dynamic_update_slice(
            buf, jnp.zeros_like(lane), start)
    return out


def tree_step(cfg: TransformerConfig, params: Params,
              cache: Dict[str, jax.Array], cache_lens: jax.Array,
              tokens: jax.Array, positions: jax.Array, tree_mask: jax.Array
              ) -> Tuple[Dict[str, jax.Array], jax.Array]:
    """Lookahead VA forward.

    tokens (B, T), positions (B, T), tree_mask (B, T, T) ancestor-closure.
    Returns (cache-with-slots-written, logits (B, T, V)).
    """
    B, T = tokens.shape
    S_max = cache["k"].shape[2]
    h = _embed(cfg, params, tokens)

    backend = attn_backends.get_backend(cfg.decode_backend)
    attend = backend.make_tree_attend(cfg, cache_lens, tree_mask, S_max)

    def layer(cfg_, lp, h_, k_c, v_c):
        return _layer_tree(cfg_, lp, h_, positions, k_c, v_c, attend)

    h, kv = _scan_layers(cfg, params, h, layer,
                         extra_xs=(cache["k"], cache["v"]), extra_args=(),
                         alias_ys_to_xs=True)
    new_cache = {"k": kv[0], "v": kv[1]}
    return new_cache, _unembed(cfg, params, h)


def commit_cache(cache: Dict[str, jax.Array], cache_lens: jax.Array,
                 gather_idx: jax.Array, n_accept: jax.Array
                 ) -> Tuple[Dict[str, jax.Array], jax.Array]:
    """Compact accepted slots: new position m+j takes KV from m+gather[j].

    gather_idx (B, T) slot indices (monotone increasing over valid j);
    n_accept (B,).  Rows beyond n_accept keep garbage (never attended).
    """
    k, v = cache["k"], cache["v"]
    L, B, S, K, dh = k.shape
    T = gather_idx.shape[1]
    bidx = jnp.arange(B)[:, None]
    src = cache_lens[:, None] + gather_idx                       # (B, T)
    dst = cache_lens[:, None] + jnp.arange(T)[None, :]
    kg = k[:, bidx, src]                                         # (L,B,T,K,dh)
    vg = v[:, bidx, src]
    k = k.at[:, bidx, dst].set(kg)
    v = v.at[:, bidx, dst].set(vg)
    return {"k": k, "v": v}, cache_lens + n_accept


def verify_accept_device(tree_tokens: jax.Array, parent: jax.Array,
                         n_live: jax.Array, chosen: jax.Array
                         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Device twin of ``repro.core.verify.verify_accept`` (the host accept
    walk), vmapped over lanes — the fused-step epilogue.

    tree_tokens (B, T) draft-slot tokens; parent (B, T) slot parents
    (root = -1, padded slots = 0); n_live (B,) live slot count per lane
    (0 marks an idle placeholder lane); chosen (B, T) the model's
    prediction at each slot.

    Returns (n_acc (B,), acc_tokens (B, T), kv_slots (B, T)) int32.  The
    walk starts at the root (always accepted: acc_tokens[0] = chosen[0],
    kv_slots[0] = 0) and repeatedly steps to the smallest slot c with
    ``parent[c] == cur and tree_tokens[c] == chosen[cur] and 0 < c <
    n_live``.  "Smallest slot" is exactly the host semantics: DraftTree
    children lists are built in increasing slot order and verify_accept
    takes the first matching child.  Entries past n_acc are zero (commit
    gathers row lens+0 there — garbage rows, never attended).  Idle lanes
    (n_live == 0) return n_acc == 0.
    """
    B, T = tree_tokens.shape

    def walk(tok, par, nl, ch):
        slots = jnp.arange(T, dtype=jnp.int32)
        acc0 = jnp.zeros((T,), jnp.int32).at[0].set(ch[0])
        kvs0 = jnp.zeros((T,), jnp.int32)

        def body(carry, _):
            cur, n, done, acc, kvs = carry
            want = ch[cur]
            ok = ((par == cur) & (tok == want) & (slots < nl)
                  & (slots > 0) & jnp.logical_not(done))
            nxt = jnp.argmax(ok).astype(jnp.int32)
            found = ok[nxt]
            acc = jnp.where(found, acc.at[n].set(ch[nxt]), acc)
            kvs = jnp.where(found, kvs.at[n].set(nxt), kvs)
            cur = jnp.where(found, nxt, cur)
            n = jnp.where(found, n + 1, n)
            done = done | jnp.logical_not(found)
            return (cur, n, done, acc, kvs), None

        init = (jnp.int32(0), jnp.int32(1), nl <= 0, acc0, kvs0)
        (_, n, _, acc, kvs), _ = jax.lax.scan(body, init, None,
                                              length=max(T - 1, 0))
        n = jnp.where(nl > 0, n, 0)
        return n, acc, kvs

    tok = jnp.asarray(tree_tokens, jnp.int32)
    par = jnp.asarray(parent, jnp.int32)
    nl = jnp.asarray(n_live, jnp.int32)
    ch = jnp.asarray(chosen, jnp.int32)
    return jax.vmap(walk)(tok, par, nl, ch)


def pack_step_result(n_acc: jax.Array, acc_tokens: jax.Array,
                     kv_slots: jax.Array) -> jax.Array:
    """Pack the fused-step outputs into the ONE (B, 1+2T) int32 array that
    crosses the host boundary per decode step:
    ``[n_acc | acc_tokens (T) | kv_slots (T)]`` per lane."""
    return jnp.concatenate([n_acc[:, None].astype(jnp.int32),
                            acc_tokens.astype(jnp.int32),
                            kv_slots.astype(jnp.int32)], axis=1)


__all__ = ["TransformerConfig", "Params", "init_params", "param_logical_axes",
           "train_logits", "lm_loss", "init_cache", "cache_logical_axes",
           "prefill", "prefill_into_slot", "reset_slot", "tree_step",
           "commit_cache", "blocks_per_lane", "init_paged_cache",
           "paged_row_index", "prefill_paged", "prefill_into_slot_paged",
           "prefill_from_offset_paged", "copy_paged_block",
           "tree_step_paged", "commit_paged_cache", "reset_blocks",
           "verify_accept_device", "pack_step_result"]
