"""Pluggable attention backends for the transformer serving stack.

Every attention call site in ``repro.models.transformer`` dispatches through
this registry instead of hard-coding one math path.  A backend implements
the two serving phases:

  * ``prefill_attention(cfg, q, k, v, positions, len_mask)`` —
    full-sequence causal attention (train / ``prefill`` /
    ``prefill_into_slot``): q (B, S, H, dh), k/v (B, S, K, dh)
    → (B, S, H, dh).
  * ``make_tree_attend(cfg, cache_lens, tree_mask, S_max)`` — returns the
    per-layer tree-decode closure
    ``attend(q, k_new, v_new, k_cache, v_cache) -> (out, k_cache, v_cache)``
    that scatters the T draft-slot KV rows at ``cache_len + slot`` and
    attends the slots against the whole cache.

Per-phase selection lives on ``TransformerConfig``: ``prefill_backend`` and
``decode_backend`` name a registered backend (the registry replaces the old
ad-hoc ``decode_attn`` string).  Registered here:

  dense        — jnp.einsum GQA over the full cache (reference semantics;
                 materializes the (B, T, S) score path per layer)
  pallas       — kernels/flash_prefill + kernels/tree_attention: blocked
                 HBM→VMEM streaming with an online-softmax accumulator
                 (compiled on TPU, interpret mode elsewhere)
  flash_decode — sequence-parallel shard_map decode
                 (repro.distributed.flash_decode); prefill delegates to
                 dense, and without an active mesh the decode phase
                 degrades to the dense math

Invariants every backend must uphold (DESIGN.md §Attention backends):

  * the mask semantics of ``build_full_tree_mask`` — past rows
    (j < cache_len) plus the ancestor-closure tree block;
  * the KV-scatter layout — draft slot i's KV lands at row
    ``cache_len + i`` of its lane (I3: the committed prefix is untouched);
  * fixed shapes (I2): nothing about the closure may depend on values, only
    on shapes, so every StepFns member still compiles once;
  * per-backend losslessness (I1): serving outputs must equal
    ``reference_decode`` run through the same backend bit-for-bit (asserted
    by the scheduler suite parameterized over backends).
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers import (causal_prefill_mask, gqa_attention,
                                 gqa_attention_chunked)


# ------------------------------------------------------------ shared helpers
def scatter_kv(k_cache: jax.Array, v_cache: jax.Array, cache_lens: jax.Array,
               k: jax.Array, v: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """Write the (B, T) draft-slot KV rows at ``cache_len + slot`` (I3)."""
    B, T = k.shape[:2]
    bidx = jnp.arange(B)[:, None]
    sidx = cache_lens[:, None] + jnp.arange(T)[None, :]
    k_cache = k_cache.at[bidx, sidx].set(k.astype(k_cache.dtype))
    v_cache = v_cache.at[bidx, sidx].set(v.astype(v_cache.dtype))
    return k_cache, v_cache


def scatter_kv_paged(k_cache: jax.Array, v_cache: jax.Array,
                     slot_rows: jax.Array, k: jax.Array, v: jax.Array
                     ) -> Tuple[jax.Array, jax.Array]:
    """Paged twin of ``scatter_kv``: write the (B, T) draft-slot KV rows at
    precomputed physical rows of the (n_blocks, block_size, K, dh) pool.
    Rows are distinct across lanes (block ownership is exclusive); only
    NULL-block garbage of idle lanes ever collides."""
    nb, bs, K, dh = k_cache.shape
    flat = slot_rows.reshape(-1)
    kf = k_cache.reshape(nb * bs, K, dh)
    vf = v_cache.reshape(nb * bs, K, dh)
    kf = kf.at[flat].set(k.reshape(-1, K, dh).astype(k_cache.dtype))
    vf = vf.at[flat].set(v.reshape(-1, K, dh).astype(v_cache.dtype))
    return kf.reshape(k_cache.shape), vf.reshape(v_cache.shape)


def build_full_tree_mask(cache_lens: jax.Array, tree_mask: jax.Array,
                         S_max: int) -> jax.Array:
    """(B, T, T) ancestor-closure → (B, T, S_max): past ∨ tree block."""
    B, T = tree_mask.shape[:2]
    j = jnp.arange(S_max)[None, None, :]                  # (1, 1, S)
    past = j < cache_lens[:, None, None]
    rel = j - cache_lens[:, None, None]                   # slot index
    in_block = (rel >= 0) & (rel < T)
    relc = jnp.clip(rel, 0, T - 1).astype(jnp.int32)      # (B, 1, S)
    # tm[b, i, s] = tree_mask[b, i, relc[b, 0, s]]
    tm = jnp.take_along_axis(
        tree_mask, jnp.broadcast_to(relc, (B, T, S_max)), axis=2)
    return past | (in_block & tm)


def dense_prefill_attention(cfg, q: jax.Array, k: jax.Array, v: jax.Array,
                            positions: jax.Array, len_mask: jax.Array
                            ) -> jax.Array:
    """Reference causal prefill: chunked scan when cfg.q_chunk divides S."""
    T = q.shape[1]
    if cfg.q_chunk and T % cfg.q_chunk == 0 and T > cfg.q_chunk:
        return gqa_attention_chunked(q, k, v, positions, len_mask,
                                     cfg.q_chunk)
    return gqa_attention(q, k, v, causal_prefill_mask(positions, len_mask))


# ---------------------------------------------------------------- backends
class AttentionBackend:
    """Base class doubling as the ``dense`` reference backend."""

    name = "dense"

    def prefill_attention(self, cfg, q, k, v, positions, len_mask
                          ) -> jax.Array:
        return dense_prefill_attention(cfg, q, k, v, positions, len_mask)

    def make_tree_attend(self, cfg, cache_lens: jax.Array,
                         tree_mask: jax.Array, S_max: int) -> Callable:
        full_mask = build_full_tree_mask(cache_lens, tree_mask, S_max)

        def attend(q, k, v, k_cache, v_cache):
            q = constrain(q, "batch", None, "heads", None)
            k_cache, v_cache = scatter_kv(k_cache, v_cache, cache_lens, k, v)
            out = gqa_attention(q, k_cache, v_cache, full_mask,
                                softmax_in_f32=cfg.attn_score_f32)
            return out, k_cache, v_cache

        return attend

    def _paged_geometry(self, cfg, block_tables: jax.Array,
                        cache_lens: jax.Array, tree_mask: jax.Array,
                        slot_valid=None):
        """Shared paged-decode precompute: the (B, T, S_virtual) full mask
        plus the physical rows for the draft-slot scatter and (for the
        gather path) every logical position of every lane.

        slot_valid (B, T) bool: slots to actually scatter; invalid slots'
        KV writes redirect to the NULL block (row 0).  Used by bucketed
        suffix prefill, whose pad slots may sit past the lane's table
        coverage where ``paged_row_index`` clipping would otherwise alias
        them onto the last real block."""
        from repro.models.transformer import paged_row_index
        bs = cfg.kv_block_size
        B, T = tree_mask.shape[:2]
        S_virtual = block_tables.shape[1] * bs
        full_mask = build_full_tree_mask(cache_lens, tree_mask, S_virtual)
        slots = cache_lens[:, None] + jnp.arange(T)[None, :]
        slot_rows = paged_row_index(block_tables, slots, bs)
        if slot_valid is not None:
            slot_rows = jnp.where(slot_valid, slot_rows, 0)
        all_pos = jnp.broadcast_to(jnp.arange(S_virtual)[None, :],
                                   (B, S_virtual))
        all_rows = paged_row_index(block_tables, all_pos, bs)
        return full_mask, slot_rows, all_rows, S_virtual

    def make_paged_tree_attend(self, cfg, block_tables: jax.Array,
                               cache_lens: jax.Array, tree_mask: jax.Array,
                               slot_valid=None) -> Callable:
        """Tree-decode closure over the paged cache — per-layer caches are
        the (n_blocks, block_size, K, dh) block pool.  Reference semantics:
        gather each lane's blocks back into a contiguous (B, S_virtual)
        window via ``jnp.take`` and reuse the dense math (parity oracle for
        the streaming kernel; positions beyond a lane's coverage resolve to
        NULL-block garbage and are masked)."""
        full_mask, slot_rows, all_rows, S_virtual = self._paged_geometry(
            cfg, block_tables, cache_lens, tree_mask, slot_valid)
        B = tree_mask.shape[0]

        def attend(q, k, v, k_cache, v_cache):
            q = constrain(q, "batch", None, "heads", None)
            k_cache, v_cache = scatter_kv_paged(k_cache, v_cache, slot_rows,
                                                k, v)
            nb, bs_, K, dh = k_cache.shape
            flat = all_rows.reshape(-1)
            kg = jnp.take(k_cache.reshape(nb * bs_, K, dh), flat, axis=0
                          ).reshape(B, S_virtual, K, dh)
            vg = jnp.take(v_cache.reshape(nb * bs_, K, dh), flat, axis=0
                          ).reshape(B, S_virtual, K, dh)
            out = gqa_attention(q, kg, vg, full_mask,
                                softmax_in_f32=cfg.attn_score_f32)
            return out, k_cache, v_cache

        return attend


class PallasBackend(AttentionBackend):
    """Blocked Pallas kernels for both phases.

    The flash-prefill kernel is causal over the buffer index; the serving
    prefill paths satisfy ``positions == arange(S)``, and pad rows sit
    causally *after* every real query, so ``len_mask`` needs no separate
    treatment — real rows see exactly the dense mask, pad rows only feed
    cache rows beyond ``lens`` (garbage by I3, never attended).
    """

    name = "pallas"

    def prefill_attention(self, cfg, q, k, v, positions, len_mask
                          ) -> jax.Array:
        from repro.kernels.flash_prefill.ops import flash_prefill
        return flash_prefill(q, k, v)

    def make_tree_attend(self, cfg, cache_lens, tree_mask, S_max):
        from repro.kernels.tree_attention.ops import tree_attention
        full_mask = build_full_tree_mask(cache_lens, tree_mask, S_max)

        def attend(q, k, v, k_cache, v_cache):
            k_cache, v_cache = scatter_kv(k_cache, v_cache, cache_lens, k, v)
            out = tree_attention(q, k_cache, v_cache, full_mask)
            return out, k_cache, v_cache

        return attend

    def make_paged_tree_attend(self, cfg, block_tables, cache_lens,
                               tree_mask, slot_valid=None):
        """Streaming paged decode: the kernel walks each lane's logical
        blocks and a scalar-prefetched block table steers the DMA to the
        physical block — no contiguous per-lane cache is ever materialized
        (the jnp.take of the dense path disappears into addressing)."""
        from repro.kernels.tree_attention.paged import paged_tree_attention
        full_mask, slot_rows, _, _ = self._paged_geometry(
            cfg, block_tables, cache_lens, tree_mask, slot_valid)

        def attend(q, k, v, k_cache, v_cache):
            k_cache, v_cache = scatter_kv_paged(k_cache, v_cache, slot_rows,
                                                k, v)
            out = paged_tree_attention(q, k_cache, v_cache, block_tables,
                                       full_mask)
            return out, k_cache, v_cache

        return attend


# ---------------------------------------------------------------- registry
_REGISTRY: Dict[str, AttentionBackend] = {}


def register_backend(backend) -> None:
    """Register a backend instance under ``backend.name`` (last wins)."""
    _REGISTRY[backend.name] = backend


def get_backend(name: str):
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown attention backend {name!r}; registered: "
            f"{', '.join(sorted(_REGISTRY))}") from None


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


register_backend(AttentionBackend())           # "dense"
register_backend(PallasBackend())

from repro.distributed.flash_decode import FlashDecodeBackend  # noqa: E402

register_backend(FlashDecodeBackend())

__all__ = ["AttentionBackend", "PallasBackend", "register_backend",
           "get_backend", "available_backends", "scatter_kv",
           "scatter_kv_paged", "build_full_tree_mask",
           "dense_prefill_attention"]
