from .transformer import TransformerConfig, init_params, train_logits, prefill, tree_step, lm_loss

__all__ = ["TransformerConfig", "init_params", "train_logits", "prefill",
           "tree_step", "lm_loss"]
