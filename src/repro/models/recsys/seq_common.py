"""Shared sequential-recommender transformer encoder (BERT4Rec / SASRec)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers import gqa_attention, rms_norm


def init_encoder(key: jax.Array, n_items: int, d: int, n_blocks: int,
                 n_heads: int, seq_len: int, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 3 + 7 * n_blocks)
    init = lambda kk, shape, s=0.02: (
        jax.random.normal(kk, shape, jnp.float32) * s).astype(dtype)
    p = {
        "item_emb": init(ks[0], (n_items, d)),
        "pos_emb": init(ks[1], (seq_len, d)),
        "ln_f": jnp.ones((d,), dtype),
    }
    for b in range(n_blocks):
        o = 2 + 7 * b
        p[f"blk{b}"] = {
            "ln1": jnp.ones((d,), dtype), "ln2": jnp.ones((d,), dtype),
            "wq": init(ks[o], (d, d)), "wk": init(ks[o + 1], (d, d)),
            "wv": init(ks[o + 2], (d, d)), "wo": init(ks[o + 3], (d, d)),
            "w1": init(ks[o + 4], (d, 4 * d)),
            "w2": init(ks[o + 5], (4 * d, d)),
        }
    return p


def encode(params: Dict, ids: jax.Array, n_blocks: int, n_heads: int,
           causal: bool, pad_mask: jax.Array) -> jax.Array:
    """ids (B, S) -> hidden (B, S, d).  pad_mask (B, S) True=valid."""
    B, S = ids.shape
    d = params["item_emb"].shape[1]
    dh = d // n_heads
    h = jnp.take(params["item_emb"], ids, axis=0) + params["pos_emb"][None, :S]
    h = constrain(h, "batch", None, None)
    attn_mask = pad_mask[:, None, :] & jnp.ones((B, S, S), bool)
    if causal:
        attn_mask = attn_mask & (jnp.arange(S)[None, :, None]
                                 >= jnp.arange(S)[None, None, :])
    for b in range(n_blocks):
        p = params[f"blk{b}"]
        hn = rms_norm(h, p["ln1"])
        q = (hn @ p["wq"]).reshape(B, S, n_heads, dh)
        k = (hn @ p["wk"]).reshape(B, S, n_heads, dh)
        v = (hn @ p["wv"]).reshape(B, S, n_heads, dh)
        a = gqa_attention(q, k, v, attn_mask).reshape(B, S, d)
        h = h + a @ p["wo"]
        hn = rms_norm(h, p["ln2"])
        h = h + jax.nn.gelu(hn @ p["w1"]) @ p["w2"]
    return rms_norm(h, params["ln_f"])


def encoder_logical_axes(n_blocks: int) -> Dict:
    p = {"item_emb": ("table_rows", None), "pos_emb": (None, None),
         "ln_f": (None,)}
    for b in range(n_blocks):
        p[f"blk{b}"] = {"ln1": (None,), "ln2": (None,),
                        "wq": (None, "tensor"), "wk": (None, "tensor"),
                        "wv": (None, "tensor"), "wo": ("tensor", None),
                        "w1": (None, "tensor"), "w2": ("tensor", None)}
    return p


__all__ = ["init_encoder", "encode", "encoder_logical_axes"]
