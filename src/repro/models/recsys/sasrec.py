"""SASRec [arXiv:1808.09781]: causal self-attention sequential recommender.

Next-item objective.  Autoregressive in principle — but item streams lack
the n-gram re-occurrence structure Lookahead's trie exploits (see DESIGN.md
§Arch-applicability); ``serve`` exposes single-step next-item scoring.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp

from .seq_common import encode, encoder_logical_axes, init_encoder


@dataclass(frozen=True)
class SasRecConfig:
    name: str = "sasrec"
    n_items: int = 50_000
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    dtype: str = "float32"

    def n_params(self) -> int:
        d = self.embed_dim
        return (self.n_items * d + self.seq_len * d
                + self.n_blocks * (4 * d * d + 8 * d * d) + d)


def init_params(cfg: SasRecConfig, key: jax.Array) -> Dict:
    return init_encoder(key, cfg.n_items, cfg.embed_dim, cfg.n_blocks,
                        cfg.n_heads, cfg.seq_len, jnp.dtype(cfg.dtype))


def param_logical_axes(cfg: SasRecConfig) -> Dict:
    return encoder_logical_axes(cfg.n_blocks)


def hidden(cfg: SasRecConfig, params: Dict, ids: jax.Array,
           pad_mask: jax.Array) -> jax.Array:
    return encode(params, ids, cfg.n_blocks, cfg.n_heads, causal=True,
                  pad_mask=pad_mask)


def loss(cfg: SasRecConfig, params: Dict, batch: Dict) -> jax.Array:
    """Next-item objective with SAMPLED softmax over a shared negative set
    (full (B,S,10⁶) softmax is infeasible at batch 65k).

    batch: ids (B,S), labels (B,S) (-1 pad), negatives (NS,), pad_mask."""
    h = hidden(cfg, params, batch["ids"], batch["pad_mask"])
    lab = jnp.maximum(batch["labels"], 0)
    pos_emb = jnp.take(params["item_emb"], lab, axis=0)        # (B,S,d)
    neg_emb = jnp.take(params["item_emb"], batch["negatives"], axis=0)
    pos_score = jnp.sum(h * pos_emb, axis=-1, keepdims=True)
    neg_score = jnp.einsum("bsd,nd->bsn", h, neg_emb)
    scores = jnp.concatenate([pos_score, neg_score], axis=-1)
    logp = jax.nn.log_softmax(scores.astype(jnp.float32), axis=-1)
    lm = (batch["labels"] >= 0)
    return -jnp.sum(logp[..., 0] * lm) / jnp.maximum(jnp.sum(lm), 1)


def serve(cfg: SasRecConfig, params: Dict, ids: jax.Array,
          pad_mask: jax.Array, cand_ids=None) -> jax.Array:
    """Next-item scores at the last valid position; cand_ids (B,C) for
    ranking-stage candidate scoring, None for full catalog (retrieval)."""
    h = hidden(cfg, params, ids, pad_mask)
    last = jnp.sum(pad_mask.astype(jnp.int32), axis=1) - 1
    hl = jnp.take_along_axis(h, last[:, None, None].astype(jnp.int32),
                             axis=1)[:, 0]
    if cand_ids is None:
        return hl @ params["item_emb"].T
    cand = jnp.take(params["item_emb"], cand_ids, axis=0)
    return jnp.einsum("bd,bcd->bc", hl, cand)


__all__ = ["SasRecConfig", "init_params", "param_logical_axes", "hidden",
           "loss", "serve"]
