"""Shared recsys helpers: MLP towers, losses."""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp


def init_mlp(key: jax.Array, dims: Sequence[int], dtype=jnp.float32
             ) -> Dict[str, jax.Array]:
    ks = jax.random.split(key, len(dims) - 1)
    p = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        p[f"w{i}"] = (jax.random.normal(ks[i], (a, b), jnp.float32)
                      * (2.0 / a) ** 0.5).astype(dtype)
        p[f"b{i}"] = jnp.zeros((b,), dtype)
    return p


def mlp(p: Dict[str, jax.Array], x: jax.Array, final_act: bool = False
        ) -> jax.Array:
    n = sum(1 for k in p if k.startswith("w"))
    for i in range(n):
        x = x @ p[f"w{i}"] + p[f"b{i}"]
        if i < n - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def bce_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    z = logits.astype(jnp.float32)
    y = labels.astype(jnp.float32)
    return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))


def in_batch_softmax_loss(q: jax.Array, c: jax.Array,
                          logq: jax.Array = None) -> jax.Array:
    """Sampled-softmax with in-batch negatives + optional logQ correction.
    q, c: (B, D) matched pairs (row i of c is the positive for row i of q)."""
    scores = (q.astype(jnp.float32) @ c.astype(jnp.float32).T)
    if logq is not None:
        scores = scores - logq[None, :]
    labels = jnp.arange(q.shape[0])
    logp = jax.nn.log_softmax(scores, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


__all__ = ["init_mlp", "mlp", "bce_loss", "in_batch_softmax_loss"]
