from . import bert4rec, embedding, sasrec, two_tower, wide_deep

__all__ = ["bert4rec", "embedding", "sasrec", "two_tower", "wide_deep"]
