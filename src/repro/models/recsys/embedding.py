"""Embedding primitives for recsys — JAX has no native EmbeddingBag or
CSR sparse, so the lookup/reduce path is built from ``jnp.take`` +
``jax.ops.segment_sum`` (this IS the hot path of every recsys model here).

Tables are row-sharded over the ``model`` mesh axis (logical axis
"table_rows"); XLA SPMD turns `take` over a sharded operand into the
gather + all-reduce pattern of a distributed embedding service.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain


def lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """table (V, D), ids (...) -> (..., D)."""
    return jnp.take(table, ids, axis=0)


def embedding_bag(table: jax.Array, ids: jax.Array,
                  weights: Optional[jax.Array] = None,
                  mask: Optional[jax.Array] = None,
                  combiner: str = "sum") -> jax.Array:
    """Fixed-shape multi-hot bag: ids (..., L) -> (..., D).

    ``mask`` (..., L) marks valid slots (padding excluded); ``weights`` are
    optional per-sample weights.
    """
    emb = jnp.take(table, ids, axis=0)                    # (..., L, D)
    w = jnp.ones(ids.shape, dtype=emb.dtype)
    if weights is not None:
        w = w * weights.astype(emb.dtype)
    if mask is not None:
        w = w * mask.astype(emb.dtype)
    emb = emb * w[..., None]
    if combiner == "sum":
        return emb.sum(axis=-2)
    if combiner == "mean":
        denom = jnp.maximum(w.sum(axis=-1, keepdims=True), 1.0)
        return emb.sum(axis=-2) / denom
    if combiner == "max":
        neg = jnp.where(w[..., None] > 0, emb, -jnp.inf)
        out = neg.max(axis=-2)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(combiner)


def embedding_bag_ragged(table: jax.Array, flat_ids: jax.Array,
                         segment_ids: jax.Array, num_segments: int,
                         weights: Optional[jax.Array] = None,
                         combiner: str = "sum") -> jax.Array:
    """Ragged bag: flat_ids (N,), segment_ids (N,) -> (num_segments, D)."""
    emb = jnp.take(table, flat_ids, axis=0)               # (N, D)
    if weights is not None:
        emb = emb * weights[:, None].astype(emb.dtype)
    if combiner == "sum":
        return jax.ops.segment_sum(emb, segment_ids, num_segments=num_segments)
    if combiner == "mean":
        s = jax.ops.segment_sum(emb, segment_ids, num_segments=num_segments)
        n = jax.ops.segment_sum(jnp.ones((flat_ids.shape[0], 1), emb.dtype),
                                segment_ids, num_segments=num_segments)
        return s / jnp.maximum(n, 1.0)
    if combiner == "max":
        return jax.ops.segment_max(emb, segment_ids, num_segments=num_segments)
    raise ValueError(combiner)


def hashed_lookup(q_table: jax.Array, r_table: jax.Array, ids: jax.Array
                  ) -> jax.Array:
    """Quotient-remainder trick [arXiv:1909.02107]: O(2·sqrt(V)) rows serve a
    vocab of size V.  q_table (Vq, D), r_table (Vr, D)."""
    vr = r_table.shape[0]
    q = jnp.take(q_table, ids // vr, axis=0)
    r = jnp.take(r_table, ids % vr, axis=0)
    return q * r


def init_table(key: jax.Array, rows: int, dim: int, scale: float = 0.01,
               dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (rows, dim), jnp.float32) * scale
            ).astype(dtype)


def shard_table(t: jax.Array) -> jax.Array:
    return constrain(t, "table_rows", None)


__all__ = ["lookup", "embedding_bag", "embedding_bag_ragged", "hashed_lookup",
           "init_table", "shard_table"]
