"""Two-tower retrieval model [Yi et al., RecSys'19 (YouTube)].

User tower and item tower: sparse-feature embedding bags → MLP 1024-512-256
→ L2-normalized 256-dim embeddings; dot-product score; trained with in-batch
sampled softmax (+ logQ correction hook).  ``retrieval_cand`` scores one
query against 10⁶ candidates with a sharded batched-dot + local/global top-k.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from . import embedding as E
from .common import in_batch_softmax_loss, init_mlp, mlp


@dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    embed_dim: int = 256            # final tower output dim
    feat_dim: int = 64              # per-field embedding dim
    n_user_fields: int = 8
    n_item_fields: int = 4
    rows_per_table: int = 100_000
    tower_dims: Tuple[int, ...] = (1024, 512, 256)
    dtype: str = "float32"

    def n_params(self) -> int:
        emb = (self.n_user_fields + self.n_item_fields) \
            * self.rows_per_table * self.feat_dim
        ud = (self.n_user_fields * self.feat_dim,) + self.tower_dims
        it = (self.n_item_fields * self.feat_dim,) + self.tower_dims
        tower = sum(a * b + b for a, b in zip(ud[:-1], ud[1:]))
        tower += sum(a * b + b for a, b in zip(it[:-1], it[1:]))
        return emb + tower


def init_params(cfg: TwoTowerConfig, key: jax.Array) -> Dict:
    k = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    return {
        "user_tables": E.init_table(
            k[0], cfg.n_user_fields * cfg.rows_per_table, cfg.feat_dim,
            dtype=dt).reshape(cfg.n_user_fields, cfg.rows_per_table,
                              cfg.feat_dim),
        "item_tables": E.init_table(
            k[1], cfg.n_item_fields * cfg.rows_per_table, cfg.feat_dim,
            dtype=dt).reshape(cfg.n_item_fields, cfg.rows_per_table,
                              cfg.feat_dim),
        "user_mlp": init_mlp(
            k[2], (cfg.n_user_fields * cfg.feat_dim,) + cfg.tower_dims, dt),
        "item_mlp": init_mlp(
            k[3], (cfg.n_item_fields * cfg.feat_dim,) + cfg.tower_dims, dt),
    }


def param_logical_axes(cfg: TwoTowerConfig) -> Dict:
    m = {f"w{i}": (None, None) for i in range(len(cfg.tower_dims))}
    m.update({f"b{i}": (None,) for i in range(len(cfg.tower_dims))})
    return {"user_tables": (None, "table_rows", None),
            "item_tables": (None, "table_rows", None),
            "user_mlp": dict(m), "item_mlp": dict(m)}


def _tower(tables: jax.Array, mlp_p: Dict, ids: jax.Array) -> jax.Array:
    """ids (B, F) single-hot per field -> (B, embed_dim) L2-normalized."""
    emb = jax.vmap(lambda t, i: jnp.take(t, i, axis=0),
                   in_axes=(0, 1), out_axes=1)(tables, ids)   # (B, F, D)
    B = ids.shape[0]
    out = mlp(mlp_p, emb.reshape(B, -1))
    return out / jnp.maximum(
        jnp.linalg.norm(out.astype(jnp.float32), axis=-1, keepdims=True),
        1e-6).astype(out.dtype)


def user_embed(cfg: TwoTowerConfig, params: Dict, user_ids: jax.Array
               ) -> jax.Array:
    return _tower(params["user_tables"], params["user_mlp"],
                  constrain(user_ids, "batch", None))


def item_embed(cfg: TwoTowerConfig, params: Dict, item_ids: jax.Array
               ) -> jax.Array:
    return _tower(params["item_tables"], params["item_mlp"],
                  constrain(item_ids, "batch", None))


def loss(cfg: TwoTowerConfig, params: Dict, batch: Dict) -> jax.Array:
    q = user_embed(cfg, params, batch["user_ids"])
    c = item_embed(cfg, params, batch["item_ids"])
    return in_batch_softmax_loss(q, c, batch.get("logq"))


def score_candidates(cfg: TwoTowerConfig, params: Dict, user_ids: jax.Array,
                     cand_emb: jax.Array, k: int = 100
                     ) -> Tuple[jax.Array, jax.Array]:
    """Retrieval scoring: user_ids (1, F); cand_emb (N, D) sharded over
    'candidates'.  Batched dot (NOT a loop) + top-k."""
    q = user_embed(cfg, params, user_ids)                      # (1, D)
    cand_emb = constrain(cand_emb, "candidates", None)
    scores = (cand_emb @ q[0]).astype(jnp.float32)             # (N,)
    return jax.lax.top_k(scores, k)


__all__ = ["TwoTowerConfig", "init_params", "param_logical_axes",
           "user_embed", "item_embed", "loss", "score_candidates"]
