"""BERT4Rec [arXiv:1904.06690]: bidirectional transformer over item
sequences, trained with a cloze (masked-item) objective.  Encoder-only —
no decode step (serve = full-sequence scoring of masked positions)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp

from .seq_common import encode, encoder_logical_axes, init_encoder


@dataclass(frozen=True)
class Bert4RecConfig:
    name: str = "bert4rec"
    n_items: int = 50_000
    embed_dim: int = 64
    n_blocks: int = 2
    n_heads: int = 2
    seq_len: int = 200
    mask_id: int = 1                 # reserved item id for [MASK]
    dtype: str = "float32"

    def n_params(self) -> int:
        d = self.embed_dim
        return (self.n_items * d + self.seq_len * d
                + self.n_blocks * (4 * d * d + 8 * d * d) + d)


def init_params(cfg: Bert4RecConfig, key: jax.Array) -> Dict:
    return init_encoder(key, cfg.n_items, cfg.embed_dim, cfg.n_blocks,
                        cfg.n_heads, cfg.seq_len, jnp.dtype(cfg.dtype))


def param_logical_axes(cfg: Bert4RecConfig) -> Dict:
    return encoder_logical_axes(cfg.n_blocks)


def hidden(cfg: Bert4RecConfig, params: Dict, ids: jax.Array,
           pad_mask: jax.Array) -> jax.Array:
    return encode(params, ids, cfg.n_blocks, cfg.n_heads, causal=False,
                  pad_mask=pad_mask)


def loss(cfg: Bert4RecConfig, params: Dict, batch: Dict) -> jax.Array:
    """Cloze objective with SAMPLED softmax (the 10⁶-item catalog makes a
    full softmax infeasible at batch 65k — (B,S,V) would be petabytes).

    batch: ids (B,S) with mask_id at cloze slots, masked_pos (B,M),
    masked_labels (B,M) (-1 = pad), negatives (NS,) shared sample,
    pad_mask (B,S).  Target = index 0 of [label ⧺ negatives]."""
    h = hidden(cfg, params, batch["ids"], batch["pad_mask"])
    B, M = batch["masked_pos"].shape
    hm = jnp.take_along_axis(h, batch["masked_pos"][..., None], axis=1)
    lab = jnp.maximum(batch["masked_labels"], 0)
    pos_emb = jnp.take(params["item_emb"], lab, axis=0)       # (B,M,d)
    neg_emb = jnp.take(params["item_emb"], batch["negatives"], axis=0)
    pos_score = jnp.sum(hm * pos_emb, axis=-1, keepdims=True)  # (B,M,1)
    neg_score = jnp.einsum("bmd,nd->bmn", hm, neg_emb)
    scores = jnp.concatenate([pos_score, neg_score], axis=-1)
    logp = jax.nn.log_softmax(scores.astype(jnp.float32), axis=-1)
    lm = (batch["masked_labels"] >= 0)
    return -jnp.sum(logp[..., 0] * lm) / jnp.maximum(jnp.sum(lm), 1)


def serve(cfg: Bert4RecConfig, params: Dict, ids: jax.Array,
          pad_mask: jax.Array, cand_ids=None) -> jax.Array:
    """Last-position scoring.  cand_ids (B, C): ranking-stage candidate
    scoring; None: full-catalog scores (B, n_items) — retrieval stage."""
    h = hidden(cfg, params, ids, pad_mask)
    last = jnp.sum(pad_mask.astype(jnp.int32), axis=1) - 1
    hl = jnp.take_along_axis(h, last[:, None, None].astype(jnp.int32),
                             axis=1)[:, 0]                     # (B,d)
    if cand_ids is None:
        return hl @ params["item_emb"].T
    cand = jnp.take(params["item_emb"], cand_ids, axis=0)      # (B,C,d)
    return jnp.einsum("bd,bcd->bc", hl, cand)


__all__ = ["Bert4RecConfig", "init_params", "param_logical_axes", "hidden",
           "loss", "serve"]
