"""Wide & Deep CTR model [arXiv:1606.07792].

40 sparse fields → 32-dim embeddings → concat → deep MLP 1024-512-256;
wide part = per-field 1-dim embeddings (linear over the raw categorical
crosses) + dense features.  The embedding-bag lookup over the multi-hot
fields is the hot path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from . import embedding as E
from .common import bce_loss, init_mlp, mlp


@dataclass(frozen=True)
class WideDeepConfig:
    name: str = "wide-deep"
    n_sparse: int = 40
    embed_dim: int = 32
    rows_per_table: int = 100_000
    multi_hot: int = 4              # ids per field (bag size)
    mlp_dims: Tuple[int, ...] = (1024, 512, 256)
    n_dense: int = 13
    dtype: str = "float32"

    def n_params(self) -> int:
        emb = self.n_sparse * self.rows_per_table * (self.embed_dim + 1)
        dims = (self.n_sparse * self.embed_dim + self.n_dense,) + self.mlp_dims
        deep = sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
        return emb + deep + dims[-1] + 1 + self.n_dense


def init_params(cfg: WideDeepConfig, key: jax.Array) -> Dict:
    k = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    deep_in = cfg.n_sparse * cfg.embed_dim + cfg.n_dense
    return {
        # one stacked table: (F, V, D) — sharded on V over 'model'
        "tables": E.init_table(k[0], cfg.n_sparse * cfg.rows_per_table,
                               cfg.embed_dim, dtype=dt
                               ).reshape(cfg.n_sparse, cfg.rows_per_table,
                                         cfg.embed_dim),
        "wide_tables": E.init_table(k[1], cfg.n_sparse * cfg.rows_per_table,
                                    1, dtype=dt
                                    ).reshape(cfg.n_sparse,
                                              cfg.rows_per_table, 1),
        "wide_dense": jnp.zeros((cfg.n_dense,), dt),
        "deep": init_mlp(k[2], (deep_in,) + cfg.mlp_dims, dt),
        "head": (jax.random.normal(k[3], (cfg.mlp_dims[-1], 1), jnp.float32)
                 * 0.05).astype(dt),
        "bias": jnp.zeros((1,), dt),
    }


def param_logical_axes(cfg: WideDeepConfig) -> Dict:
    deep = {f"w{i}": (None, None) for i in range(len(cfg.mlp_dims))}
    deep.update({f"b{i}": (None,) for i in range(len(cfg.mlp_dims))})
    return {"tables": (None, "table_rows", None),
            "wide_tables": (None, "table_rows", None),
            "wide_dense": (None,), "deep": deep,
            "head": (None, None), "bias": (None,)}


def forward(cfg: WideDeepConfig, params: Dict, sparse_ids: jax.Array,
            sparse_mask: jax.Array, dense: jax.Array) -> jax.Array:
    """sparse_ids (B, F, L) int32, sparse_mask (B, F, L), dense (B, n_dense)
    -> logits (B,)."""
    B = sparse_ids.shape[0]
    sparse_ids = constrain(sparse_ids, "batch", None, None)
    # per-field bag: vmap the bag over the field axis against stacked tables
    bag = jax.vmap(lambda t, i, m: E.embedding_bag(t, i, mask=m),
                   in_axes=(0, 1, 1), out_axes=1)
    emb = bag(params["tables"], sparse_ids, sparse_mask)       # (B, F, D)
    wide = bag(params["wide_tables"], sparse_ids, sparse_mask)  # (B, F, 1)
    deep_in = jnp.concatenate(
        [emb.reshape(B, -1), dense.astype(emb.dtype)], axis=-1)
    deep_out = mlp(params["deep"], deep_in, final_act=True)
    logit = (deep_out @ params["head"])[:, 0]
    logit = logit + wide.sum(axis=(1, 2)) + dense @ params["wide_dense"]
    return logit + params["bias"][0]


def loss(cfg: WideDeepConfig, params: Dict, batch: Dict) -> jax.Array:
    logits = forward(cfg, params, batch["sparse_ids"], batch["sparse_mask"],
                     batch["dense"])
    return bce_loss(logits, batch["labels"])


__all__ = ["WideDeepConfig", "init_params", "param_logical_axes", "forward",
           "loss"]
