"""EquiformerV2-style equivariant graph attention via eSCN SO(2) convolutions
[arXiv:2306.12059].

Core compute pattern (the irrep tensor-product regime of the taxonomy):
  per edge e = (s → t):
    1. rotate irreps features of s, t into the edge frame (Wigner-D blocks,
       edge aligned with ẑ)                                — O(L³) per edge
    2. SO(2) convolution: block-diagonal in m, |m| ≤ m_max  — the eSCN trick
       that replaces the O(L⁶) Clebsch-Gordan tensor product
    3. attention: logits from invariant (l=0) channels + radial basis,
       segment-softmax over incoming edges
    4. rotate messages back, scatter-sum into target nodes
       (``jax.ops.segment_sum`` — JAX's message-passing primitive)

Feature layout: x (N, (l_max+1)², C) with m-major blocks per l.

Two execution paths:
  * dense    — all edge tensors materialized (small/medium graphs),
  * chunked  — lax.scan over edge chunks with two-pass segment softmax
               (memory-bounded; giant graphs).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from .so3 import apply_blocks, lsq, real_sph_harm, rotation_to_z, wigner_blocks


@dataclass(frozen=True)
class EquiformerConfig:
    name: str = "equiformer-v2"
    n_layers: int = 12
    channels: int = 128              # d_hidden: channels per irrep component
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    d_feat_in: int = 128             # scalar input features per node
    n_rbf: int = 32
    cutoff: float = 5.0
    n_out: int = 1                   # graph targets or node classes
    node_level: bool = False         # True: per-node outputs (classification)
    edge_chunk: int = 0              # >0: chunked path with this chunk size
    scan_layers: bool = True         # False: unroll (dry-run cost accuracy)
    remat: bool = False              # checkpoint each layer (training)
    dtype: str = "float32"

    @property
    def lsq(self) -> int:
        return lsq(self.l_max)

    def n_params(self) -> int:
        C, L, M = self.channels, self.l_max, self.m_max
        n0 = L + 1
        so2 = (2 * n0 * C) * (n0 * C)                    # m=0
        for m in range(1, M + 1):
            nl = L + 1 - m
            so2 += 2 * (2 * nl * C) * (nl * C)           # W_r, W_i
        per_layer = (so2 + self.n_rbf * (L + 1) * 2 * C  # radial scale
                     + (2 * C + self.n_rbf) * self.n_heads  # attn mlp
                     + C * C                              # out proj
                     + 2 * (L + 1) * C                    # norms
                     + (L + 1) * C * 2 * C + C * 2 * C + (L + 1) * 2 * C * C)
        return (self.d_feat_in * C + self.n_layers * per_layer
                + C * C + C * self.n_out)


def _m_indices(l_max: int) -> Dict[int, Tuple[List[int], List[int]]]:
    """For each m: (plus-component indices, minus-component indices) into the
    lsq layout, over degrees l >= m."""
    out = {}
    for m in range(0, l_max + 1):
        plus = [l * l + l + m for l in range(m, l_max + 1)]
        minus = [l * l + l - m for l in range(m, l_max + 1)]
        out[m] = (plus, minus)
    return out


def init_params(cfg: EquiformerConfig, key: jax.Array) -> Dict:
    C, L, M, Lq = cfg.channels, cfg.l_max, cfg.m_max, cfg.lsq
    nL = cfg.n_layers
    dt = jnp.dtype(cfg.dtype)
    ks = iter(jax.random.split(key, 64))
    init = lambda shape, s=None: (
        jax.random.normal(next(ks), shape, jnp.float32)
        * (s if s is not None else (1.0 / math.sqrt(shape[-2] if len(shape) > 1
                                                    else shape[-1])))
    ).astype(dt)

    layer = {
        # SO(2) conv (input = concat(src, tgt) -> 2C per component)
        "w0": init((nL, (L + 1) * 2 * C, (L + 1) * C), 0.02),
    }
    for m in range(1, M + 1):
        nl = L + 1 - m
        layer[f"wr{m}"] = init((nL, nl * 2 * C, nl * C), 0.02)
        layer[f"wi{m}"] = init((nL, nl * 2 * C, nl * C), 0.02)
    layer.update({
        "rad_w": init((nL, cfg.n_rbf, (L + 1) * 2 * C), 0.05),
        "attn_w": init((nL, 2 * C + cfg.n_rbf, cfg.n_heads), 0.05),
        "attn_b": jnp.zeros((nL, cfg.n_heads), dt),
        "out_proj": init((nL, C, C), 0.02),
        "ln1": jnp.ones((nL, L + 1, C), dt),
        "ln2": jnp.ones((nL, L + 1, C), dt),
        # FFN: per-l linear C->2C, invariant gate, per-l linear 2C->C
        "ffn_w1": init((nL, L + 1, C, 2 * C), 0.02),
        "ffn_gate": init((nL, C, 2 * C), 0.02),
        "ffn_w2": init((nL, L + 1, 2 * C, C), 0.02),
    })
    return {
        "w_in": init((cfg.d_feat_in, C), 0.02),
        "layers": layer,
        "head_w1": init((C, C), 0.02),
        "head_w2": init((C, cfg.n_out), 0.02),
        "ln_f": jnp.ones((L + 1, C), dt),
    }


def param_logical_axes(cfg: EquiformerConfig) -> Dict:
    layer = {"w0": (None, None, "tensor")}
    for m in range(1, cfg.m_max + 1):
        layer[f"wr{m}"] = (None, None, "tensor")
        layer[f"wi{m}"] = (None, None, "tensor")
    layer.update({
        "rad_w": (None, None, None), "attn_w": (None, None, None),
        "attn_b": (None, None), "out_proj": (None, None, None),
        "ln1": (None, None, None), "ln2": (None, None, None),
        "ffn_w1": (None, None, None, "tensor"),
        "ffn_gate": (None, None, "tensor"),
        "ffn_w2": (None, None, "tensor", None),
    })
    return {"w_in": (None, None), "layers": layer, "head_w1": (None, None),
            "head_w2": (None, None), "ln_f": (None, None)}


# ------------------------------------------------------------------- pieces
def _rbf(dist: jax.Array, n: int, cutoff: float) -> jax.Array:
    mu = jnp.linspace(0.0, cutoff, n)
    beta = (n / cutoff) ** 2
    return jnp.exp(-beta * (dist[..., None] - mu) ** 2)


def _eq_norm(x: jax.Array, scale: jax.Array, l_max: int,
             eps: float = 1e-6) -> jax.Array:
    """Equivariant RMS norm: normalize each l-block by its RMS over (m, C)."""
    outs = []
    for l in range(l_max + 1):
        blk = x[..., l * l:(l + 1) * (l + 1), :]
        ms = jnp.mean(jnp.square(blk.astype(jnp.float32)),
                      axis=(-2, -1), keepdims=True)
        outs.append((blk * jax.lax.rsqrt(ms + eps).astype(x.dtype))
                    * scale[..., l, :][..., None, :])
    return jnp.concatenate(outs, axis=-2)


def _so2_conv(z: jax.Array, lp: Dict, cfg: EquiformerConfig) -> jax.Array:
    """z (E, lsq, 2C) rotated concat features -> (E, lsq, C) messages.
    Block-diagonal in m; components with |m| > m_max are truncated (eSCN)."""
    E = z.shape[0]
    C, L = cfg.channels, cfg.l_max
    midx = _m_indices(L)
    out = jnp.zeros((E, cfg.lsq, C), z.dtype)
    # m = 0
    p0, _ = midx[0]
    z0 = z[:, jnp.array(p0)].reshape(E, -1)
    y0 = (z0 @ lp["w0"]).reshape(E, L + 1, C)
    out = out.at[:, jnp.array(p0)].set(y0)
    # 1 <= m <= m_max
    for m in range(1, cfg.m_max + 1):
        plus, minus = midx[m]
        nl = len(plus)
        zp = z[:, jnp.array(plus)].reshape(E, -1)
        zm = z[:, jnp.array(minus)].reshape(E, -1)
        wr, wi = lp[f"wr{m}"], lp[f"wi{m}"]
        yp = (zp @ wr - zm @ wi).reshape(E, nl, C)
        ym = (zm @ wr + zp @ wi).reshape(E, nl, C)
        out = out.at[:, jnp.array(plus)].set(yp)
        out = out.at[:, jnp.array(minus)].set(ym)
    return out


def _edge_messages(lp: Dict, cfg: EquiformerConfig, xn: jax.Array,
                   src: jax.Array, dst: jax.Array, blocks: List[jax.Array],
                   rbf: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Full per-edge message + attention logits.

    Returns (msg (E, lsq, C) — rotated back, logits (E, H))."""
    xs = xn[src]
    xt = xn[dst]
    xs_r = apply_blocks(blocks, xs)
    xt_r = apply_blocks(blocks, xt)
    z = jnp.concatenate([xs_r, xt_r], axis=-1)                  # (E, lsq, 2C)
    # radial modulation per (l, 2C), broadcast over m within l
    rad = (rbf @ lp["rad_w"]).reshape(
        rbf.shape[0], cfg.l_max + 1, 2 * cfg.channels)
    rep = jnp.concatenate(
        [jnp.repeat(rad[:, l:l + 1], 2 * l + 1, axis=1)
         for l in range(cfg.l_max + 1)], axis=1)
    z = z * rep
    msg = _so2_conv(z, lp, cfg)
    msg = apply_blocks(blocks, msg, transpose=True)             # rotate back
    inv = jnp.concatenate([xs[:, 0, :], xt[:, 0, :], rbf.astype(xs.dtype)],
                          axis=-1)
    logits = (inv @ lp["attn_w"] + lp["attn_b"]).astype(jnp.float32)
    return msg, logits


def _attention_dense(lp: Dict, cfg: EquiformerConfig, x: jax.Array,
                     src: jax.Array, dst: jax.Array, blocks: List[jax.Array],
                     rbf: jax.Array, edge_mask: jax.Array,
                     n_nodes: int) -> jax.Array:
    xn = _eq_norm(x, lp["ln1"], cfg.l_max)
    msg, logits = _edge_messages(lp, cfg, xn, src, dst, blocks, rbf)
    logits = jnp.where(edge_mask[:, None], logits, -1e30)
    seg_max = jax.ops.segment_max(logits, dst, num_segments=n_nodes)
    seg_max = jnp.maximum(seg_max, -1e30)
    w = jnp.exp(logits - seg_max[dst]) * edge_mask[:, None]
    denom = jax.ops.segment_sum(w, dst, num_segments=n_nodes)
    H = cfg.n_heads
    ch = cfg.channels // H
    wmsg = (msg.reshape(msg.shape[0], cfg.lsq, H, ch)
            * w[:, None, :, None].astype(msg.dtype))
    num = jax.ops.segment_sum(wmsg, dst, num_segments=n_nodes)
    agg = num / jnp.maximum(denom, 1e-20)[:, None, :, None].astype(msg.dtype)
    agg = agg.reshape(n_nodes, cfg.lsq, cfg.channels)
    return x + agg @ lp["out_proj"]


def _attention_chunked(lp: Dict, cfg: EquiformerConfig, x: jax.Array,
                       src: jax.Array, dst: jax.Array, edge_vec: jax.Array,
                       rbf: jax.Array, edge_mask: jax.Array,
                       n_nodes: int) -> jax.Array:
    """Two-pass chunk-scanned attention: pass A computes logits (cheap —
    invariants only) and the segment max/denominator; pass B streams the full
    SO(2) messages.  Wigner blocks recomputed per chunk (flops-for-memory)."""
    E = src.shape[0]
    ck = cfg.edge_chunk
    nchunk = E // ck
    assert E % ck == 0, (E, ck)
    H = cfg.n_heads
    xn = _eq_norm(x, lp["ln1"], cfg.l_max)
    inv = xn[:, 0, :]

    def logits_chunk(s, d, r, m):
        z = jnp.concatenate([inv[s], inv[d], r.astype(inv.dtype)], axis=-1)
        lg = (z @ lp["attn_w"] + lp["attn_b"]).astype(jnp.float32)
        return jnp.where(m[:, None], lg, -1e30)

    resh = lambda a, shp: a.reshape((nchunk, ck) + shp)
    srcs, dsts = resh(src, ()), resh(dst, ())
    rbfs, masks = resh(rbf, (rbf.shape[-1],)), resh(edge_mask, ())
    vecs = resh(edge_vec, (3,))

    def passA(carry, xs):
        smax, sden = carry
        s, d, r, m = xs
        lg = logits_chunk(s, d, r, m)
        smax = jnp.maximum(smax, jax.ops.segment_max(
            lg, d, num_segments=n_nodes))
        return (smax, sden), None

    smax0 = jnp.full((n_nodes, H), -jnp.inf, jnp.float32)
    (smax, _), _ = jax.lax.scan(passA, (smax0, None),
                                (srcs, dsts, rbfs, masks))
    smax = jnp.maximum(smax, -1e30)

    ch = cfg.channels // H

    def passB(carry, xs):
        num, den = carry
        s, d, r, m, v = xs
        R = rotation_to_z(v)
        blocks = [b.astype(x.dtype) for b in wigner_blocks(R, cfg.l_max)]
        msg, lg = _edge_messages(lp, cfg, xn, s, d, blocks, r)
        lg = jnp.where(m[:, None], lg, -1e30)
        w = jnp.exp(lg - smax[d]) * m[:, None]
        den = den + jax.ops.segment_sum(w, d, num_segments=n_nodes)
        wmsg = (msg.reshape(ck, cfg.lsq, H, ch)
                * w[:, None, :, None].astype(msg.dtype))
        num = num + jax.ops.segment_sum(wmsg, d, num_segments=n_nodes)
        return (num, den), None

    num0 = jnp.zeros((n_nodes, cfg.lsq, H, ch), x.dtype)
    den0 = jnp.zeros((n_nodes, H), jnp.float32)
    (num, den), _ = jax.lax.scan(passB, (num0, den0),
                                 (srcs, dsts, rbfs, masks, vecs))
    agg = num / jnp.maximum(den, 1e-20)[:, None, :, None].astype(x.dtype)
    agg = agg.reshape(n_nodes, cfg.lsq, cfg.channels)
    return x + agg @ lp["out_proj"]


def _ffn(lp: Dict, cfg: EquiformerConfig, x: jax.Array) -> jax.Array:
    xn = _eq_norm(x, lp["ln2"], cfg.l_max)
    gate = jax.nn.sigmoid(xn[:, 0, :] @ lp["ffn_gate"])         # (N, 2C)
    outs = []
    for l in range(cfg.l_max + 1):
        blk = xn[:, l * l:(l + 1) * (l + 1), :]
        h = jnp.einsum("nmc,cd->nmd", blk, lp["ffn_w1"][l])
        h = h * gate[:, None, :]
        outs.append(jnp.einsum("nmd,dc->nmc", h, lp["ffn_w2"][l]))
    return x + jnp.concatenate(outs, axis=-2)


# ------------------------------------------------------------------- forward
def forward(cfg: EquiformerConfig, params: Dict, node_feat: jax.Array,
            positions: jax.Array, edges: jax.Array, edge_mask: jax.Array,
            graph_ids: Optional[jax.Array] = None,
            n_graphs: int = 1) -> Dict[str, jax.Array]:
    """node_feat (N, d_feat), positions (N, 3), edges (E, 2) int32 [src, dst],
    edge_mask (E,).  Returns dict with 'node_out' (N, n_out) and 'graph_out'
    (n_graphs, n_out) (mean-pooled)."""
    N = node_feat.shape[0]
    C = cfg.channels
    x = jnp.zeros((N, cfg.lsq, C), jnp.dtype(cfg.dtype))
    x = x.at[:, 0, :].set(node_feat.astype(x.dtype) @ params["w_in"])

    src, dst = edges[:, 0], edges[:, 1]
    src = constrain(src, "edges")
    dst = constrain(dst, "edges")
    rel = positions[src] - positions[dst]
    dist = jnp.maximum(jnp.linalg.norm(rel.astype(jnp.float32), axis=-1),
                       1e-6)
    rbf = _rbf(dist, cfg.n_rbf, cfg.cutoff).astype(x.dtype)
    edge_vec = (rel / dist[:, None]).astype(jnp.float32)

    use_chunk = cfg.edge_chunk > 0 and src.shape[0] % cfg.edge_chunk == 0 \
        and src.shape[0] > cfg.edge_chunk
    if not use_chunk:
        R = rotation_to_z(edge_vec)
        blocks = [b.astype(x.dtype) for b in wigner_blocks(R, cfg.l_max)]

    def layer_body(x, lp):
        lp = jax.tree.map(lambda a: a.astype(x.dtype), lp)
        if use_chunk:
            x = _attention_chunked(lp, cfg, x, src, dst, edge_vec, rbf,
                                   edge_mask, N)
        else:
            x = _attention_dense(lp, cfg, x, src, dst, blocks, rbf,
                                 edge_mask, N)
        x = _ffn(lp, cfg, x)
        # node-sharded residual stream (gathers all-gather per layer)
        x = constrain(x, "nodes", None, None)
        return x, None

    if cfg.remat:
        layer_body = jax.checkpoint(layer_body)

    if cfg.scan_layers:
        x, _ = jax.lax.scan(layer_body, x, params["layers"])
    else:
        for i in range(cfg.n_layers):
            x, _ = layer_body(x, jax.tree.map(lambda a: a[i],
                                              params["layers"]))
    x = _eq_norm(x, params["ln_f"], cfg.l_max)
    inv = jax.nn.silu(x[:, 0, :] @ params["head_w1"])
    node_out = inv @ params["head_w2"]
    if graph_ids is None:
        graph_out = jnp.mean(node_out, axis=0, keepdims=True)
    else:
        s = jax.ops.segment_sum(node_out, graph_ids, num_segments=n_graphs)
        n = jax.ops.segment_sum(jnp.ones((N, 1), node_out.dtype), graph_ids,
                                num_segments=n_graphs)
        graph_out = s / jnp.maximum(n, 1.0)
    return {"node_out": node_out, "graph_out": graph_out,
            "l1_feats": x[:, 1:4, :]}


def node_class_loss(cfg: EquiformerConfig, params: Dict, batch: Dict
                    ) -> jax.Array:
    out = forward(cfg, params, batch["node_feat"], batch["positions"],
                  batch["edges"], batch["edge_mask"])
    logits = out["node_out"].astype(jnp.float32)
    labels = batch["labels"]
    lm = (labels >= 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, jnp.maximum(labels, 0)[:, None],
                               axis=-1)[:, 0]
    return jnp.sum(nll * lm) / jnp.maximum(jnp.sum(lm), 1)


def energy_loss(cfg: EquiformerConfig, params: Dict, batch: Dict
                ) -> jax.Array:
    out = forward(cfg, params, batch["node_feat"], batch["positions"],
                  batch["edges"], batch["edge_mask"],
                  graph_ids=batch["graph_ids"],
                  n_graphs=batch["energies"].shape[0])
    pred = out["graph_out"][:, 0].astype(jnp.float32)
    return jnp.mean(jnp.square(pred - batch["energies"].astype(jnp.float32)))


__all__ = ["EquiformerConfig", "init_params", "param_logical_axes", "forward",
           "node_class_loss", "energy_loss"]
