from . import equiformer, sampler, so3

__all__ = ["equiformer", "sampler", "so3"]
