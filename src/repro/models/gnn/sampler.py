"""Fanout neighbor sampler (GraphSAGE-style) — real sampler, host-side numpy.

Builds a CSR adjacency once, then samples k-hop neighborhoods with per-hop
fanouts and emits a padded subgraph (fixed shapes for jit): node list,
re-indexed edges, edge mask, and the seed positions.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np


@dataclass
class CSRGraph:
    indptr: np.ndarray    # (N+1,)
    indices: np.ndarray   # (E,) neighbor ids (incoming edges: col indices)
    n_nodes: int

    @staticmethod
    def from_edges(edges: np.ndarray, n_nodes: int) -> "CSRGraph":
        """edges (E, 2) [src, dst] -> CSR over dst (incoming neighbors)."""
        order = np.argsort(edges[:, 1], kind="stable")
        src = edges[order, 0].astype(np.int64)
        dst = edges[order, 1].astype(np.int64)
        counts = np.bincount(dst, minlength=n_nodes)
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.cumsum(counts, out=indptr[1:])
        return CSRGraph(indptr=indptr, indices=src, n_nodes=n_nodes)


def sample_subgraph(g: CSRGraph, seeds: np.ndarray, fanouts: Sequence[int],
                    rng: np.random.RandomState,
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Sample a fanout neighborhood.

    Returns (nodes, edges, edge_mask, seed_slots):
      nodes (M,)            — global node ids (padded with 0),
      edges (Epad, 2) int32 — LOCAL indices into ``nodes`` [src, dst],
      edge_mask (Epad,)     — False on padding,
      seed_slots (B,)       — local positions of the seeds.
    Fixed output sizes: M = B·Π(1+fanout terms) upper bound; Epad = B·Σ…
    """
    B = len(seeds)
    frontier = np.asarray(seeds, np.int64)
    all_nodes: List[np.ndarray] = [frontier]
    all_edges: List[np.ndarray] = []
    for f in fanouts:
        srcs, dsts = [], []
        for v in frontier:
            lo, hi = g.indptr[v], g.indptr[v + 1]
            deg = hi - lo
            if deg == 0:
                continue
            pick = g.indices[lo + rng.randint(0, deg, size=f)]
            srcs.append(pick)
            dsts.append(np.full(f, v, np.int64))
        if srcs:
            e = np.stack([np.concatenate(srcs), np.concatenate(dsts)], axis=1)
            all_edges.append(e)
            frontier = np.unique(np.concatenate(srcs))
            all_nodes.append(frontier)
        else:
            frontier = np.zeros((0,), np.int64)

    nodes = np.unique(np.concatenate(all_nodes)) if all_nodes else frontier
    local = {int(v): i for i, v in enumerate(nodes)}
    # fixed-size caps
    max_nodes = _cap_nodes(B, fanouts)
    max_edges = _cap_edges(B, fanouts)
    nodes_pad = np.zeros(max_nodes, np.int64)
    nodes_pad[:len(nodes)] = nodes
    if all_edges:
        e = np.concatenate(all_edges, axis=0)
        e_local = np.stack([[local[int(s)] for s in e[:, 0]],
                            [local[int(d)] for d in e[:, 1]]], axis=1)
    else:
        e_local = np.zeros((0, 2), np.int64)
    e_pad = np.zeros((max_edges, 2), np.int32)
    mask = np.zeros(max_edges, bool)
    n_e = min(len(e_local), max_edges)
    e_pad[:n_e] = e_local[:n_e]
    mask[:n_e] = True
    seed_slots = np.array([local[int(s)] for s in seeds], np.int32)
    return nodes_pad, e_pad, mask, seed_slots


def _cap_nodes(B: int, fanouts: Sequence[int]) -> int:
    n, layer = B, B
    for f in fanouts:
        layer = layer * f
        n += layer
    return n


def _cap_edges(B: int, fanouts: Sequence[int]) -> int:
    e, layer = 0, B
    for f in fanouts:
        e += layer * f
        layer = layer * f
    return e


__all__ = ["CSRGraph", "sample_subgraph"]
